"""Make the build-time `compile` package importable when pytest runs from
the repository root (`pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
