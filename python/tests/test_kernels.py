"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple rows, k=1 edges) and
the regularisation strength; assert_allclose at float32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import pgd, proximal_cd, ref, sketch


def _rand(rng, *shape):
    return jnp.asarray(rng.uniform(0.0, 1.0, size=shape), dtype=jnp.float32)


def _instance(seed, rows, k, d):
    rng = np.random.default_rng(seed)
    a = _rand(rng, rows, d)
    b = _rand(rng, k, d)
    u = _rand(rng, rows, k)
    c, g = ref.normal_ref(a, b)
    return a, b, u, c, g


shapes = st.tuples(
    st.integers(min_value=1, max_value=300),   # rows (crosses TILE_ROWS=128)
    st.integers(min_value=1, max_value=12),    # k
    st.integers(min_value=1, max_value=40),    # d
)


@settings(max_examples=25, deadline=None)
@given(shapes=shapes, mu=st.floats(0.0, 50.0), seed=st.integers(0, 2**16))
def test_proximal_cd_matches_ref(shapes, mu, seed):
    rows, k, d = shapes
    _, _, u, c, g = _instance(seed, rows, k, d)
    got = proximal_cd.proximal_cd(c, g, u, mu)
    want = ref.proximal_cd_ref(c, g, u, jnp.float32(mu))
    assert got.shape == (rows, k)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
    assert np.all(np.asarray(got) >= 0.0)


@settings(max_examples=25, deadline=None)
@given(shapes=shapes, eta=st.floats(1e-4, 0.2), seed=st.integers(0, 2**16))
def test_pgd_matches_ref(shapes, eta, seed):
    rows, k, d = shapes
    _, _, u, c, g = _instance(seed, rows, k, d)
    got = pgd.pgd(c, g, u, eta)
    want = ref.pgd_ref(c, g, u, jnp.float32(eta))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
    assert np.all(np.asarray(got) >= 0.0)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 200),
    n=st.integers(1, 300),
    d=st.integers(1, 150),
    seed=st.integers(0, 2**16),
)
def test_sketch_apply_matches_matmul(rows, n, d, seed):
    rng = np.random.default_rng(seed)
    m = _rand(rng, rows, n)
    s = jnp.asarray(rng.normal(size=(n, d)), dtype=jnp.float32)
    got = sketch.sketch_apply(m, s)
    want = m @ s
    assert got.shape == (rows, d)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


def test_cd_kernel_large_mu_freezes():
    _, _, u, c, g = _instance(7, 64, 4, 16)
    got = proximal_cd.proximal_cd(c, g, u, 1e9)
    assert_allclose(np.asarray(got), np.asarray(u), rtol=1e-4, atol=1e-5)


def test_cd_kernel_mu_zero_is_exact_hals_sweep():
    # mu=0: the sweep is exact cyclic CD; repeated application must reach a
    # fixed point that solves the NLS problem on a consistent instance
    rng = np.random.default_rng(11)
    xstar = _rand(rng, 32, 3)
    b = _rand(rng, 3, 24)
    a = xstar @ b
    c, g = ref.normal_ref(a, b)
    x = _rand(rng, 32, 3)
    for _ in range(200):
        x = proximal_cd.proximal_cd(c, g, x, 0.0)
    assert_allclose(np.asarray(x), np.asarray(xstar), rtol=5e-2, atol=5e-3)


def test_cd_kernel_monotone_objective():
    rows, k, d = 48, 5, 20
    a, b, u, c, g = _instance(3, rows, k, d)
    mu = 2.0

    def obj(x):
        r = a - x @ b
        return float(jnp.sum(r * r) + mu * jnp.sum((x - u) ** 2))

    x1 = proximal_cd.proximal_cd(c, g, u, mu)
    assert obj(np.asarray(x1)) <= obj(np.asarray(u)) + 1e-5


@pytest.mark.parametrize("rows", [1, 127, 128, 129, 256])
def test_tile_boundary_rows(rows):
    # rows around the TILE_ROWS boundary must all round-trip exactly
    _, _, u, c, g = _instance(5, rows, 3, 8)
    got = proximal_cd.proximal_cd(c, g, u, 1.0)
    want = ref.proximal_cd_ref(c, g, u, jnp.float32(1.0))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
