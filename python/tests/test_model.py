"""L2 model correctness: the fused steps vs composed references, shapes,
and loss identity."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _rand(rng, *shape):
    return jnp.asarray(rng.uniform(0.0, 1.0, size=shape), dtype=jnp.float32)


def test_cd_update_matches_composed_ref():
    rng = np.random.default_rng(21)
    a = _rand(rng, 96, 24)
    b = _rand(rng, 5, 24)
    u = _rand(rng, 96, 5)
    got = model.cd_update(a, b, u, 3.0)
    c, g = ref.normal_ref(a, b)
    want = ref.proximal_cd_ref(c, g, u, jnp.float32(3.0))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_sanls_u_step_matches_ref():
    rng = np.random.default_rng(23)
    m_block = _rand(rng, 64, 80)
    v = _rand(rng, 80, 4)
    s = jnp.asarray(rng.normal(size=(80, 16)) / 4.0, dtype=jnp.float32)
    u = _rand(rng, 64, 4)
    got = model.sanls_u_step(m_block, v, s, u, 2.0)
    want = ref.sanls_u_step_ref(m_block, v, s, u, jnp.float32(2.0))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(2, 80),
    n=st.integers(2, 60),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_nmf_loss_matches_explicit(rows, n, k, seed):
    rng = np.random.default_rng(seed)
    m = _rand(rng, rows, n)
    u = _rand(rng, rows, k)
    v = _rand(rng, n, k)
    got = float(model.nmf_loss(m, u, v))
    want = float(ref.nmf_loss_ref(m, u, v))
    assert abs(got - want) < 2e-3, f"{got} vs {want}"


def test_sanls_step_reduces_objective():
    # one fused sketched step must reduce the sketched+proximal objective
    rng = np.random.default_rng(29)
    xstar = _rand(rng, 48, 3)
    vstar = _rand(rng, 40, 3)
    m_block = xstar @ vstar.T
    v = vstar
    s = jnp.asarray(rng.normal(size=(40, 20)) / np.sqrt(20), dtype=jnp.float32)
    u0 = _rand(rng, 48, 3)

    def true_obj(u):
        r = m_block - u @ v.T
        return float(jnp.sum(r * r))

    u1 = model.sanls_u_step(m_block, v, s, u0, 1.0)
    assert true_obj(u1) < true_obj(u0), "sketched step failed to descend"


def test_jit_entry_catalogue_shapes():
    for kind, shapes in [
        ("cd_update", {"rows": 128, "k": 16, "d": 32}),
        ("pgd_update", {"rows": 128, "k": 16, "d": 32}),
        ("sanls_u_step", {"rows": 128, "n": 256, "k": 16, "d": 32}),
        ("nmf_loss", {"rows": 128, "n": 256, "k": 16}),
    ]:
        jitted, args = model.jit_entry(kind, shapes)
        lowered = jitted.lower(*args)
        assert lowered is not None
