"""AOT export smoke tests: HLO text generation and manifest consistency."""

import json
import tempfile

from compile import aot, model


def test_entry_names_match_rust_convention():
    assert aot.entry_name("cd_update", {"rows": 128, "k": 16, "d": 32}) == "cd_update_r128_k16_d32"
    assert (
        aot.entry_name("sanls_u_step", {"rows": 128, "n": 256, "k": 16, "d": 32})
        == "sanls_u_step_r128_n256_k16_d32"
    )


def test_hlo_text_is_parseable_hlo():
    jitted, args = model.jit_entry("cd_update", {"rows": 128, "k": 16, "d": 32})
    text = aot.to_hlo_text(jitted, args)
    assert "HloModule" in text, "must be HLO text"
    assert "f32[128,16]" in text, "factor shape must appear"
    # tuple return convention the rust loader expects
    assert "ROOT" in text


def test_export_all_writes_manifest():
    with tempfile.TemporaryDirectory() as d:
        entries = aot.export_all(d)
        manifest = json.load(open(f"{d}/manifest.json"))
        assert len(manifest["entries"]) == len(entries) == len(aot.CATALOGUE)
        for e in manifest["entries"]:
            content = open(f"{d}/{e['file']}").read()
            assert content.startswith("HloModule"), e["name"]
            assert e["dims"]["k"] > 0
