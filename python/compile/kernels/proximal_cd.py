"""L1 Pallas kernel: proximal coordinate descent (paper Alg. 3).

The compute hot-spot of DSANLS. Given the normal-equation operands
``c = A @ B.T`` (rows x k) and ``g = B @ B.T`` (k x k), perform one
Gauss-Seidel sweep of the mu-regularised NLS update, row-parallel.

TPU mapping (DESIGN.md #Hardware-Adaptation):
  * grid over row tiles: each program instance owns a ``(TILE_ROWS, k)``
    slab of U and C streamed HBM->VMEM by the BlockSpec;
  * the k x k gram and the scalar mu stay VMEM-resident for every tile
    (index_map pins them to block (0, 0));
  * the k-column sweep is sequential *by construction* (Gauss-Seidel), so
    it unrolls as k rank-1 updates over the row tile - each one a VPU
    max/multiply plus a (TILE_ROWS, k) x (k,) matvec on the MXU;
  * rows are the parallel dimension - the same axis the paper parallelises
    across cluster nodes.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; lowering through the interpreter emits plain HLO that both
pytest and the rust PJRT runtime can run (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per program instance. 128 matches the MXU/VPU lane width and keeps
# the per-tile VMEM footprint at (2*TILE*k + k*k + 1) * 4 bytes - about
# 132 KiB for k=128, comfortably inside the ~16 MiB VMEM budget.
TILE_ROWS = 128


def _cd_kernel(c_ref, g_ref, u_ref, mu_ref, o_ref, *, k: int):
    """One proximal-CD sweep over a (TILE_ROWS, k) row tile."""
    c = c_ref[...]
    g = g_ref[...]
    u0 = u_ref[...]
    mu = mu_ref[0, 0]
    x = u0
    # Sequential Gauss-Seidel sweep over the k columns (static unroll: k is
    # a compile-time constant, matching rust solvers::cd and ref.py).
    for j in range(k):
        g_col = g[:, j]
        xg_j = x @ g_col                      # (TILE,) matvec on the MXU
        t = mu * u0[:, j] + c[:, j] - (xg_j - x[:, j] * g_col[j])
        denom = g_col[j] + mu
        new_col = jnp.where(denom > 0.0, jnp.maximum(t / denom, 0.0), 0.0)
        x = x.at[:, j].set(new_col)
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=())
def proximal_cd(c, g, u, mu):
    """Pallas proximal-CD sweep: ``c (rows,k)``, ``g (k,k)``, ``u (rows,k)``,
    ``mu`` scalar -> updated ``u``. Rows are padded to a TILE_ROWS multiple
    internally (padded rows solve a harmless all-zero problem)."""
    rows, k = u.shape
    assert c.shape == (rows, k), f"c shape {c.shape} != {(rows, k)}"
    assert g.shape == (k, k), f"g shape {g.shape} != {(k, k)}"
    mu_arr = jnp.asarray(mu, dtype=u.dtype).reshape(1, 1)

    pad = (-rows) % TILE_ROWS
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, pad), (0, 0)))
    padded = rows + pad

    out = pl.pallas_call(
        functools.partial(_cd_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((padded, k), u.dtype),
        grid=(padded // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, k), lambda i: (i, 0)),   # C: streamed
            pl.BlockSpec((k, k), lambda i: (0, 0)),           # G: resident
            pl.BlockSpec((TILE_ROWS, k), lambda i: (i, 0)),   # U: streamed
            pl.BlockSpec((1, 1), lambda i: (0, 0)),           # mu: resident
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, k), lambda i: (i, 0)),
        interpret=True,
    )(c, g, u, mu_arr)
    return out[:rows]


def vmem_bytes(k: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint per program instance (see module docs)."""
    return dtype_bytes * (3 * TILE_ROWS * k + k * k + 1)
