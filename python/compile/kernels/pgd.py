"""L1 Pallas kernel: one projected-gradient step (paper Sec. 3.5.1, Eq. 14).

``X <- max(X - 2 eta (X @ G - C), 0)`` over a row tile. Same tiling as the
proximal-CD kernel: rows parallel on the grid, G VMEM-resident, one
(TILE, k) x (k, k) matmul on the MXU plus a VPU axpy/relu.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 128


def _pgd_kernel(c_ref, g_ref, u_ref, eta_ref, o_ref):
    c = c_ref[...]
    g = g_ref[...]
    u = u_ref[...]
    eta = eta_ref[0, 0]
    grad = u @ g - c
    o_ref[...] = jnp.maximum(u - 2.0 * eta * grad, 0.0)


@jax.jit
def pgd(c, g, u, eta):
    """Pallas projected-gradient step; shapes as in ``proximal_cd``."""
    rows, k = u.shape
    assert c.shape == (rows, k)
    assert g.shape == (k, k)
    eta_arr = jnp.asarray(eta, dtype=u.dtype).reshape(1, 1)

    pad = (-rows) % TILE_ROWS
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, pad), (0, 0)))
    padded = rows + pad

    out = pl.pallas_call(
        functools.partial(_pgd_kernel),
        out_shape=jax.ShapeDtypeStruct((padded, k), u.dtype),
        grid=(padded // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((TILE_ROWS, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, k), lambda i: (i, 0)),
        interpret=True,
    )(c, g, u, eta_arr)
    return out[:rows]
