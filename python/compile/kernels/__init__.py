"""L1 Pallas kernels (build-time only; verified against ref.py)."""

from . import pgd, proximal_cd, ref, sketch  # noqa: F401
