"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth every kernel is pytest/hypothesis-verified
against, and they mirror the rust-native solver implementations
(`rust/src/solvers/cd.rs`, `pgd.rs`) line for line, so all three layers
agree on semantics.
"""

import jax.numpy as jnp


def proximal_cd_ref(c, g, u, mu):
    """One proximal coordinate-descent sweep (paper Alg. 3).

    Solves one Gauss-Seidel pass of
        min_{X >= 0} ||A - X B||_F^2 + mu ||X - U||_F^2
    in normal-equation form: ``c = A @ B.T`` (rows x k), ``g = B @ B.T``
    (k x k). Columns are updated in increasing order; columns l < j use the
    already-updated values, l > j the old ones; the mu-anchor uses the old
    column j (exactly rust `proximal_cd_update`).
    """
    k = g.shape[0]
    x = u
    for j in range(k):
        # sum_{l != j} g[l, j] * x[:, l]  ==  x @ g[:, j] - x[:, j] * g[j, j]
        xg_j = x @ g[:, j]
        t = mu * u[:, j] + c[:, j] - (xg_j - x[:, j] * g[j, j])
        denom = g[j, j] + mu
        new_col = jnp.where(denom > 0.0, jnp.maximum(t / denom, 0.0), 0.0)
        x = x.at[:, j].set(new_col)
    return x


def pgd_ref(c, g, u, eta):
    """One projected-gradient step (paper Eq. 14):
    ``X <- max(X - 2 eta (X g - c), 0)``."""
    return jnp.maximum(u - 2.0 * eta * (u @ g - c), 0.0)


def normal_ref(a, b):
    """Normal-equation operands: ``c = A @ B.T``, ``g = B @ B.T``."""
    return a @ b.T, b @ b.T


def sanls_u_step_ref(m_block, v, s, u, mu):
    """Full sketched U-step (paper Alg. 2 lines 4-8, single node):
    sketch, form normal operands, one proximal-CD sweep."""
    a = m_block @ s            # M_{I_r:} S^t      (rows x d)
    b = v.T @ s                # V^T S^t           (k x d)
    c, g = normal_ref(a, b)
    return proximal_cd_ref(c, g, u, mu)


def nmf_loss_ref(m, u, v):
    """Relative Frobenius error ||M - U V^T||_F / ||M||_F."""
    resid = m - u @ v.T
    return jnp.sqrt(jnp.sum(resid * resid) / jnp.sum(m * m))
