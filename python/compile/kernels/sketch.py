"""L1 Pallas kernel: sketch application ``A = M_block @ S`` as a tiled
matmul (the other compute hot-spot of DSANLS, Alg. 2 line 5).

Classic blocked-matmul schedule expressed with BlockSpec, the TPU analogue
of the threadblock tiling a CUDA version would use (DESIGN.md
#Hardware-Adaptation): grid = (row tiles x sketch-col tiles), the
contraction dimension n streamed through VMEM in TILE_N slabs with a
float32 accumulator resident in the output block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 256
TILE_D = 128


def _matmul_kernel(m_ref, s_ref, o_ref, *, n_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += m_ref[...] @ s_ref[...]


def sketch_apply(m_block, s):
    """``m_block (rows, n) @ s (n, d)`` via the tiled Pallas matmul.
    Dimensions are zero-padded to tile multiples and sliced back."""
    rows, n = m_block.shape
    n2, d = s.shape
    assert n == n2, f"contraction mismatch {n} vs {n2}"

    pad_m = (-rows) % TILE_M
    pad_n = (-n) % TILE_N
    pad_d = (-d) % TILE_D
    mp = jnp.pad(m_block, ((0, pad_m), (0, pad_n)))
    sp = jnp.pad(s, ((0, pad_n), (0, pad_d)))
    gm, gn, gd = (rows + pad_m) // TILE_M, (n + pad_n) // TILE_N, (d + pad_d) // TILE_D

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_steps=gn),
        out_shape=jax.ShapeDtypeStruct((rows + pad_m, d + pad_d), m_block.dtype),
        grid=(gm, gd, gn),
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_N), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE_N, TILE_D), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_D), lambda i, j, kk: (i, j)),
        interpret=True,
    )(mp, sp)
    return out[:rows, :d]
