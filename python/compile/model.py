"""L2: the DSANLS update step as a JAX graph, calling the L1 kernels.

These functions are the AOT entry points ``aot.py`` lowers to HLO text for
the rust PJRT runtime. Python never runs at request time - the rust
coordinator feeds the compiled artifacts the same (sketched) operands its
native solver would consume.

Entry points:
  * ``cd_update``     - normal-equation build (XLA matmuls; they fuse to
                        MXU ops) + the Pallas proximal-CD sweep.
  * ``pgd_update``    - same with the projected-gradient kernel.
  * ``sanls_u_step``  - the full fused per-node U-step of Alg. 2: sketch
                        apply (Pallas tiled matmul), summand ``V^T S``,
                        normal operands, CD sweep. One HLO module ==
                        one PJRT dispatch per iteration from rust.
  * ``nmf_loss``      - relative Frobenius error (monitoring).
"""

import jax
import jax.numpy as jnp

from .kernels import pgd as pgd_kernel
from .kernels import proximal_cd as cd_kernel
from .kernels import sketch as sketch_kernel


def cd_update(a, b, u, mu):
    """Proximal-CD factor update for ``min ||A - U B||^2 + mu||U - U0||^2``.

    ``a (rows, d)``, ``b (k, d)``, ``u (rows, k)``, scalar ``mu``.
    """
    c = a @ b.T          # cross products  (rows x k)  - MXU matmul
    g = b @ b.T          # gram            (k x k)
    return cd_kernel.proximal_cd(c, g, u, mu)


def pgd_update(a, b, u, eta):
    """One projected-gradient step on the same operands."""
    c = a @ b.T
    g = b @ b.T
    return pgd_kernel.pgd(c, g, u, eta)


def sanls_u_step(m_block, v, s, u, mu):
    """Fused per-node sketched U-step (paper Alg. 2 lines 4-8).

    ``m_block (rows, n)`` - the node's row block of M;
    ``v (n, k)``          - the full fixed factor (or the node's view);
    ``s (n, d)``          - the shared sketch for this iteration;
    ``u (rows, k)``       - current factor block; scalar ``mu``.
    """
    a = sketch_kernel.sketch_apply(m_block, s)   # M S    (Pallas tiled matmul)
    b = (v.T @ s).astype(u.dtype)                # V^T S  (k x d)
    return cd_update(a, b, u, mu)


def nmf_loss(m, u, v):
    """Relative error ||M - U V^T||_F / ||M||_F without materialising the
    reconstruction: ||M||^2 - 2<MV, U> + <U^T U, V^T V>."""
    m_sq = jnp.sum(m * m)
    cross = jnp.sum((m @ v) * u)
    rec = jnp.sum((u.T @ u) * (v.T @ v))
    resid = jnp.maximum(m_sq - 2.0 * cross + rec, 0.0)
    return jnp.sqrt(resid / m_sq)


def jit_entry(name: str, shapes: dict):
    """Build the jitted function + example args for an AOT entry point."""
    f32 = jnp.float32
    spec = lambda *dims: jax.ShapeDtypeStruct(dims, f32)  # noqa: E731
    if name == "cd_update":
        r, k, d = shapes["rows"], shapes["k"], shapes["d"]
        return jax.jit(cd_update), (spec(r, d), spec(k, d), spec(r, k), spec())
    if name == "pgd_update":
        r, k, d = shapes["rows"], shapes["k"], shapes["d"]
        return jax.jit(pgd_update), (spec(r, d), spec(k, d), spec(r, k), spec())
    if name == "sanls_u_step":
        r, n, k, d = shapes["rows"], shapes["n"], shapes["k"], shapes["d"]
        return (
            jax.jit(sanls_u_step),
            (spec(r, n), spec(n, k), spec(n, d), spec(r, k), spec()),
        )
    if name == "nmf_loss":
        r, n, k = shapes["rows"], shapes["n"], shapes["k"]
        return jax.jit(nmf_loss), (spec(r, n), spec(r, k), spec(n, k))
    raise KeyError(f"unknown entry point {name}")
