"""AOT export: lower the L2 entry points to HLO **text** + manifest.json.

Run once at build time (`make artifacts`):

    cd python && python -m compile.aot --out ../artifacts

HLO text (not serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction
ids; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and rust/src/runtime/mod.rs).
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model

# The artifact catalogue: every (entry-point, shape) pair the rust runtime
# may dispatch to. Shapes are compile-time constants for PJRT, so we export
# the quickstart/e2e/test shapes; the rust HybridBackend falls back to the
# native solver for anything else.
CATALOGUE = [
    ("cd_update", {"rows": 128, "k": 16, "d": 32}),
    ("cd_update", {"rows": 256, "k": 16, "d": 64}),
    ("cd_update", {"rows": 512, "k": 32, "d": 128}),
    ("pgd_update", {"rows": 128, "k": 16, "d": 32}),
    ("sanls_u_step", {"rows": 128, "n": 256, "k": 16, "d": 32}),
    ("nmf_loss", {"rows": 128, "n": 256, "k": 16}),
]


def entry_name(kind: str, shapes: dict) -> str:
    """Canonical artifact name, e.g. ``cd_update_r128_k16_d32`` (must match
    rust PjrtBackend::artifact_for)."""
    parts = [kind]
    for key in ("rows", "n", "k", "d"):
        if key in shapes:
            prefix = {"rows": "r", "n": "n", "k": "k", "d": "d"}[key]
            parts.append(f"{prefix}{shapes[key]}")
    return "_".join(parts[:1]) + "_" + "_".join(parts[1:])


def to_hlo_text(jitted, example_args) -> str:
    """jax lowered -> stablehlo -> XlaComputation -> HLO text."""
    lowered = jitted.lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for kind, shapes in CATALOGUE:
        jitted, args = model.jit_entry(kind, shapes)
        text = to_hlo_text(jitted, args)
        name = entry_name(kind, shapes)
        filename = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, filename), "w") as f:
            f.write(text)
        entries.append({"name": name, "file": filename, "dims": shapes})
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"entries": entries}, f, indent=1)
    return entries


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    print(f"AOT-lowering {len(CATALOGUE)} entry points to {args.out}")
    entries = export_all(args.out)
    print(f"wrote {len(entries)} artifacts + manifest.json")


if __name__ == "__main__":
    main()
