"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT export.

Never imported at runtime - `make artifacts` runs `python -m compile.aot`
once and the rust binary is self-contained afterwards.
"""
