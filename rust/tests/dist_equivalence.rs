//! Distributed-correctness integration tests: the cluster algorithms must
//! be *algorithms*, not approximations of themselves — node count, data
//! layout and communication order must not change the math. Everything
//! runs through the unified `nmf::job::Job` builder (or the per-rank node
//! runners it drives).

use dsanls::algos::{reduce_outputs, DistAnlsOptions, DsanlsOptions};
use dsanls::data::partition::uniform_partition;
use dsanls::data::shard::{exact_fro_sq, NodeData, NodeInput};
use dsanls::dist::run_tcp_cluster;
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::control::RunControl;
use dsanls::nmf::job::{Algo, Backend, DataSource, Job, Outcome, Wire};
use dsanls::nmf::{Sanls, SanlsOptions};
use dsanls::rng::Pcg64;
use dsanls::secure::syn::{assemble_syn, syn_rank};
use dsanls::secure::{SecureAlgo, SynOptions};
use dsanls::sketch::SketchKind;
use dsanls::solvers::SolverKind;

fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed as u128, 0);
    let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
    Matrix::Dense(u.matmul_nt(&v))
}

fn run_dsanls(m: &Matrix, opts: &DsanlsOptions) -> Outcome {
    Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(m))
        .run()
        .expect("dsanls job failed")
}

fn run_dist_anls(m: &Matrix, opts: &DistAnlsOptions) -> Outcome {
    Job::builder()
        .algorithm(Algo::DistAnls(opts.clone()))
        .data(DataSource::Full(m))
        .run()
        .expect("baseline job failed")
}

fn run_syn_sd(m: &Matrix, cols: &dsanls::data::Partition, opts: &SynOptions) -> Outcome {
    Job::builder()
        .algorithm(Algo::Syn(opts.clone(), SecureAlgo::SynSd))
        .data(DataSource::Full(m))
        .secure_partition(cols.clone())
        .run()
        .expect("syn-sd job failed")
}

/// DSANLS iterates are identical for ANY node count (shared-seed sketches +
/// rank-ordered all-reduce): N ∈ {1, 2, 3, 5, 8} must give the same traces.
#[test]
fn dsanls_invariant_to_node_count() {
    let m = low_rank(90, 72, 4, 1001);
    let run = |nodes| {
        run_dsanls(
            &m,
            &DsanlsOptions {
                nodes,
                rank: 4,
                iterations: 15,
                d_u: 20,
                d_v: 24,
                eval_every: 3,
                ..Default::default()
            },
        )
    };
    let reference = run(1);
    for nodes in [2usize, 3, 5, 8] {
        let r = run(nodes);
        assert_eq!(r.trace.len(), reference.trace.len());
        for (a, b) in r.trace.iter().zip(reference.trace.iter()) {
            assert!(
                (a.rel_error - b.rel_error).abs() < 5e-5,
                "N={nodes} iter {}: {} vs {}",
                a.iteration,
                a.rel_error,
                b.rel_error
            );
        }
    }
}

/// DSANLS with N=1 equals centralized SANLS exactly (same seeds → same
/// sketches → same iterates).
#[test]
fn dsanls_single_node_equals_centralized_sanls() {
    let m = low_rank(60, 50, 3, 1003);
    let dist = run_dsanls(
        &m,
        &DsanlsOptions {
            nodes: 1,
            rank: 3,
            iterations: 12,
            sketch: SketchKind::Subsample,
            d_u: 15,
            d_v: 18,
            seed: 42,
            eval_every: 0,
            ..Default::default()
        },
    );
    let central = Sanls::new(SanlsOptions {
        rank: 3,
        iterations: 12,
        sketch: SketchKind::Subsample,
        d_u: 15,
        d_v: 18,
        seed: 42,
        eval_every: 0,
        ..Default::default()
    })
    .run(&m);
    assert!(
        (dist.final_error() - central.final_error()).abs() < 1e-6,
        "dist {} vs central {}",
        dist.final_error(),
        central.final_error()
    );
}

/// The baselines must also be node-count invariant: the all-gather gives
/// every node the full fixed factor, so N only changes the partitioning.
#[test]
fn baseline_invariant_to_node_count() {
    let m = low_rank(60, 48, 3, 1005);
    let run = |nodes| {
        run_dist_anls(
            &m,
            &DistAnlsOptions {
                nodes,
                rank: 3,
                iterations: 10,
                solver: SolverKind::Hals,
                eval_every: 0,
                ..Default::default()
            },
        )
        .final_error()
    };
    let e1 = run(1);
    for nodes in [2usize, 4, 6] {
        let e = run(nodes);
        assert!((e - e1).abs() < 5e-5, "N={nodes}: {e} vs {e1}");
    }
}

/// Determinism: identical config ⇒ bit-identical factors, twice.
#[test]
fn dsanls_runs_are_deterministic() {
    let m = low_rank(50, 40, 3, 1007);
    let opts = DsanlsOptions {
        nodes: 3,
        rank: 3,
        iterations: 10,
        d_u: 12,
        d_v: 14,
        eval_every: 0,
        ..Default::default()
    };
    let a = run_dsanls(&m, &opts);
    let b = run_dsanls(&m, &opts);
    assert_eq!(a.u.data(), b.u.data());
    assert_eq!(a.v.data(), b.v.data());
}

/// Sparse and dense storage of the same matrix must give identical DSANLS
/// traces with the subsampling sketch (it is storage-agnostic).
#[test]
fn sparse_dense_storage_equivalence() {
    let dense = Mat::from_fn(64, 48, |i, j| {
        if (i * 7 + j * 3) % 4 == 0 {
            ((i + j) as f32).sin().abs()
        } else {
            0.0
        }
    });
    let sparse = dsanls::linalg::Csr::from_dense(&dense, 0.0);
    let opts = DsanlsOptions {
        nodes: 2,
        rank: 3,
        iterations: 8,
        sketch: SketchKind::Subsample,
        d_u: 12,
        d_v: 16,
        eval_every: 0,
        ..Default::default()
    };
    let run_d = run_dsanls(&Matrix::Dense(dense), &opts);
    let run_s = run_dsanls(&Matrix::Sparse(sparse), &opts);
    assert!(
        (run_d.final_error() - run_s.final_error()).abs() < 1e-5,
        "dense {} vs sparse {}",
        run_d.final_error(),
        run_s.final_error()
    );
}

/// Simulated-time sanity: the run must report positive finite per-iteration
/// time and populated per-node statistics.
#[test]
fn per_iteration_time_reported() {
    let m = low_rank(240, 120, 4, 1011);
    let r2 = run_dsanls(
        &m,
        &DsanlsOptions {
            nodes: 2,
            rank: 4,
            iterations: 6,
            d_u: 24,
            d_v: 32,
            eval_every: 0,
            ..Default::default()
        },
    );
    assert!(r2.sec_per_iter > 0.0);
    assert!(r2.sec_per_iter.is_finite());
    assert_eq!(r2.stats.len(), 2);
    assert!(r2.stats.iter().all(|s| s.messages > 0));
}

/// The tentpole contract of the transport subsystem: DSANLS over real
/// localhost TCP produces factors **bit-identical** to the simulated
/// backend (same seed, same rank-ordered reductions, same per-node thread
/// policy) — both through the same `Job` builder, only the `transport`
/// axis changes.
#[test]
fn dsanls_tcp_backend_bit_identical_to_sim() {
    let m = low_rank(60, 48, 3, 1013);
    let opts = DsanlsOptions {
        nodes: 3,
        rank: 3,
        iterations: 8,
        d_u: 12,
        d_v: 14,
        eval_every: 4,
        ..Default::default()
    };
    let sim = run_dsanls(&m, &opts);
    let tcp = Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(&m))
        .transport(Backend::Tcp { port: 0 })
        .run()
        .expect("tcp job failed");
    assert_eq!(sim.u.data(), tcp.u.data(), "U diverged across backends");
    assert_eq!(sim.v.data(), tcp.v.data(), "V diverged across backends");
    // traced errors are computed from the same factors → bit-identical too
    assert_eq!(sim.trace.len(), tcp.trace.len());
    for (a, b) in sim.trace.iter().zip(tcp.trace.iter()) {
        assert_eq!(a.iteration, b.iteration);
        assert_eq!(a.rel_error.to_bits(), b.rel_error.to_bits());
    }
}

/// The new comm flags honour the same cross-backend contract. With
/// `overlap_comm` the pipeline prefetches factor-independent GEMMs behind
/// the in-flight reduce but never reorders the math — Sim and TCP both
/// stay bit-identical to the blocking exact run. With a quantized wire
/// (bf16) every rank round-trips its own contribution through the same
/// codec the peers decode, so the (lossy) factors still agree
/// bit-for-bit between the simulated and real-TCP backends.
#[test]
fn overlap_and_quantized_wire_match_across_backends() {
    let m = low_rank(60, 48, 3, 1021);
    let base = DsanlsOptions {
        nodes: 3,
        rank: 3,
        iterations: 8,
        d_u: 12,
        d_v: 14,
        eval_every: 4,
        ..Default::default()
    };
    let run = |overlap: bool, wire: Wire, tcp: bool| {
        let mut b = Job::builder()
            .algorithm(Algo::Dsanls(base.clone()))
            .data(DataSource::Full(&m))
            .overlap_comm(overlap)
            .wire_precision(wire);
        if tcp {
            b = b.transport(Backend::Tcp { port: 0 });
        }
        b.run().expect("job failed")
    };

    // overlap alone changes nothing: both backends match the exact run
    let exact = run_dsanls(&m, &base);
    let sim_ov = run(true, Wire::F32, false);
    let tcp_ov = run(true, Wire::F32, true);
    assert_eq!(exact.u.data(), sim_ov.u.data(), "overlap changed the sim iterates");
    assert_eq!(sim_ov.u.data(), tcp_ov.u.data(), "overlapped U diverged across backends");
    assert_eq!(sim_ov.v.data(), tcp_ov.v.data(), "overlapped V diverged across backends");

    // quantized wire: lossy vs exact, but identical across backends
    let sim_q = run(true, Wire::Bf16, false);
    let tcp_q = run(true, Wire::Bf16, true);
    assert_ne!(exact.u.data(), sim_q.u.data(), "bf16 wire must actually quantize");
    assert_eq!(sim_q.u.data(), tcp_q.u.data(), "quantized U diverged across backends");
    assert_eq!(sim_q.v.data(), tcp_q.v.data(), "quantized V diverged across backends");
}

/// Same for a secure protocol: Syn-SD over TCP matches the simulator
/// bit-for-bit (its consensus is a rank-ordered all-reduce).
#[test]
fn syn_sd_tcp_backend_bit_identical_to_sim() {
    let m = low_rank(40, 30, 3, 1015);
    let cols = uniform_partition(30, 3);
    let opts = SynOptions {
        nodes: 3,
        rank: 3,
        t1: 3,
        t2: 2,
        d1: 10,
        d2: 5,
        d3: 10,
        eval_every: 0,
        ..Default::default()
    };
    let sim = run_syn_sd(&m, &cols, &opts);
    let tcp = Job::builder()
        .algorithm(Algo::Syn(opts.clone(), SecureAlgo::SynSd))
        .data(DataSource::Full(&m))
        .secure_partition(cols.clone())
        .transport(Backend::Tcp { port: 0 })
        .run()
        .expect("tcp job failed");
    assert_eq!(sim.u.data(), tcp.u.data(), "U diverged across backends");
    assert_eq!(sim.v.data(), tcp.v.data(), "V diverged across backends");
}

/// The shard data plane's contract, end to end over real TCP: ranks that
/// hold **only their blocks** (plus the chain-reduced exact ‖M‖²) must
/// produce factors bit-identical to the full-matrix simulator. Drives the
/// unified `dsanls_rank` node runner directly on shard-resident input.
#[test]
fn dsanls_sharded_tcp_bit_identical_to_full_sim() {
    let m = low_rank(72, 54, 3, 1017);
    let opts = DsanlsOptions {
        nodes: 3,
        rank: 3,
        iterations: 8,
        d_u: 12,
        d_v: 14,
        eval_every: 4,
        ..Default::default()
    };
    let sim = run_dsanls(&m, &opts);
    let outputs = run_tcp_cluster(opts.nodes, opts.comm, |ctx| {
        let rr = uniform_partition(m.rows(), opts.nodes).range(ctx.rank);
        let cr = uniform_partition(m.cols(), opts.nodes).range(ctx.rank);
        let mut data = NodeData::from_full(&m, rr, cr);
        data.fro_sq = None; // what a real worker does: resolve via the chain
        let fro = exact_fro_sq(ctx.comm_mut(), opts.nodes, data.m_rows.as_ref()).unwrap();
        data.fro_sq = Some(fro);
        dsanls::algos::dsanls::dsanls_rank(
            ctx,
            NodeInput::Shard(&data),
            &opts,
            None,
            &RunControl::unsupervised(),
            false,
        )
    })
    .expect("tcp cluster failed");
    let tcp = reduce_outputs(outputs, opts.rank, opts.iterations);
    assert_eq!(sim.u.data(), tcp.u.data(), "sharded U diverged from full sim");
    assert_eq!(sim.v.data(), tcp.v.data(), "sharded V diverged from full sim");
}

/// Sharded Syn-SD parties (column block + global metadata only) match the
/// full-matrix simulator bit-for-bit — through the same `syn_rank` node
/// runner both ways.
#[test]
fn syn_sd_sharded_matches_full_sim() {
    let m = low_rank(40, 30, 3, 1019);
    let cols = uniform_partition(30, 3);
    let opts = SynOptions {
        nodes: 3,
        rank: 3,
        t1: 3,
        t2: 2,
        d1: 10,
        d2: 5,
        d3: 10,
        eval_every: 0,
        ..Default::default()
    };
    let sim = run_syn_sd(&m, &cols, &opts);
    let outputs = run_tcp_cluster(opts.nodes, opts.comm, |ctx| {
        // a secure party's shard: its column block; the row block exists
        // only to feed the ‖M‖² chain, then is dropped (worker behaviour)
        let rr = uniform_partition(m.rows(), opts.nodes).range(ctx.rank);
        let mut data = NodeData::from_full(&m, rr, cols.range(ctx.rank));
        data.fro_sq = None;
        let fro = exact_fro_sq(ctx.comm_mut(), opts.nodes, data.m_rows.as_ref()).unwrap();
        data.fro_sq = Some(fro);
        data.drop_rows();
        syn_rank(
            ctx,
            NodeInput::Shard(&data),
            &cols,
            &opts,
            SecureAlgo::SynSd,
            None,
            None,
            &RunControl::unsupervised(),
            false,
        )
    })
    .expect("tcp cluster failed");
    let tcp = assemble_syn(outputs, opts.rank, opts.t1 * opts.t2);
    assert_eq!(sim.u.data(), tcp.u.data(), "sharded U diverged from full sim");
    assert_eq!(sim.v.data(), tcp.v.data(), "sharded V diverged from full sim");
}
