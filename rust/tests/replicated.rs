//! Replicated-serving integration tests: zero-downtime checkpoint
//! hot-swap under concurrent load (no dropped queries, no
//! mixed-generation replies), generation-keyed fold-in cache
//! invalidation, the `OP_RELOAD` wire op, and consistent-hash router
//! failover across two live replicas.

use std::path::PathBuf;
use std::time::Duration;

use dsanls::linalg::Mat;
use dsanls::metrics::JsonValue;
use dsanls::nmf::control::{write_checkpoint, Checkpoint, CheckpointMeta, ResumeState};
use dsanls::rng::Pcg64;
use dsanls::router::{route, RouteOptions};
use dsanls::serve::{
    serve, CheckpointSource, FactorModel, ServeClient, ServeOptions, FIRST_GENERATION,
};

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsanls_repl_{tag}_{}.ckpt", std::process::id()))
}

fn meta(users: usize, items: usize, k: usize) -> CheckpointMeta {
    CheckpointMeta {
        algo: "dsanls".into(),
        seed: 7,
        k,
        rows: users,
        cols: items,
        params: 0xFEED,
    }
}

fn toy_checkpoint(users: usize, items: usize, k: usize, seed: u128) -> Checkpoint {
    let mut rng = Pcg64::new(seed, 0);
    let u = Mat::rand_uniform(users, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(items, k, 1.0, &mut rng);
    Checkpoint { meta: meta(users, items, k), state: ResumeState { iteration: 9, u, v } }
}

fn toy_model(users: usize, items: usize, k: usize, seed: u128) -> FactorModel {
    FactorModel::from_checkpoint(toy_checkpoint(users, items, k, seed))
}

/// All score rows of `model` as one dense block (row r = user r).
fn all_rows(model: &FactorModel) -> Mat {
    let users: Vec<u64> = (0..model.users() as u64).collect();
    let (mut w, mut scores) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
    model.scores_into(&users, &mut w, &mut scores).unwrap();
    scores
}

fn local_top_k(model: &FactorModel, user: u64, n: usize) -> Vec<(u64, f32)> {
    let (mut w, mut scores) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
    model.scores_into(&[user], &mut w, &mut scores).unwrap();
    let mut out = Vec::new();
    dsanls::serve::top_n(scores.row(0), n, &mut out);
    out.into_iter().map(|(i, s)| (i as u64, s)).collect()
}

// ---------------------------------------------------------------------------
// Hot-swap under concurrent load
// ---------------------------------------------------------------------------

#[test]
fn hot_swap_under_load_drops_nothing_and_never_mixes_generations() {
    let model_a = toy_model(24, 12, 3, 0xA111);
    let model_b = toy_model(24, 12, 3, 0xB222);
    let rows_a = std::sync::Arc::new(all_rows(&model_a));
    let rows_b = std::sync::Arc::new(all_rows(&model_b));

    // linger long enough that batches regularly straddle the swap moment
    let opts = ServeOptions { batch_wait_us: 500, ..ServeOptions::default() };
    let mut handle = serve("127.0.0.1:0", model_a, opts).unwrap();
    let addr = handle.addr().to_string();

    const THREADS: u64 = 4;
    const PER_THREAD: u64 = 40;
    let mut workers = Vec::new();
    for c in 0..THREADS {
        let addr = addr.clone();
        let (rows_a, rows_b) = (rows_a.clone(), rows_b.clone());
        workers.push(std::thread::spawn(move || {
            let mut client = ServeClient::connect(&addr).unwrap();
            let mut seen = [0u64; 2]; // replies answered by gen 1 / gen 2
            for round in 0..PER_THREAD {
                let u1 = (c * 5 + round) % 24;
                let u2 = (u1 + 7) % 24;
                let scores = client.reconstruct(&[u1, u2]).unwrap();
                let gen = client.generation();
                // the whole reply must come from exactly ONE generation —
                // and the one the reply frame advertised
                let from = |rows: &Mat| {
                    scores.row(0) == rows.row(u1 as usize)
                        && scores.row(1) == rows.row(u2 as usize)
                };
                match gen {
                    1 => assert!(from(&rows_a), "gen-1 reply not pure model A"),
                    2 => assert!(from(&rows_b), "gen-2 reply not pure model B"),
                    g => panic!("impossible generation {g}"),
                }
                seen[(gen - 1) as usize] += 1;
            }
            seen
        }));
    }

    // swap mid-stream, while every client is in flight
    std::thread::sleep(Duration::from_millis(30));
    assert_eq!(handle.generation(), FIRST_GENERATION);
    let swapped_to = handle.swap_model(model_b);
    assert_eq!(swapped_to, 2);

    let mut totals = [0u64; 2];
    for w in workers {
        let seen = w.join().unwrap();
        totals[0] += seen[0];
        totals[1] += seen[1];
    }
    // zero dropped: every query got a (pure) answer
    assert_eq!(totals[0] + totals[1], THREADS * PER_THREAD);

    let json = handle.metrics_json();
    let num = |k: &str| json.get(k).and_then(JsonValue::as_f64).unwrap_or(f64::NAN);
    assert_eq!(num("queries"), (THREADS * PER_THREAD) as f64);
    assert_eq!(num("errors"), 0.0);
    assert_eq!(num("generation"), 2.0);
    assert_eq!(num("swaps"), 1.0);
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Generation-keyed fold-in cache
// ---------------------------------------------------------------------------

#[test]
fn swap_invalidates_fold_in_cache_without_a_flush() {
    let model_a = toy_model(10, 16, 4, 0xCA11);
    let model_b = toy_model(10, 16, 4, 0xCB22);
    let opts = ServeOptions { batch_wait_us: 0, ..ServeOptions::default() };
    let mut handle = serve("127.0.0.1:0", model_a, opts).unwrap();
    let mut client = ServeClient::connect(&handle.addr().to_string()).unwrap();

    let entries: Vec<(u64, f32)> = vec![(1, 2.0), (8, 0.5), (15, 1.25)];
    let (emb_a, _) = client.fold_in(&entries, 0).unwrap(); // solve #1
    let (emb_a2, _) = client.fold_in(&entries, 0).unwrap(); // cache hit
    assert_eq!(emb_a2, emb_a);

    handle.swap_model(model_b);

    // the identical row after the swap must RE-SOLVE against model B —
    // a stale gen-1 embedding must never serve from the cache
    let (emb_b, _) = client.fold_in(&entries, 0).unwrap(); // solve #2
    assert_eq!(client.generation(), 2);
    assert_ne!(emb_b, emb_a, "swap served a stale cached embedding");
    let (emb_b2, _) = client.fold_in(&entries, 0).unwrap(); // cache hit
    assert_eq!(emb_b2, emb_b);

    let json = handle.metrics_json();
    let num = |k: &str| json.get(k).and_then(JsonValue::as_f64).unwrap_or(f64::NAN);
    assert_eq!(num("fold_in_solves"), 2.0, "{}", json.to_string());
    assert_eq!(num("cache_hits"), 2.0, "{}", json.to_string());
    assert_eq!(num("errors"), 0.0);
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// OP_RELOAD: re-read the checkpoint over the wire
// ---------------------------------------------------------------------------

#[test]
fn reload_wire_op_swaps_in_the_rewritten_checkpoint() {
    let path = tmpfile("reload");
    let ck_a = toy_checkpoint(10, 16, 4, 0xDA11);
    write_checkpoint(&path, &ck_a.meta, ck_a.state.iteration, &ck_a.state.u, &ck_a.state.v)
        .unwrap();
    let model = FactorModel::load(&path).unwrap();
    let rows_a = all_rows(&model);

    let opts = ServeOptions {
        batch_wait_us: 0,
        source: Some(CheckpointSource {
            path: path.clone(),
            expect_algo: Some("dsanls".into()),
            expect_params: Some(0xFEED),
        }),
        ..ServeOptions::default()
    };
    let mut handle = serve("127.0.0.1:0", model, opts).unwrap();
    let mut client = ServeClient::connect(&handle.addr().to_string()).unwrap();

    let scores = client.reconstruct(&[3]).unwrap();
    assert_eq!(scores.row(0), rows_a.row(3));
    assert_eq!(client.generation(), FIRST_GENERATION);

    // a newer training snapshot lands (atomic rename, same path) …
    let mut ck_b = toy_checkpoint(10, 16, 4, 0xDB22);
    ck_b.state.iteration = 21;
    write_checkpoint(&path, &ck_b.meta, ck_b.state.iteration, &ck_b.state.u, &ck_b.state.v)
        .unwrap();
    let rows_b = all_rows(&FactorModel::from_checkpoint(ck_b));

    // … and the wire op swaps it in
    let (generation, iteration) = client.reload().unwrap();
    assert_eq!((generation, iteration), (2, 21));
    let scores = client.reconstruct(&[3]).unwrap();
    assert_eq!(scores.row(0), rows_b.row(3));
    assert_eq!(client.generation(), 2);
    assert_eq!(handle.generation(), 2);
    handle.shutdown();
    std::fs::remove_file(&path).ok();

    // a server started from an in-memory model has nothing to re-read
    let mut handle = serve(
        "127.0.0.1:0",
        toy_model(6, 8, 2, 0xF00),
        ServeOptions { batch_wait_us: 0, ..ServeOptions::default() },
    )
    .unwrap();
    let mut client = ServeClient::connect(&handle.addr().to_string()).unwrap();
    let err = client.reload().unwrap_err().to_string();
    assert!(err.contains("reload refused"), "{err}");
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Router: consistent-hash fan-out, rolling reload, failover
// ---------------------------------------------------------------------------

#[test]
fn router_answers_through_failover_and_rolls_reloads_across_the_fleet() {
    let path = tmpfile("router");
    let ck_a = toy_checkpoint(64, 12, 3, 0xEA11);
    write_checkpoint(&path, &ck_a.meta, ck_a.state.iteration, &ck_a.state.u, &ck_a.state.v)
        .unwrap();
    let reference_a = FactorModel::load(&path).unwrap();

    let replica_opts = || ServeOptions {
        batch_wait_us: 0,
        source: Some(CheckpointSource {
            path: path.clone(),
            expect_algo: Some("dsanls".into()),
            expect_params: Some(0xFEED),
        }),
        ..ServeOptions::default()
    };
    let mut r1 = serve("127.0.0.1:0", FactorModel::load(&path).unwrap(), replica_opts()).unwrap();
    let mut r2 = serve("127.0.0.1:0", FactorModel::load(&path).unwrap(), replica_opts()).unwrap();
    let replicas = vec![r1.addr().to_string(), r2.addr().to_string()];

    // long cooldown: once a replica is seen dead it stays routed-around
    // for the rest of the test (keeps the `up` assertion deterministic)
    let opts = RouteOptions { cooldown: Duration::from_secs(60), ..RouteOptions::default() };
    let mut router = route("127.0.0.1:0", &replicas, opts).unwrap();
    let mut client = ServeClient::connect(&router.addr().to_string()).unwrap();

    // 64 distinct user keys spread across both replicas; every answer is
    // exact regardless of which replica served it
    for user in 0..64u64 {
        assert_eq!(client.top_k(&[user], 3).unwrap()[0], local_top_k(&reference_a, user, 3));
        assert_eq!(client.generation(), FIRST_GENERATION, "user {user}");
    }

    // aggregated stats: both replicas took traffic, fleet is converged
    let stats = client.stats().unwrap();
    let json = JsonValue::parse(&stats).unwrap();
    let num = |k: &str| json.get(k).and_then(JsonValue::as_f64).unwrap_or(f64::NAN);
    assert!(num("queries") >= 64.0, "{stats}");
    assert_eq!(num("generation"), 1.0, "{stats}");
    let replica_list = match json.get("replicas") {
        Some(JsonValue::Array(list)) => list,
        other => panic!("missing per-replica breakdown: {other:?}"),
    };
    assert_eq!(replica_list.len(), 2);
    for entry in replica_list {
        let served = entry
            .get("stats")
            .and_then(|s| s.get("queries"))
            .and_then(JsonValue::as_f64)
            .unwrap_or(f64::NAN);
        assert!(served >= 1.0, "a replica took no traffic: {stats}");
    }
    let router_num = |k: &str| {
        json.get("router").and_then(|r| r.get(k)).and_then(JsonValue::as_f64).unwrap_or(f64::NAN)
    };
    assert_eq!(router_num("replicas"), 2.0, "{stats}");
    assert_eq!(router_num("up"), 2.0, "{stats}");
    assert_eq!(router_num("routed"), 65.0, "{stats}"); // 64 keyed + this stats
    assert_eq!(router_num("failovers"), 0.0, "{stats}");

    // rolling update: rewrite the checkpoint, reload THROUGH the router —
    // the broadcast must land on every replica
    let mut ck_b = toy_checkpoint(64, 12, 3, 0xEB22);
    ck_b.state.iteration = 21;
    write_checkpoint(&path, &ck_b.meta, ck_b.state.iteration, &ck_b.state.u, &ck_b.state.v)
        .unwrap();
    let reference_b = FactorModel::from_checkpoint(ck_b);
    assert_eq!(client.reload().unwrap(), (2, 21));
    assert_eq!(r1.generation(), 2);
    assert_eq!(r2.generation(), 2);
    assert_eq!(client.top_k(&[5], 3).unwrap()[0], local_top_k(&reference_b, 5, 3));

    // kill one replica: the ring fails its keys over and keeps answering
    r2.shutdown();
    for user in 0..64u64 {
        assert_eq!(
            client.top_k(&[user], 3).unwrap()[0],
            local_top_k(&reference_b, user, 3),
            "user {user} after failover"
        );
        assert_eq!(client.generation(), 2, "user {user} after failover");
    }
    let m = router.metrics_json();
    let rnum = |k: &str| m.get(k).and_then(JsonValue::as_f64).unwrap_or(f64::NAN);
    assert!(rnum("failovers") >= 1.0, "{}", m.to_string());
    assert_eq!(rnum("up"), 1.0, "{}", m.to_string());
    assert_eq!(rnum("errors"), 0.0, "{}", m.to_string());

    router.shutdown();
    r1.shutdown();
    std::fs::remove_file(&path).ok();
}
