//! Security/privacy integration tests: Definition-1 audits for every
//! protocol, and the Theorem-2/3 boundary.

use dsanls::data::partition::{imbalanced_partition, uniform_partition, Partition};
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, DataSource, Job, Outcome};
use dsanls::rng::Pcg64;
use dsanls::secure::{
    sketch_inversion, AsynOptions, AuditLog, AuditVerdict, SecureAlgo, SynOptions,
};
use dsanls::sketch::{SketchKind, SketchMatrix};

fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed as u128, 0);
    let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
    Matrix::Dense(u.matmul_nt(&v))
}

fn run_secure(
    m: &Matrix,
    cols: &Partition,
    algo: Algo,
    audit: Option<&AuditLog>,
) -> Outcome {
    let mut b = Job::builder()
        .algorithm(algo)
        .data(DataSource::Full(m))
        .secure_partition(cols.clone());
    if let Some(a) = audit {
        b = b.audit(a);
    }
    b.run().expect("secure job failed")
}

fn run_syn_sd(m: &Matrix, cols: &Partition, opts: &SynOptions, audit: Option<&AuditLog>) -> Outcome {
    run_secure(m, cols, Algo::Syn(opts.clone(), SecureAlgo::SynSd), audit)
}

fn run_syn_ssd(
    m: &Matrix,
    cols: &Partition,
    opts: &SynOptions,
    variant: SecureAlgo,
    audit: Option<&AuditLog>,
) -> Outcome {
    run_secure(m, cols, Algo::Syn(opts.clone(), variant), audit)
}

fn run_asyn(
    m: &Matrix,
    cols: &Partition,
    opts: &AsynOptions,
    variant: SecureAlgo,
    audit: Option<&AuditLog>,
) -> Outcome {
    run_secure(m, cols, Algo::Asyn(opts.clone(), variant), audit)
}

fn mat_rows(m: &Mat) -> Vec<Vec<f32>> {
    (0..m.rows()).map(|i| m.row(i).to_vec()).collect()
}

/// Collect each party's secrets: the columns of its `M_{:J_r}` block (as
/// rows of the transpose) and its private `V_{J_r:}` rows.
fn secrets_of(m: &Matrix, v: &Mat, cols: &dsanls::data::Partition) -> Vec<(usize, Vec<Vec<f32>>)> {
    let mut secrets = Vec::new();
    for r in 0..cols.nodes() {
        let range = cols.range(r);
        let m_col_t = m.col_block(range.clone()).transpose().to_dense();
        let mut rows = mat_rows(&m_col_t);
        rows.extend(mat_rows(&v.row_block(range)));
        secrets.push((r, rows));
    }
    secrets
}

#[test]
fn every_sync_protocol_passes_the_audit() {
    let m = low_rank(48, 36, 3, 2001);
    let cols = uniform_partition(36, 3);
    let opts = SynOptions {
        nodes: 3,
        rank: 3,
        t1: 4,
        t2: 2,
        d1: 12,
        d2: 6,
        d3: 12,
        eval_every: 0,
        ..Default::default()
    };
    for algo in [SecureAlgo::SynSd, SecureAlgo::SynSsdU, SecureAlgo::SynSsdV, SecureAlgo::SynSsdUv]
    {
        let audit = AuditLog::new();
        let run = match algo {
            SecureAlgo::SynSd => run_syn_sd(&m, &cols, &opts, Some(&audit)),
            _ => run_syn_ssd(&m, &cols, &opts, algo, Some(&audit)),
        };
        assert!(audit.len() > 0, "{}: nothing was audited", algo.name());
        let secrets = secrets_of(&m, &run.v, &cols);
        assert_eq!(
            audit.verdict(&secrets),
            AuditVerdict::Clean,
            "{} leaked private data",
            algo.name()
        );
    }
}

#[test]
fn async_protocols_pass_the_audit() {
    let m = low_rank(48, 36, 3, 2003);
    let cols = uniform_partition(36, 3);
    let opts = AsynOptions {
        nodes: 3,
        rank: 3,
        rounds: 4,
        local_iters: 2,
        d1: 12,
        ..Default::default()
    };
    for algo in [SecureAlgo::AsynSd, SecureAlgo::AsynSsdV] {
        let audit = AuditLog::new();
        let run = run_asyn(&m, &cols, &opts, algo, Some(&audit));
        assert!(audit.len() > 0);
        let secrets = secrets_of(&m, &run.v, &cols);
        assert_eq!(
            audit.verdict(&secrets),
            AuditVerdict::Clean,
            "{} leaked private data",
            algo.name()
        );
    }
}

/// A deliberately broken protocol (sending raw V rows) MUST be caught — the
/// audit is only as good as its ability to flag real leaks.
#[test]
fn audit_catches_a_leaky_protocol() {
    let m = low_rank(30, 20, 3, 2005);
    let cols = uniform_partition(20, 2);
    let audit = AuditLog::new();
    // run a legit protocol first so the log is realistic…
    let opts = SynOptions {
        nodes: 2,
        rank: 3,
        t1: 2,
        t2: 2,
        d1: 10,
        d2: 5,
        d3: 10,
        eval_every: 0,
        ..Default::default()
    };
    let run = run_syn_ssd(&m, &cols, &opts, SecureAlgo::SynSsdUv, Some(&audit));
    // …then simulate a buggy node that ships its V block raw:
    audit.record(1, "bug/raw-v", run.v.row_block(cols.range(1)).data());
    let secrets = secrets_of(&m, &run.v, &cols);
    assert!(
        matches!(audit.verdict(&secrets), AuditVerdict::Leak { owner: 1, .. }),
        "audit failed to catch an injected leak"
    );
}

/// Theorem 2/3 boundary: with Σd < n the attack must fail; the moment the
/// stacked sketches reach full rank it must succeed.
#[test]
fn sketch_inversion_boundary() {
    let mut rng = Pcg64::new(2007, 0);
    let n = 24;
    let m = Mat::rand_uniform(5, n, 1.0, &mut rng);
    let mut sketches = Vec::new();
    let mut obs = Vec::new();
    let d = 6;
    let mut recovered_at = None;
    for t in 0..6 {
        let mut srng = Pcg64::new(3000 + t as u128, 1);
        let s = SketchMatrix::generate(SketchKind::Gaussian, n, d, &mut srng);
        obs.push(s.mul_right_dense(&m));
        sketches.push(s);
        let total: usize = sketches.len() * d;
        match sketch_inversion(&sketches, &obs) {
            None => assert!(total < n, "attack failed with Σd={total} ≥ n={n}"),
            Some(rec) => {
                assert!(total >= n, "attack succeeded with Σd={total} < n={n}");
                assert!(rec.dist_sq(&m) < 1e-3);
                recovered_at.get_or_insert(sketches.len());
            }
        }
    }
    assert_eq!(recovered_at, Some(4), "recovery should start exactly at Σd ≥ n");
}

/// Imbalanced workload: async protocols must finish (no deadlock) and never
/// stall, while sync protocols accumulate stall time on the light nodes.
#[test]
fn imbalance_behaviour_matches_paper() {
    let m = low_rank(60, 60, 3, 2009);
    let cols = imbalanced_partition(60, 3, 0.5);

    let sync = run_syn_sd(
        &m,
        &cols,
        &SynOptions {
            nodes: 3,
            rank: 3,
            t1: 4,
            t2: 2,
            eval_every: 0,
            ..Default::default()
        },
        None,
    );
    let total_stall: f64 = sync.stats.iter().map(|s| s.stall_time).sum();
    assert!(total_stall > 0.0, "sync under skew must stall");

    let asyncr = run_asyn(
        &m,
        &cols,
        &AsynOptions { nodes: 3, rank: 3, rounds: 4, local_iters: 2, ..Default::default() },
        SecureAlgo::AsynSsdV,
        None,
    );
    assert!(asyncr.stats.iter().all(|s| s.stall_time == 0.0));
    assert!(asyncr.final_error().is_finite());
}
