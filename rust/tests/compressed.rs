//! Compressed data plane integration tests: factorizing directly from
//! `dsanls shard --compress` directories must (1) recover a low-rank
//! matrix within the sketch-distortion bound documented in DEPLOYMENT.md,
//! (2) stay **bit-identical** between the simulated and TCP backends
//! (shared-seed fixed sketches + rank-ordered reductions, exactly like
//! raw runs), (3) shrink per-rank residency by roughly the compression
//! ratio, and (4) reject the unsupported combinations with typed errors
//! at build time, before any rank spawns.

use dsanls::algos::{DistAnlsOptions, DsanlsOptions};
use dsanls::data::compress::{ratio_dims, write_compressed_dir};
use dsanls::data::partition::uniform_partition;
use dsanls::data::shard::{NodeData, ShardManifest};
use dsanls::data::{CompressedBlock, Dataset};
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, Backend, DataSource, Job, Outcome};
use dsanls::rng::Pcg64;
use dsanls::secure::{AsynOptions, SecureAlgo, SynOptions};
use dsanls::sketch::SketchKind;
use std::path::PathBuf;

fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed as u128, 0);
    let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
    Matrix::Dense(u.matmul_nt(&v))
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dsanls_ctest_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a compressed directory for `m` at the given ratio and sketch kind.
fn compress(m: &Matrix, nodes: usize, kind: SketchKind, ratio: f64, tag: &str) -> PathBuf {
    let base = ShardManifest::uniform(
        nodes,
        m.rows(),
        m.cols(),
        m.fro_sq(),
        7,
        1.0,
        matches!(m, Matrix::Dense(_)),
        "FACE".into(),
    );
    let (d_r, d_c) = ratio_dims(m.rows(), m.cols(), ratio).unwrap();
    let dir = tmpdir(tag);
    write_compressed_dir(&dir, m, &base, kind, d_r, d_c).unwrap();
    dir
}

fn run_compressed(dir: &PathBuf, algo: Algo, backend: Backend) -> Outcome {
    Job::builder()
        .algorithm(algo)
        .data(DataSource::Compressed(dir.clone()))
        .transport(backend)
        .run()
        .expect("compressed job failed")
}

/// DSANLS on sketched shards: the compressed-domain residual proxy must
/// converge, the *exact* factor recovery error (checked against the raw
/// matrix the test still holds) must land within the documented
/// sketch-distortion bound, and Sim vs TCP must agree bit-for-bit.
#[test]
fn dsanls_recovers_from_compressed_shards_and_backends_agree() {
    let m = low_rank(96, 80, 4, 2001);
    for (kind, tag) in [(SketchKind::Gaussian, "dg"), (SketchKind::CountSketch, "dc")] {
        let dir = compress(&m, 2, kind, 2.0, tag);
        let algo = || {
            Algo::Dsanls(DsanlsOptions {
                nodes: 2,
                rank: 4,
                iterations: 30,
                eval_every: 10,
                ..Default::default()
            })
        };
        let sim = run_compressed(&dir, algo(), Backend::Sim);
        // the trace is the compressed-domain proxy — it must be finite,
        // normalised, and decreasing overall
        assert!(sim.trace.iter().all(|p| p.rel_error.is_finite()));
        assert!(
            sim.final_error() < sim.trace[0].rel_error,
            "{kind:?}: proxy did not decrease: {:?}",
            sim.trace
        );
        // exact recovery against the raw matrix (which no rank ever saw):
        // documented bound for ratio 2 on low-rank data
        let recovery = sim.check_error(&m);
        assert!(
            recovery < 0.25,
            "{kind:?}: recovery error {recovery} above the documented ratio-2 bound"
        );
        // every rank reported the compressed source and sketched residency
        assert_eq!(sim.loads.len(), 2);
        for l in &sim.loads {
            assert_eq!(l.source.label(), "compressed shard");
        }

        let tcp = run_compressed(&dir, algo(), Backend::Tcp { port: 0 });
        assert_eq!(sim.u.data(), tcp.u.data(), "{kind:?}: U differs across backends");
        assert_eq!(sim.v.data(), tcp.v.data(), "{kind:?}: V differs across backends");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// The MPI-FAUN baselines on sketched shards: same recovery and
/// bit-identity contract as DSANLS.
#[test]
fn dist_anls_recovers_from_compressed_shards_and_backends_agree() {
    let m = low_rank(90, 72, 4, 2003);
    let dir = compress(&m, 2, SketchKind::CountSketch, 2.0, "ba");
    let algo = || {
        Algo::DistAnls(DistAnlsOptions {
            nodes: 2,
            rank: 4,
            iterations: 25,
            eval_every: 5,
            ..Default::default()
        })
    };
    let sim = run_compressed(&dir, algo(), Backend::Sim);
    assert!(sim.trace.iter().all(|p| p.rel_error.is_finite()));
    let recovery = sim.check_error(&m);
    assert!(recovery < 0.25, "baseline recovery error {recovery} above the ratio-2 bound");

    let tcp = run_compressed(&dir, algo(), Backend::Tcp { port: 0 });
    assert_eq!(sim.u.data(), tcp.u.data(), "baseline U differs across backends");
    assert_eq!(sim.v.data(), tcp.v.data(), "baseline V differs across backends");
    std::fs::remove_dir_all(&dir).ok();
}

/// CountSketch residency: a rank's compressed views plus its regenerated
/// sketch pair must come in at roughly `1/R` of the raw blocks it would
/// otherwise hold (the structured sketches add only `O(rows + cols)`).
#[test]
fn compressed_residency_is_about_one_over_ratio() {
    let dataset = Dataset::Face;
    let ratio = 4.0;
    let nodes = 4usize;
    let m = dataset.generate_scaled(7, 0.25);
    let dir = compress(&m, nodes, SketchKind::CountSketch, ratio, "res");

    let (rows, cols) = (m.rows(), m.cols());
    let rr = uniform_partition(rows, nodes).range(0);
    let cr = uniform_partition(cols, nodes).range(0);
    let raw = NodeData::generate(dataset, 7, 0.25, Some(rr), Some(cr));
    let raw_bytes = raw.resident_bytes();

    let (blk, man) = CompressedBlock::load(&dir, 0).unwrap();
    let compressed_bytes = blk.resident_bytes();
    assert_eq!(blk.d_c(), man.d_c);
    // views are exactly the sketched shapes …
    assert_eq!(blk.u_view().cols(), man.d_c);
    assert_eq!(blk.v_view().cols(), man.d_r);
    // … and total residency lands near raw/R (sketch overhead is O(n))
    let bound = (raw_bytes as f64 / ratio) * 1.5;
    assert!(
        (compressed_bytes as f64) < bound,
        "compressed rank holds {compressed_bytes} bytes, raw holds {raw_bytes} — \
         expected ≈1/{ratio} ({bound} allowed)"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Unsupported combinations fail at `build()` with typed, actionable
/// errors — never a panic mid-run.
#[test]
fn unsupported_combinations_are_typed_build_errors() {
    let dir = PathBuf::from("/nonexistent/compressed"); // build() never reads it
    let data = || DataSource::Compressed(dir.clone());

    let err = Job::builder()
        .algorithm(Algo::Syn(SynOptions::default(), SecureAlgo::SynSd))
        .data(data())
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("secure"), "{err}");

    let err = Job::builder()
        .algorithm(Algo::Asyn(AsynOptions::default(), SecureAlgo::AsynSd))
        .data(data())
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("secure"), "{err}");

    let err = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions::default()))
        .data(data())
        .overlap_comm(true)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("overlap"), "{err}");

    let err = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions::default()))
        .data(data())
        .elastic(true)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("elastic"), "{err}");

    let err = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions::default()))
        .data(data())
        .checkpoint_every(5, "/tmp/ck.bin")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("checkpoint"), "{err}");

    let err = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions::default()))
        .data(data())
        .resume_from("/tmp/ck.bin")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("checkpoint"), "{err}");

    // a node-count mismatch is caught when the manifest is read
    let m = low_rank(64, 64, 3, 9);
    let cdir = compress(&m, 2, SketchKind::CountSketch, 2.0, "mm");
    let err = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions { nodes: 3, ..Default::default() }))
        .data(DataSource::Compressed(cdir.clone()))
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("built for 2 nodes"), "{err}");
    std::fs::remove_dir_all(&cdir).ok();
}
