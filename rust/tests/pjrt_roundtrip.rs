//! Integration: the AOT python→HLO→PJRT→rust path produces the same
//! numbers as the native rust solver — the three layers compose.
//!
//! Requires `make artifacts`; tests are skipped (with a loud message) when
//! the artifact directory is missing so `cargo test` alone stays green.

use dsanls::linalg::Mat;
use dsanls::rng::Pcg64;
use dsanls::runtime::{ExecInput, LocalSolver, NativeBackend, PjrtBackend, PjrtRuntime};

fn runtime() -> Option<PjrtRuntime> {
    let dir = PjrtRuntime::default_dir();
    match PjrtRuntime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed as u128, 0);
    Mat::rand_uniform(rows, cols, 1.0, &mut rng)
}

#[test]
fn cd_update_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let backend = PjrtBackend::new(rt);
    for (rows, k, d, seed) in [(128usize, 16usize, 32usize, 1u64), (256, 16, 64, 2)] {
        assert!(backend.supports(rows, k, d), "artifact r{rows}_k{k}_d{d} missing");
        let a = rand_mat(rows, d, seed);
        let b = rand_mat(k, d, seed + 10);
        let u0 = rand_mat(rows, k, seed + 20);
        for mu in [0.0f32, 1.0, 17.5] {
            let mut u_pjrt = u0.clone();
            backend.cd_update(&mut u_pjrt, &a, &b, mu).expect("pjrt path");
            let mut u_native = u0.clone();
            NativeBackend.cd_update(&mut u_native, &a, &b, mu).unwrap();
            let mut max_diff = 0.0f32;
            for (x, y) in u_pjrt.data().iter().zip(u_native.data().iter()) {
                max_diff = max_diff.max((x - y).abs());
            }
            assert!(
                max_diff < 1e-3,
                "pjrt vs native diverged: {max_diff} (r{rows} k{k} d{d} mu={mu})"
            );
            assert!(u_pjrt.is_nonnegative());
        }
    }
}

#[test]
fn pgd_artifact_matches_native_formula() {
    let Some(rt) = runtime() else { return };
    let (rows, k, d) = (128usize, 16usize, 32usize);
    let a = rand_mat(rows, d, 5);
    let b = rand_mat(k, d, 6);
    let u0 = rand_mat(rows, k, 7);
    let eta = 0.01f32;
    let outs = rt
        .execute(
            "pgd_update_r128_k16_d32",
            &[
                ExecInput::Matrix(&a),
                ExecInput::Matrix(&b),
                ExecInput::Matrix(&u0),
                ExecInput::Scalar(eta),
            ],
        )
        .expect("pgd artifact");
    let got = &outs[0];
    // native formula
    let (gram, cross) = dsanls::solvers::normal_from(&a, &b);
    let mut want = u0.clone();
    dsanls::solvers::pgd::pgd_update(
        &mut want,
        &dsanls::solvers::Normal::new(&gram, &cross),
        eta,
    );
    for (x, y) in got.data().iter().zip(want.data().iter()) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

#[test]
fn fused_sanls_step_artifact_runs() {
    let Some(rt) = runtime() else { return };
    let (rows, n, k, d) = (128usize, 256usize, 16usize, 32usize);
    let m_block = rand_mat(rows, n, 11);
    let v = rand_mat(n, k, 12);
    // gaussian sketch scaled 1/sqrt(d), matching Assumption 1
    let mut rng = Pcg64::new(13, 0);
    let s = Mat::rand_gaussian(n, d, 1.0 / (d as f32).sqrt(), rng.clone());
    let _ = &mut rng;
    let u0 = rand_mat(rows, k, 14);
    let outs = rt
        .execute(
            "sanls_u_step_r128_n256_k16_d32",
            &[
                ExecInput::Matrix(&m_block),
                ExecInput::Matrix(&v),
                ExecInput::Matrix(&s),
                ExecInput::Matrix(&u0),
                ExecInput::Scalar(2.0),
            ],
        )
        .expect("fused artifact");
    let got = &outs[0];
    assert_eq!((got.rows(), got.cols()), (rows, k));
    assert!(got.is_nonnegative());
    // must equal: native cd_update on (A = M·S, B = Vᵀ·S)
    let a = m_block.matmul(&s);
    let b = v.matmul_tn(&s); // Vᵀ·S  (k×d)
    let mut want = u0.clone();
    NativeBackend.cd_update(&mut want, &a, &b, 2.0).unwrap();
    let mut max_diff = 0.0f32;
    for (x, y) in got.data().iter().zip(want.data().iter()) {
        max_diff = max_diff.max((x - y).abs());
    }
    assert!(max_diff < 5e-3, "fused vs composed diverged: {max_diff}");
}

#[test]
fn loss_artifact_matches_native_loss() {
    let Some(rt) = runtime() else { return };
    let (rows, n, k) = (128usize, 256usize, 16usize);
    let m = rand_mat(rows, n, 21);
    let u = rand_mat(rows, k, 22);
    let v = rand_mat(n, k, 23);
    let outs = rt
        .execute(
            "nmf_loss_r128_n256_k16",
            &[ExecInput::Matrix(&m), ExecInput::Matrix(&u), ExecInput::Matrix(&v)],
        )
        .expect("loss artifact");
    let got = outs[0].get(0, 0) as f64;
    let want = dsanls::nmf::rel_error(&dsanls::linalg::Matrix::Dense(m), &u, &v);
    assert!((got - want).abs() < 1e-3, "pjrt loss {got} vs native {want}");
}
