//! The paper's quantitative claims, as executable assertions. These encode
//! the *shapes* of the evaluation section (who wins, what scales with what)
//! rather than absolute numbers — see DESIGN.md §5.

use dsanls::algos::{DistAnlsOptions, DsanlsOptions};
use dsanls::dist::CommModel;
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, DataSource, Job, Outcome};
use dsanls::rng::Pcg64;
use dsanls::sketch::{SketchKind, SketchMatrix};
use dsanls::solvers::SolverKind;

fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed as u128, 0);
    let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
    Matrix::Dense(u.matmul_nt(&v))
}

fn run_dsanls(m: &Matrix, opts: &DsanlsOptions) -> Outcome {
    Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(m))
        .run()
        .expect("dsanls job failed")
}

fn run_dist_anls(m: &Matrix, opts: &DistAnlsOptions) -> Outcome {
    Job::builder()
        .algorithm(Algo::DistAnls(opts.clone()))
        .data(DataSource::Full(m))
        .run()
        .expect("baseline job failed")
}

/// Sec. 3.3: DSANLS communication is O(kd) per iteration vs the baselines'
/// O(kn) — the measured per-node byte ratio must be ≈ n/d.
#[test]
fn communication_ratio_matches_n_over_d() {
    let (n, d, k, iters) = (400usize, 40usize, 8usize, 10usize);
    let m = low_rank(300, n, 4, 3001);
    let ds = run_dsanls(
        &m,
        &DsanlsOptions {
            nodes: 4,
            rank: k,
            iterations: iters,
            d_u: d,
            d_v: 30,
            eval_every: 0,
            ..Default::default()
        },
    );
    let base = run_dist_anls(
        &m,
        &DistAnlsOptions {
            nodes: 4,
            rank: k,
            iterations: iters,
            solver: SolverKind::Hals,
            eval_every: 0,
            ..Default::default()
        },
    );
    let ratio = base.total_bytes_sent() as f64 / ds.total_bytes_sent() as f64;
    // baseline per iteration ≈ (n+m)k gathered + 2k² reduced; DSANLS ≈ k(d_u+d_v).
    // With m=300, n=400, d_u=40, d_v=30 the predicted ratio is ≈ (700k)/(70k) = 10.
    assert!(
        ratio > 4.0,
        "DSANLS must save ≫1× communication, measured only {ratio:.2}×"
    );
}

/// Sec. 3.6.1: DSANLS per-iteration *compute* is O(kd(m/N + k)) vs
/// O(kn(m/N + k)) — on a compute-dominated configuration (zero-cost
/// network) the measured speedup must be substantial.
#[test]
fn compute_speedup_on_free_network() {
    let free = CommModel { latency: 0.0, bandwidth: f64::INFINITY };
    let m = low_rank(1200, 800, 8, 3003);
    let (d, k) = (80usize, 16usize);
    let ds = run_dsanls(
        &m,
        &DsanlsOptions {
            nodes: 4,
            rank: k,
            iterations: 6,
            sketch: SketchKind::Subsample,
            d_u: d,
            d_v: 120,
            eval_every: 0,
            comm: free,
            ..Default::default()
        },
    );
    let hals = run_dist_anls(
        &m,
        &DistAnlsOptions {
            nodes: 4,
            rank: k,
            iterations: 6,
            solver: SolverKind::Hals,
            eval_every: 0,
            comm: free,
            ..Default::default()
        },
    );
    let speedup = hals.sec_per_iter / ds.sec_per_iter;
    assert!(
        speedup > 1.5,
        "subsampled DSANLS should be ≫1× faster per iteration (got {speedup:.2}×, n/d = {})",
        800 / d
    );
}

/// Sec. 5.2.2 / Fig. 3: ANLS/BPP has the highest per-iteration cost of the
/// baselines once k is nontrivial (its per-row solve is O(k³)).
#[test]
fn bpp_is_the_most_expensive_baseline() {
    let m = low_rank(300, 200, 8, 3005);
    let run = |solver| {
        run_dist_anls(
            &m,
            &DistAnlsOptions {
                nodes: 2,
                rank: 32,
                iterations: 4,
                solver,
                eval_every: 0,
                ..Default::default()
            },
        )
        .sec_per_iter
    };
    let t_mu = run(SolverKind::Mu);
    let t_hals = run(SolverKind::Hals);
    let t_bpp = run(SolverKind::AnlsBpp);
    assert!(
        t_bpp > t_mu && t_bpp > t_hals,
        "BPP must be slowest: mu={t_mu:.5} hals={t_hals:.5} bpp={t_bpp:.5}"
    );
}

/// Sec. 3.4: gaussian sketch converges at least as well per *iteration* as
/// subsampling (more informative columns), while subsampling is cheaper
/// per iteration.
#[test]
fn gaussian_informative_subsample_cheap() {
    let m = low_rank(400, 300, 6, 3007);
    let run = |sketch| {
        run_dsanls(
            &m,
            &DsanlsOptions {
                nodes: 2,
                rank: 6,
                iterations: 25,
                sketch,
                d_u: 30,
                d_v: 40,
                eval_every: 0,
                ..Default::default()
            },
        )
    };
    let g = run(SketchKind::Gaussian);
    let s = run(SketchKind::Subsample);
    // per-iteration convergence: gaussian within (or better than) ~25 % of
    // subsample's final error after the same iteration count
    assert!(
        g.final_error() < s.final_error() * 1.25,
        "gaussian {} vs subsample {}",
        g.final_error(),
        s.final_error()
    );
    // cost: subsample strictly cheaper per iteration
    assert!(
        s.sec_per_iter < g.sec_per_iter,
        "subsample {} vs gaussian {} per-iteration",
        s.sec_per_iter,
        g.sec_per_iter
    );
}

/// Assumption 2 footing: iterates stay bounded along the run (the paper
/// observes this "as long as the step sizes used are not too large").
#[test]
fn iterates_stay_bounded() {
    let m = low_rank(100, 80, 4, 3009);
    let bound = (2.0 * m.fro_sq().sqrt()).sqrt() as f32; // Eq. 22 box bound
    let run = run_dsanls(
        &m,
        &DsanlsOptions {
            nodes: 2,
            rank: 4,
            iterations: 60,
            d_u: 20,
            d_v: 25,
            eval_every: 0,
            ..Default::default()
        },
    );
    assert!(!run.u.has_non_finite() && !run.v.has_non_finite());
    assert!(
        run.u.max_abs() <= bound * 10.0,
        "U grew unboundedly: {} vs box bound {}",
        run.u.max_abs(),
        bound
    );
}

/// Eq. 16: the sketched gradient is an unbiased estimator of the true
/// gradient — verified empirically over many sketch draws.
#[test]
fn sketched_gradient_is_unbiased() {
    let mut rng = Pcg64::new(3011, 0);
    let m = Mat::rand_uniform(20, 30, 1.0, &mut rng);
    let u = Mat::rand_uniform(20, 4, 1.0, &mut rng);
    let v = Mat::rand_uniform(30, 4, 1.0, &mut rng);
    // true gradient: 2(UVᵀ − M)V
    let resid = {
        let mut r = u.matmul_nt(&v);
        r.axpy(-1.0, &m);
        r
    };
    let g_true = resid.matmul(&v);

    let trials = 800;
    let mut g_acc = Mat::zeros(20, 4);
    for t in 0..trials {
        let mut srng = Pcg64::new(4000 + t as u128, 2);
        let s = SketchMatrix::generate(SketchKind::Subsample, 30, 6, &mut srng);
        // sketched gradient: (U(VᵀS) − MS)(VᵀS)ᵀ = resid·S·SᵀV
        let ms = s.mul_right_dense(&resid);
        let vs = s.mul_rows_tn(&v, 0); // k×d
        let g_sketch = ms.matmul_nt(&vs);
        g_acc.axpy(1.0 / trials as f32, &g_sketch);
    }
    // mean sketched gradient ≈ true gradient (law of large numbers)
    let rel = g_acc.dist_sq(&g_true).sqrt() / g_true.fro_sq().sqrt().max(1e-9);
    assert!(rel < 0.2, "sketched gradient biased: rel dev {rel}");
}
