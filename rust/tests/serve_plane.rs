//! Serving-plane integration tests: checkpoint loading error paths,
//! fold-in bit-identity against the training-loop reference solve, and
//! the full TCP query path (`serve()` ↔ [`ServeClient`]) — batched top-k,
//! reconstruction, fold-in with its LRU cache, stats, and typed error
//! replies, including under concurrent clients.

use std::path::PathBuf;

use dsanls::linalg::{Csr, Mat, Matrix};
use dsanls::nmf::control::{
    read_checkpoint, write_checkpoint, Checkpoint, CheckpointMeta, ResumeState,
};
use dsanls::nmf::update_unsketched;
use dsanls::rng::Pcg64;
use dsanls::serve::{serve, FactorModel, FoldIn, ServeClient, ServeOptions, FOLD_IN_INIT};
use dsanls::solvers::{SolverKind, Workspace};
use dsanls::testkit::Runner;

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsanls_serve_{tag}_{}.ckpt", std::process::id()))
}

fn meta(users: usize, items: usize, k: usize) -> CheckpointMeta {
    CheckpointMeta {
        algo: "dsanls".into(),
        seed: 7,
        k,
        rows: users,
        cols: items,
        params: 0xFEED,
    }
}

fn toy_checkpoint(users: usize, items: usize, k: usize, seed: u128) -> Checkpoint {
    let mut rng = Pcg64::new(seed, 0);
    let u = Mat::rand_uniform(users, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(items, k, 1.0, &mut rng);
    Checkpoint { meta: meta(users, items, k), state: ResumeState { iteration: 9, u, v } }
}

fn toy_model(users: usize, items: usize, k: usize, seed: u128) -> FactorModel {
    FactorModel::from_checkpoint(toy_checkpoint(users, items, k, seed))
}

// ---------------------------------------------------------------------------
// Checkpoint → model error paths
// ---------------------------------------------------------------------------

#[test]
fn model_load_surfaces_checkpoint_corruption_as_typed_errors() {
    let path = tmpfile("corrupt");
    let ck = toy_checkpoint(6, 9, 3, 0xC0DE);
    write_checkpoint(&path, &ck.meta, ck.state.iteration, &ck.state.u, &ck.state.v).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // every strict prefix must fail (header, factor data, missing footer)
    Runner::new("serve_truncated_checkpoint", 32).run(|g| {
        let cut = g.usize_in(0, bytes.len() - 1);
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = FactorModel::load(&path).unwrap_err().to_string();
        assert!(
            err.contains("loading factor model from"),
            "cut at {cut}: serving context missing from {err:?}"
        );
    });

    // bad magic
    let mut b = bytes.clone();
    b[0] ^= 0xFF;
    std::fs::write(&path, &b).unwrap();
    let err = FactorModel::load(&path).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // format-version mismatch (version u32 sits right after the 8-byte magic)
    let mut b = bytes.clone();
    b[8] = b[8].wrapping_add(1);
    std::fs::write(&path, &b).unwrap();
    let err = FactorModel::load(&path).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // intact file loads, and the identity gate catches mismatched runs
    std::fs::write(&path, &bytes).unwrap();
    let model = FactorModel::load(&path).unwrap();
    assert_eq!((model.users(), model.items(), model.k()), (6, 9, 3));
    model.check_identity(Some("dsanls"), Some(0xFEED)).unwrap();
    let err = model.check_identity(Some("dist-anls"), None).unwrap_err().to_string();
    assert!(err.contains("dsanls") && err.contains("dist-anls"), "{err}");
    let err = model.check_identity(None, Some(0xBAD)).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "{err}");
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Fold-in bit-identity vs the training-loop reference
// ---------------------------------------------------------------------------

#[test]
fn fold_in_is_bit_identical_to_fixed_v_reference_solve() {
    // reference: one update_unsketched step on a 1×items sparse row with V
    // fixed — exactly what the training loop would do for a single new row
    dsanls::parallel::set_local_threads(Some(1));
    Runner::new("serve_fold_in_bit_identity", 24).run(|g| {
        let items = g.usize_in(5, 40);
        let k = g.usize_in(2, 8);
        let nnz = g.usize_in(1, items);
        let sweeps = g.usize_in(1, 4);
        let t = g.usize_in(0, 3);
        let solver = *g.choose(&[SolverKind::Hals, SolverKind::ProximalCd, SolverKind::Pgd]);
        let model = toy_model(4, items, k, g.seed() as u128);

        // duplicate-free sparse row (distinct item ids)
        let mut row: Vec<(usize, f32)> = Vec::new();
        for i in 0..nnz {
            let j = (i * items) / nnz; // distinct, ascending
            row.push((j, g.f32_in(0.1, 3.0)));
        }

        let mut fold = FoldIn::new();
        let w = fold.solve(&model, &row, solver, sweeps, t).unwrap().to_vec();

        let triplets: Vec<(usize, usize, f32)> =
            row.iter().map(|&(j, v)| (0, j, v)).collect();
        let m = Matrix::Sparse(Csr::from_triplets(1, items, triplets));
        let mut x_ref = Mat::zeros(1, k);
        x_ref.data_mut().fill(FOLD_IN_INIT);
        let mut ws = Workspace::new();
        update_unsketched(&mut x_ref, &m, model.v(), solver, t, sweeps, &mut ws);

        assert_eq!(
            w,
            x_ref.data().to_vec(),
            "fold-in diverged from the fixed-V reference (items={items} k={k} nnz={nnz} \
             sweeps={sweeps} t={t} solver={solver:?})"
        );
    });
    dsanls::parallel::set_local_threads(None);
}

// ---------------------------------------------------------------------------
// TCP end-to-end
// ---------------------------------------------------------------------------

fn local_top_k(model: &FactorModel, user: u64, n: usize) -> Vec<(u64, f32)> {
    let (mut w, mut scores) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
    model.scores_into(&[user], &mut w, &mut scores).unwrap();
    let mut out = Vec::new();
    dsanls::serve::top_n(scores.row(0), n, &mut out);
    out.into_iter().map(|(i, s)| (i as u64, s)).collect()
}

#[test]
fn serve_answers_queries_over_tcp_from_a_real_checkpoint() {
    let path = tmpfile("e2e");
    let ck = toy_checkpoint(10, 16, 4, 0xE2E);
    write_checkpoint(&path, &ck.meta, ck.state.iteration, &ck.state.u, &ck.state.v).unwrap();
    let model = FactorModel::load(&path).unwrap();
    let reference = model.clone();
    let opts = ServeOptions { batch_wait_us: 0, ..ServeOptions::default() };
    let solver = opts.solver;
    let sweeps = opts.sweeps;
    let mut handle = serve("127.0.0.1:0", model, opts).unwrap();
    let mut client = ServeClient::connect(&handle.addr().to_string()).unwrap();

    // top-k matches the locally computed selection exactly
    let rows = client.top_k(&[3, 0, 7], 5).unwrap();
    assert_eq!(rows.len(), 3);
    for (row, &user) in rows.iter().zip(&[3u64, 0, 7]) {
        assert_eq!(row, &local_top_k(&reference, user, 5), "user {user}");
    }

    // reconstruction is the exact score rows
    let scores = client.reconstruct(&[2, 5]).unwrap();
    assert_eq!((scores.rows(), scores.cols()), (2, 16));
    let (mut w, mut want) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
    reference.scores_into(&[2, 5], &mut w, &mut want).unwrap();
    assert_eq!(scores.data(), want.data());

    // fold-in matches a local solve with the server's options bit-for-bit,
    // and its top list is consistent with the returned embedding
    let entries: Vec<(u64, f32)> = vec![(1, 2.0), (8, 0.5), (15, 1.25)];
    let (emb, top) = client.fold_in(&entries, 4).unwrap();
    let local_row: Vec<(usize, f32)> =
        entries.iter().map(|&(i, v)| (i as usize, v)).collect();
    let mut fold = FoldIn::new();
    let local = fold.solve(&reference, &local_row, solver, sweeps, 0).unwrap();
    assert_eq!(emb, local.to_vec());
    assert_eq!(top.len(), 4);
    let mut fw = Mat::zeros(1, emb.len());
    fw.data_mut().copy_from_slice(&emb);
    let mut fscores = Mat::zeros(0, 0);
    reference.scores_for_w(&fw, &mut fscores);
    let mut expect_top = Vec::new();
    dsanls::serve::top_n(fscores.row(0), 4, &mut expect_top);
    let expect_top: Vec<(u64, f32)> =
        expect_top.into_iter().map(|(i, s)| (i as u64, s)).collect();
    assert_eq!(top, expect_top);

    // the identical row again: served from the LRU cache, same embedding
    let (emb2, _) = client.fold_in(&entries, 0).unwrap();
    assert_eq!(emb2, emb);
    // order-insensitive key: a permuted row hits the same cache entry
    let shuffled: Vec<(u64, f32)> = vec![(15, 1.25), (1, 2.0), (8, 0.5)];
    let (emb3, _) = client.fold_in(&shuffled, 0).unwrap();
    assert_eq!(emb3, emb);

    // typed errors surface through the client
    let err = client.top_k(&[999], 3).unwrap_err().to_string();
    assert!(err.contains("unknown user id 999"), "{err}");
    let err = client.fold_in(&[(99, 1.0)], 0).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");

    // stats reflect the traffic (one solve, two cache hits, the errors)
    let stats = client.stats().unwrap();
    let json = dsanls::metrics::JsonValue::parse(&stats).unwrap();
    let num = |k: &str| json.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    assert_eq!(num("fold_in_solves"), 1.0, "{stats}");
    assert_eq!(num("cache_hits"), 2.0, "{stats}");
    assert_eq!(num("errors"), 2.0, "{stats}");
    assert!(num("queries") >= 8.0, "{stats}");
    assert!(num("latency_p50_ms") >= 0.0, "{stats}");

    handle.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn concurrent_clients_coalesce_and_get_their_own_answers() {
    let reference = toy_model(24, 12, 3, 0xBA7C);
    let opts = ServeOptions { batch_wait_us: 2_000, ..ServeOptions::default() };
    let mut handle = serve("127.0.0.1:0", reference.clone(), opts).unwrap();
    let addr = handle.addr().to_string();

    let mut threads = Vec::new();
    for c in 0..6u64 {
        let addr = addr.clone();
        let reference = reference.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = ServeClient::connect(&addr).unwrap();
            for round in 0..5u64 {
                let user = (c * 4 + round) % 24;
                let got = client.top_k(&[user], 3).unwrap();
                assert_eq!(got[0], local_top_k(&reference, user, 3), "client {c} user {user}");
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }

    let json = handle.metrics_json();
    let num = |k: &str| json.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    assert_eq!(num("queries"), 30.0);
    assert_eq!(num("errors"), 0.0);
    assert_eq!(num("rows_scored"), 30.0);
    assert!(num("batches") >= 1.0);
    handle.shutdown();
}
