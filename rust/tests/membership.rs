//! Deterministic chaos harness for elastic membership epochs.
//!
//! The contract under test: when a rank dies mid-run on an elastic job, the
//! survivors quiesce at the next iteration boundary, a replacement re-joins
//! the collective via the epoch handshake, the boundary state is replayed,
//! and the finished run is **bit-identical** to a run that was never
//! interrupted — with `Outcome::retries == 0` (nobody restarted) and
//! `Outcome::epochs` counting the membership rebuilds.
//!
//! Every kill is scripted through `FaultPlan`, so each case is a pure
//! function of (algorithm, victim rank, kill iteration) and replays
//! identically under `--test-threads` pinning in CI.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dsanls::algos::{DistAnlsOptions, DsanlsOptions};
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, Backend, DataSource, Job, Outcome};
use dsanls::rng::Pcg64;
use dsanls::secure::{AsynOptions, SecureAlgo, SynOptions};
use dsanls::transport::{FaultPlan, SimCluster, SimComm};

fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed as u128, 0);
    let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
    Matrix::Dense(u.matmul_nt(&v))
}

const NODES: usize = 3;

/// The three synchronous families (elastic membership is a synchronous
/// protocol; the asynchronous parameter server is rejected at build).
fn sync_algos() -> Vec<(&'static str, Algo)> {
    let dsanls = DsanlsOptions {
        nodes: NODES,
        rank: 3,
        iterations: 4,
        d_u: 8,
        d_v: 8,
        eval_every: 2,
        ..Default::default()
    };
    let hals = DistAnlsOptions {
        nodes: NODES,
        rank: 3,
        iterations: 4,
        eval_every: 2,
        ..Default::default()
    };
    let syn = SynOptions {
        nodes: NODES,
        rank: 3,
        t1: 2,
        t2: 2,
        d1: 8,
        d2: 4,
        d3: 8,
        eval_every: 0,
        ..Default::default()
    };
    vec![
        ("dsanls", Algo::Dsanls(dsanls)),
        ("dist-anls", Algo::DistAnls(hals)),
        ("syn-sd", Algo::Syn(syn, SecureAlgo::SynSd)),
    ]
}

fn baseline(algo: &Algo, m: &Matrix) -> Outcome {
    Job::builder()
        .algorithm(algo.clone())
        .data(DataSource::Full(m))
        .run()
        .unwrap_or_else(|e| panic!("baseline {algo:?}: {e}"))
}

fn chaos(algo: &Algo, m: &Matrix, plan: FaultPlan, label: &str) -> Outcome {
    Job::builder()
        .algorithm(algo.clone())
        .data(DataSource::Full(m))
        .elastic(true)
        .fault_plan(plan)
        .run()
        .unwrap_or_else(|e| panic!("{label}: {e}"))
}

/// Factors and the traced error sequence must match bit for bit. The
/// modelled clock is NOT compared: the rolled-back iteration is computed
/// twice (once by the victim, once replayed after recovery), so the
/// recovered run legitimately reports more simulated time.
fn assert_bit_identical(out: &Outcome, base: &Outcome, label: &str) {
    assert_eq!(out.u.data(), base.u.data(), "{label}: U diverged from the uninterrupted run");
    assert_eq!(out.v.data(), base.v.data(), "{label}: V diverged from the uninterrupted run");
    let errs = |o: &Outcome| -> Vec<(usize, u64)> {
        o.trace.iter().map(|p| (p.iteration, p.rel_error.to_bits())).collect()
    };
    assert_eq!(errs(out), errs(base), "{label}: error trace diverged");
}

/// The full chaos matrix: every synchronous family × every victim rank ×
/// kill iterations {1, 3}. Each re-joined run must be bit-identical to the
/// uninterrupted baseline, with exactly one membership rebuild and zero
/// cluster restarts.
#[test]
fn chaos_kill_each_rank_rejoined_run_bit_identical() {
    let m = low_rank(48, 36, 3, 4242);
    for (name, algo) in sync_algos() {
        let base = baseline(&algo, &m);
        assert_eq!(base.epochs, 1, "{name}: uninterrupted run grew epochs");
        for victim in 0..NODES {
            for kill_at in [1usize, 3] {
                let label = format!("{name}: kill rank {victim} at iteration {kill_at}");
                let out = chaos(&algo, &m, FaultPlan::new().kill(victim, kill_at), &label);
                assert_eq!(out.epochs, 2, "{label}: expected exactly one rebuild");
                assert_eq!(out.retries, 0, "{label}: recovery must not restart the job");
                assert_bit_identical(&out, &base, &label);
            }
        }
    }
}

/// Two scripted deaths in one run: the second victim dies after the first
/// replacement has been admitted. Two rebuilds, still bit-identical.
#[test]
fn chaos_two_kills_two_rebuilds() {
    let m = low_rank(48, 36, 3, 4242);
    let (name, algo) = sync_algos().remove(0);
    let base = baseline(&algo, &m);
    let label = format!("{name}: kill rank 0 at 1, then rank 2 at 3");
    let plan = FaultPlan::new().kill(0, 1).kill(2, 3);
    let out = chaos(&algo, &m, plan, &label);
    assert_eq!(out.epochs, 3, "{label}: expected two rebuilds");
    assert_eq!(out.retries, 0, "{label}");
    assert_bit_identical(&out, &base, &label);
}

/// With elastic membership on but no faults scripted, the boundary-state
/// replication must be bit-transparent: identical factors, single epoch.
#[test]
fn elastic_without_faults_is_transparent() {
    let m = low_rank(48, 36, 3, 4242);
    for (name, algo) in sync_algos() {
        let base = baseline(&algo, &m);
        let out = Job::builder()
            .algorithm(algo.clone())
            .data(DataSource::Full(&m))
            .elastic(true)
            .run()
            .unwrap_or_else(|e| panic!("{name} elastic, no faults: {e}"));
        assert_eq!(out.epochs, 1, "{name}: no fault, no rebuild");
        assert_bit_identical(&out, &base, &format!("{name}: elastic no-fault"));
    }
}

/// Sim-vs-TCP mirror: a chaos-recovered run on the simulated backend must
/// agree bit for bit with an uninterrupted run over real TCP sockets — the
/// recovery path lands on exactly the state the wire protocol computes.
#[test]
fn chaos_recovered_sim_matches_uninterrupted_tcp() {
    let m = low_rank(48, 36, 3, 4242);
    let (name, algo) = sync_algos().remove(0);
    let tcp = Job::builder()
        .algorithm(algo.clone())
        .data(DataSource::Full(&m))
        .transport(Backend::Tcp { port: 0 })
        .run()
        .unwrap_or_else(|e| panic!("{name} tcp baseline: {e}"));
    let label = format!("{name}: chaos sim vs clean tcp");
    let out = chaos(&algo, &m, FaultPlan::new().kill(1, 2), &label);
    assert_eq!(out.epochs, 2, "{label}");
    assert_eq!(out.u.data(), tcp.u.data(), "{label}: U diverged");
    assert_eq!(out.v.data(), tcp.v.data(), "{label}: V diverged");
}

/// Epoch-handshake misuse surfaces as typed errors, promptly — no case may
/// hang the caller. (The wire-level twins — stale epoch numbers and mixed
/// wire versions at the TCP join handshake — are covered by the transport
/// unit tests; this exercises the public `SimComm::join` surface.)
#[test]
fn join_misuse_is_typed_and_prompt() {
    let started = Instant::now();

    // Joining a slot whose incumbent is alive is a double-join.
    let cluster = SimCluster::new(2);
    let err = SimComm::join(&cluster, 0).unwrap_err();
    assert!(err.to_string().contains("double-join"), "alive slot: {err}");

    // Out-of-range ranks cannot claim a slot at all.
    let err = SimComm::join(&cluster, 7).unwrap_err();
    assert!(err.to_string().contains("cannot join as rank 7"), "{err}");

    // A rank that finished cleanly cannot be re-joined.
    let finished = SimCluster::new(2);
    drop(SimComm::new(0, Arc::clone(&finished)));
    let err = SimComm::join(&finished, 0).unwrap_err();
    assert!(err.to_string().contains("nothing to re-join"), "{err}");

    // A dead slot with no surviving rank ever rebuilding: the join times
    // out with a typed error instead of blocking forever, and releases
    // its claim so a later join may retry.
    let orphan = SimCluster::new(2);
    let _ = catch_unwind(AssertUnwindSafe(|| {
        let _dying = SimComm::new(1, Arc::clone(&orphan));
        panic!("scripted death");
    }));
    orphan.set_rejoin_timeout(Duration::from_millis(50));
    let err = SimComm::join(&orphan, 1).unwrap_err();
    assert!(err.to_string().contains("timed out"), "{err}");

    // The timeout released the claim: a concurrent pair now races for the
    // slot — the loser sees a typed double-join, not a deadlock.
    orphan.set_rejoin_timeout(Duration::from_millis(400));
    let c2 = Arc::clone(&orphan);
    let racer = std::thread::spawn(move || SimComm::join(&c2, 1).map(|_| ()));
    std::thread::sleep(Duration::from_millis(100));
    let err = SimComm::join(&orphan, 1).unwrap_err();
    assert!(err.to_string().contains("double-join"), "racing joiner: {err}");
    // The first joiner still times out cleanly (no survivors to admit it).
    let first = racer.join().expect("joiner thread panicked").unwrap_err();
    assert!(first.to_string().contains("timed out"), "{first}");

    assert!(
        started.elapsed() < Duration::from_secs(10),
        "join misuse must fail fast, took {:?}",
        started.elapsed()
    );
}

/// Elastic misuse is rejected when the job is built, with errors that name
/// the conflicting knob.
#[test]
fn builder_rejects_elastic_misuse() {
    let m = low_rank(48, 36, 3, 4242);
    let sync = sync_algos().remove(0).1;

    // A fault plan without elastic membership would just kill the job.
    let err = Job::builder()
        .algorithm(sync.clone())
        .data(DataSource::Full(&m))
        .fault_plan(FaultPlan::new().kill(0, 1))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains(".elastic(true)"), "{err}");

    // min_ranks is an elastic-only knob…
    let err = Job::builder()
        .algorithm(sync.clone())
        .data(DataSource::Full(&m))
        .min_ranks(2)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("min_ranks"), "{err}");

    // …and must fit the cluster.
    let err = Job::builder()
        .algorithm(sync.clone())
        .data(DataSource::Full(&m))
        .elastic(true)
        .min_ranks(9)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("1..="), "{err}");

    // In-process TCP elasticity is a launch-CLI feature, not a Job one.
    let err = Job::builder()
        .algorithm(sync)
        .data(DataSource::Full(&m))
        .transport(Backend::Tcp { port: 0 })
        .elastic(true)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("launch --elastic"), "{err}");

    // The asynchronous parameter server has no iteration boundary to
    // quiesce at.
    let asyn = Algo::Asyn(
        AsynOptions { nodes: 2, rank: 3, rounds: 3, local_iters: 2, d1: 8, ..Default::default() },
        SecureAlgo::AsynSd,
    );
    let err = Job::builder()
        .algorithm(asyn)
        .data(DataSource::Full(&m))
        .elastic(true)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("synchronous"), "{err}");
}
