//! Residency audit for the shard data plane: a worker building its rank's
//! [`dsanls::data::NodeData`] must never hold a full-matrix-sized buffer.
//!
//! Two assertions back the claim:
//!
//! 1. **Dimension checks** — every resident block is exactly the rank's
//!    partition slice (`rows/N × cols` and `rows × cols/N`), for every
//!    dataset.
//! 2. **Peak live heap** — a peak-tracking global allocator measures the
//!    high-water mark of live bytes during shard-local generation and
//!    compares it against full-matrix generation of the same dataset: at
//!    `N = 8` the shard build must peak well under half of the full
//!    build's peak (the blocks themselves are 2/8 of the matrix; the
//!    remainder is factor-sized scratch).
//!
//! Single test in this file: the global counter must not see concurrent
//! unrelated allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicIsize, Ordering};

use dsanls::data::partition::uniform_partition;
use dsanls::data::shard::NodeData;
use dsanls::data::{Dataset, ALL_DATASETS};

struct PeakAlloc;

static LIVE: AtomicIsize = AtomicIsize::new(0);
static PEAK: AtomicIsize = AtomicIsize::new(0);
static TRACKING: AtomicBool = AtomicBool::new(false);

fn on_alloc(size: usize) {
    if TRACKING.load(Ordering::Relaxed) {
        let live = LIVE.fetch_add(size as isize, Ordering::Relaxed) + size as isize;
        PEAK.fetch_max(live, Ordering::Relaxed);
    }
}

fn on_dealloc(size: usize) {
    if TRACKING.load(Ordering::Relaxed) {
        LIVE.fetch_sub(size as isize, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        on_dealloc(layout.size());
        on_alloc(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        on_dealloc(layout.size());
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: PeakAlloc = PeakAlloc;

/// Run `f` and return the peak live heap bytes it reached (relative to
/// entry — frees of pre-existing buffers can drive LIVE negative, which
/// only makes the measurement conservative).
fn measure_peak<T>(f: impl FnOnce() -> T) -> (T, usize) {
    LIVE.store(0, Ordering::SeqCst);
    PEAK.store(0, Ordering::SeqCst);
    TRACKING.store(true, Ordering::SeqCst);
    let out = f();
    TRACKING.store(false, Ordering::SeqCst);
    let peak = PEAK.load(Ordering::SeqCst).max(0) as usize;
    (out, peak)
}

#[test]
fn shard_generation_peaks_at_block_size_not_matrix_size() {
    // single-threaded so GEMM scratch is one thread's, and warmed up below
    dsanls::parallel::set_local_threads(Some(1));
    let nodes = 8usize;

    // -- dimension checks across every dataset (cheap, tiny scale) --
    for d in ALL_DATASETS {
        let (rows, cols) = d.scaled_shape(0.02);
        for rank in [0usize, nodes - 1] {
            let rr = uniform_partition(rows, nodes).range(rank);
            let cr = uniform_partition(cols, nodes).range(rank);
            let data = NodeData::generate(d, 7, 0.02, Some(rr.clone()), Some(cr.clone()));
            let rb = data.require_rows();
            let cb = data.require_cols();
            assert_eq!((rb.rows(), rb.cols()), (rr.len(), cols), "{:?} row block dims", d);
            assert_eq!((cb.rows(), cb.cols()), (rows, cr.len()), "{:?} col block dims", d);
            assert!(
                data.resident_bytes() < rows * cols * 4 / 2,
                "{:?}: resident {} bytes vs full {}",
                d,
                data.resident_bytes(),
                rows * cols * 4
            );
        }
    }

    // -- peak-heap comparison on the dense FACE dataset at full scale --
    let dataset = Dataset::Face;
    let (rows, cols) = dataset.scaled_shape(1.0);
    let rr = uniform_partition(rows, nodes).range(0);
    let cr = uniform_partition(cols, nodes).range(0);
    let (rr_len, cr_len) = (rr.len(), cr.len());

    // warm up thread-local GEMM packing scratch so it doesn't count
    let _ = NodeData::generate(dataset, 7, 0.05, Some(0..64), Some(0..64));

    let (full, full_peak) = measure_peak(|| dataset.generate_scaled(7, 1.0));
    let full_bytes = full.rows() * full.cols() * 4;
    drop(full);

    let (shard, shard_peak) =
        measure_peak(|| NodeData::generate(dataset, 7, 1.0, Some(rr), Some(cr)));

    // the rank holds one row block + one col block ≈ 2/N of the matrix
    // (ceil-partitioned), far below the full matrix
    let block_bytes = 4 * (rr_len * cols + rows * cr_len);
    assert_eq!(shard.resident_bytes(), block_bytes, "resident bytes must be exactly the blocks");
    assert!(
        block_bytes < full_bytes / 2,
        "blocks ({block_bytes} bytes) should be far below the {full_bytes} byte matrix"
    );
    assert!(
        shard_peak < full_peak / 2,
        "shard-local generation peaked at {shard_peak} bytes — not meaningfully below the \
         full-matrix build's {full_peak} bytes (blocks are 2/{nodes} of the matrix)"
    );

    dsanls::parallel::set_local_threads(None);
}
