//! Steady-state allocation audit for the overlapped (double-buffered)
//! DSANLS pipeline.
//!
//! The perf contract of the comm/compute-overlap rework: once warmed up,
//! one pipelined iteration — summand build via
//! [`SketchMatrix::mul_rows_tn_into`], prefetched `A_r = M_r · Sᵀ` via
//! [`SketchMatrix::mul_right_dense_into`], the take/restore ping-pong on
//! [`Workspace::take_pipe`] / [`Workspace::take_summand`], and the
//! normal-equation + solver step — performs **zero heap allocations**. The
//! Subsample sketch (the paper's default, `dsanls-s`) is the audited
//! family; sketch *regeneration* (a d-length index draw per iteration) is
//! outside the pipeline buffers and outside this audit. A counting global
//! allocator verifies the claim.
//!
//! Single-threaded (`set_local_threads(Some(1))`) so the measurement
//! captures the kernels rather than pool-dispatch bookkeeping; the single
//! `#[test]` keeps the harness from running anything else against the
//! global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use dsanls::linalg::Mat;
use dsanls::nmf::MuSchedule;
use dsanls::rng::Pcg64;
use dsanls::sketch::{SketchKind, SketchMatrix};
use dsanls::solvers::{self, SolverKind, Workspace};

struct CountingAlloc;

static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn pipelined_iteration_steady_state_allocates_nothing() {
    dsanls::parallel::set_local_threads(Some(1));

    // one rank's U-step shapes: M_r (rows×cols), V_block (cols×k), d-wide sketch
    let (rows, cols, k, d) = (240usize, 180usize, 12usize, 32usize);
    let mut rng = Pcg64::new(0xF1FE11, 0);
    let m_block = Mat::rand_uniform(rows, cols, 1.0, &mut rng);
    let v_block = Mat::rand_uniform(cols, k, 1.0, &mut rng);
    let mut u = Mat::rand_uniform(rows, k, 1.0, &mut rng);
    let mu = MuSchedule::default();
    let s_u = SketchMatrix::generate(SketchKind::Subsample, cols, d, &mut rng);

    let mut ws = Workspace::new();

    // one pipelined iteration body, as dsanls runs it with overlap on:
    // build the reduce summand, compute the prefetched A_r into a pipe
    // slot (in the real loop this happens behind the in-flight reduce),
    // then solve and hand every buffer back to the workspace
    let iteration = |ws: &mut Workspace, u: &mut Mat, t: usize| {
        let mut summand = ws.take_summand();
        s_u.mul_rows_tn_into(&v_block, 0, &mut summand);
        let mut a_r = ws.take_pipe(0);
        s_u.mul_right_dense_into(&m_block, &mut a_r);
        {
            let nrm = ws.normal_from(&a_r, &summand);
            solvers::update_auto(SolverKind::ProximalCd, u, &nrm, &mu, t);
        }
        ws.restore_pipe(0, a_r);
        ws.restore_summand(summand);
    };

    // warm-up: sizes the pipe/summand buffers and the workspace scratch
    for t in 0..3 {
        iteration(&mut ws, &mut u, t);
    }
    let ptrs = ws.pipeline_ptrs();

    // measured steady state
    ALLOC_EVENTS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for t in 3..13 {
        iteration(&mut ws, &mut u, t);
    }
    COUNTING.store(false, Ordering::SeqCst);
    let events = ALLOC_EVENTS.load(Ordering::SeqCst);

    assert_eq!(
        events, 0,
        "steady-state pipelined iteration performed {events} heap allocations \
         over 10 iterations (expected 0)"
    );
    // the ping-pong buffers must have been reused, not reallocated
    assert_eq!(ws.pipeline_ptrs(), ptrs, "pipeline buffers were reallocated in steady state");

    assert!(u.is_nonnegative());
    dsanls::parallel::set_local_threads(None);
}
