//! Property-based invariants via testkit (proptest-lite): randomized
//! shapes/seeds over the core substrate and coordination primitives.

use dsanls::dist::{run_cluster, CommModel};
use dsanls::linalg::{Csr, Mat, Matrix};
use dsanls::nmf::rel_error;
use dsanls::parallel;
use dsanls::sketch::{SketchKind, SketchMatrix};
use dsanls::solvers::{self, Normal, SolverKind};
use dsanls::testkit::Runner;

#[test]
fn prop_partition_covers_everything() {
    Runner::new("partition-coverage", 64).run(|g| {
        let total = g.usize_in(0, 5000);
        let nodes = g.usize_in(1, 16);
        let skew = g.f32_in(0.0, 0.9) as f64;
        let p = if g.bool() {
            dsanls::data::uniform_partition(total, nodes)
        } else {
            dsanls::data::imbalanced_partition(total, nodes, skew)
        };
        assert!(p.validate(), "partition must tile 0..{total} over {nodes}");
        let sum: usize = (0..nodes).map(|r| p.len(r)).sum();
        assert_eq!(sum, total);
    });
}

#[test]
fn prop_all_reduce_equals_serial_sum() {
    Runner::new("all-reduce-sum", 24).run(|g| {
        let nodes = g.usize_in(1, 8);
        let len = g.usize_in(1, 200);
        let seed = g.seed();
        let results = run_cluster(nodes, CommModel::default(), |ctx| {
            let mut rng = dsanls::rng::Pcg64::new(seed as u128, ctx.rank as u128);
            let mine: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
            let mut buf = mine.clone();
            ctx.all_reduce_sum(&mut buf);
            (mine, buf)
        });
        // serial reference in rank order (the deterministic contract)
        let mut expect = vec![0.0f32; len];
        for (mine, _) in &results {
            for (e, v) in expect.iter_mut().zip(mine.iter()) {
                *e += v;
            }
        }
        for (_, reduced) in &results {
            assert_eq!(reduced, &expect, "all-reduce must equal serial rank-ordered sum");
        }
    });
}

#[test]
fn prop_gemm_transpose_identities() {
    Runner::new("gemm-identities", 24).run(|g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 20);
        let n = g.usize_in(1, 40);
        let mut rng = g.rng();
        let a = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let b = Mat::rand_uniform(k, n, 1.0, &mut rng);
        let nn = a.matmul(&b);
        let nt = a.matmul_nt(&b.transpose());
        let tn = a.transpose().matmul_tn(&b); // (aᵀ)ᵀ·b = a·b
        for (x, y) in nn.data().iter().zip(nt.data().iter()) {
            assert!((x - y).abs() < 1e-3);
        }
        for (x, y) in nn.data().iter().zip(tn.data().iter()) {
            assert!((x - y).abs() < 1e-3);
        }
    });
}

#[test]
fn prop_sparse_roundtrip_and_spmm() {
    Runner::new("sparse-roundtrip", 24).run(|g| {
        let rows = g.usize_in(1, 50);
        let cols = g.usize_in(1, 50);
        let nnz = g.usize_in(0, rows * cols / 2 + 1);
        let mut rng = g.rng();
        let triplets: Vec<(usize, usize, f32)> = (0..nnz)
            .map(|_| (rng.below(rows), rng.below(cols), rng.next_f32() + 0.01))
            .collect();
        let sp = Csr::from_triplets(rows, cols, triplets);
        let dense = sp.to_dense();
        // CSR must round-trip through dense
        assert_eq!(Csr::from_dense(&dense, 0.0).to_dense().data(), dense.data());
        // SpMM agrees with dense matmul
        let k = g.usize_in(1, 6);
        let x = Mat::rand_uniform(cols, k, 1.0, &mut rng);
        let got = sp.spmm(&x);
        let want = dense.matmul(&x);
        for (a, b) in got.data().iter().zip(want.data().iter()) {
            assert!((a - b).abs() < 1e-3);
        }
    });
}

#[test]
fn prop_every_solver_keeps_nonnegativity_and_descends() {
    Runner::new("solver-invariants", 20).run(|g| {
        let rows = g.usize_in(1, 30);
        let k = g.usize_in(1, 6);
        let d = g.usize_in(k, 30);
        let mut rng = g.rng();
        let xstar = Mat::rand_uniform(rows, k, 1.0, &mut rng);
        let b = Mat::rand_uniform(k, d, 1.0, &mut rng);
        let a = xstar.matmul(&b);
        let (gram, cross) = solvers::normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let kind = *g.choose(&[
            SolverKind::ProximalCd,
            SolverKind::Pgd,
            SolverKind::Hals,
            SolverKind::Mu,
            SolverKind::AnlsBpp,
        ]);
        let mut x = Mat::rand_uniform(rows, k, 0.5, &mut rng);
        let before = a.dist_sq(&x.matmul(&b));
        solvers::update_auto(kind, &mut x, &nrm, &dsanls::nmf::MuSchedule::default(), 0);
        let after = a.dist_sq(&x.matmul(&b));
        assert!(x.is_nonnegative(), "{kind:?} produced negatives");
        assert!(!x.has_non_finite(), "{kind:?} produced NaN/inf");
        assert!(after <= before * (1.0 + 1e-4) + 1e-6, "{kind:?} ascended: {before} -> {after}");
    });
}

#[test]
fn prop_sketch_shapes_and_moment() {
    Runner::new("sketch-shape-moment", 24).run(|g| {
        let n = g.usize_in(2, 64);
        let d = g.usize_in(1, n);
        let kind = *g.choose(&[
            SketchKind::Gaussian,
            SketchKind::Subsample,
            SketchKind::CountSketch,
            SketchKind::Srht,
        ]);
        let mut rng = g.rng();
        let s = SketchMatrix::generate(kind, n, d, &mut rng);
        let dense = s.to_dense();
        assert_eq!((dense.rows(), dense.cols()), (n, d));
        // column norms are bounded (no blow-up): E‖S‖² per column ≈ n/d·…
        assert!(dense.max_abs().is_finite());
        // apply on identity = materialisation
        let eye = Mat::eye(n);
        let applied = s.mul_right_dense(&eye);
        for (x, y) in applied.data().iter().zip(dense.data().iter()) {
            assert!((x - y).abs() < 1e-4, "{kind:?} apply != materialise");
        }
    });
}

#[test]
fn prop_rel_error_bounds() {
    Runner::new("rel-error-bounds", 24).run(|g| {
        let rows = g.usize_in(1, 40);
        let cols = g.usize_in(1, 40);
        let k = g.usize_in(1, 5);
        let mut rng = g.rng();
        let m = Matrix::Dense(Mat::rand_uniform(rows, cols, 1.0, &mut rng));
        let u = Mat::rand_uniform(rows, k, 0.2, &mut rng);
        let v = Mat::rand_uniform(cols, k, 0.2, &mut rng);
        let e = rel_error(&m, &u, &v);
        assert!(e.is_finite() && e >= 0.0, "rel error {e}");
        // zero factors → error exactly 1
        let e0 = rel_error(&m, &Mat::zeros(rows, k), &Mat::zeros(cols, k));
        assert!((e0 - 1.0).abs() < 1e-6);
    });
}

#[test]
fn prop_split_ranges_parallel_consistency() {
    Runner::new("split-ranges", 48).run(|g| {
        let n = g.usize_in(0, 10_000);
        let parts = g.usize_in(1, 32);
        let rs = parallel::split_ranges(n, parts);
        assert_eq!(rs.len(), parts);
        let covered: usize = rs.iter().map(|r| r.len()).sum();
        assert_eq!(covered, n);
        let max = rs.iter().map(|r| r.len()).max().unwrap_or(0);
        let min = rs.iter().map(|r| r.len()).min().unwrap_or(0);
        assert!(max - min <= 1, "ranges must be balanced");
    });
}

/// Failure injection: a slow node (simulated skew) must not change the
/// *math* of a synchronous collective run, only its timing.
#[test]
fn prop_slow_node_changes_time_not_values() {
    Runner::new("slow-node", 12).run(|g| {
        let nodes = g.usize_in(2, 6);
        let slow = g.usize_in(0, nodes - 1);
        let results = run_cluster(nodes, CommModel::default(), |ctx| {
            if ctx.rank == slow {
                ctx.advance(1.0); // inject 1s of simulated compute skew
            }
            let mut buf = vec![1.0f32; 16];
            ctx.all_reduce_sum(&mut buf);
            (buf[0], ctx.clock())
        });
        for (v, clock) in &results {
            assert_eq!(*v, nodes as f32, "values must be unaffected by skew");
            assert!(*clock >= 1.0, "everyone pays the straggler at the barrier");
        }
    });
}
