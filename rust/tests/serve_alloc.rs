//! Steady-state allocation audit for the serving-plane fold-in path.
//!
//! The perf contract of [`dsanls::serve::FoldIn`]: once warmed up, a
//! fold-in solve — canonicalise the sparse row, accumulate the cross row,
//! run the solver sweeps against the model's cached gram — performs
//! **zero heap allocations**. The entry buffer, cross row and iterate are
//! owned by the workspace and only regrown on shape changes, mirroring
//! the training loop's `Workspace` contract (`tests/alloc_hotpath.rs`).
//!
//! Same harness rules as that file: a counting global allocator, one
//! `#[test]` per binary, and the run pinned to one thread so the
//! measurement captures the kernels rather than pool dispatch.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use dsanls::linalg::Mat;
use dsanls::nmf::control::{Checkpoint, CheckpointMeta, ResumeState};
use dsanls::rng::Pcg64;
use dsanls::serve::{FactorModel, FoldIn};
use dsanls::solvers::SolverKind;

struct CountingAlloc;

static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_fold_in_allocates_nothing() {
    // single-threaded: measure the solve, not pool dispatch
    dsanls::parallel::set_local_threads(Some(1));

    let (items, k) = (120usize, 8usize);
    let mut rng = Pcg64::new(0xF01D, 0);
    let u = Mat::rand_uniform(4, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(items, k, 1.0, &mut rng);
    let model = FactorModel::from_checkpoint(Checkpoint {
        meta: CheckpointMeta {
            algo: "dsanls".into(),
            seed: 1,
            k,
            rows: 4,
            cols: items,
            params: 0,
        },
        state: ResumeState { iteration: 1, u, v },
    });

    // rows of a fixed sparsity, rotated so the steady state sees fresh
    // data (same shape, different values) every solve
    let row = |t: usize| -> Vec<(usize, f32)> {
        (0..12).map(|i| ((i * 10 + t) % items, 0.5 + i as f32 * 0.25)).collect()
    };

    let mut fold = FoldIn::new();
    let mut rows: Vec<Vec<(usize, f32)>> = (0..13).map(row).collect();
    for r in &mut rows {
        r.sort_unstable_by_key(|&(j, _)| j); // duplicate-free by construction
    }

    // warm-up: sizes the entry buffer, the cross row and the iterate
    for r in rows.iter().take(3) {
        fold.solve(&model, r, SolverKind::Hals, 4, 0).unwrap();
    }
    let ptrs = fold.scratch_ptrs();

    // measured steady state
    ALLOC_EVENTS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    let mut checksum = 0.0f32;
    for r in rows.iter().skip(3) {
        let w = fold.solve(&model, r, SolverKind::Hals, 4, 0).unwrap();
        checksum += w[0];
    }
    COUNTING.store(false, Ordering::SeqCst);
    let events = ALLOC_EVENTS.load(Ordering::SeqCst);

    assert_eq!(
        events, 0,
        "steady-state fold-in path performed {events} heap allocations over 10 solves \
         (expected 0)"
    );
    assert_eq!(fold.scratch_ptrs(), ptrs, "fold-in scratch was reallocated in steady state");
    assert!(checksum.is_finite() && checksum >= 0.0);
    dsanls::parallel::set_local_threads(None);
}
