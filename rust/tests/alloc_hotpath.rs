//! Steady-state allocation audit for the iteration hot path.
//!
//! The perf contract of the packed-GEMM + workspace rework: once warmed up,
//! the GEMM → normal-equation → solver sequence of an ANLS iteration
//! performs **zero heap allocations** — gram/cross live in a reused
//! [`dsanls::solvers::Workspace`], GEMM packing scratch is thread-local,
//! and the row sweeps use stack buffers. A counting global allocator
//! verifies the claim.
//!
//! The run is pinned to one thread (`set_local_threads(Some(1))`) so the
//! measurement captures the kernels themselves rather than pool-dispatch
//! bookkeeping; the single `#[test]` in this file keeps the harness from
//! running anything else concurrently against the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use dsanls::linalg::Mat;
use dsanls::nmf::MuSchedule;
use dsanls::rng::Pcg64;
use dsanls::solvers::{self, SolverKind, Workspace};

struct CountingAlloc;

static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_iteration_allocates_nothing_in_gemm_solver_path() {
    // single-threaded: measure the kernels, not pool dispatch
    dsanls::parallel::set_local_threads(Some(1));

    // DSANLS-iteration shapes: A_r (rows×d), B (k×d), factor block rows×k
    let (rows, k, d) = (300usize, 16usize, 40usize);
    let mut rng = Pcg64::new(0xA110C, 0);
    let a = Mat::rand_uniform(rows, d, 1.0, &mut rng);
    let b = Mat::rand_uniform(k, d, 1.0, &mut rng);
    let mut u_cd = Mat::rand_uniform(rows, k, 1.0, &mut rng);
    let mut u_pgd = Mat::rand_uniform(rows, k, 1.0, &mut rng);
    let mu = MuSchedule::default();

    let mut ws = Workspace::new();

    // warm-up: sizes the workspace and the thread-local GEMM pack buffers
    for t in 0..3 {
        let nrm = ws.normal_from(&a, &b);
        solvers::update_auto(SolverKind::ProximalCd, &mut u_cd, &nrm, &mu, t);
        solvers::update_auto(SolverKind::Pgd, &mut u_pgd, &nrm, &mu, t);
    }
    let ptrs = ws.scratch_ptrs();

    // measured steady state
    ALLOC_EVENTS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for t in 3..13 {
        let nrm = ws.normal_from(&a, &b);
        solvers::update_auto(SolverKind::ProximalCd, &mut u_cd, &nrm, &mu, t);
        solvers::update_auto(SolverKind::Pgd, &mut u_pgd, &nrm, &mu, t);
    }
    COUNTING.store(false, Ordering::SeqCst);
    let events = ALLOC_EVENTS.load(Ordering::SeqCst);

    assert_eq!(
        events, 0,
        "steady-state GEMM/normal-equation/solver path performed {events} heap allocations \
         over 10 iterations (expected 0)"
    );
    // and the workspace must have kept its buffers, not reallocated them
    assert_eq!(ws.scratch_ptrs(), ptrs, "workspace scratch was reallocated in steady state");

    assert!(u_cd.is_nonnegative() && u_pgd.is_nonnegative());
    dsanls::parallel::set_local_threads(None);
}
