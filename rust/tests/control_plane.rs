//! Integration tests for the supervised execution control plane:
//! `Job::spawn()` → `JobHandle` (cancel / wait / try_wait / progress
//! draining), convergence- and deadline-based stopping, checkpoint →
//! interrupt → resume bit-identity on both transport backends, and the
//! typed rejection of misuse.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use dsanls::algos::DsanlsOptions;
use dsanls::data::partition::weight_balanced_partition;
use dsanls::data::shard::{col_nnz_counts, write_shard_dir, ShardManifest};
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, Backend, DataSource, Job, Outcome};
use dsanls::nmf::StopReason;
use dsanls::rng::Pcg64;
use dsanls::secure::{SecureAlgo, SynOptions};

fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed as u128, 0);
    let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
    Matrix::Dense(u.matmul_nt(&v))
}

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsanls_ctl_{tag}_{}.ckpt", std::process::id()))
}

fn small_opts(iterations: usize) -> DsanlsOptions {
    DsanlsOptions {
        nodes: 2,
        rank: 2,
        iterations,
        d_u: 4,
        d_v: 4,
        eval_every: 0,
        ..Default::default()
    }
}

fn run_plain(m: &Matrix, opts: &DsanlsOptions, backend: Backend) -> Outcome {
    Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(m))
        .transport(backend)
        .run()
        .expect("plain job failed")
}

/// `JobHandle::cancel()` must end the run cleanly (StopReason::Cancelled,
/// factors returned) long before the iteration budget — on BOTH backends.
#[test]
fn cancel_returns_within_one_iteration_on_sim_and_tcp() {
    let m = low_rank(24, 16, 2, 8001);
    for backend in [Backend::Sim, Backend::Tcp { port: 0 }] {
        let handle = Job::builder()
            .algorithm(Algo::Dsanls(small_opts(50_000)))
            .data(DataSource::Full(&m))
            .transport(backend)
            .spawn()
            .expect("spawn failed");
        // let it make some progress, then cancel
        std::thread::sleep(Duration::from_millis(60));
        let tick = Instant::now();
        handle.cancel();
        let out = handle.wait().expect("cancelled job must still yield an outcome");
        assert_eq!(out.stop_reason, StopReason::Cancelled, "{backend:?}");
        assert!(
            tick.elapsed() < Duration::from_secs(20),
            "{backend:?}: cancel took {:?} — not within one (tiny) iteration",
            tick.elapsed()
        );
        let done = out.trace.last().unwrap().iteration;
        assert!(done < 50_000, "{backend:?}: ran the full budget despite cancel");
        assert_eq!(out.u.rows(), 24, "{backend:?}: factors must survive a clean cancel");
        assert!(out.final_error().is_finite(), "{backend:?}");
    }
}

/// A zero-second deadline stops at the very first poll with
/// `StopReason::DeadlineExceeded`.
#[test]
fn deadline_stops_immediately() {
    let m = low_rank(24, 16, 2, 8003);
    let out = Job::builder()
        .algorithm(Algo::Dsanls(small_opts(10_000)))
        .data(DataSource::Full(&m))
        .max_seconds(0.0)
        .run()
        .unwrap();
    assert_eq!(out.stop_reason, StopReason::DeadlineExceeded);
    assert_eq!(out.trace.last().unwrap().iteration, 0, "no iteration should complete");
}

/// Convergence stopping: with a reachable target the run ends early with
/// `StopReason::TargetReached` and a traced error at (or below) target.
#[test]
fn target_error_stops_early_with_reason() {
    let m = low_rank(60, 48, 3, 8005);
    let mut opts = DsanlsOptions {
        nodes: 2,
        rank: 3,
        iterations: 40,
        d_u: 16,
        d_v: 16,
        eval_every: 1,
        ..Default::default()
    };
    let probe = run_plain(&m, &opts, Backend::Sim);
    let first = probe.trace.first().unwrap().rel_error;
    let last = probe.final_error();
    assert!(last < first, "probe run must converge for this test to mean anything");
    let target = (first + last) / 2.0;

    opts.iterations = 100_000; // the target, not the budget, must stop it
    let out = Job::builder()
        .algorithm(Algo::Dsanls(opts))
        .data(DataSource::Full(&m))
        .target_error(target)
        .run()
        .unwrap();
    assert_eq!(out.stop_reason, StopReason::TargetReached);
    assert!(
        out.final_error() <= target,
        "stopped at {} but target was {target}",
        out.final_error()
    );
    let done = out.trace.last().unwrap().iteration;
    assert!(done < 100_000 && done > 0, "stopped after {done} iterations");
}

/// The asynchronous protocols stop on target too — via the parameter
/// server's residual aggregation (there is no collective to agree in).
#[test]
fn asyn_target_error_stops_via_server_aggregate() {
    use dsanls::secure::AsynOptions;
    let m = low_rank(48, 36, 3, 8007);
    let opts = AsynOptions {
        nodes: 2,
        rank: 3,
        rounds: 30,
        local_iters: 2,
        d1: 12,
        ..Default::default()
    };
    let probe = Job::builder()
        .algorithm(Algo::Asyn(opts.clone(), SecureAlgo::AsynSd))
        .data(DataSource::Full(&m))
        .run()
        .unwrap();
    let first = probe.trace.first().unwrap().rel_error;
    let target = (probe.final_error() * 0.3 + first * 0.7).max(probe.final_error() * 1.2);

    let mut long = opts;
    long.rounds = 2_000;
    let out = Job::builder()
        .algorithm(Algo::Asyn(long, SecureAlgo::AsynSd))
        .data(DataSource::Full(&m))
        .target_error(target)
        .run()
        .unwrap();
    assert_eq!(out.stop_reason, StopReason::TargetReached);
    assert!(out.final_error().is_finite());
}

/// The acceptance contract: a seeded job that is checkpointed, killed and
/// resumed yields factors **bit-identical** to the same job run
/// uninterrupted — on Sim AND Tcp. (Deterministic variant: the
/// "interruption" is a run whose budget ends at the checkpoint.)
#[test]
fn checkpoint_resume_bit_identity_on_both_backends() {
    let m = low_rank(40, 30, 3, 8009);
    let full = DsanlsOptions {
        nodes: 2,
        rank: 3,
        iterations: 12,
        d_u: 8,
        d_v: 8,
        eval_every: 3,
        ..Default::default()
    };
    for backend in [Backend::Sim, Backend::Tcp { port: 0 }] {
        let reference = run_plain(&m, &full, backend);

        let ckpt = tmpfile(&format!("bitident_{:?}", matches!(backend, Backend::Sim)));
        let mut half = full.clone();
        half.iterations = 5; // killed after 5 iterations…
        let interrupted = Job::builder()
            .algorithm(Algo::Dsanls(half))
            .data(DataSource::Full(&m))
            .transport(backend)
            .checkpoint_every(5, &ckpt)
            .run()
            .unwrap();
        assert_eq!(interrupted.stop_reason, StopReason::Completed);
        assert!(ckpt.exists(), "{backend:?}: checkpoint was not written");

        // …and resumed to the full budget
        let resumed = Job::builder()
            .algorithm(Algo::Dsanls(full.clone()))
            .data(DataSource::Full(&m))
            .transport(backend)
            .resume_from(&ckpt)
            .run()
            .unwrap();
        assert_eq!(
            reference.u.data(),
            resumed.u.data(),
            "{backend:?}: resumed U diverged from the uninterrupted run"
        );
        assert_eq!(
            reference.v.data(),
            resumed.v.data(),
            "{backend:?}: resumed V diverged from the uninterrupted run"
        );
        std::fs::remove_file(&ckpt).ok();
    }
}

/// The live variant: spawn with a checkpoint cadence, cancel once a
/// checkpoint exists, resume — wherever the cancel landed, the resumed
/// run must reach the uninterrupted factors bit-for-bit.
#[test]
fn cancelled_spawn_resumes_to_identical_factors() {
    let m = low_rank(36, 24, 3, 8011);
    let full = DsanlsOptions {
        nodes: 2,
        rank: 3,
        iterations: 600,
        d_u: 8,
        d_v: 8,
        eval_every: 0,
        ..Default::default()
    };
    let reference = run_plain(&m, &full, Backend::Sim);

    let ckpt = tmpfile("cancelled_spawn");
    let handle = Job::builder()
        .algorithm(Algo::Dsanls(full.clone()))
        .data(DataSource::Full(&m))
        .checkpoint_every(2, &ckpt)
        .spawn()
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while !ckpt.exists() && !handle.is_finished() {
        assert!(Instant::now() < deadline, "no checkpoint appeared in 30s");
        std::thread::sleep(Duration::from_millis(5));
    }
    handle.cancel();
    let cancelled = handle.wait().unwrap();

    if cancelled.stop_reason == StopReason::Cancelled {
        let resumed = Job::builder()
            .algorithm(Algo::Dsanls(full))
            .data(DataSource::Full(&m))
            .resume_from(&ckpt)
            .run()
            .unwrap();
        assert_eq!(reference.u.data(), resumed.u.data(), "U diverged after resume");
        assert_eq!(reference.v.data(), resumed.v.data(), "V diverged after resume");
    } // else: the job outran the cancel — the deterministic test covers identity
    std::fs::remove_file(&ckpt).ok();
}

/// Corrupt or mismatched checkpoints are typed errors from the builder,
/// never panics or garbage factors.
#[test]
fn corrupt_and_mismatched_checkpoints_are_rejected() {
    let m = low_rank(30, 20, 2, 8013);
    let opts = small_opts(6);
    let ckpt = tmpfile("reject");
    Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(&m))
        .checkpoint_every(3, &ckpt)
        .run()
        .unwrap();
    let bytes = std::fs::read(&ckpt).unwrap();

    // truncated file
    std::fs::write(&ckpt, &bytes[..bytes.len() / 2]).unwrap();
    let mut longer = opts.clone();
    longer.iterations = 12;
    let err = Job::builder()
        .algorithm(Algo::Dsanls(longer.clone()))
        .data(DataSource::Full(&m))
        .resume_from(&ckpt)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");

    // corrupted magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&ckpt, &bad).unwrap();
    let err = Job::builder()
        .algorithm(Algo::Dsanls(longer.clone()))
        .data(DataSource::Full(&m))
        .resume_from(&ckpt)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");

    // wrong seed: resumed factors would silently diverge — typed error
    std::fs::write(&ckpt, &bytes).unwrap();
    let mut reseeded = longer.clone();
    reseeded.seed = 999;
    let err = Job::builder()
        .algorithm(Algo::Dsanls(reseeded))
        .data(DataSource::Full(&m))
        .resume_from(&ckpt)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("seed"), "{err}");

    // changed result-affecting options (sketch size): the resumed tail
    // would replay a different trajectory — typed error
    let mut resketched = longer.clone();
    resketched.d_u = 16;
    let err = Job::builder()
        .algorithm(Algo::Dsanls(resketched))
        .data(DataSource::Full(&m))
        .resume_from(&ckpt)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("options"), "{err}");

    // wrong shape (different matrix)
    let other = low_rank(10, 8, 2, 8014);
    let err = Job::builder()
        .algorithm(Algo::Dsanls(longer.clone()))
        .data(DataSource::Full(&other))
        .resume_from(&ckpt)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("rank-"), "{err}");

    // nothing left to resume (checkpoint at == budget)
    let err = Job::builder()
        .algorithm(Algo::Dsanls(small_opts(3)))
        .data(DataSource::Full(&m))
        .resume_from(&ckpt)
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("nothing"), "{err}");
    std::fs::remove_file(&ckpt).ok();
}

/// Supervision misuse is typed: secure protocols refuse checkpoints, and
/// spawn refuses caller-borrowed hooks.
#[test]
fn supervision_misuse_is_typed() {
    let m = low_rank(24, 16, 2, 8015);
    let syn = SynOptions { nodes: 2, rank: 2, t1: 2, t2: 2, eval_every: 0, ..Default::default() };
    let err = Job::builder()
        .algorithm(Algo::Syn(syn, SecureAlgo::SynSd))
        .data(DataSource::Full(&m))
        .checkpoint_every(2, tmpfile("secure"))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("secure"), "{err}");

    let err = Job::builder()
        .algorithm(Algo::Dsanls(small_opts(4)))
        .data(DataSource::Full(&m))
        .checkpoint_every(0, tmpfile("zero"))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("cadence"), "{err}");

    let obs = |_e: &dsanls::algos::ProgressEvent| {};
    let err = Job::builder()
        .algorithm(Algo::Dsanls(small_opts(4)))
        .data(DataSource::Full(&m))
        .observer(&obs)
        .spawn()
        .unwrap_err();
    assert!(err.to_string().contains("drain_progress"), "{err}");

    let audit = dsanls::secure::AuditLog::new();
    let err = Job::builder()
        .algorithm(Algo::Dsanls(small_opts(4)))
        .data(DataSource::Full(&m))
        .audit(&audit)
        .spawn()
        .unwrap_err();
    assert!(err.to_string().contains("audit"), "{err}");
}

/// `try_wait` is non-blocking, `drain_progress` streams samples, and a
/// spent handle says so.
#[test]
fn handle_try_wait_and_progress_draining() {
    let m = low_rank(30, 20, 2, 8017);
    let mut opts = small_opts(40);
    opts.eval_every = 1; // one progress event per iteration
    let mut handle = Job::builder()
        .algorithm(Algo::Dsanls(opts))
        .data(DataSource::Full(&m))
        .spawn()
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    let outcome = loop {
        if let Some(out) = handle.try_wait().unwrap() {
            break out;
        }
        assert!(Instant::now() < deadline, "job did not finish in 60s");
        std::thread::sleep(Duration::from_millis(5));
    };
    assert_eq!(outcome.stop_reason, StopReason::Completed);
    let events = handle.drain_progress();
    assert_eq!(events.len(), outcome.trace.len(), "every traced sample must stream");
    assert!(handle.drain_progress().is_empty(), "drain must consume");
    let err = handle.try_wait().unwrap_err();
    assert!(err.to_string().contains("already"), "{err}");
}

/// nnz-balanced shard directories drive the secure protocols end to end:
/// the job picks the manifest's column partition up automatically and the
/// factors are bit-identical to the full-matrix run under that partition.
#[test]
fn balanced_shard_dir_drives_secure_job_bit_identically() {
    let mut rng = Pcg64::new(8019, 0);
    let sp = dsanls::data::synth::power_law_sparse(48, 60, 1400, 3, 1.0, &mut rng);
    let m = Matrix::Sparse(sp);
    let nodes = 3;
    let balanced = weight_balanced_partition(&col_nnz_counts(&m), nodes);
    let dir = std::env::temp_dir().join(format!("dsanls_ctl_balshard_{}", std::process::id()));
    let mut manifest = ShardManifest::uniform(
        nodes,
        m.rows(),
        m.cols(),
        m.fro_sq(),
        8019,
        1.0,
        false,
        "FILE:skewtest".into(),
    );
    manifest.col_bounds = balanced.bounds();
    write_shard_dir(&dir, &m, &manifest).unwrap();

    let opts = SynOptions {
        nodes,
        rank: 3,
        t1: 3,
        t2: 2,
        d1: 10,
        d2: 5,
        d3: 10,
        eval_every: 0,
        ..Default::default()
    };
    let full = Job::builder()
        .algorithm(Algo::Syn(opts.clone(), SecureAlgo::SynSd))
        .data(DataSource::Full(&m))
        .secure_partition(balanced.clone())
        .run()
        .unwrap();
    let sharded = Job::builder()
        .algorithm(Algo::Syn(opts.clone(), SecureAlgo::SynSd))
        .data(DataSource::ShardDir(dir.clone()))
        .run()
        .unwrap();
    assert_eq!(full.u.data(), sharded.u.data(), "U diverged on balanced shards");
    assert_eq!(full.v.data(), sharded.v.data(), "V diverged on balanced shards");

    // a non-secure job must refuse the balanced directory with a typed error
    let mut d = small_opts(4);
    d.nodes = nodes;
    let err = Job::builder()
        .algorithm(Algo::Dsanls(d))
        .data(DataSource::ShardDir(dir.clone()))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("balanced"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
