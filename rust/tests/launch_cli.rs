//! End-to-end CLI test for the multi-process TCP deployment: `dsanls
//! launch` must spawn real worker OS processes over localhost, run the
//! configured experiment, and produce factors bit-identical to the
//! simulated backend (`--verify-sim` makes the binary itself assert that
//! and exit nonzero on divergence).

use std::path::PathBuf;
use std::process::Command;

fn exe() -> &'static str {
    env!("CARGO_BIN_EXE_dsanls")
}

fn temp_out(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsanls_launch_{tag}_{}", std::process::id()))
}

#[test]
fn launch_four_nodes_dsanls_bit_identical_to_sim() {
    let out_dir = temp_out("dsanls");
    std::fs::create_dir_all(&out_dir).unwrap();
    let output = Command::new(exe())
        .args([
            "launch",
            "--nodes",
            "4",
            "--verify-sim",
            "--experiment.name=launchtest",
            "--experiment.algorithm=dsanls",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
            "--experiment.rank=4",
            "--experiment.iterations=6",
            "--experiment.eval_every=3",
        ])
        .arg(format!("--output.dir={}", out_dir.display()))
        .output()
        .expect("failed to spawn dsanls launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "launch failed ({})\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stdout.contains("bit-identical to simulated backend: true"),
        "verify-sim did not confirm bit-identity\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        out_dir.join("launchtest-tcp.csv").exists(),
        "launch did not write the trace CSV"
    );
    std::fs::remove_dir_all(&out_dir).ok();
}

#[test]
fn launch_secure_syn_sd_end_to_end() {
    let out_dir = temp_out("synsd");
    std::fs::create_dir_all(&out_dir).unwrap();
    let output = Command::new(exe())
        .args([
            "launch",
            "--nodes",
            "3",
            "--verify-sim",
            "--experiment.name=launchsyn",
            "--experiment.algorithm=syn-sd",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
            "--experiment.rank=3",
            "--secure.t1=2",
            "--secure.t2=2",
            "--experiment.eval_every=0",
        ])
        .arg(format!("--output.dir={}", out_dir.display()))
        .output()
        .expect("failed to spawn dsanls launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "secure launch failed ({})\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(stdout.contains("bit-identical to simulated backend: true"), "{stdout}");
    std::fs::remove_dir_all(&out_dir).ok();
}

/// The full multi-host data path on one machine: `dsanls shard` writes the
/// block files, `dsanls launch --shards` runs workers that load only their
/// blocks, and `--verify-sim` asserts the factors are bit-identical to the
/// full-matrix simulator.
#[test]
fn shard_then_launch_over_files_bit_identical_to_sim() {
    let out_dir = temp_out("shardlaunch");
    let shard_dir = out_dir.join("shards");
    std::fs::create_dir_all(&out_dir).unwrap();
    let cfg: Vec<String> = [
        "--experiment.name=shardtest",
        "--experiment.algorithm=dsanls",
        "--experiment.dataset=face",
        "--experiment.scale=0.05",
        "--experiment.rank=4",
        "--experiment.iterations=6",
        "--experiment.eval_every=3",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();

    let output = Command::new(exe())
        .args(["shard", "--out", shard_dir.to_str().unwrap(), "--nodes", "3"])
        .args(&cfg)
        .output()
        .expect("failed to spawn dsanls shard");
    assert!(
        output.status.success(),
        "shard failed: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    assert!(shard_dir.join("manifest.bin").exists());
    assert!(shard_dir.join("rank-2.cols.blk").exists());

    let output = Command::new(exe())
        .args([
            "launch",
            "--nodes",
            "3",
            "--verify-sim",
            "--shards",
            shard_dir.to_str().unwrap(),
        ])
        .args(&cfg)
        .arg(format!("--output.dir={}", out_dir.display()))
        .output()
        .expect("failed to spawn dsanls launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "sharded launch failed ({})\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stdout.contains("bit-identical to simulated backend: true"),
        "verify-sim did not confirm bit-identity over shard files\nstdout:\n{stdout}"
    );
    assert!(stdout.contains("file shard"), "load stats should report file shards\n{stdout}");
    std::fs::remove_dir_all(&out_dir).ok();
}

/// A shard directory built for a different cluster size must be rejected
/// with an actionable error, not a hang or a bit-identity failure.
#[test]
fn launch_rejects_mismatched_shard_dir() {
    let out_dir = temp_out("shardmismatch");
    let shard_dir = out_dir.join("shards");
    std::fs::create_dir_all(&out_dir).unwrap();
    let cfg = ["--experiment.dataset=face", "--experiment.scale=0.05"];
    let output = Command::new(exe())
        .args(["shard", "--out", shard_dir.to_str().unwrap(), "--nodes", "2"])
        .args(cfg)
        .output()
        .expect("failed to spawn dsanls shard");
    assert!(output.status.success());

    let output = Command::new(exe())
        .args(["launch", "--nodes", "3", "--shards", shard_dir.to_str().unwrap()])
        .args(cfg)
        .output()
        .expect("failed to spawn dsanls launch");
    assert!(!output.status.success());
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("dsanls shard"), "unhelpful error: {stderr}");
    std::fs::remove_dir_all(&out_dir).ok();
}

/// Rank-failure retry, end to end over real worker processes: rank 1 is
/// fault-injected to die mid-run; `launch --retries 1 --checkpoint` must
/// restart the cluster from the checkpoint and still produce factors
/// bit-identical to the uninterrupted simulator (`--verify-sim`).
#[test]
fn launch_retries_rank_failure_from_checkpoint() {
    let out_dir = temp_out("retry");
    std::fs::create_dir_all(&out_dir).unwrap();
    let ckpt = out_dir.join("run.ckpt");
    let output = Command::new(exe())
        .args([
            "launch",
            "--nodes",
            "3",
            "--verify-sim",
            "--retries",
            "1",
            "--checkpoint",
            ckpt.to_str().unwrap(),
            "--checkpoint-every",
            "2",
            "--fault-rank",
            "1",
            "--fault-iteration",
            "5",
            "--experiment.name=retrytest",
            "--experiment.algorithm=dsanls",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
            "--experiment.rank=4",
            "--experiment.iterations=8",
            "--experiment.eval_every=4",
        ])
        .arg(format!("--output.dir={}", out_dir.display()))
        .output()
        .expect("failed to spawn dsanls launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "retry launch failed ({})\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stderr.contains("retrying (attempt 1/1)"),
        "retry was not attempted\nstderr:\n{stderr}"
    );
    assert!(
        stdout.contains("retries: 1"),
        "retry count must surface in the outcome\nstdout:\n{stdout}"
    );
    assert!(
        stdout.contains("bit-identical to simulated backend: true"),
        "resumed factors diverged from the uninterrupted simulator\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    std::fs::remove_dir_all(&out_dir).ok();
}

/// Retry exhaustion is a clean failure naming the dead worker, not a hang.
#[test]
fn launch_retry_exhaustion_fails_cleanly() {
    let out_dir = temp_out("retryfail");
    std::fs::create_dir_all(&out_dir).unwrap();
    let output = Command::new(exe())
        .args([
            "launch",
            "--nodes",
            "2",
            "--retries",
            "0",
            "--fault-rank",
            "0",
            "--fault-iteration",
            "2",
            "--experiment.algorithm=dsanls",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
            "--experiment.rank=3",
            "--experiment.iterations=6",
            "--experiment.eval_every=0",
        ])
        .arg(format!("--output.dir={}", out_dir.display()))
        .output()
        .expect("failed to spawn dsanls launch");
    assert!(!output.status.success(), "exhausted retries must fail the launch");
    std::fs::remove_dir_all(&out_dir).ok();
}

/// Elastic recovery, end to end over real worker processes: rank 1 is
/// fault-injected to die mid-run; `launch --elastic` must spawn a
/// replacement (`worker --join`), rebuild the membership epoch WITHOUT
/// restarting the survivors, and still produce factors bit-identical to
/// the uninterrupted simulator. The outcome proves the path taken:
/// `retries: 0` (nobody restarted) and `epochs: 2` (one rebuild).
#[test]
fn launch_elastic_replaces_dead_worker_without_restart() {
    let out_dir = temp_out("elastic");
    std::fs::create_dir_all(&out_dir).unwrap();
    let output = Command::new(exe())
        .args([
            "launch",
            "--nodes",
            "3",
            "--verify-sim",
            "--elastic",
            "--fault-rank",
            "1",
            "--fault-iteration",
            "3",
            "--experiment.name=elastictest",
            "--experiment.algorithm=dsanls",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
            "--experiment.rank=4",
            "--experiment.iterations=6",
            "--experiment.eval_every=3",
        ])
        .arg(format!("--output.dir={}", out_dir.display()))
        .output()
        .expect("failed to spawn dsanls launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "elastic launch failed ({})\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stderr.contains("spawning replacement"),
        "no replacement was spawned\nstderr:\n{stderr}"
    );
    assert!(
        !stderr.contains("retrying"),
        "elastic recovery must not fall back to a cluster restart\nstderr:\n{stderr}"
    );
    assert!(
        stdout.contains("retries: 0"),
        "elastic recovery must report zero restarts\nstdout:\n{stdout}"
    );
    assert!(
        stdout.contains("epochs: 2"),
        "exactly one membership rebuild expected\nstdout:\n{stdout}"
    );
    assert!(
        stdout.contains("bit-identical to simulated backend: true"),
        "recovered factors diverged from the uninterrupted simulator\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    std::fs::remove_dir_all(&out_dir).ok();
}

/// `--max-joins 0` with a scripted death: the budget is exhausted
/// immediately and the launch fails cleanly, naming the budget.
#[test]
fn launch_elastic_join_budget_exhaustion_fails_cleanly() {
    let out_dir = temp_out("elasticbudget");
    std::fs::create_dir_all(&out_dir).unwrap();
    let output = Command::new(exe())
        .args([
            "launch",
            "--nodes",
            "2",
            "--elastic",
            "--max-joins",
            "0",
            "--fault-rank",
            "0",
            "--fault-iteration",
            "2",
            "--experiment.algorithm=dsanls",
            "--experiment.dataset=face",
            "--experiment.scale=0.05",
            "--experiment.rank=3",
            "--experiment.iterations=6",
            "--experiment.eval_every=0",
        ])
        .arg(format!("--output.dir={}", out_dir.display()))
        .output()
        .expect("failed to spawn dsanls launch");
    assert!(!output.status.success(), "an exhausted join budget must fail the launch");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        stderr.contains("join budget exhausted"),
        "unhelpful error: {stderr}"
    );
    std::fs::remove_dir_all(&out_dir).ok();
}

#[test]
fn worker_without_rendezvous_is_a_clean_error() {
    let output = Command::new(exe())
        .args(["worker", "--rank", "0"])
        .output()
        .expect("failed to spawn dsanls worker");
    assert!(!output.status.success());
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("--rendezvous"), "unhelpful error: {stderr}");
}

#[test]
fn launch_rejects_zero_nodes() {
    let output = Command::new(exe())
        .args(["launch", "--nodes", "0"])
        .output()
        .expect("failed to spawn dsanls launch");
    assert!(!output.status.success());
}
