//! Integration tests for the unified `nmf::job::Job` builder: all six
//! paper methods through one API on both transport backends, every data
//! source, streaming observers, and typed errors on misuse.

use std::sync::Mutex;

use dsanls::algos::{DistAnlsOptions, DsanlsOptions, ProgressEvent};
use dsanls::data::shard::{write_shard_dir, ShardManifest};
use dsanls::data::Dataset;
use dsanls::linalg::{Mat, Matrix};
use dsanls::nmf::job::{Algo, Backend, DataSource, Job, Outcome};
use dsanls::rng::Pcg64;
use dsanls::secure::{AsynOptions, SecureAlgo, SynOptions};

fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed as u128, 0);
    let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
    let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
    Matrix::Dense(u.matmul_nt(&v))
}

/// The six paper methods, tiny configurations (nodes = 2 everywhere).
fn all_six() -> Vec<Algo> {
    let dsanls = DsanlsOptions {
        nodes: 2,
        rank: 3,
        iterations: 4,
        d_u: 8,
        d_v: 8,
        eval_every: 2,
        ..Default::default()
    };
    let hals = DistAnlsOptions {
        nodes: 2,
        rank: 3,
        iterations: 4,
        eval_every: 2,
        ..Default::default()
    };
    let syn = SynOptions {
        nodes: 2,
        rank: 3,
        t1: 2,
        t2: 2,
        d1: 8,
        d2: 4,
        d3: 8,
        eval_every: 0,
        ..Default::default()
    };
    let asyn = AsynOptions {
        nodes: 2,
        rank: 3,
        rounds: 3,
        local_iters: 2,
        d1: 8,
        ..Default::default()
    };
    vec![
        Algo::Dsanls(dsanls),
        Algo::DistAnls(hals),
        Algo::Syn(syn.clone(), SecureAlgo::SynSd),
        Algo::Syn(syn, SecureAlgo::SynSsdUv),
        Algo::Asyn(asyn.clone(), SecureAlgo::AsynSd),
        Algo::Asyn(asyn, SecureAlgo::AsynSsdV),
    ]
}

fn check_outcome(out: &Outcome, what: &str) {
    assert!(!out.trace.is_empty(), "{what}: empty trace");
    assert!(out.final_error().is_finite(), "{what}: bad error");
    assert!(out.u.is_nonnegative(), "{what}: negative factor");
    assert!(out.v.is_nonnegative(), "{what}: negative factor");
}

/// Acceptance contract: every method runs through `Job::builder()` on BOTH
/// `Backend::Sim` and `Backend::Tcp`.
#[test]
fn all_six_methods_on_both_backends() {
    let m = low_rank(48, 36, 3, 7001);
    for algo in all_six() {
        for backend in [Backend::Sim, Backend::Tcp { port: 0 }] {
            let label = format!("{algo:?} on {backend:?}");
            let out = Job::builder()
                .algorithm(algo.clone())
                .data(DataSource::Full(&m))
                .transport(backend)
                .run()
                .unwrap_or_else(|e| panic!("{label}: {e}"));
            check_outcome(&out, &label);
            if matches!(backend, Backend::Tcp { .. }) {
                assert!(out.label.ends_with("/tcp"), "{label}: label {}", out.label);
            }
        }
    }
}

/// Every method also runs on shard-local synthetic data — no rank ever
/// materialises the full matrix — and reports per-rank load statistics.
#[test]
fn all_six_methods_on_synthetic_windows() {
    for algo in all_six() {
        let out = Job::builder()
            .algorithm(algo.clone())
            .data(DataSource::SyntheticWindow { dataset: Dataset::Face, seed: 9, scale: 0.03 })
            .run()
            .unwrap_or_else(|e| panic!("{algo:?} on synth shards: {e}"));
        check_outcome(&out, &format!("{algo:?} on synth shards"));
        assert!(!out.loads.is_empty(), "{algo:?}: synth shards must report load stats");
    }
}

/// Synthetic-window jobs are bit-identical to full-matrix jobs of the same
/// dataset (windowed generation + exact ‖M‖² chain).
#[test]
fn synthetic_window_bit_identical_to_full() {
    let m = Dataset::Face.generate_scaled(9, 0.03);
    let opts = DsanlsOptions {
        nodes: 3,
        rank: 3,
        iterations: 5,
        d_u: 8,
        d_v: 8,
        eval_every: 0,
        ..Default::default()
    };
    let full = Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(&m))
        .run()
        .unwrap();
    let shard = Job::builder()
        .algorithm(Algo::Dsanls(opts))
        .data(DataSource::SyntheticWindow { dataset: Dataset::Face, seed: 9, scale: 0.03 })
        .run()
        .unwrap();
    assert_eq!(full.u.data(), shard.u.data(), "U diverged across data sources");
    assert_eq!(full.v.data(), shard.v.data(), "V diverged across data sources");
}

/// A `dsanls shard` directory drives the same job; factors stay
/// bit-identical to the full-matrix run.
#[test]
fn shard_dir_source_bit_identical_to_full() {
    let m = Dataset::Face.generate_scaled(11, 0.03);
    let dir = std::env::temp_dir().join(format!("dsanls_jobshard_{}", std::process::id()));
    let manifest = ShardManifest::uniform(
        2,
        m.rows(),
        m.cols(),
        m.fro_sq(),
        11,
        0.03,
        matches!(m, Matrix::Dense(_)),
        "FACE".into(),
    );
    write_shard_dir(&dir, &m, &manifest).unwrap();
    let opts = DsanlsOptions {
        nodes: 2,
        rank: 3,
        iterations: 5,
        d_u: 8,
        d_v: 8,
        eval_every: 0,
        ..Default::default()
    };
    let full = Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::Full(&m))
        .run()
        .unwrap();
    let shard = Job::builder()
        .algorithm(Algo::Dsanls(opts.clone()))
        .data(DataSource::ShardDir(dir.clone()))
        .run()
        .unwrap();
    assert_eq!(full.u.data(), shard.u.data(), "U diverged across data sources");
    assert_eq!(full.v.data(), shard.v.data(), "V diverged across data sources");
    assert_eq!(shard.loads.len(), 2, "file shards must report per-rank loads");

    // rank-count mismatch: typed error, not a panic or a hang
    let mut three = opts;
    three.nodes = 3;
    let err = Job::builder()
        .algorithm(Algo::Dsanls(three))
        .data(DataSource::ShardDir(dir.clone()))
        .run()
        .unwrap_err();
    assert!(
        err.to_string().contains("2 nodes") && err.to_string().contains("3"),
        "unhelpful shard-mismatch error: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The streaming observer sees every traced sample as it is recorded, in
/// order, with monotonically growing communication counters.
#[test]
fn observer_streams_progress() {
    let m = low_rank(40, 30, 3, 7003);
    let events: Mutex<Vec<ProgressEvent>> = Mutex::new(Vec::new());
    let obs = |e: &ProgressEvent| events.lock().unwrap().push(*e);
    let out = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions {
            nodes: 2,
            rank: 3,
            iterations: 6,
            d_u: 8,
            d_v: 8,
            eval_every: 2,
            ..Default::default()
        }))
        .data(DataSource::Full(&m))
        .observer(&obs)
        .run()
        .unwrap();
    let events = events.into_inner().unwrap();
    assert_eq!(events.len(), out.trace.len(), "one event per traced sample");
    for (e, p) in events.iter().zip(out.trace.iter()) {
        assert_eq!(e.iteration, p.iteration);
        assert_eq!(e.rel_error.to_bits(), p.rel_error.to_bits());
    }
    for w in events.windows(2) {
        assert!(w[1].iteration > w[0].iteration, "events must stream in order");
        assert!(
            w[1].stats.bytes_sent >= w[0].stats.bytes_sent,
            "comm counters must be cumulative"
        );
    }
    assert!(events.last().unwrap().stats.messages > 0);
}

/// The asynchronous protocols replay their merged trace to the observer at
/// assembly (per-client clocks only merge then).
#[test]
fn observer_sees_asyn_trace() {
    let m = low_rank(40, 30, 3, 7005);
    let count = Mutex::new(0usize);
    let obs = |_e: &ProgressEvent| *count.lock().unwrap() += 1;
    let out = Job::builder()
        .algorithm(Algo::Asyn(
            AsynOptions {
                nodes: 2,
                rank: 3,
                rounds: 3,
                local_iters: 2,
                d1: 8,
                ..Default::default()
            },
            SecureAlgo::AsynSd,
        ))
        .data(DataSource::Full(&m))
        .observer(&obs)
        .run()
        .unwrap();
    assert_eq!(*count.lock().unwrap(), out.trace.len());
}

/// Builder misuse returns typed errors, never panics.
#[test]
fn misuse_is_a_typed_error() {
    let m = low_rank(20, 16, 2, 7007);

    // missing algorithm
    let err = Job::builder().data(DataSource::Full(&m)).run().unwrap_err();
    assert!(err.to_string().contains("algorithm"), "{err}");

    // missing data
    let err = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions::default()))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("data source"), "{err}");

    // async with <2 parties
    let err = Job::builder()
        .algorithm(Algo::Asyn(
            AsynOptions { nodes: 1, ..Default::default() },
            SecureAlgo::AsynSd,
        ))
        .data(DataSource::Full(&m))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("2 parties"), "{err}");

    // missing shard directory: error, not panic
    let err = Job::builder()
        .algorithm(Algo::Dsanls(DsanlsOptions { nodes: 2, ..Default::default() }))
        .data(DataSource::ShardDir("/definitely/not/a/shard/dir".into()))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("manifest"), "{err}");
}
