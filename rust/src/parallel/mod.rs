//! Intra-node parallelism: a scoped fork-join helper over `std::thread`.
//!
//! The environment vendors neither `rayon` nor `tokio`, so the few places
//! that want intra-node parallel loops (blocked GEMM row panels, SpMM row
//! ranges) use [`par_chunks_mut`] / [`par_ranges`] built on
//! `std::thread::scope`. Threads are spawned per call; for the matrix sizes
//! in the benchmarks the spawn cost (~10µs) is far below the work per panel,
//! and keeping it dependency-free beats a handwritten work-stealing pool.
//!
//! Cluster-level parallelism (one thread per simulated node) lives in
//! [`crate::dist`], not here.

thread_local! {
    /// Per-thread override of the worker count. The simulated cluster sets
    /// this inside each node thread so that N node threads × inner GEMM
    /// threads never oversubscribe the machine (§Perf: the nested spawn
    /// storm inflated per-node wallclock ~5× on 10-node runs).
    static LOCAL_THREADS: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// Override the data-parallel worker count for the **current thread**
/// (`None` restores the global default). Used by [`crate::dist::run_cluster`].
pub fn set_local_threads(n: Option<usize>) {
    LOCAL_THREADS.with(|c| c.set(n.map(|v| v.max(1))));
}

/// Number of worker threads to use for data-parallel loops.
///
/// Per-thread override first (see [`set_local_threads`]), then
/// `DSANLS_THREADS`, then the machine's available parallelism capped at 8
/// (beyond that the memory-bound kernels stop scaling).
pub fn num_threads() -> usize {
    if let Some(n) = LOCAL_THREADS.with(|c| c.get()) {
        return n;
    }
    static N: once_cell::sync::Lazy<usize> = once_cell::sync::Lazy::new(|| {
        if let Ok(s) = std::env::var("DSANLS_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(8)
    });
    *N
}

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data`,
/// `chunk_len` elements each (last chunk may be short), on up to
/// [`num_threads`] threads.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len.max(1));
    if n_chunks <= 1 || num_threads() == 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    // Hand each worker an index into the chunk list via an atomic cursor.
    let chunks = std::sync::Mutex::new(
        chunks
            .into_iter()
            .map(Some)
            .collect::<Vec<Option<(usize, &mut [T])>>>(),
    );
    let workers = num_threads().min(n_chunks);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let item = {
                    let mut guard = chunks.lock().unwrap();
                    if i >= guard.len() {
                        return;
                    }
                    guard[i].take()
                };
                if let Some((idx, chunk)) = item {
                    f(idx, chunk);
                }
            });
        }
    });
}

/// Split `0..n` into `parts` near-equal contiguous ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f(range)` for each of `parts` near-equal subranges of `0..n` in
/// parallel. `f` must only touch data it can reach through shared refs —
/// use this for read-only sharding or interior-mutability-free reductions.
pub fn par_ranges<F>(n: usize, parts: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let ranges = split_ranges(n, parts.min(num_threads()).max(1));
    if ranges.len() <= 1 {
        for r in ranges {
            f(r);
        }
        return;
    }
    std::thread::scope(|s| {
        for r in ranges {
            let f = &f;
            s.spawn(move || f(r));
        }
    });
}

/// Parallel map over `0..parts`, collecting results in order.
pub fn par_map<T: Send, F>(parts: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if parts <= 1 {
        return (0..parts).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..parts).map(|_| None).collect();
    std::thread::scope(|s| {
        for (i, slot) in out.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || *slot = Some(f(i)));
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers() {
        for n in [0usize, 1, 7, 100, 101] {
            for parts in [1usize, 2, 3, 8] {
                let rs = split_ranges(n, parts);
                assert_eq!(rs.len(), parts);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                // contiguous and ordered
                let mut prev = 0;
                for r in rs {
                    assert_eq!(r.start, prev);
                    prev = r.end;
                }
                assert_eq!(prev, n);
            }
        }
    }

    #[test]
    fn par_chunks_mut_touches_all() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 37, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        // chunk 0 occupies first 37 slots
        assert!(v[..37].iter().all(|&x| x == 1));
    }

    #[test]
    fn par_map_ordered() {
        let out = par_map(16, |i| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_ranges_sums() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let total = AtomicUsize::new(0);
        par_ranges(1000, 8, |r| {
            total.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }
}
