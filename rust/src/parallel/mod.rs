//! Intra-node parallelism: a **persistent worker pool** with an atomic
//! task cursor.
//!
//! The environment vendors neither `rayon` nor `tokio`, so the parallel
//! loops under the GEMM kernels, SpMM and the row-parallel NLS solvers are
//! built on a hand-rolled pool:
//!
//! * Workers are spawned **once** (lazily, on the first parallel call) and
//!   parked on a condvar between jobs. The seed implementation spawned
//!   fresh OS threads on every `par_chunks_mut` call — ~10 µs per spawn ×
//!   6 spawns per GEMM × 4 GEMMs per ANLS iteration was pure overhead, and
//!   worse, it defeated thread-local pack-buffer reuse in the packed GEMM
//!   (every spawn re-allocated ~1 MB of packing scratch).
//! * A *job* is a closure plus an atomic cursor over `0..ntasks`; the
//!   calling thread participates, so a job can never deadlock even when
//!   every pool worker is busy (nested parallel calls from the simulated
//!   cluster's node threads degrade gracefully to caller-inline execution).
//! * [`set_local_threads`] still caps the per-call worker count for the
//!   current thread: the simulated cluster sets it inside each node thread
//!   so N node threads × inner GEMM workers never oversubscribe the
//!   machine (§Perf: the nested spawn storm inflated per-node wallclock
//!   ~5× on 10-node runs). The cap applies per job; the pool itself is
//!   process-wide.
//!
//! Cluster-level parallelism (one thread per simulated node) lives in
//! [`crate::dist`], not here.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once, OnceLock};

thread_local! {
    /// Per-thread override of the worker count. The simulated cluster sets
    /// this inside each node thread (see module docs).
    static LOCAL_THREADS: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// Override the data-parallel worker count for the **current thread**
/// (`None` restores the global default). Used by [`crate::dist::run_cluster`].
pub fn set_local_threads(n: Option<usize>) {
    LOCAL_THREADS.with(|c| c.set(n.map(|v| v.max(1))));
}

/// Global worker-count default: `DSANLS_THREADS`, else available
/// parallelism capped at 8 (beyond that the memory-bound kernels stop
/// scaling).
fn global_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("DSANLS_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    })
}

/// Number of worker threads to use for data-parallel loops on this thread.
/// Per-thread override first (see [`set_local_threads`]), then the global
/// default.
pub fn num_threads() -> usize {
    if let Some(n) = LOCAL_THREADS.with(|c| c.get()) {
        return n;
    }
    global_threads()
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// Lifetime-erased pointer to the job closure. The submitting thread blocks
/// inside [`run_tasks`] until every task has finished, so the pointee is
/// guaranteed alive whenever a worker dereferences it; dropping the raw
/// pointer itself is a no-op.
struct RawTask(*const (dyn Fn(usize) + Sync));

unsafe impl Send for RawTask {}
unsafe impl Sync for RawTask {}

struct Job {
    run: RawTask,
    ntasks: usize,
    /// Next task index to claim.
    cursor: AtomicUsize,
    /// Tasks not yet finished.
    pending: AtomicUsize,
    /// Threads currently attached to this job (the submitter counts as 1).
    joined: AtomicUsize,
    /// Maximum threads allowed on this job (honours the submitter's
    /// [`num_threads`], i.e. the cluster's oversubscription cap).
    max_workers: usize,
    panicked: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
}

struct PoolState {
    jobs: Vec<Arc<Job>>,
}

struct Pool {
    state: Mutex<PoolState>,
    cv: Condvar,
}

static POOL: OnceLock<Pool> = OnceLock::new();
static WORKERS_STARTED: Once = Once::new();

/// Number of persistent pool workers (the calling thread is always an extra
/// participant, so this is `global_threads() - 1`).
fn pool_worker_count() -> usize {
    global_threads().saturating_sub(1)
}

fn pool() -> &'static Pool {
    let p = POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState { jobs: Vec::new() }),
        cv: Condvar::new(),
    });
    WORKERS_STARTED.call_once(|| {
        for i in 0..pool_worker_count() {
            std::thread::Builder::new()
                .name(format!("dsanls-pool-{i}"))
                .spawn(move || worker_loop(POOL.get().expect("pool initialised")))
                .expect("failed to spawn pool worker");
        }
    });
    p
}

fn worker_loop(pool: &'static Pool) {
    loop {
        let job: Arc<Job> = {
            let mut st = pool.state.lock().unwrap();
            loop {
                // prune exhausted jobs, pick one that still has tasks and a
                // free worker slot
                st.jobs.retain(|j| j.cursor.load(Ordering::Relaxed) < j.ntasks);
                let picked = st.jobs.iter().find(|j| {
                    j.cursor.load(Ordering::Relaxed) < j.ntasks
                        && j.joined.load(Ordering::Relaxed) < j.max_workers
                });
                if let Some(j) = picked {
                    j.joined.fetch_add(1, Ordering::Relaxed);
                    break Arc::clone(j);
                }
                st = pool.cv.wait(st).unwrap();
            }
        };
        execute_job(&job);
    }
}

/// Claim and run tasks until the cursor is exhausted. Decrements `pending`
/// per finished task and flags completion. Panics inside tasks are caught
/// (the submitter re-raises) so a pool worker never dies.
fn execute_job(job: &Job) {
    loop {
        let i = job.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= job.ntasks {
            break;
        }
        let f = unsafe { &*job.run.0 };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
        if result.is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut d = job.done.lock().unwrap();
            *d = true;
            job.done_cv.notify_all();
        }
    }
}

/// Run `f(0..ntasks)` across the pool plus the calling thread, returning
/// when every task has completed. Worker count per job respects
/// [`num_threads`] of the caller.
fn run_tasks<F: Fn(usize) + Sync>(ntasks: usize, f: F) {
    if ntasks == 0 {
        return;
    }
    let workers = num_threads().min(ntasks);
    if ntasks == 1 || workers <= 1 || pool_worker_count() == 0 {
        for i in 0..ntasks {
            f(i);
        }
        return;
    }
    let f_obj: &(dyn Fn(usize) + Sync) = &f;
    // SAFETY: lifetime erasure only — this function does not return until
    // `pending` hits zero, i.e. until no thread will call (or claim) the
    // closure again, so the borrow outlives every dereference.
    let raw = RawTask(unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f_obj)
            as *const _
    });
    let job = Arc::new(Job {
        run: raw,
        ntasks,
        cursor: AtomicUsize::new(0),
        pending: AtomicUsize::new(ntasks),
        joined: AtomicUsize::new(1), // the submitter
        max_workers: workers,
        panicked: AtomicBool::new(false),
        done: Mutex::new(false),
        done_cv: Condvar::new(),
    });
    let pool = pool();
    {
        let mut st = pool.state.lock().unwrap();
        st.jobs.push(job.clone());
    }
    pool.cv.notify_all();
    // the submitter works too, so completion never depends on pool capacity
    execute_job(&job);
    {
        let mut d = job.done.lock().unwrap();
        while !*d {
            d = job.done_cv.wait(d).unwrap();
        }
    }
    {
        let mut st = pool.state.lock().unwrap();
        st.jobs.retain(|j| !Arc::ptr_eq(j, &job));
    }
    if job.panicked.load(Ordering::Relaxed) {
        panic!("parallel task panicked");
    }
}

// ---------------------------------------------------------------------------
// Public data-parallel helpers (same signatures as the seed)
// ---------------------------------------------------------------------------

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data`,
/// `chunk_len` elements each (last chunk may be short), on up to
/// [`num_threads`] threads.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let len = data.len();
    let n_chunks = len.div_ceil(chunk_len);
    if n_chunks <= 1 || num_threads() == 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let base = data.as_mut_ptr() as usize;
    run_tasks(n_chunks, |i| {
        let start = i * chunk_len;
        let clen = chunk_len.min(len - start);
        // SAFETY: chunks [start, start+clen) are disjoint across task
        // indices and in-bounds by construction.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(start), clen) };
        f(i, chunk);
    });
}

/// Split `0..n` into `parts` near-equal contiguous ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f(range)` for each of `parts` near-equal subranges of `0..n` in
/// parallel. `f` must only touch data it can reach through shared refs —
/// use this for read-only sharding or interior-mutability-free reductions.
pub fn par_ranges<F>(n: usize, parts: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let ranges = split_ranges(n, parts.min(num_threads()).max(1));
    if ranges.len() <= 1 {
        for r in ranges {
            f(r);
        }
        return;
    }
    run_tasks(ranges.len(), |i| f(ranges[i].clone()));
}

/// Parallel map over `0..parts`, collecting results in order.
pub fn par_map<T: Send, F>(parts: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if parts <= 1 || num_threads() == 1 {
        return (0..parts).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..parts).map(|_| None).collect();
    let base = out.as_mut_ptr() as usize;
    run_tasks(parts, |i| {
        // SAFETY: each task writes exactly one distinct, pre-initialised slot.
        let slot = unsafe { &mut *(base as *mut Option<T>).add(i) };
        *slot = Some(f(i));
    });
    out.into_iter().map(|x| x.expect("parallel map task skipped")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers() {
        for n in [0usize, 1, 7, 100, 101] {
            for parts in [1usize, 2, 3, 8] {
                let rs = split_ranges(n, parts);
                assert_eq!(rs.len(), parts);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                // contiguous and ordered
                let mut prev = 0;
                for r in rs {
                    assert_eq!(r.start, prev);
                    prev = r.end;
                }
                assert_eq!(prev, n);
            }
        }
    }

    #[test]
    fn par_chunks_mut_touches_all() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 37, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        // chunk 0 occupies first 37 slots
        assert!(v[..37].iter().all(|&x| x == 1));
    }

    #[test]
    fn par_map_ordered() {
        let out = par_map(16, |i| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_ranges_sums() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let total = AtomicUsize::new(0);
        par_ranges(1000, 8, |r| {
            total.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn pool_survives_many_jobs() {
        // the persistent pool must drain thousands of small jobs without
        // leaking or deadlocking (the seed spawned threads per call; the
        // pool reuses them)
        for round in 0..200 {
            let out = par_map(8, |i| i + round);
            assert_eq!(out.len(), 8);
            let mut v = vec![0u8; 256];
            par_chunks_mut(&mut v, 19, |_, c| c.fill(1));
            assert!(v.iter().all(|&x| x == 1));
        }
    }

    #[test]
    fn nested_parallelism_completes() {
        // a parallel task issuing its own parallel call must not deadlock:
        // the inner submitter participates in its own job
        let out = par_map(4, |i| {
            let inner = par_map(4, |j| i * 10 + j);
            inner.into_iter().sum::<usize>()
        });
        assert_eq!(out, vec![6, 46, 86, 126]);
    }

    #[test]
    fn panicking_task_propagates_to_submitter() {
        let caught = std::panic::catch_unwind(|| {
            par_map(8, |i| {
                if i == 5 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(caught.is_err(), "panic inside a parallel task must propagate");
        // and the pool must still be usable afterwards
        let ok = par_map(4, |i| i);
        assert_eq!(ok, vec![0, 1, 2, 3]);
    }

    #[test]
    fn local_thread_override_forces_inline() {
        set_local_threads(Some(1));
        let before = num_threads();
        assert_eq!(before, 1);
        let out = par_map(4, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6]);
        set_local_threads(None);
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        // simulate cluster node threads submitting jobs concurrently
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    set_local_threads(Some(2));
                    for round in 0..50 {
                        let out = par_map(6, |i| t * 1000 + round * 10 + i);
                        for (i, v) in out.iter().enumerate() {
                            assert_eq!(*v, t * 1000 + round * 10 + i);
                        }
                    }
                    set_local_threads(None);
                });
            }
        });
    }
}
