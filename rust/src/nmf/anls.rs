//! Centralized two-block NMF (Alg. 1) and its sketched variant SANLS
//! (Sec. 3.2). These single-machine loops serve as (a) correctness oracles
//! for the distributed versions (N=1 equivalence tests) and (b) the local
//! computation inside the secure protocols.

use std::time::Instant;

use super::{init_factors, rel_error, Factorization, MuSchedule};
use crate::linalg::{Mat, Matrix};
use crate::rng::{Role, StreamRng};
use crate::sketch::{SketchKind, SketchMatrix};
use crate::solvers::{self, SolverKind, Workspace};

/// Options for plain (unsketched) ANLS, Alg. 1.
#[derive(Debug, Clone)]
pub struct AnlsOptions {
    pub rank: usize,
    pub iterations: usize,
    pub solver: SolverKind,
    pub seed: u64,
    /// Evaluate the relative error every this many iterations (0 = only at
    /// the end). Evaluation time is excluded from the trace clock.
    pub eval_every: usize,
    /// Inner solver sweeps per outer iteration (exact ANLS uses >1 HALS
    /// sweeps; MU/BPP use 1).
    pub inner_sweeps: usize,
}

impl Default for AnlsOptions {
    fn default() -> Self {
        AnlsOptions {
            rank: 10,
            iterations: 50,
            solver: SolverKind::Hals,
            seed: 42,
            eval_every: 1,
            inner_sweeps: 1,
        }
    }
}

/// Centralized ANLS (Alg. 1): alternate exact/inexact NLS updates of U and V.
pub struct Anls {
    pub opts: AnlsOptions,
}

impl Anls {
    pub fn new(opts: AnlsOptions) -> Self {
        Anls { opts }
    }

    pub fn run(&self, m: &Matrix) -> Factorization {
        let o = &self.opts;
        let mut rng = StreamRng::new(o.seed).for_iteration(0, Role::Init);
        let (mut u, mut v) = init_factors(m, o.rank, &mut rng);
        let mt = m.transpose();

        let mut trace = Vec::new();
        let mut elapsed = 0.0f64;
        trace.push((0, 0.0, rel_error(m, &u, &v)));

        // gram/cross scratch shared by both factor steps, reused every
        // iteration — the steady-state loop allocates nothing here
        let mut ws = Workspace::new();
        for t in 0..o.iterations {
            let tick = Instant::now();
            // U-step: gram = VᵀV, cross = M·V
            update_unsketched(&mut u, m, &v, o.solver, t, o.inner_sweeps, &mut ws);
            // V-step: gram = UᵀU, cross = Mᵀ·U
            update_unsketched(&mut v, &mt, &u, o.solver, t, o.inner_sweeps, &mut ws);
            elapsed += tick.elapsed().as_secs_f64();

            if o.eval_every > 0 && (t + 1) % o.eval_every == 0 {
                trace.push((t + 1, elapsed, rel_error(m, &u, &v)));
            }
        }
        if trace.last().map(|&(i, _, _)| i) != Some(o.iterations) {
            trace.push((o.iterations, elapsed, rel_error(m, &u, &v)));
        }
        Factorization { u, v, trace }
    }
}

/// One unsketched factor update: solves `min_{X≥0} ‖M − X·Fᵀ‖` where `F` is
/// the fixed factor, using the requested solver. Shared by the centralized
/// loop and the secure protocols' local steps. The caller supplies the
/// [`Workspace`] holding the gram/cross scratch so repeated calls reuse it.
pub fn update_unsketched(
    x: &mut Mat,
    m: &Matrix,
    fixed: &Mat,
    solver: SolverKind,
    t: usize,
    sweeps: usize,
    ws: &mut Workspace,
) {
    let nrm = ws.normal_unsketched(m, fixed);
    for _ in 0..sweeps.max(1) {
        solvers::update_auto(solver, x, &nrm, &MuSchedule::default(), t);
    }
}

/// Options for SANLS (sketched ANLS, Sec. 3.2).
#[derive(Debug, Clone)]
pub struct SanlsOptions {
    pub rank: usize,
    pub iterations: usize,
    pub solver: SolverKind, // ProximalCd or Pgd (Theorem 1 solvers)
    pub sketch: SketchKind,
    /// Sketch size for the U-subproblem (d columns of S ∈ R^{n×d}).
    pub d_u: usize,
    /// Sketch size for the V-subproblem (d' columns of S' ∈ R^{m×d'}).
    pub d_v: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub mu: MuSchedule,
}

impl Default for SanlsOptions {
    fn default() -> Self {
        SanlsOptions {
            rank: 10,
            iterations: 100,
            solver: SolverKind::ProximalCd,
            sketch: SketchKind::Subsample,
            d_u: 0, // 0 ⇒ auto: n/10 (paper footnote 1)
            d_v: 0,
            seed: 42,
            eval_every: 1,
            mu: MuSchedule::default(),
        }
    }
}

impl SanlsOptions {
    /// Paper footnote 1: `d = 0.1·n` for medium matrices, floored to ≥ 2k.
    pub fn resolve_d(&self, n: usize, m: usize) -> (usize, usize) {
        let auto = |dim: usize| ((dim / 10).max(2 * self.rank)).min(dim).max(1);
        let du = if self.d_u == 0 { auto(n) } else { self.d_u.min(n) };
        let dv = if self.d_v == 0 { auto(m) } else { self.d_v.min(m) };
        (du, dv)
    }
}

/// Centralized SANLS (Sec. 3.2): sketch each NLS subproblem, solve it
/// inexactly with a Theorem-1 solver.
pub struct Sanls {
    pub opts: SanlsOptions,
}

impl Sanls {
    pub fn new(opts: SanlsOptions) -> Self {
        Sanls { opts }
    }

    pub fn run(&self, m: &Matrix) -> Factorization {
        let o = &self.opts;
        let stream = StreamRng::new(o.seed);
        let mut rng = stream.for_iteration(0, Role::Init);
        let (mut u, mut v) = init_factors(m, o.rank, &mut rng);
        let (n_rows, n_cols) = (m.rows(), m.cols());
        let (d_u, d_v) = o.resolve_d(n_cols, n_rows);
        let mt = m.transpose();

        let mut trace = Vec::new();
        let mut elapsed = 0.0f64;
        trace.push((0, 0.0, rel_error(m, &u, &v)));

        let mut ws = Workspace::new();
        for t in 0..o.iterations {
            let tick = Instant::now();
            assert!(
                matches!(o.solver, SolverKind::ProximalCd | SolverKind::Pgd),
                "SANLS requires a Theorem-1 solver (rcd or pgd)"
            );

            // --- U-subproblem: min ‖(M − U Vᵀ) Sᵗ‖ (Eq. 6) ---
            let mut s_rng = stream.for_iteration(t as u64, Role::SketchU);
            let s = SketchMatrix::generate(o.sketch, n_cols, d_u, &mut s_rng);
            let a = s.mul_right(m); // M·S  (m×d)
            let b = s.mul_rows_tn(&v, 0); // Vᵀ·S (k×d)
            let nrm = ws.normal_from(&a, &b);
            solvers::update_auto(o.solver, &mut u, &nrm, &o.mu, t);

            // --- V-subproblem: min ‖(Mᵀ − V Uᵀ) S'ᵗ‖ (Eq. 7) ---
            let mut s_rng = stream.for_iteration(t as u64, Role::SketchV);
            let s2 = SketchMatrix::generate(o.sketch, n_rows, d_v, &mut s_rng);
            let a2 = s2.mul_right(&mt); // Mᵀ·S' (n×d')
            let b2 = s2.mul_rows_tn(&u, 0); // Uᵀ·S' (k×d')
            let nrm2 = ws.normal_from(&a2, &b2);
            solvers::update_auto(o.solver, &mut v, &nrm2, &o.mu, t);

            elapsed += tick.elapsed().as_secs_f64();
            if o.eval_every > 0 && (t + 1) % o.eval_every == 0 {
                trace.push((t + 1, elapsed, rel_error(m, &u, &v)));
            }
        }
        if trace.last().map(|&(i, _, _)| i) != Some(o.iterations) {
            trace.push((o.iterations, elapsed, rel_error(m, &u, &v)));
        }
        Factorization { u, v, trace }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn low_rank_matrix(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed as u128, 0);
        let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
        Matrix::Dense(u.matmul_nt(&v))
    }

    #[test]
    fn anls_hals_converges_on_low_rank() {
        let m = low_rank_matrix(40, 30, 3, 71);
        let f = Anls::new(AnlsOptions {
            rank: 3,
            iterations: 80,
            solver: SolverKind::Hals,
            inner_sweeps: 2,
            ..Default::default()
        })
        .run(&m);
        assert!(f.final_error() < 0.05, "HALS err = {}", f.final_error());
        assert!(f.u.is_nonnegative() && f.v.is_nonnegative());
    }

    #[test]
    fn anls_mu_decreases_error() {
        let m = low_rank_matrix(30, 25, 3, 73);
        let f = Anls::new(AnlsOptions {
            rank: 3,
            iterations: 60,
            solver: SolverKind::Mu,
            ..Default::default()
        })
        .run(&m);
        let first = f.trace.first().unwrap().2;
        assert!(f.final_error() < 0.8 * first, "MU: {} -> {}", first, f.final_error());
    }

    #[test]
    fn anls_bpp_converges_fast_per_iteration() {
        let m = low_rank_matrix(25, 20, 3, 79);
        let f = Anls::new(AnlsOptions {
            rank: 3,
            iterations: 25,
            solver: SolverKind::AnlsBpp,
            ..Default::default()
        })
        .run(&m);
        assert!(f.final_error() < 0.05, "BPP err = {}", f.final_error());
    }

    #[test]
    fn sanls_converges_with_both_solvers_and_sketches() {
        let m = low_rank_matrix(60, 50, 3, 83);
        for solver in [SolverKind::ProximalCd, SolverKind::Pgd] {
            for sketch in [SketchKind::Subsample, SketchKind::Gaussian] {
                let f = Sanls::new(SanlsOptions {
                    rank: 3,
                    iterations: 150,
                    solver,
                    sketch,
                    d_u: 25,
                    d_v: 25,
                    eval_every: 10,
                    ..Default::default()
                })
                .run(&m);
                let first = f.trace.first().unwrap().2;
                assert!(
                    f.final_error() < 0.55 * first,
                    "{solver:?}/{sketch:?}: {} -> {}",
                    first,
                    f.final_error()
                );
                assert!(f.u.is_nonnegative() && f.v.is_nonnegative());
            }
        }
    }

    #[test]
    fn sanls_rcd_beats_pgd_per_iteration() {
        // The paper's Fig. 5 claim: RCD converges faster than PGD.
        let m = low_rank_matrix(50, 40, 4, 89);
        let run = |solver| {
            Sanls::new(SanlsOptions {
                rank: 4,
                iterations: 60,
                solver,
                sketch: SketchKind::Subsample,
                d_u: 20,
                d_v: 20,
                eval_every: 0,
                ..Default::default()
            })
            .run(&m)
            .final_error()
        };
        let rcd = run(SolverKind::ProximalCd);
        let pgd = run(SolverKind::Pgd);
        assert!(rcd <= pgd * 1.2, "RCD {rcd} not clearly ≤ PGD {pgd}");
    }
}
