//! Supervised execution control plane: cooperative cancellation,
//! convergence-based stopping, checkpoint/resume, and the shared token a
//! [`crate::nmf::job::JobHandle`] uses to steer a running cluster.
//!
//! The paper's experiment harness runs a *fixed* iteration count and
//! assumes every rank survives. A production service needs the opposite
//! defaults: a job should stop **when it has converged** (target relative
//! error), **when its time budget is spent** (wall-clock deadline), or
//! **when the operator says so** (cancellation) — and an interrupted job
//! should resume from its last checkpoint to **bit-identical** factors.
//! This module supplies those four pieces; the [`crate::nmf::job::Job`]
//! builder wires them into every algorithm runner.
//!
//! ## The collective stop decision
//!
//! Distributed cancellation has one hard constraint: every rank of a
//! synchronous cluster must leave the iteration loop at the **same**
//! iteration, or the survivors hang in a collective the leavers never
//! enter. [`RunControl::poll_sync`] therefore makes stopping itself a
//! collective: once per iteration every rank contributes its local view
//! (`cancelled? deadline passed? target reached?`) to a three-float
//! all-reduce, and all ranks apply the identical agreed decision. The
//! poll runs *untimed* ([`crate::dist::NodeCtx::untimed`]), so it
//! perturbs neither the modelled clock nor the byte counters the paper's
//! communication claims are asserted on.
//!
//! The asynchronous protocols have no collectives; their clients poll
//! [`RunControl::poll_local`] between rounds, and the parameter server
//! aggregates the clients' residual fractions to broadcast a
//! target-error stop flag in its replies (see [`crate::secure::asyn`]).
//!
//! ## Checkpoint format
//!
//! A checkpoint is the rank-0-assembled factor pair plus the run cursor:
//! because every random stream in the system is *derived* from
//! `(seed, iteration, role)` ([`crate::rng::StreamRng`]), the iteration
//! counter **is** the RNG cursor — restoring `(U, V, t)` and re-entering
//! the loop at `t` replays the exact tail of an uninterrupted run, so
//! resumed factors are bit-identical (asserted on both backends by
//! `tests/control_plane.rs`). Files are written atomically (tmp +
//! rename), versioned, and framed by magic headers/footers; a truncated
//! or corrupt file is a typed [`crate::error::Error`], never a panic.
//!
//! Checkpointing covers DSANLS and the MPI-FAUN baselines. The secure
//! protocols intentionally refuse it: their per-party state (`V_{J_r:}`,
//! mid-consensus `U_(r)` copies) must never leave the party, and a
//! central snapshot would do exactly that.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dist::NodeCtx;
use crate::error::{Context, Result};
use crate::linalg::Mat;
use crate::transport::Communicator;

// ---------------------------------------------------------------------------
// StopReason / StopPolicy
// ---------------------------------------------------------------------------

/// Why a run ended — surfaced in [`crate::nmf::job::Outcome::stop_reason`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The run executed its full iteration budget.
    Completed,
    /// [`ControlToken::cancel`] (or [`crate::nmf::job::JobHandle::cancel`])
    /// was observed at an iteration boundary.
    Cancelled,
    /// The [`StopPolicy::max_seconds`] wall-clock budget ran out.
    DeadlineExceeded,
    /// The traced relative error reached [`StopPolicy::target_error`].
    TargetReached,
}

impl StopReason {
    /// Stable wire/on-disk code.
    pub fn code(self) -> u64 {
        match self {
            StopReason::Completed => 0,
            StopReason::Cancelled => 1,
            StopReason::DeadlineExceeded => 2,
            StopReason::TargetReached => 3,
        }
    }

    /// Inverse of [`StopReason::code`].
    pub fn from_code(c: u64) -> Result<StopReason> {
        match c {
            0 => Ok(StopReason::Completed),
            1 => Ok(StopReason::Cancelled),
            2 => Ok(StopReason::DeadlineExceeded),
            3 => Ok(StopReason::TargetReached),
            other => crate::bail!("unknown stop-reason code {other}"),
        }
    }

    /// Human-readable label for run summaries.
    pub fn label(self) -> &'static str {
        match self {
            StopReason::Completed => "completed",
            StopReason::Cancelled => "cancelled",
            StopReason::DeadlineExceeded => "deadline exceeded",
            StopReason::TargetReached => "target error reached",
        }
    }

    fn priority(self) -> u8 {
        match self {
            StopReason::Completed => 0,
            StopReason::TargetReached => 1,
            StopReason::DeadlineExceeded => 2,
            StopReason::Cancelled => 3,
        }
    }

    /// Merge two ranks' reasons into the run-level one (most decisive
    /// wins: cancellation over deadline over convergence over completion —
    /// the same priority [`RunControl::poll_sync`] applies).
    pub fn merge(self, other: StopReason) -> StopReason {
        if self.priority() >= other.priority() {
            self
        } else {
            other
        }
    }
}

/// Early-stopping policy: any combination of a wall-clock budget and a
/// convergence target, on top of the algorithm's iteration budget (which
/// stays in the per-algorithm `*Options`).
#[derive(Debug, Clone, Copy, Default)]
pub struct StopPolicy {
    /// Wall-clock budget in seconds, measured from job start.
    pub max_seconds: Option<f64>,
    /// Stop once the traced relative error falls to (or below) this value.
    /// Only *traced* samples count — pair it with a non-zero `eval_every`.
    pub target_error: Option<f64>,
}

impl StopPolicy {
    /// A policy with no early stopping (run the full iteration budget).
    pub fn new() -> StopPolicy {
        StopPolicy::default()
    }

    /// Set the wall-clock budget.
    pub fn max_seconds(mut self, secs: f64) -> StopPolicy {
        self.max_seconds = Some(secs);
        self
    }

    /// Set the convergence target.
    pub fn target_error(mut self, err: f64) -> StopPolicy {
        self.target_error = Some(err);
        self
    }
}

// ---------------------------------------------------------------------------
// ControlToken
// ---------------------------------------------------------------------------

/// Shared cancellation token. Cloneable across threads via `Arc`; checked
/// cooperatively once per iteration by every algorithm runner.
///
/// Two grades of stopping:
/// * [`ControlToken::cancel`] — cooperative. Every rank observes the flag
///   at its next iteration boundary and the cluster agrees collectively,
///   so the job ends cleanly with [`StopReason::Cancelled`] and the
///   factors computed so far — bounded by **one iteration** of latency.
/// * [`ControlToken::kill`] — abortive. Also interrupts every registered
///   transport inbox, so ranks blocked in a TCP/simulated `read` unblock
///   immediately with an error instead of waiting out an iteration (or an
///   I/O timeout). The job aborts; partial results are lost.
#[derive(Default)]
pub struct ControlToken {
    cancelled: AtomicBool,
    killed: AtomicBool,
    /// Transport interrupters registered by the job drivers (one per
    /// backend inbox); invoked by [`ControlToken::kill`].
    #[allow(clippy::type_complexity)]
    interrupters: Mutex<Vec<Box<dyn Fn() + Send + Sync>>>,
}

impl std::fmt::Debug for ControlToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ControlToken")
            .field("cancelled", &self.is_cancelled())
            .field("killed", &self.is_killed())
            .finish()
    }
}

impl ControlToken {
    /// A fresh, uncancelled token.
    pub fn new() -> Arc<ControlToken> {
        Arc::new(ControlToken::default())
    }

    /// Request cooperative cancellation (observed within one iteration).
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
    }

    /// Has [`ControlToken::cancel`] (or `kill`) been called?
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// Abort: cancel *and* interrupt every registered transport inbox so
    /// blocked readers unblock immediately. The run ends with an error.
    ///
    /// The killed flag is set and the interrupter list drained under one
    /// lock, so an interrupter registered concurrently either observes
    /// the flag (and fires in `register_interrupter`) or lands in the
    /// list drained here — never neither.
    pub fn kill(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
        let fired = {
            let mut g = self.interrupters.lock().unwrap();
            self.killed.store(true, Ordering::SeqCst);
            std::mem::take(&mut *g)
        };
        for f in fired {
            f();
        }
    }

    /// Has [`ControlToken::kill`] been called?
    pub fn is_killed(&self) -> bool {
        self.killed.load(Ordering::SeqCst)
    }

    /// Register a transport interrupter (called by the job drivers when
    /// they stand up a backend). If the token was already killed the
    /// interrupter fires immediately (the killed check happens under the
    /// list lock — see [`ControlToken::kill`]).
    pub fn register_interrupter(&self, f: Box<dyn Fn() + Send + Sync>) {
        let mut g = self.interrupters.lock().unwrap();
        if self.is_killed() {
            drop(g);
            f();
            return;
        }
        g.push(f);
    }

    /// Drop every registered interrupter. The job drivers call this once a
    /// run finishes so a long-lived token (or [`crate::nmf::job::JobHandle`])
    /// does not keep the completed run's transport inboxes alive.
    pub fn clear_interrupters(&self) {
        self.interrupters.lock().unwrap().clear();
    }
}

// ---------------------------------------------------------------------------
// Checkpoint / resume configuration
// ---------------------------------------------------------------------------

/// Where and how often a run snapshots its factors.
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    /// Snapshot every `every` iterations (≥ 1).
    pub every: usize,
    /// Checkpoint file path (written atomically; overwritten in place).
    pub path: PathBuf,
}

/// A loaded checkpoint, resolved once by the job and shared read-only by
/// every rank (each slices its own blocks out of the assembled factors).
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Iteration the snapshot was taken at (the loop re-enters here).
    pub iteration: usize,
    /// Assembled row factor at `iteration`.
    pub u: Mat,
    /// Assembled column factor at `iteration`.
    pub v: Mat,
}

/// Identity of the run a checkpoint belongs to — everything that must
/// match for a resume to be bit-identical.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointMeta {
    /// Stable algorithm-family tag (`dsanls` / `dist-anls`).
    pub algo: String,
    /// Shared RNG seed (every stream derives from it).
    pub seed: u64,
    /// Factorisation rank `k`.
    pub k: usize,
    /// Global matrix rows.
    pub rows: usize,
    /// Global matrix columns.
    pub cols: usize,
    /// Fingerprint of every further result-affecting option (solver,
    /// sketch kind and sizes, μ schedule, …) — see [`params_fingerprint`].
    /// Seed/k/shape alone do not pin the trajectory: resuming with, say,
    /// a different `d_u` would replay a *different* tail and silently
    /// break the bit-identity guarantee.
    pub params: u64,
}

/// Order-sensitive FNV-1a fold over a run's result-affecting option words
/// — the checkpoint fingerprint. Each algorithm packs its options into
/// `u64` words (f32 knobs via `to_bits`, names via [`fingerprint_str`])
/// and folds them here; a resume is only accepted when the fingerprints
/// match.
pub fn params_fingerprint(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// FNV-1a of a name (solver / sketch kind) into one fingerprint word.
pub fn fingerprint_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// A checkpoint file read back from disk.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Run identity recorded at write time.
    pub meta: CheckpointMeta,
    /// The resumable state.
    pub state: ResumeState,
}

// ---------------------------------------------------------------------------
// RunControl: what the runners see
// ---------------------------------------------------------------------------

/// The resolved control plane one run executes under: the shared token,
/// the stop policy (with its deadline already anchored to job start),
/// checkpointing, and the optional resume state. One instance is shared
/// by reference across every rank of the run, which is what makes the
/// per-iteration stop poll agree by construction.
#[derive(Debug)]
pub struct RunControl {
    /// Cooperative cancellation flag.
    pub token: Arc<ControlToken>,
    /// Early-stopping policy.
    pub stop: StopPolicy,
    /// `Instant` the wall-clock budget expires at (anchored at job start).
    pub deadline: Option<Instant>,
    /// Periodic snapshotting (DSANLS / baselines only).
    pub checkpoint: Option<CheckpointCfg>,
    /// Loaded resume state (validated against the job before the run).
    pub resume: Option<Arc<ResumeState>>,
    /// Fault injection for tests and operator drills: the rank this
    /// control belongs to exits the process when its loop reaches this
    /// iteration (`dsanls worker --fault-iteration`). Never set by the
    /// library itself.
    pub fault_at: Option<usize>,
    /// Can anything ever flip this run's token? `true` for in-process jobs
    /// (the caller holds [`crate::nmf::job::Job::control_token`] or a
    /// `JobHandle`); `false` for `dsanls worker` ranks, whose token is
    /// created locally and unreachable. When this is `false` *and* no stop
    /// policy is set, [`RunControl::poll_sync`] skips its collective
    /// entirely — the poll is untimed for the modelled clock, but on the
    /// TCP backend it would still be a real network round trip per
    /// iteration bought for nothing.
    pub cancellable: bool,
    /// Elastic membership: when set, the runners replicate their boundary
    /// state each iteration and recover from peer loss by rebuilding the
    /// epoch ([`crate::dist::elastic`]) instead of dying.
    pub elastic: Option<ElasticCtl>,
}

/// Elastic-membership knobs a run executes under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElasticCtl {
    /// Smallest surviving-cluster size worth rebuilding for; below it a
    /// peer loss is fatal (the work distribution would be meaningless).
    pub min_ranks: usize,
}

impl RunControl {
    /// A control plane with nothing to do — the default for legacy
    /// blocking runs and helper tests.
    pub fn unsupervised() -> RunControl {
        RunControl {
            token: ControlToken::new(),
            stop: StopPolicy::default(),
            deadline: None,
            checkpoint: None,
            resume: None,
            fault_at: None,
            cancellable: false,
            elastic: None,
        }
    }

    /// Could this run ever stop early? When not — unreachable token, no
    /// deadline, no target — the per-iteration polls reduce to the fault
    /// hook and skip their collective/flag work.
    fn active(&self) -> bool {
        self.cancellable || self.deadline.is_some() || self.stop.target_error.is_some()
    }

    /// Anchor a policy's wall-clock budget at "now". Non-finite or absurd
    /// budgets are clamped to ~100 years (effectively "no deadline") —
    /// `Duration::from_secs_f64` panics on them, and misuse must stay a
    /// non-event, not a panic.
    pub fn deadline_from(stop: &StopPolicy) -> Option<Instant> {
        const FOREVER: f64 = 3.15e9; // ~100 years
        stop.max_seconds.map(|s| {
            let s = if s.is_finite() { s.clamp(0.0, FOREVER) } else { FOREVER };
            Instant::now() + Duration::from_secs_f64(s)
        })
    }

    /// The iteration the run's loop starts at (0, or the resume cursor).
    pub fn start_iteration(&self) -> usize {
        self.resume.as_ref().map_or(0, |r| r.iteration)
    }

    /// Should the run snapshot after completing iteration `done`?
    pub fn should_checkpoint(&self, done: usize) -> bool {
        match &self.checkpoint {
            Some(c) => c.every > 0 && done % c.every == 0,
            None => false,
        }
    }

    fn local_flags(&self, last_err: f64) -> [f32; 3] {
        let cancelled = self.token.is_cancelled();
        let late = self.deadline.is_some_and(|d| Instant::now() >= d);
        let converged = self
            .stop
            .target_error
            .is_some_and(|t| last_err.is_finite() && last_err <= t);
        let f = |b: bool| if b { 1.0f32 } else { 0.0 };
        [f(cancelled), f(late), f(converged)]
    }

    /// The per-iteration **collective** stop poll for the synchronous
    /// algorithms: all ranks contribute their local flags to an untimed
    /// three-float all-reduce and apply the identical agreed decision, so
    /// no rank ever leaves a collective loop alone. `last_err` is the most
    /// recently traced relative error (NaN when this rank has none — on
    /// the full-matrix path only rank 0 traces real values, and its flag
    /// alone decides). Priority: cancellation > deadline > convergence.
    pub fn poll_sync<C: Communicator>(
        &self,
        ctx: &mut NodeCtx<C>,
        iteration: usize,
        last_err: f64,
    ) -> Option<StopReason> {
        self.maybe_fault(iteration);
        if !self.active() {
            // nothing could ever stop this run early — skip the collective
            // (all ranks share this RunControl/config, so all skip alike)
            return None;
        }
        let mut flags = self.local_flags(last_err);
        ctx.untimed(|ctx| ctx.all_reduce_sum(&mut flags));
        if flags[0] > 0.0 {
            Some(StopReason::Cancelled)
        } else if flags[1] > 0.0 {
            Some(StopReason::DeadlineExceeded)
        } else if flags[2] > 0.0 {
            Some(StopReason::TargetReached)
        } else {
            None
        }
    }

    /// The communication-free stop poll for asynchronous clients (each
    /// client stops independently; there is no collective to desync).
    /// Convergence is decided by the parameter server, not here.
    pub fn poll_local(&self, iteration: usize) -> Option<StopReason> {
        self.maybe_fault(iteration);
        if !self.active() {
            return None;
        }
        let f = self.local_flags(f64::NAN);
        if f[0] > 0.0 {
            Some(StopReason::Cancelled)
        } else if f[1] > 0.0 {
            Some(StopReason::DeadlineExceeded)
        } else {
            None
        }
    }

    fn maybe_fault(&self, iteration: usize) {
        if self.fault_at == Some(iteration) {
            eprintln!("fault injection: dying at iteration {iteration} (--fault-iteration)");
            std::process::exit(101);
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint file I/O
// ---------------------------------------------------------------------------

/// On-disk checkpoint format version; readers reject mismatches with a
/// "re-checkpoint" diagnostic.
pub const CHECKPOINT_FORMAT_VERSION: u32 = 1;

const CKPT_MAGIC: &[u8; 8] = b"DSCKPT01";
const CKPT_FOOTER: &[u8; 8] = b"DSCKEND1";

/// Scalar/bulk encodings come from the shared [`crate::binio`] module;
/// `IO` pins the "checkpoint" error wording.
const IO: crate::binio::BinFormat = crate::binio::CHECKPOINT;

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    IO.write_u64(w, v)
}

fn write_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    IO.write_u32(w, v)
}

fn read_exact_ctx<R: Read>(r: &mut R, buf: &mut [u8], what: &str) -> Result<()> {
    IO.read_exact(r, buf, what)
}

fn read_u64<R: Read>(r: &mut R, what: &str) -> Result<u64> {
    IO.read_u64(r, what)
}

fn read_u32<R: Read>(r: &mut R, what: &str) -> Result<u32> {
    IO.read_u32(r, what)
}

fn write_mat<W: Write>(w: &mut W, m: &Mat) -> Result<()> {
    write_u64(w, m.rows() as u64)?;
    write_u64(w, m.cols() as u64)?;
    for &v in m.data() {
        w.write_all(&v.to_le_bytes()).context("writing checkpoint factor data")?;
    }
    Ok(())
}

fn read_mat<R: Read>(r: &mut R, what: &str) -> Result<Mat> {
    let rows = read_u64(r, "factor rows")? as usize;
    let cols = read_u64(r, "factor cols")? as usize;
    let n = rows
        .checked_mul(cols)
        .filter(|&n| n <= (1usize << 31))
        .with_context(|| format!("checkpoint {what} claims an implausible {rows}x{cols} shape"))?;
    let data = IO.read_f32s(r, n, what)?;
    Ok(Mat::from_vec(rows, cols, data))
}

/// Write a checkpoint **atomically**: the state is serialised to
/// `<path>.tmp` and renamed into place, so a crash mid-write can never
/// leave a half-written file where the resume path will look.
pub fn write_checkpoint(
    path: &Path,
    meta: &CheckpointMeta,
    iteration: usize,
    u: &Mat,
    v: &Mat,
) -> Result<()> {
    // append (never replace) the suffix: `run.1` and `run.2` must not
    // collide on one tmp file when two jobs checkpoint into one directory
    let mut tmp_name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("checkpoint"));
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating checkpoint {}", tmp.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(CKPT_MAGIC).context("writing checkpoint magic")?;
        write_u32(&mut w, CHECKPOINT_FORMAT_VERSION)?;
        let tag = meta.algo.as_bytes();
        write_u32(&mut w, tag.len() as u32)?;
        w.write_all(tag).context("writing checkpoint algo tag")?;
        write_u64(&mut w, meta.seed)?;
        write_u64(&mut w, meta.k as u64)?;
        write_u64(&mut w, meta.rows as u64)?;
        write_u64(&mut w, meta.cols as u64)?;
        write_u64(&mut w, meta.params)?;
        write_u64(&mut w, iteration as u64)?;
        write_mat(&mut w, u)?;
        write_mat(&mut w, v)?;
        w.write_all(CKPT_FOOTER).context("writing checkpoint footer")?;
        w.flush().context("flushing checkpoint")?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming checkpoint into place at {}", path.display()))
}

/// Read a checkpoint back, validating magic, version, shapes and the
/// end-of-file footer (which catches truncation after the factor data).
pub fn read_checkpoint(path: &Path) -> Result<Checkpoint> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    read_exact_ctx(&mut r, &mut magic, "magic")?;
    if &magic != CKPT_MAGIC {
        crate::bail!(
            "{}: bad magic {magic:02x?} — not a dsanls checkpoint",
            path.display()
        );
    }
    let version = read_u32(&mut r, "format version")?;
    if version != CHECKPOINT_FORMAT_VERSION {
        crate::bail!(
            "{}: checkpoint format version {version}, this binary reads \
             {CHECKPOINT_FORMAT_VERSION} — re-checkpoint with this binary",
            path.display()
        );
    }
    let tag_len = read_u32(&mut r, "algo tag length")? as usize;
    if tag_len > 64 {
        crate::bail!("checkpoint algo tag length {tag_len} is implausible (corrupt file?)");
    }
    let mut tag = vec![0u8; tag_len];
    read_exact_ctx(&mut r, &mut tag, "algo tag")?;
    let algo = String::from_utf8(tag).map_err(|_| crate::err!("checkpoint algo tag not UTF-8"))?;
    let seed = read_u64(&mut r, "seed")?;
    let k = read_u64(&mut r, "rank")? as usize;
    let rows = read_u64(&mut r, "rows")? as usize;
    let cols = read_u64(&mut r, "cols")? as usize;
    let params = read_u64(&mut r, "params fingerprint")?;
    let iteration = read_u64(&mut r, "iteration")? as usize;
    let u = read_mat(&mut r, "U factor")?;
    let v = read_mat(&mut r, "V factor")?;
    let mut footer = [0u8; 8];
    read_exact_ctx(&mut r, &mut footer, "footer")?;
    if &footer != CKPT_FOOTER {
        crate::bail!("{}: checkpoint footer missing (truncated file?)", path.display());
    }
    if (u.rows(), u.cols()) != (rows, k) || (v.rows(), v.cols()) != (cols, k) {
        crate::bail!(
            "checkpoint factors {}x{} / {}x{} do not match the recorded {rows}x{cols} rank-{k} run",
            u.rows(),
            u.cols(),
            v.rows(),
            v.cols()
        );
    }
    Ok(Checkpoint {
        meta: CheckpointMeta { algo, seed, k, rows, cols, params },
        state: ResumeState { iteration, u, v },
    })
}

impl Checkpoint {
    /// Validate this checkpoint against the run that wants to resume from
    /// it. Every mismatch is a typed error naming both sides: resuming a
    /// different algorithm, seed or shape would silently produce garbage
    /// factors otherwise.
    pub fn validate(
        &self,
        algo: &str,
        seed: u64,
        k: usize,
        rows: usize,
        cols: usize,
        params: u64,
        iterations: usize,
    ) -> Result<()> {
        if self.meta.algo != algo {
            crate::bail!(
                "checkpoint was written by {} but this job runs {algo}",
                self.meta.algo
            );
        }
        if self.meta.seed != seed {
            crate::bail!(
                "checkpoint seed {} does not match the job seed {seed} — resumed factors \
                 would not be bit-identical",
                self.meta.seed
            );
        }
        if (self.meta.k, self.meta.rows, self.meta.cols) != (k, rows, cols) {
            crate::bail!(
                "checkpoint is a {}x{} rank-{} run, this job is {rows}x{cols} rank-{k}",
                self.meta.rows,
                self.meta.cols,
                self.meta.k
            );
        }
        if self.meta.params != params {
            crate::bail!(
                "checkpoint was written with different algorithm options (solver / sketch \
                 sizes / μ schedule) — resumed factors would not be bit-identical; resume \
                 with the original options"
            );
        }
        if self.state.iteration >= iterations {
            crate::bail!(
                "checkpoint is at iteration {} but the job runs only {iterations} — nothing \
                 left to resume",
                self.state.iteration
            );
        }
        Ok(())
    }
}

/// Read + validate a resume checkpoint against a run identity in one step
/// — the single resolution path shared by the in-process
/// [`crate::nmf::job::Job`] and the `dsanls worker` CLI.
#[allow(clippy::too_many_arguments)]
pub fn load_resume(
    path: &Path,
    tag: &str,
    seed: u64,
    k: usize,
    rows: usize,
    cols: usize,
    params: u64,
    iterations: usize,
) -> Result<Arc<ResumeState>> {
    let ck = read_checkpoint(path)?;
    ck.validate(tag, seed, k, rows, cols, params, iterations)?;
    Ok(Arc::new(ck.state))
}

/// Fail fast on an unwritable checkpoint destination: the parent
/// directory must exist *before* the run starts — a mid-run checkpoint
/// write failure is fatal to the run and loses the compute so far, so a
/// typo'd path must not survive job validation.
pub fn validate_checkpoint_path(path: &Path) -> Result<()> {
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    if !parent.is_dir() {
        crate::bail!(
            "checkpoint directory {} does not exist — create it before the run (a mid-run \
             checkpoint write failure is fatal)",
            parent.display()
        );
    }
    Ok(())
}

/// Collective checkpoint: every rank contributes its factor blocks with
/// untimed all-gathers (so the snapshot does not disturb the measured
/// run), rank 0 assembles and writes the file. All ranks must call this
/// at the same iteration — guaranteed because [`RunControl`] is shared.
/// A write failure is fatal to the run (panics like a transport failure):
/// an operator who asked for checkpoints must not silently lose them.
pub fn checkpoint_sync<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    cfg: &CheckpointCfg,
    meta: &CheckpointMeta,
    iteration: usize,
    u_block: &Mat,
    v_block: &Mat,
) {
    let k = meta.k;
    let assembled = ctx.untimed(|ctx| {
        let u_blocks = ctx.all_gather(u_block.data());
        let v_blocks = ctx.all_gather(v_block.data());
        (ctx.rank == 0).then(|| {
            (
                crate::algos::assemble_blocks_pub(&u_blocks, k),
                crate::algos::assemble_blocks_pub(&v_blocks, k),
            )
        })
    });
    if let Some((u, v)) = assembled {
        write_checkpoint(&cfg.path, meta, iteration, &u, &v)
            .unwrap_or_else(|e| panic!("checkpoint at iteration {iteration} failed: {e}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> CheckpointMeta {
        CheckpointMeta { algo: "dsanls".into(), seed: 42, k: 3, rows: 8, cols: 6, params: 0xF1 }
    }

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dsanls_ckpt_{tag}_{}.ckpt", std::process::id()))
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        let path = tmpfile("rt");
        let u = Mat::from_fn(8, 3, |i, j| (i * 3 + j) as f32 * 0.25 + 0.125);
        let v = Mat::from_fn(6, 3, |i, j| (i * 3 + j) as f32 * -0.5);
        write_checkpoint(&path, &meta(), 7, &u, &v).unwrap();
        let back = read_checkpoint(&path).unwrap();
        assert_eq!(back.meta, meta());
        assert_eq!(back.state.iteration, 7);
        assert_eq!(back.state.u.data(), u.data());
        assert_eq!(back.state.v.data(), v.data());
        back.validate("dsanls", 42, 3, 8, 6, 0xF1, 10).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_and_truncated_checkpoints_are_typed_errors() {
        let path = tmpfile("bad");
        let u = Mat::from_fn(8, 3, |_, _| 1.0);
        let v = Mat::from_fn(6, 3, |_, _| 2.0);
        write_checkpoint(&path, &meta(), 3, &u, &v).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        // truncation at several prefixes (header, mid-factor, missing footer)
        for cut in [0usize, 5, 11, 30, 60, bytes.len() - 4] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(read_checkpoint(&path).is_err(), "cut at {cut} did not error");
        }

        // bad magic
        let mut b = bytes.clone();
        b[0] ^= 0xFF;
        std::fs::write(&path, &b).unwrap();
        assert!(read_checkpoint(&path).unwrap_err().to_string().contains("magic"));

        // wrong version
        let mut b = bytes.clone();
        b[8] = b[8].wrapping_add(1);
        std::fs::write(&path, &b).unwrap();
        assert!(read_checkpoint(&path).unwrap_err().to_string().contains("version"));

        // validation mismatches
        std::fs::write(&path, &bytes).unwrap();
        let ck = read_checkpoint(&path).unwrap();
        assert!(ck.validate("dist-anls", 42, 3, 8, 6, 0xF1, 10).is_err(), "algo mismatch");
        assert!(ck.validate("dsanls", 43, 3, 8, 6, 0xF1, 10).is_err(), "seed mismatch");
        assert!(ck.validate("dsanls", 42, 4, 8, 6, 0xF1, 10).is_err(), "rank mismatch");
        assert!(ck.validate("dsanls", 42, 3, 8, 6, 0xF2, 10).is_err(), "options mismatch");
        assert!(ck.validate("dsanls", 42, 3, 8, 6, 0xF1, 3).is_err(), "nothing to resume");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stop_reason_merge_prefers_decisive() {
        use StopReason::*;
        assert_eq!(Completed.merge(Cancelled), Cancelled);
        assert_eq!(TargetReached.merge(Completed), TargetReached);
        assert_eq!(DeadlineExceeded.merge(Cancelled), Cancelled);
        assert_eq!(Cancelled.merge(TargetReached), Cancelled);
        assert_eq!(Completed.merge(Completed), Completed);
        for r in [Completed, Cancelled, DeadlineExceeded, TargetReached] {
            assert_eq!(StopReason::from_code(r.code()).unwrap(), r);
        }
        assert!(StopReason::from_code(9).is_err());
    }

    #[test]
    fn token_flags_and_policy() {
        let t = ControlToken::new();
        assert!(!t.is_cancelled());
        t.cancel();
        assert!(t.is_cancelled() && !t.is_killed());
        let ctl = RunControl {
            token: t,
            stop: StopPolicy::new().target_error(0.5),
            deadline: None,
            checkpoint: None,
            resume: None,
            fault_at: None,
            cancellable: true,
            elastic: None,
        };
        let f = ctl.local_flags(0.4);
        assert_eq!(f, [1.0, 0.0, 1.0]);
        let f = ctl.local_flags(f64::NAN);
        assert_eq!(f[2], 0.0, "NaN error must not trigger the target");
        assert_eq!(ctl.poll_local(0), Some(StopReason::Cancelled));
    }

    #[test]
    fn checkpoint_cadence() {
        let mut ctl = RunControl::unsupervised();
        assert!(!ctl.should_checkpoint(4));
        ctl.checkpoint = Some(CheckpointCfg { every: 4, path: "x".into() });
        assert!(ctl.should_checkpoint(4) && ctl.should_checkpoint(8));
        assert!(!ctl.should_checkpoint(3));
    }
}
