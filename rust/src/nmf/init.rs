//! Factor initialisation.
//!
//! Uniform random entries scaled so the initial reconstruction matches the
//! input's mean magnitude: with `U, V ~ Uniform[0, s)`, `E[(UVᵀ)_{ij}] =
//! k·s²/4`, so `s = 2·√(mean(M)/k)` makes the first iterate start near the
//! right scale — without it, MU (multiplicative, scale-preserving) starts
//! orders of magnitude off and the Fig. 2 comparison would be distorted.

use crate::linalg::{Mat, Matrix};
use crate::rng::Pcg64;

/// The scale `s` used for Uniform[0, s) init.
pub fn init_scale(m: &Matrix, k: usize) -> f32 {
    init_scale_from(m.fro_sq(), m.rows(), m.cols(), k)
}

/// [`init_scale`] from global metadata only — what a sharded rank uses: it
/// holds a block of `M`, not `M`, but knows the exact global `‖M‖²_F`
/// (shard-file header or the ordered chain reduction in
/// [`crate::data::shard::exact_fro_sq`]). Feeding the exact norm keeps the
/// scale — and therefore every factor bit — identical to the full-matrix
/// path.
pub fn init_scale_from(fro_sq: f64, rows: usize, cols: usize, k: usize) -> f32 {
    // mean |entry| estimate via RMS (exact mean would need a full pass for
    // dense and is ~RMS for the nonnegative data we target)
    let rms = (fro_sq / (rows as f64 * cols as f64)).sqrt();
    // for sparse matrices the "typical" entry is the RMS over all cells
    // (zeros included) — that is what UVᵀ must reproduce on average
    2.0 * ((rms.max(1e-12) / k as f64).sqrt() as f32)
}

/// Draw `U (m×k)` and `V (n×k)` from the shared-seed stream: every node
/// calling this with the same rng state gets identical factors — required
/// by the distributed algorithms so that replicated state starts in sync.
pub fn init_factors(m: &Matrix, k: usize, rng: &mut Pcg64) -> (Mat, Mat) {
    init_factors_from(m.fro_sq(), m.rows(), m.cols(), k, rng)
}

/// [`init_factors`] from global metadata (shape + exact `‖M‖²_F`) — the
/// sharded-rank entry point. Identical draws, identical factors.
pub fn init_factors_from(
    fro_sq: f64,
    rows: usize,
    cols: usize,
    k: usize,
    rng: &mut Pcg64,
) -> (Mat, Mat) {
    let s = init_scale_from(fro_sq, rows, cols, k);
    let u = Mat::rand_uniform(rows, k, s, rng);
    let v = Mat::rand_uniform(cols, k, s, rng);
    (u, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_nonnegative() {
        let m = Matrix::Dense(Mat::from_fn(6, 5, |i, j| (i + j) as f32));
        let mut r1 = Pcg64::new(77, 0);
        let mut r2 = Pcg64::new(77, 0);
        let (u1, v1) = init_factors(&m, 3, &mut r1);
        let (u2, v2) = init_factors(&m, 3, &mut r2);
        assert_eq!(u1.data(), u2.data());
        assert_eq!(v1.data(), v2.data());
        assert!(u1.is_nonnegative() && v1.is_nonnegative());
        assert_eq!(u1.rows(), 6);
        assert_eq!(v1.rows(), 5);
    }

    #[test]
    fn initial_error_is_order_one() {
        // the init scale must place the starting relative error near 1,
        // not 10³ (which is what an unscaled init would give on large data)
        let mut rng = Pcg64::new(5, 5);
        let u0 = Mat::rand_uniform(50, 4, 3.0, &mut rng);
        let v0 = Mat::rand_uniform(40, 4, 3.0, &mut rng);
        let m = Matrix::Dense(u0.matmul_nt(&v0));
        let (u, v) = init_factors(&m, 4, &mut rng);
        let e = crate::nmf::rel_error(&m, &u, &v);
        assert!(e < 5.0, "initial error too large: {e}");
    }
}
