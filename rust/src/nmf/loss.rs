//! Relative-error evaluation `‖M − U·Vᵀ‖_F / ‖M‖_F` — the paper's error
//! measure (Sec. 5.1), computed without ever materialising the m×n
//! reconstruction:
//!
//! `‖M − UVᵀ‖² = ‖M‖² − 2·⟨M, UVᵀ⟩ + ⟨UᵀU, VᵀV⟩`
//!
//! * `⟨M, UVᵀ⟩` — dense: `⟨M·V, U⟩` (one m×k GEMM); sparse: a scan over
//!   nonzeros only ([`crate::linalg::Csr::dot_with_uv`]).
//! * `⟨UᵀU, VᵀV⟩` — two k×k grams and a k² dot.
//!
//! Cost: `O(nnz·k + (m+n)k²)` — the same trick MPI-FAUN uses, so error
//! evaluation never dominates the benchmarks.

use crate::linalg::{Mat, Matrix};

/// `(‖M‖²_F, ‖M − UVᵀ‖²_F)` — the pieces of the relative error.
pub fn rel_error_parts(m: &Matrix, u: &Mat, v: &Mat) -> (f64, f64) {
    assert_eq!(u.rows(), m.rows(), "U rows != M rows");
    assert_eq!(v.rows(), m.cols(), "V rows != M cols");
    assert_eq!(u.cols(), v.cols(), "rank mismatch");
    let m_sq = m.fro_sq();

    // ⟨M, UVᵀ⟩
    let cross = match m {
        Matrix::Dense(md) => {
            let mv = md.matmul(v); // m×k
            dot_flat(mv.data(), u.data())
        }
        Matrix::Sparse(ms) => ms.dot_with_uv(u, v),
    };

    // ⟨UᵀU, VᵀV⟩
    let gu = u.gram();
    let gv = v.gram();
    let rec_sq = dot_flat(gu.data(), gv.data());

    let resid = m_sq - 2.0 * cross + rec_sq;
    // Preserve NaN (diverged factors must surface as NaN, not silently
    // clamp to 0 — f64::max would swallow it); only clamp real round-off.
    let resid = if resid.is_finite() { resid.max(0.0) } else { f64::NAN };
    (m_sq, resid)
}

/// Relative error `‖M − UVᵀ‖_F / ‖M‖_F`.
pub fn rel_error(m: &Matrix, u: &Mat, v: &Mat) -> f64 {
    let (m_sq, resid) = rel_error_parts(m, u, v);
    if m_sq <= 0.0 {
        return 0.0;
    }
    (resid / m_sq).sqrt()
}

fn dot_flat(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(&x, &y)| x as f64 * y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Csr;
    use crate::rng::Pcg64;

    #[test]
    fn matches_explicit_reconstruction_dense() {
        let mut rng = Pcg64::new(31, 0);
        let m = Mat::rand_uniform(12, 9, 1.0, &mut rng);
        let u = Mat::rand_uniform(12, 4, 1.0, &mut rng);
        let v = Mat::rand_uniform(9, 4, 1.0, &mut rng);
        let explicit = (m.dist_sq(&u.matmul_nt(&v)) / m.fro_sq()).sqrt();
        let fast = rel_error(&Matrix::Dense(m), &u, &v);
        assert!((explicit - fast).abs() < 1e-4, "{explicit} vs {fast}");
    }

    #[test]
    fn matches_explicit_reconstruction_sparse() {
        let mut rng = Pcg64::new(32, 0);
        let dense = Mat::from_fn(15, 11, |i, j| {
            if (i * 11 + j) % 3 == 0 {
                ((i + 2 * j) as f32).cos().abs()
            } else {
                0.0
            }
        });
        let u = Mat::rand_uniform(15, 3, 1.0, &mut rng);
        let v = Mat::rand_uniform(11, 3, 1.0, &mut rng);
        let explicit = (dense.dist_sq(&u.matmul_nt(&v)) / dense.fro_sq()).sqrt();
        let sparse = Matrix::Sparse(Csr::from_dense(&dense, 0.0));
        let fast = rel_error(&sparse, &u, &v);
        assert!((explicit - fast).abs() < 1e-4, "{explicit} vs {fast}");
    }

    #[test]
    fn zero_when_exact() {
        let mut rng = Pcg64::new(33, 0);
        let u = Mat::rand_uniform(10, 3, 1.0, &mut rng);
        let v = Mat::rand_uniform(8, 3, 1.0, &mut rng);
        let m = Matrix::Dense(u.matmul_nt(&v));
        assert!(rel_error(&m, &u, &v) < 1e-3);
    }
}
