//! The unified `Job` API: **one builder for every algorithm × transport ×
//! data source**.
//!
//! The paper contributes a *family* of interchangeable distributed NMF
//! methods — DSANLS, the MPI-FAUN baselines, Syn-SD/SSD and Asyn-SD/SSD —
//! and this module is their single front door. A [`Job`] composes three
//! orthogonal axes:
//!
//! * **[`Algo`]** — which of the six methods runs, with its per-algorithm
//!   parameters (the existing `*Options` structs);
//! * **[`DataSource`]** — where each rank's data comes from: a
//!   caller-materialised matrix ([`DataSource::Full`]), shard-local
//!   windowed synthesis ([`DataSource::SyntheticWindow`] — no rank ever
//!   holds the full matrix), or a pre-sliced `dsanls shard` directory
//!   ([`DataSource::ShardDir`]);
//! * **[`Backend`]** — which transport the cluster runs on: the in-process
//!   simulated mesh with the modelled clock ([`Backend::Sim`]) or real
//!   localhost TCP sockets, one thread per rank ([`Backend::Tcp`]).
//!   (Multi-*process* and multi-host deployment keeps its dedicated
//!   `dsanls launch` / `dsanls worker` CLI, which drives the same
//!   [`Algorithm::run_rank`] node runners.)
//!
//! Because every per-rank node runner takes a resolved
//! [`NodeInput`] and every collective reduces in rank order, a seeded job
//! produces **bit-identical factors** across backends and data sources —
//! the property `tests/dist_equivalence.rs` and `dsanls launch
//! --verify-sim` assert.
//!
//! Progress can be **streamed** while the job runs: a
//! [`JobBuilder::observer`] callback receives every traced sample
//! ([`ProgressEvent`] — iteration, virtual clock, relative error,
//! communication statistics) the moment rank 0 records it, instead of
//! waiting for the post-hoc [`Outcome`] series. (The asynchronous
//! protocols log per-client samples with private clocks; their merged
//! trace is replayed to the observer at assembly, carrying the clients'
//! summed statistics.)
//!
//! ```
//! use dsanls::algos::DsanlsOptions;
//! use dsanls::linalg::{Mat, Matrix};
//! use dsanls::nmf::job::{Algo, Backend, DataSource, Job};
//! use dsanls::rng::Pcg64;
//!
//! let mut rng = Pcg64::new(7, 0);
//! let u = Mat::rand_uniform(40, 3, 1.0, &mut rng);
//! let v = Mat::rand_uniform(30, 3, 1.0, &mut rng);
//! let m = Matrix::Dense(u.matmul_nt(&v));
//!
//! let out = Job::builder()
//!     .algorithm(Algo::Dsanls(DsanlsOptions {
//!         nodes: 2,
//!         rank: 3,
//!         iterations: 4,
//!         d_u: 8,
//!         d_v: 8,
//!         eval_every: 2,
//!         ..Default::default()
//!     }))
//!     .data(DataSource::Full(&m))
//!     .transport(Backend::Sim)
//!     .run()
//!     .unwrap();
//! assert!(out.final_error().is_finite());
//! assert_eq!(out.u.rows(), 40);
//! ```
//!
//! ## Supervised lifecycle
//!
//! A job can also run **supervised**: [`Job::spawn`] starts it on a
//! background thread and returns a [`JobHandle`] with `cancel()` (clean,
//! within one iteration), `kill()` (abortive, unblocks stuck transport
//! reads), `wait()`/`try_wait()`, and `drain_progress()`. The builder's
//! control knobs — [`JobBuilder::stop`] (wall-clock deadline and/or
//! target relative error), [`JobBuilder::checkpoint_every`] and
//! [`JobBuilder::resume_from`] — apply to blocking and spawned runs
//! alike; a checkpointed job that is interrupted and resumed produces
//! factors **bit-identical** to the same job run uninterrupted (the
//! iteration counter is the full RNG cursor — see
//! [`crate::nmf::control`]).
//!
//! Misuse — a missing algorithm or data source, a shard directory built
//! for a different cluster size, an asynchronous run with fewer than two
//! parties, checkpointing a secure protocol — returns a typed
//! [`crate::error::Error`] from [`JobBuilder::build`] / [`Job::run`]; it
//! never panics.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::algos::{
    self, DistAnlsOptions, DsanlsOptions, NodeOutput, ObserverFn, ProgressEvent, TracePoint,
};
use crate::config::{Algorithm as ConfigAlgorithm, ExperimentConfig};
use crate::data::partition::{uniform_partition, Partition};
use crate::data::shard::{self, LoadSource, LoadStats, NodeData, NodeInput};
use crate::data::Dataset;
use crate::dist::{CommModel, CommStats, NodeCtx};
use crate::error::{Context, Result};
use crate::linalg::{Mat, Matrix};
use crate::metrics::Series;
use crate::nmf::control::{
    CheckpointCfg, ControlToken, ElasticCtl, RunControl, StopPolicy, StopReason,
};
use crate::nmf::{init_factors_from, rel_error};
use crate::rng::{Role, StreamRng};
use crate::secure::asyn::{self, AsynClientOutput, AsynOptions};
use crate::secure::syn::{self, SynNodeOutput, SynOptions};
use crate::secure::{AuditLog, SecureAlgo};
use crate::solvers::SolverKind;
use crate::transport::{
    Communicator, FaultKillSignal, FaultPlan, Rendezvous, SimCluster, SimComm, TcpComm,
    TcpOptions,
};

/// Wire precision for collective factor payloads, re-exported for the
/// builder surface: `.wire_precision(Wire::Bf16)`.
pub use crate::transport::wire::Precision as Wire;

// ---------------------------------------------------------------------------
// Outcome
// ---------------------------------------------------------------------------

/// The uniform outcome of any job (and of the legacy
/// [`crate::coordinator::run_experiment`] path, which is built on it).
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Human-readable run label (algorithm / backend).
    pub label: String,
    /// Error-over-time samples.
    pub trace: Vec<TracePoint>,
    /// Per-rank communication/compute statistics.
    pub stats: Vec<CommStats>,
    /// Seconds per iteration (simulated clock or TCP wall time).
    pub sec_per_iter: f64,
    /// Assembled row factor `U`.
    pub u: Mat,
    /// Assembled column factor `V`.
    pub v: Mat,
    /// Per-rank data-plane statistics (what each rank loaded, resident
    /// bytes, load time). Empty when every rank reads a shared
    /// caller-materialised matrix ([`DataSource::Full`]).
    pub loads: Vec<LoadStats>,
    /// Why the run ended: full iteration budget, cooperative cancellation,
    /// wall-clock deadline, or convergence to the target error.
    pub stop_reason: StopReason,
    /// Rank-failure retries consumed before this outcome (only the
    /// multi-process `dsanls launch` path retries; in-process jobs are 0).
    pub retries: usize,
    /// Membership epochs the cluster went through (1 for an undisturbed
    /// run; each elastic re-join adds one — see [`JobBuilder::elastic`]).
    pub epochs: usize,
}

impl Outcome {
    /// Last traced relative error (NaN on an empty trace).
    pub fn final_error(&self) -> f64 {
        self.trace.last().map(|p| p.rel_error).unwrap_or(f64::NAN)
    }

    /// Total bytes sent across all ranks.
    pub fn total_bytes_sent(&self) -> usize {
        self.stats.iter().map(|s| s.bytes_sent).sum()
    }

    /// The trace as a labelled CSV/plot series.
    pub fn series(&self) -> Series {
        Series::new(self.label.clone(), self.trace.clone())
    }

    /// Recompute the true global error of the returned factors (sanity
    /// check against the traced value).
    pub fn check_error(&self, m: &Matrix) -> f64 {
        rel_error(m, &self.u, &self.v)
    }

    /// View as the legacy [`crate::algos::DistRun`] (compatibility for
    /// code that still consumes the old result shape).
    pub fn into_dist_run(self) -> crate::algos::DistRun {
        crate::algos::DistRun {
            u: self.u,
            v: self.v,
            trace: self.trace,
            stats: self.stats,
            sec_per_iter: self.sec_per_iter,
        }
    }

    /// View as the legacy [`crate::secure::SecureRun`] (compatibility for
    /// code that still consumes the old result shape).
    pub fn into_secure_run(self) -> crate::secure::SecureRun {
        crate::secure::SecureRun {
            u: self.u,
            v: self.v,
            trace: self.trace,
            stats: self.stats,
            sec_per_iter: self.sec_per_iter,
        }
    }
}

// ---------------------------------------------------------------------------
// The three axes: Algo × DataSource × Backend
// ---------------------------------------------------------------------------

/// Which of the paper's six methods a job runs, with its per-algorithm
/// parameters. A new scenario is a new variant here — not a new family of
/// free functions.
#[derive(Debug, Clone)]
pub enum Algo {
    /// DSANLS (Alg. 2) — the paper's contribution.
    Dsanls(DsanlsOptions),
    /// MPI-FAUN-style unsketched baseline (MU / HALS / ANLS-BPP per
    /// `opts.solver`).
    DistAnls(DistAnlsOptions),
    /// Synchronous secure protocol: Syn-SD (Alg. 4) or a Syn-SSD variant
    /// (Alg. 5) per the [`SecureAlgo`] tag.
    Syn(SynOptions, SecureAlgo),
    /// Asynchronous secure protocol: Asyn-SD or Asyn-SSD-V (Alg. 6/7) per
    /// the [`SecureAlgo`] tag. Runs on `nodes + 1` ranks — the extra rank
    /// is the parameter server.
    Asyn(AsynOptions, SecureAlgo),
}

impl Algo {
    /// Map a CLI/TOML [`ExperimentConfig`] onto the algorithm axis — the
    /// single config→options mapping every driver (CLI `run`, `launch`
    /// workers, benches) shares.
    pub fn from_config(cfg: &ExperimentConfig) -> Algo {
        match cfg.algorithm {
            ConfigAlgorithm::Dsanls => Algo::Dsanls(dsanls_options(cfg)),
            ConfigAlgorithm::Baseline(solver) => Algo::DistAnls(dist_anls_options(cfg, solver)),
            ConfigAlgorithm::Secure(
                algo @ (SecureAlgo::SynSd
                | SecureAlgo::SynSsdU
                | SecureAlgo::SynSsdV
                | SecureAlgo::SynSsdUv),
            ) => Algo::Syn(syn_options(cfg), algo),
            ConfigAlgorithm::Secure(algo) => Algo::Asyn(asyn_options(cfg), algo),
        }
    }

    /// Checkpoint identity of this algorithm — `(tag, seed, k, iterations,
    /// params fingerprint)`, everything a resume must match. The single
    /// source both the in-process job and the `dsanls worker` CLI resolve
    /// checkpoints through; a typed error for the secure family, which
    /// refuses checkpointing (party-private state stays on the parties).
    pub fn ckpt_identity(&self) -> Result<(&'static str, u64, usize, usize, u64)> {
        match self {
            Algo::Dsanls(o) => Ok((
                algos::dsanls::CKPT_TAG,
                o.seed,
                o.rank,
                o.iterations,
                algos::dsanls::ckpt_params(o),
            )),
            Algo::DistAnls(o) => Ok((
                algos::dist_anls::CKPT_TAG,
                o.seed,
                o.rank,
                o.iterations,
                algos::dist_anls::ckpt_params(o),
            )),
            _ => crate::bail!(
                "checkpoint/resume supports DSANLS and the MPI-FAUN baselines only — the \
                 secure protocols keep party-private state on the parties, and a central \
                 snapshot would leak exactly that"
            ),
        }
    }
}

/// Where each rank's share of the input comes from.
#[derive(Debug, Clone)]
pub enum DataSource<'a> {
    /// A caller-materialised matrix every rank can see (each slices its own
    /// blocks) — the simulator/tests path, zero data-plane overhead.
    Full(&'a Matrix),
    /// Shard-local windowed synthesis: each rank generates **only its
    /// blocks** of the named dataset in a single generator pass
    /// ([`crate::data::shard::NodeData::generate`]) and the cluster
    /// resolves the exact global `‖M‖²` with the ordered chain reduction —
    /// bit-identical to [`DataSource::Full`] of the same dataset.
    SyntheticWindow {
        /// Which Table-1 workload to synthesise.
        dataset: Dataset,
        /// Generator seed.
        seed: u64,
        /// Dataset scale factor.
        scale: f64,
    },
    /// A `dsanls shard` directory: each rank reads only its block files;
    /// the manifest carries the exact global norm. The directory's rank
    /// count must match the algorithm's `nodes`.
    ShardDir(PathBuf),
    /// A `dsanls shard --compress` directory: each rank reads only its two
    /// fixed sketched views ([`crate::data::CompressedBlock`]) — the raw
    /// matrix never exists on any rank. DSANLS and the MPI-FAUN baselines
    /// factorize the views directly; the trace reports the sketched
    /// residual proxy against the manifest's `‖M·S_c‖²` constant.
    Compressed(PathBuf),
}

/// Which transport the cluster runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// In-process simulated mesh: N rank threads, modelled clock/stall
    /// accounting ([`CommModel`]).
    Sim,
    /// Real localhost TCP sockets, one thread per rank in this process
    /// (rendezvous + full peer mesh), measured wall-clock timing.
    Tcp {
        /// Rendezvous port (0 = ephemeral).
        port: u16,
    },
}

// ---------------------------------------------------------------------------
// The Algorithm trait: one generic node runner per method
// ---------------------------------------------------------------------------

/// Everything a rank needs besides its communicator: its resolved data
/// view, the secure column partition, and the optional streaming
/// observer/audit hooks.
pub struct RankEnv<'a> {
    /// This rank's id in `0..cluster_ranks()`.
    pub rank: usize,
    /// The rank's resolved view of the input.
    pub input: NodeInput<'a>,
    /// Column partition for the secure protocols (uniform by default).
    pub cols: &'a Partition,
    /// Streaming progress callback (rank 0 only; `None` elsewhere).
    pub observer: Option<&'a ObserverFn>,
    /// Outbound-payload audit log (secure protocols).
    pub audit: Option<&'a AuditLog>,
    /// The run's control plane (stop policy, cancellation token,
    /// checkpoint/resume) — shared by every rank of the run.
    pub ctl: &'a RunControl,
    /// This rank is a replacement that entered via the elastic epoch-join
    /// handshake: it skips init and recovers its state from the cluster's
    /// committed boundary instead ([`crate::dist::elastic`]).
    pub joining: bool,
}

/// What one rank returns — the union of the per-algorithm node outputs.
pub enum RankOutput {
    /// A DSANLS / baseline rank ([`NodeOutput`]).
    Node(NodeOutput),
    /// A synchronous secure party.
    Syn(SynNodeOutput),
    /// An asynchronous client.
    AsynClient(AsynClientOutput),
    /// The asynchronous parameter server: final `U` plus the exact global
    /// `‖M‖²` (the trace merge needs it).
    AsynServer {
        /// Final server factor.
        u: Mat,
        /// Exact global `‖M‖²_F`.
        fro_sq: f64,
    },
}

impl RankOutput {
    /// The stop reason this rank's loop ended with (the parameter server
    /// has none of its own — it serves until its clients leave).
    pub fn stop(&self) -> StopReason {
        match self {
            RankOutput::Node(o) => o.stop,
            RankOutput::Syn(o) => o.stop,
            RankOutput::AsynClient(o) => o.stop,
            RankOutput::AsynServer { .. } => StopReason::Completed,
        }
    }

    /// Membership epochs this rank participated in (the asynchronous
    /// family never rebuilds, so it is always 1 there).
    fn epochs(&self) -> usize {
        match self {
            RankOutput::Node(o) => o.epochs,
            RankOutput::Syn(o) => o.epochs,
            RankOutput::AsynClient(_) | RankOutput::AsynServer { .. } => 1,
        }
    }

    fn into_node(self, rank: usize) -> Result<NodeOutput> {
        match self {
            RankOutput::Node(o) => Ok(o),
            _ => Err(crate::err!("rank {rank} returned an unexpected output kind")),
        }
    }

    fn into_syn(self, rank: usize) -> Result<SynNodeOutput> {
        match self {
            RankOutput::Syn(o) => Ok(o),
            _ => Err(crate::err!("rank {rank} returned an unexpected output kind")),
        }
    }

    fn into_asyn_client(self, rank: usize) -> Result<AsynClientOutput> {
        match self {
            RankOutput::AsynClient(o) => Ok(o),
            _ => Err(crate::err!("rank {rank} returned an unexpected output kind")),
        }
    }
}

/// The per-algorithm surface the [`Job`] drivers (and the multi-process
/// `dsanls worker`) run against: validation, cluster shape, per-rank data
/// needs, the generic **node runner**, and the final reduction. Implemented
/// by [`Algo`]; a future method plugs in by extending the enum (or
/// providing its own implementation) — the drivers never change.
pub trait Algorithm {
    /// Human-readable run label (e.g. `DSANLS/S`, `Syn-SD`).
    fn label(&self) -> String;

    /// Data parties `N`.
    fn nodes(&self) -> usize;

    /// Total cluster ranks (`N`, plus the parameter server for the
    /// asynchronous protocols).
    fn cluster_ranks(&self) -> usize {
        self.nodes()
    }

    /// The modelled interconnect for the simulated backend.
    fn comm_model(&self) -> CommModel;

    /// Which blocks (`(row, col)`) `rank` keeps resident.
    fn block_needs(&self, rank: usize) -> (bool, bool);

    /// Parameter sanity — every violation is a typed error, not a panic.
    fn validate(&self) -> Result<()>;

    /// Run one rank over any transport. Consumes the communicator (the
    /// asynchronous protocols own theirs); synchronous methods wrap it in a
    /// [`NodeCtx`] internally.
    fn run_rank<C: Communicator>(&self, comm: C, env: RankEnv<'_>) -> Result<RankOutput>;

    /// Assemble rank-ordered outputs into the final [`Outcome`].
    fn reduce(
        &self,
        outputs: Vec<RankOutput>,
        label: String,
        loads: Vec<LoadStats>,
        observer: Option<&ObserverFn>,
    ) -> Result<Outcome>;
}

fn initial(name: &str) -> String {
    name.chars().next().unwrap_or('?').to_uppercase().to_string()
}

impl Algorithm for Algo {
    fn label(&self) -> String {
        match self {
            Algo::Dsanls(o) => format!("DSANLS/{}", initial(o.sketch.name())),
            Algo::DistAnls(o) => format!("MPI-FAUN-{}", o.solver.name().to_uppercase()),
            Algo::Syn(_, v) | Algo::Asyn(_, v) => v.name().into(),
        }
    }

    fn nodes(&self) -> usize {
        match self {
            Algo::Dsanls(o) => o.nodes,
            Algo::DistAnls(o) => o.nodes,
            Algo::Syn(o, _) => o.nodes,
            Algo::Asyn(o, _) => o.nodes,
        }
    }

    fn cluster_ranks(&self) -> usize {
        self.nodes() + usize::from(matches!(self, Algo::Asyn(..)))
    }

    fn comm_model(&self) -> CommModel {
        match self {
            Algo::Dsanls(o) => o.comm,
            Algo::DistAnls(o) => o.comm,
            Algo::Syn(o, _) => o.comm,
            Algo::Asyn(o, _) => o.comm,
        }
    }

    fn block_needs(&self, rank: usize) -> (bool, bool) {
        match self {
            // DSANLS and the baselines iterate on both the row and col block
            Algo::Dsanls(_) | Algo::DistAnls(_) => (true, true),
            // synchronous secure parties hold only their column block
            Algo::Syn(..) => (false, true),
            // async: clients hold a column block; the parameter server (rank
            // N) holds no data at all
            Algo::Asyn(o, _) => (false, rank < o.nodes),
        }
    }

    fn validate(&self) -> Result<()> {
        if self.nodes() == 0 {
            crate::bail!("a job needs at least one node");
        }
        match self {
            Algo::Dsanls(o) => {
                if !matches!(o.solver, SolverKind::ProximalCd | SolverKind::Pgd) {
                    crate::bail!(
                        "DSANLS requires a Theorem-1 solver (rcd or pgd), got {}",
                        o.solver.name()
                    );
                }
            }
            Algo::DistAnls(_) => {}
            Algo::Syn(_, v) => {
                if !matches!(
                    v,
                    SecureAlgo::SynSd
                        | SecureAlgo::SynSsdU
                        | SecureAlgo::SynSsdV
                        | SecureAlgo::SynSsdUv
                ) {
                    crate::bail!("Algo::Syn takes a synchronous variant, got {}", v.name());
                }
            }
            Algo::Asyn(o, v) => {
                if !matches!(v, SecureAlgo::AsynSd | SecureAlgo::AsynSsdV) {
                    crate::bail!("Algo::Asyn takes an asynchronous variant, got {}", v.name());
                }
                if o.nodes < 2 {
                    crate::bail!(
                        "the asynchronous protocols need at least 2 parties, got {}",
                        o.nodes
                    );
                }
            }
        }
        Ok(())
    }

    fn run_rank<C: Communicator>(&self, comm: C, env: RankEnv<'_>) -> Result<RankOutput> {
        match self {
            Algo::Dsanls(o) => {
                let mut ctx = NodeCtx::new(comm, o.comm);
                Ok(RankOutput::Node(algos::dsanls::dsanls_rank(
                    &mut ctx,
                    env.input,
                    o,
                    env.observer,
                    env.ctl,
                    env.joining,
                )))
            }
            Algo::DistAnls(o) => {
                let mut ctx = NodeCtx::new(comm, o.comm);
                Ok(RankOutput::Node(algos::dist_anls::dist_anls_rank(
                    &mut ctx,
                    env.input,
                    o,
                    env.observer,
                    env.ctl,
                    env.joining,
                )))
            }
            Algo::Syn(o, v) => {
                let mut ctx = NodeCtx::new(comm, o.comm);
                Ok(RankOutput::Syn(syn::syn_rank(
                    &mut ctx,
                    env.input,
                    env.cols,
                    o,
                    *v,
                    env.audit,
                    env.observer,
                    env.ctl,
                    env.joining,
                )))
            }
            Algo::Asyn(o, v) => {
                // shared-seed init from global metadata only: server and
                // every client derive identical factors at t=0
                let (rows, cols) = env.input.dims();
                let fro_sq = env.input.fro_sq();
                let stream = StreamRng::new(o.seed);
                let (u0, v_full) = {
                    let mut rng = stream.for_iteration(0, Role::Init);
                    init_factors_from(fro_sq, rows, cols, o.rank, &mut rng)
                };
                if env.rank == asyn::server_rank(o.nodes) {
                    Ok(RankOutput::AsynServer {
                        u: asyn::server_loop(comm, o, u0, env.ctl),
                        fro_sq,
                    })
                } else {
                    let v0 = v_full.row_block(env.cols.range(env.rank));
                    Ok(RankOutput::AsynClient(asyn::client_rank(
                        comm, env.rank, env.input, env.cols, o, *v, u0, v0, env.audit, env.ctl,
                    )))
                }
            }
        }
    }

    fn reduce(
        &self,
        outputs: Vec<RankOutput>,
        label: String,
        loads: Vec<LoadStats>,
        observer: Option<&ObserverFn>,
    ) -> Result<Outcome> {
        // run-level stop reason: the collectively agreed one for the
        // synchronous families (identical on every rank), the most decisive
        // across clients for the asynchronous ones
        let stop_reason = outputs
            .iter()
            .map(RankOutput::stop)
            .fold(StopReason::Completed, StopReason::merge);
        // every rank of an elastic run agrees on the epoch count by
        // construction (they rebuilt together); max() also covers a joiner
        // that entered mid-epoch
        let epochs = outputs.iter().map(RankOutput::epochs).max().unwrap_or(1).max(1);
        match self {
            Algo::Dsanls(_) | Algo::DistAnls(_) => {
                let (k, iters) = match self {
                    Algo::Dsanls(o) => (o.rank, o.iterations),
                    Algo::DistAnls(o) => (o.rank, o.iterations),
                    _ => unreachable!(),
                };
                let outs = outputs
                    .into_iter()
                    .enumerate()
                    .map(|(r, o)| o.into_node(r))
                    .collect::<Result<Vec<_>>>()?;
                // sec_per_iter divides by the iterations the clock actually
                // covers (early stop / resume), not the configured budget
                let span = algos::trace_span(&outs[0].trace, iters);
                let run = algos::reduce_outputs(outs, k, span);
                Ok(Outcome {
                    label,
                    trace: run.trace,
                    stats: run.stats,
                    sec_per_iter: run.sec_per_iter,
                    u: run.u,
                    v: run.v,
                    loads,
                    stop_reason,
                    retries: 0,
                    epochs,
                })
            }
            Algo::Syn(o, _) => {
                let outs = outputs
                    .into_iter()
                    .enumerate()
                    .map(|(r, out)| out.into_syn(r))
                    .collect::<Result<Vec<_>>>()?;
                let span = algos::trace_span(&outs[0].trace, o.t1 * o.t2);
                let run = syn::assemble_syn(outs, o.rank, span);
                Ok(Outcome {
                    label,
                    trace: run.trace,
                    stats: run.stats,
                    sec_per_iter: run.sec_per_iter,
                    u: run.u,
                    v: run.v,
                    loads,
                    stop_reason,
                    retries: 0,
                    epochs,
                })
            }
            Algo::Asyn(o, _) => {
                let mut outputs = outputs;
                let server = outputs.pop().context("async run returned no server output")?;
                let (u, fro_sq) = match server {
                    RankOutput::AsynServer { u, fro_sq } => (u, fro_sq),
                    _ => crate::bail!("last async rank was not the parameter server"),
                };
                let clients = outputs
                    .into_iter()
                    .enumerate()
                    .map(|(r, out)| out.into_asyn_client(r))
                    .collect::<Result<Vec<_>>>()?;
                let run = asyn::assemble_asyn(u, clients, o, fro_sq);
                if let Some(obs) = observer {
                    // async samples carry private client clocks; the global
                    // error only exists after the merge, so the stream is
                    // replayed here with the clients' summed statistics
                    let agg = sum_stats(&run.stats);
                    for p in &run.trace {
                        obs(&ProgressEvent {
                            iteration: p.iteration,
                            sim_time: p.sim_time,
                            rel_error: p.rel_error,
                            stats: agg,
                        });
                    }
                }
                Ok(Outcome {
                    label,
                    trace: run.trace,
                    stats: run.stats,
                    sec_per_iter: run.sec_per_iter,
                    u: run.u,
                    v: run.v,
                    loads,
                    stop_reason,
                    retries: 0,
                    epochs,
                })
            }
        }
    }
}

fn sum_stats(stats: &[CommStats]) -> CommStats {
    let mut t = CommStats::default();
    for s in stats {
        t.bytes_sent += s.bytes_sent;
        t.bytes_received += s.bytes_received;
        t.messages += s.messages;
        t.compute_time += s.compute_time;
        t.comm_time += s.comm_time;
        t.stall_time += s.stall_time;
    }
    t
}

// ---------------------------------------------------------------------------
// Config → options mapping (shared by run_on, launch workers and benches)
// ---------------------------------------------------------------------------

/// Map the generic config onto DSANLS options.
pub fn dsanls_options(cfg: &ExperimentConfig) -> DsanlsOptions {
    DsanlsOptions {
        nodes: cfg.nodes,
        rank: cfg.rank,
        iterations: cfg.iterations,
        solver: cfg.solver,
        sketch: cfg.sketch,
        d_u: cfg.d_u,
        d_v: cfg.d_v,
        seed: cfg.seed,
        eval_every: cfg.eval_every,
        mu: cfg.mu,
        comm: cfg.comm,
        box_bound: false,
        overlap: cfg.overlap_comm,
        precision: cfg.wire_precision,
    }
}

/// Map the generic config onto the MPI-FAUN baseline options.
pub fn dist_anls_options(cfg: &ExperimentConfig, solver: SolverKind) -> DistAnlsOptions {
    DistAnlsOptions {
        nodes: cfg.nodes,
        rank: cfg.rank,
        iterations: cfg.iterations,
        solver,
        seed: cfg.seed,
        eval_every: cfg.eval_every,
        comm: cfg.comm,
        inner_sweeps: 1,
        overlap: cfg.overlap_comm,
        precision: cfg.wire_precision,
    }
}

/// Map the generic config onto the synchronous secure options.
pub fn syn_options(cfg: &ExperimentConfig) -> SynOptions {
    SynOptions {
        nodes: cfg.nodes,
        rank: cfg.rank,
        t1: cfg.t1,
        t2: cfg.t2,
        solver: cfg.solver,
        mu: cfg.mu,
        d1: cfg.d_u,
        d2: cfg.d_v,
        d3: cfg.d_u,
        sketch: cfg.sketch,
        seed: cfg.seed,
        eval_every: cfg.eval_every,
        comm: cfg.comm,
        overlap: cfg.overlap_comm,
        precision: cfg.wire_precision,
    }
}

/// Map the generic config onto the asynchronous secure options.
pub fn asyn_options(cfg: &ExperimentConfig) -> AsynOptions {
    AsynOptions {
        nodes: cfg.nodes,
        rank: cfg.rank,
        rounds: cfg.rounds,
        local_iters: cfg.local_iters,
        solver: cfg.solver,
        mu: cfg.mu,
        d1: cfg.d_u,
        sketch: cfg.sketch,
        omega0: 0.5,
        tau: 10.0,
        seed: cfg.seed,
        comm: cfg.comm,
    }
}

// ---------------------------------------------------------------------------
// Job + builder
// ---------------------------------------------------------------------------

/// A fully-specified experiment: algorithm × data source × transport, plus
/// the optional knobs (thread cap, secure partition, observer, audit) and
/// the supervision plane (stop policy, checkpoint/resume, control token).
/// Build one with [`Job::builder`]; run it blocking with [`Job::run`] or
/// supervised in the background with [`Job::spawn`].
pub struct Job<'a> {
    algo: Algo,
    data: DataSource<'a>,
    backend: Backend,
    threads: Option<usize>,
    partition: Option<Partition>,
    observer: Option<&'a ObserverFn>,
    audit: Option<&'a AuditLog>,
    stop: StopPolicy,
    checkpoint: Option<CheckpointCfg>,
    resume: Option<PathBuf>,
    token: Arc<ControlToken>,
    elastic: Option<ElasticCtl>,
    fault_plan: Option<FaultPlan>,
}

/// Builder for [`Job`] — `algorithm` and `data` are required, everything
/// else has sensible defaults ([`Backend::Sim`], derived thread cap,
/// uniform partition, no observer/audit, no early stopping).
pub struct JobBuilder<'a> {
    algo: Option<Algo>,
    data: Option<DataSource<'a>>,
    backend: Backend,
    threads: Option<usize>,
    partition: Option<Partition>,
    observer: Option<&'a ObserverFn>,
    audit: Option<&'a AuditLog>,
    stop: StopPolicy,
    checkpoint: Option<CheckpointCfg>,
    resume: Option<PathBuf>,
    /// `Some` overrides the algorithm options' `overlap` flag at build time.
    overlap: Option<bool>,
    /// `Some` overrides the algorithm options' wire precision at build time.
    precision: Option<Wire>,
    elastic: bool,
    min_ranks: Option<usize>,
    fault_plan: Option<FaultPlan>,
}

impl<'a> Job<'a> {
    /// Start composing a job.
    pub fn builder() -> JobBuilder<'a> {
        JobBuilder {
            algo: None,
            data: None,
            backend: Backend::Sim,
            threads: None,
            partition: None,
            observer: None,
            audit: None,
            stop: StopPolicy::default(),
            checkpoint: None,
            resume: None,
            overlap: None,
            precision: None,
            elastic: false,
            min_ranks: None,
            fault_plan: None,
        }
    }

    /// The job's control token — cancel it from another thread while
    /// [`Job::run`] blocks ([`Job::spawn`] hands the same token back on
    /// its [`JobHandle`]). Clone it **before** calling `run()`: a run that
    /// starts with no outstanding token clones knows nothing can cancel it
    /// and skips the per-iteration cancellation poll.
    pub fn control_token(&self) -> Arc<ControlToken> {
        self.token.clone()
    }

    /// Resolve the run's control plane: anchor the deadline, validate the
    /// checkpoint cadence, load + validate the resume checkpoint.
    fn resolve_control(&self, rows: usize, cols: usize) -> Result<RunControl> {
        let mut resume = None;
        if self.checkpoint.is_some() || self.resume.is_some() {
            let (tag, seed, k, iterations, params) = self.algo.ckpt_identity()?;
            if let Some(c) = &self.checkpoint {
                if c.every == 0 {
                    crate::bail!("checkpoint_every needs a cadence ≥ 1 iteration");
                }
                crate::nmf::control::validate_checkpoint_path(&c.path)?;
            }
            if let Some(path) = &self.resume {
                resume = Some(crate::nmf::control::load_resume(
                    path, tag, seed, k, rows, cols, params, iterations,
                )?);
            }
        }
        Ok(RunControl {
            // cancellation is only possible if the token escaped this Job
            // (via control_token() or a JobHandle clone). A plain
            // JobBuilder::run() holds the only reference, so the
            // per-iteration stop poll can skip its collective — on the TCP
            // backend that is a real round trip per iteration. Grab the
            // token BEFORE calling run(): the decision is made here, once.
            cancellable: Arc::strong_count(&self.token) > 1,
            token: self.token.clone(),
            stop: self.stop,
            deadline: RunControl::deadline_from(&self.stop),
            checkpoint: self.checkpoint.clone(),
            resume,
            fault_at: None,
            elastic: self.elastic,
        })
    }

    /// Run the job **blocking** and assemble the [`Outcome`]. Semantically
    /// `spawn()` + `wait()` — implemented in place so borrowed data
    /// sources ([`DataSource::Full`]) and borrowed observers need no
    /// clone. The control plane is fully honoured: another thread holding
    /// [`Job::control_token`] can cancel, and stop policies, checkpoints
    /// and resume behave identically to a spawned job.
    pub fn run(&self) -> Result<Outcome> {
        self.algo.validate()?;
        let nodes = self.algo.nodes();
        if self.threads == Some(0) {
            crate::bail!("threads(0) is not a valid per-rank cap");
        }

        // resolve the global shape (and fail fast on a mismatched shard
        // dir); shard manifests carry their own column partition
        let (rows, cols, shard_cols) = match &self.data {
            DataSource::Full(m) => (m.rows(), m.cols(), None),
            DataSource::SyntheticWindow { dataset, scale, .. } => {
                let (r, c) = dataset.scaled_shape(*scale);
                (r, c, None)
            }
            DataSource::ShardDir(dir) => {
                let man = shard::read_manifest(dir)?;
                if man.nodes != nodes {
                    crate::bail!(
                        "shard directory {} was built for {} nodes, this job runs {nodes} — \
                         re-run `dsanls shard`",
                        dir.display(),
                        man.nodes
                    );
                }
                man.require_uniform_for(
                    dir,
                    matches!(self.algo, Algo::Syn(..) | Algo::Asyn(..)),
                )?;
                (man.rows, man.cols, Some(man.col_partition()))
            }
            DataSource::Compressed(dir) => {
                let man = crate::data::compress::read_compressed_manifest(dir)?;
                if man.base.nodes != nodes {
                    crate::bail!(
                        "compressed shard directory {} was built for {} nodes, this job \
                         runs {nodes} — re-run `dsanls shard --compress`",
                        dir.display(),
                        man.base.nodes
                    );
                }
                (man.base.rows, man.base.cols, None)
            }
        };

        // resolve + validate the secure column partition
        let cols_part = match (&self.partition, &self.algo) {
            (Some(p), Algo::Syn(..) | Algo::Asyn(..)) => {
                if p.nodes() != nodes {
                    crate::bail!(
                        "secure partition covers {} parties but the job runs {nodes}",
                        p.nodes()
                    );
                }
                if p.total != cols {
                    crate::bail!(
                        "secure partition spans {} columns but the data has {cols}",
                        p.total
                    );
                }
                if let Some(sp) = &shard_cols {
                    if p != sp {
                        crate::bail!(
                            "secure partition does not match the shard directory's own \
                             column partition — shard directories carry theirs in the \
                             manifest; drop .secure_partition(..)"
                        );
                    }
                }
                p.clone()
            }
            (Some(_), _) => {
                crate::bail!("secure_partition only applies to the secure protocols")
            }
            (None, _) => shard_cols.unwrap_or_else(|| uniform_partition(cols, nodes)),
        };

        let ctl = self.resolve_control(rows, cols)?;
        let label = match self.backend {
            Backend::Sim => self.algo.label(),
            Backend::Tcp { .. } => format!("{}/tcp", self.algo.label()),
        };
        // drop-guard: whether the drivers return, error or PANIC (a killed
        // job panics out of its collectives), the transport interrupters
        // must come off the token so a long-lived token (or JobHandle)
        // does not pin this run's inbox buffers
        struct ClearInterrupters<'t>(&'t ControlToken);
        impl Drop for ClearInterrupters<'_> {
            fn drop(&mut self) {
                self.0.clear_interrupters();
            }
        }
        let _clear = ClearInterrupters(&self.token);

        let res = Resolved { job: self, rows, cols, cols_part, ctl: &ctl };
        // a rank panic — most importantly the one ControlToken::kill()
        // provokes by interrupting blocked reads — must surface as the
        // documented typed error, not unwind into the caller's thread
        let driven = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || match self.backend {
                Backend::Sim => drive_sim(&res),
                Backend::Tcp { port } => drive_tcp(&res, port),
            },
        ));
        let results = match driven {
            Ok(r) => r?,
            Err(panic) => return Err(panic_to_error(panic, &self.token)),
        };
        let mut outputs = Vec::with_capacity(results.len());
        let mut loads = Vec::new();
        for r in results {
            outputs.push(r.out);
            loads.extend(r.load);
        }
        self.algo.reduce(outputs, label, loads, self.observer)
    }

    /// Start the job on a **background thread** and return a supervising
    /// [`JobHandle`] offering cancellation, `wait`/`try_wait`, and live
    /// progress draining.
    ///
    /// Ownership: a spawned job must own everything it touches, so a
    /// [`DataSource::Full`] matrix is **cloned** once here (synthetic
    /// windows and shard directories are already owned descriptions).
    /// Borrowed hooks cannot cross the thread boundary: progress streams
    /// through [`JobHandle::drain_progress`] instead of a builder
    /// observer, and the audit harness requires the blocking [`Job::run`].
    pub fn spawn(self) -> Result<JobHandle> {
        self.algo.validate()?; // fail fast, before a thread exists
        if self.observer.is_some() {
            crate::bail!(
                "spawned jobs stream progress through JobHandle::drain_progress() — drop \
                 .observer(..) (it borrows from the caller) or use the blocking run()"
            );
        }
        if self.audit.is_some() {
            crate::bail!(
                "the audit harness borrows from the caller; use the blocking run() for \
                 audited jobs"
            );
        }
        let token = self.token.clone();
        let events: Arc<Mutex<Vec<ProgressEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let data = OwnedData::from_source(&self.data);
        let Job {
            algo,
            backend,
            threads,
            partition,
            stop,
            checkpoint,
            resume,
            elastic,
            fault_plan,
            ..
        } = self;
        let ev = events.clone();
        let tok = token.clone();
        let thread = std::thread::Builder::new()
            .name("dsanls-job".into())
            .spawn(move || -> Result<Outcome> {
                let obs = move |e: &ProgressEvent| ev.lock().unwrap().push(*e);
                let job = Job {
                    algo,
                    data: data.as_source(),
                    backend,
                    threads,
                    partition,
                    observer: Some(&obs),
                    audit: None,
                    stop,
                    checkpoint,
                    resume,
                    token: tok,
                    elastic,
                    fault_plan,
                };
                // a panic outside the drivers (run() already contains rank
                // panics) must reach wait() as a typed error, not a dead
                // thread
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job.run())) {
                    Ok(out) => out,
                    Err(panic) => Err(panic_to_error(panic, &job.token)),
                }
            })
            .context("spawning the job thread")?;
        Ok(JobHandle { token, events, thread: Some(thread) })
    }
}

/// Map a caught rank panic onto the typed error a supervised run reports
/// — shared by the blocking ([`Job::run`]) and spawned ([`Job::spawn`])
/// paths, so `kill()` panics get the same "job killed" framing on both.
fn panic_to_error(
    panic: Box<dyn std::any::Any + Send>,
    token: &ControlToken,
) -> crate::error::Error {
    let msg = panic
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
        .or_else(|| {
            // a peer loss that was not (or could not be) recovered
            panic
                .downcast_ref::<crate::transport::PeerLostSignal>()
                .map(|s| s.detail.clone())
        })
        .or_else(|| {
            // a scripted kill on a non-elastic job is plain death
            panic.downcast_ref::<FaultKillSignal>().map(|s| {
                format!("rank {} killed by the fault plan at iteration {}", s.rank, s.iteration)
            })
        })
        .unwrap_or_else(|| "job panicked".into());
    if token.is_killed() {
        crate::error::Error::msg(format!("job killed: {msg}"))
    } else {
        crate::error::Error::msg(msg)
    }
}

/// Owned mirror of [`DataSource`] — what a spawned job carries across the
/// thread boundary.
enum OwnedData {
    Full(Matrix),
    Synthetic { dataset: Dataset, seed: u64, scale: f64 },
    ShardDir(PathBuf),
    Compressed(PathBuf),
}

impl OwnedData {
    fn from_source(d: &DataSource<'_>) -> OwnedData {
        match d {
            DataSource::Full(m) => OwnedData::Full((*m).clone()),
            DataSource::SyntheticWindow { dataset, seed, scale } => {
                OwnedData::Synthetic { dataset: *dataset, seed: *seed, scale: *scale }
            }
            DataSource::ShardDir(p) => OwnedData::ShardDir(p.clone()),
            DataSource::Compressed(p) => OwnedData::Compressed(p.clone()),
        }
    }

    fn as_source(&self) -> DataSource<'_> {
        match self {
            OwnedData::Full(m) => DataSource::Full(m),
            OwnedData::Synthetic { dataset, seed, scale } => {
                DataSource::SyntheticWindow { dataset: *dataset, seed: *seed, scale: *scale }
            }
            OwnedData::ShardDir(p) => DataSource::ShardDir(p.clone()),
            OwnedData::Compressed(p) => DataSource::Compressed(p.clone()),
        }
    }
}

// ---------------------------------------------------------------------------
// JobHandle: the supervising side of a spawned job
// ---------------------------------------------------------------------------

/// Handle to a job running on a background thread ([`Job::spawn`]).
///
/// * [`JobHandle::cancel`] — cooperative: every rank observes the shared
///   [`ControlToken`] at its next iteration boundary and the cluster
///   agrees collectively, so the job returns within **one iteration**
///   with [`StopReason::Cancelled`] and the factors computed so far.
/// * [`JobHandle::kill`] — abortive: interrupts blocked transport reads
///   (TCP and simulated); the job returns an error promptly and partial
///   results are lost.
/// * [`JobHandle::drain_progress`] — every traced sample recorded since
///   the last drain, without blocking the run.
pub struct JobHandle {
    token: Arc<ControlToken>,
    events: Arc<Mutex<Vec<ProgressEvent>>>,
    thread: Option<std::thread::JoinHandle<Result<Outcome>>>,
}

impl JobHandle {
    /// Request cooperative cancellation (bounded by one iteration).
    pub fn cancel(&self) {
        self.token.cancel();
    }

    /// Abort: cancel *and* interrupt blocked transport reads. The job
    /// returns an error; use [`JobHandle::cancel`] for a clean outcome.
    pub fn kill(&self) {
        self.token.kill();
    }

    /// The shared control token (e.g. to hand to a signal handler).
    pub fn token(&self) -> Arc<ControlToken> {
        self.token.clone()
    }

    /// Has the job finished (successfully or not)?
    pub fn is_finished(&self) -> bool {
        self.thread.as_ref().map_or(true, |t| t.is_finished())
    }

    /// Drain every progress event recorded since the last drain (the
    /// spawned job's replacement for a builder observer).
    pub fn drain_progress(&self) -> Vec<ProgressEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    fn join(&mut self) -> Result<Outcome> {
        let thread = self
            .thread
            .take()
            .context("the job was already waited on")?;
        match thread.join() {
            Ok(res) => res,
            Err(_) => Err(crate::err!("job thread panicked")),
        }
    }

    /// Block until the job finishes and return its [`Outcome`].
    pub fn wait(mut self) -> Result<Outcome> {
        self.join()
    }

    /// Non-blocking check: `Ok(Some(outcome))` once the job finished,
    /// `Ok(None)` while it is still running. After it returns an outcome
    /// (or error) the handle is spent.
    pub fn try_wait(&mut self) -> Result<Option<Outcome>> {
        if self.thread.is_none() {
            crate::bail!("the job was already waited on");
        }
        if !self.is_finished() {
            return Ok(None);
        }
        self.join().map(Some)
    }
}

impl<'a> JobBuilder<'a> {
    /// Which algorithm to run (required).
    pub fn algorithm(mut self, algo: Algo) -> Self {
        self.algo = Some(algo);
        self
    }

    /// Algorithm + partition skew straight from a CLI/TOML config.
    pub fn from_config(self, cfg: &ExperimentConfig, data_cols: usize) -> Self {
        let algo = Algo::from_config(cfg);
        let b = match &algo {
            Algo::Syn(..) | Algo::Asyn(..) => {
                self.secure_partition(crate::coordinator::secure_partition(cfg, data_cols))
            }
            _ => self,
        };
        b.algorithm(algo)
    }

    /// Where each rank's data comes from (required).
    pub fn data(mut self, data: DataSource<'a>) -> Self {
        self.data = Some(data);
        self
    }

    /// Which transport backend runs the cluster (default [`Backend::Sim`]).
    pub fn transport(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Override the per-rank intra-node thread cap (default: machine cores
    /// divided evenly across ranks — the cap that keeps sim and TCP
    /// bit-identical; any override is applied identically on both
    /// backends).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Column partition for the secure protocols (default uniform; pass
    /// [`crate::data::partition::imbalanced_partition`] for the skewed
    /// Fig. 7/9 workloads).
    pub fn secure_partition(mut self, p: Partition) -> Self {
        self.partition = Some(p);
        self
    }

    /// Stream every traced sample to `f` as rank 0 records it.
    pub fn observer(mut self, f: &'a ObserverFn) -> Self {
        self.observer = Some(f);
        self
    }

    /// Record every outbound secure-protocol payload into `log` (the
    /// Definition-1 audit harness).
    pub fn audit(mut self, log: &'a AuditLog) -> Self {
        self.audit = Some(log);
        self
    }

    /// Early-stopping policy (wall-clock budget and/or convergence
    /// target) on top of the algorithm's iteration budget.
    pub fn stop(mut self, policy: StopPolicy) -> Self {
        self.stop = policy;
        self
    }

    /// Convenience: stop once this many wall-clock seconds elapsed.
    pub fn max_seconds(mut self, secs: f64) -> Self {
        self.stop.max_seconds = Some(secs);
        self
    }

    /// Convenience: stop once the traced relative error reaches `err`
    /// (pair with a non-zero `eval_every` — only traced samples count).
    pub fn target_error(mut self, err: f64) -> Self {
        self.stop.target_error = Some(err);
        self
    }

    /// Snapshot rank-0-assembled factors to `path` every `every`
    /// iterations (atomic write; DSANLS and the baselines only). An
    /// interrupted job resumes from the file with
    /// [`JobBuilder::resume_from`] to bit-identical factors.
    pub fn checkpoint_every(mut self, every: usize, path: impl Into<PathBuf>) -> Self {
        self.checkpoint = Some(CheckpointCfg { every, path: path.into() });
        self
    }

    /// Resume from a checkpoint written by [`JobBuilder::checkpoint_every`]
    /// (validated against this job's algorithm, seed, rank and shape).
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(path.into());
        self
    }

    /// Overlap each collective's wire time with the next factor-independent
    /// computation (double-buffered pipeline). Off by default; bit-identical
    /// to the blocking schedule. Not supported by the asynchronous protocol
    /// (whose sends are already fire-and-forget) — [`JobBuilder::build`]
    /// returns a typed error there.
    pub fn overlap_comm(mut self, on: bool) -> Self {
        self.overlap = Some(on);
        self
    }

    /// Ship collective factor payloads at a reduced wire precision
    /// ([`Wire::Fp16`] / [`Wire::Bf16`] — ~2× fewer bytes, iterates
    /// perturbed within the format's relative error; [`Wire::F32`] is the
    /// exact default). Control/stats lanes always stay f32. Not supported
    /// by the asynchronous protocol.
    pub fn wire_precision(mut self, precision: Wire) -> Self {
        self.precision = Some(precision);
        self
    }

    /// Survive rank death: replicate the boundary state each iteration and,
    /// when a rank dies, rebuild membership at the next boundary — a
    /// replacement rank re-joins the collective and everyone resumes from
    /// the last committed iteration, bit-identical to an uninterrupted run
    /// ([`crate::dist::elastic`]). Supported by the synchronous families on
    /// the simulated backend (multi-process TCP elasticity runs via
    /// `dsanls launch --elastic`); the asynchronous parameter server
    /// tolerates client churn natively instead.
    pub fn elastic(mut self, on: bool) -> Self {
        self.elastic = on;
        self
    }

    /// Smallest surviving cluster worth rebuilding for (default 1). A peer
    /// loss that leaves fewer survivors is fatal.
    pub fn min_ranks(mut self, n: usize) -> Self {
        self.min_ranks = Some(n);
        self
    }

    /// Chaos injection for the membership tests: kill the scripted ranks
    /// at the scripted iterations ([`FaultPlan`]). Requires
    /// [`JobBuilder::elastic`] and the simulated backend.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Validate the required axes and produce the [`Job`].
    pub fn build(self) -> Result<Job<'a>> {
        let mut algo = self
            .algo
            .context("job needs an algorithm — call .algorithm(Algo::...)")?;
        let data = self
            .data
            .context("job needs a data source — call .data(DataSource::...)")?;
        if self.overlap.is_some() || self.precision.is_some() {
            match &mut algo {
                Algo::Dsanls(o) => {
                    o.overlap = self.overlap.unwrap_or(o.overlap);
                    o.precision = self.precision.unwrap_or(o.precision);
                }
                Algo::DistAnls(o) => {
                    o.overlap = self.overlap.unwrap_or(o.overlap);
                    o.precision = self.precision.unwrap_or(o.precision);
                }
                Algo::Syn(o, _) => {
                    o.overlap = self.overlap.unwrap_or(o.overlap);
                    o.precision = self.precision.unwrap_or(o.precision);
                }
                Algo::Asyn(..) => crate::bail!(
                    "overlap_comm/wire_precision are not supported by the asynchronous \
                     protocols — their parameter-server sends are already fire-and-forget"
                ),
            }
        }
        if matches!(data, DataSource::Compressed(_)) {
            match &algo {
                Algo::Syn(..) | Algo::Asyn(..) => crate::bail!(
                    "compressed shards are supported by DSANLS and the MPI-FAUN baselines \
                     only — the secure protocols' correctness proofs are stated on the raw \
                     column partition, not on sketched views"
                ),
                Algo::Dsanls(o) if o.overlap => crate::bail!(
                    "overlap_comm needs the raw row block to prefetch the next sketch \
                     against — compressed input holds only the fixed views; drop \
                     .overlap_comm(true)"
                ),
                Algo::DistAnls(o) if o.overlap => crate::bail!(
                    "overlap_comm needs the raw blocks — compressed input holds only the \
                     fixed views; drop .overlap_comm(true)"
                ),
                _ => {}
            }
            if self.elastic {
                crate::bail!(
                    "elastic membership is not supported on compressed input yet — a \
                     joiner would need the dead rank's sketched views re-served"
                );
            }
            if self.checkpoint.is_some() || self.resume.is_some() {
                crate::bail!(
                    "checkpoint/resume is not supported on compressed input — the \
                     checkpoint fingerprint cannot attest which sketched views produced \
                     the factors; run to completion and save the output instead"
                );
            }
        }
        let elastic = if self.elastic {
            match &algo {
                Algo::Asyn(..) => crate::bail!(
                    "elastic membership applies to the synchronous families — the \
                     asynchronous parameter server already tolerates client churn"
                ),
                Algo::Dsanls(o) if o.overlap => crate::bail!(
                    "elastic membership and overlap_comm are mutually exclusive: an \
                     in-flight overlapped collective cannot be replayed across an epoch"
                ),
                Algo::DistAnls(o) if o.overlap => crate::bail!(
                    "elastic membership and overlap_comm are mutually exclusive: an \
                     in-flight overlapped collective cannot be replayed across an epoch"
                ),
                Algo::Syn(o, _) if o.overlap => crate::bail!(
                    "elastic membership and overlap_comm are mutually exclusive: an \
                     in-flight overlapped collective cannot be replayed across an epoch"
                ),
                _ => {}
            }
            if matches!(self.backend, Backend::Tcp { .. }) {
                crate::bail!(
                    "in-process TCP elasticity is not supported — elastic TCP fleets are \
                     one process per rank, via `dsanls launch --elastic`"
                );
            }
            let min_ranks = self.min_ranks.unwrap_or(1);
            if min_ranks == 0 || min_ranks > algo.nodes() {
                crate::bail!(
                    "min_ranks must be in 1..={} (the cluster size), got {min_ranks}",
                    algo.nodes()
                );
            }
            Some(ElasticCtl { min_ranks })
        } else {
            if self.min_ranks.is_some() {
                crate::bail!("min_ranks needs .elastic(true)");
            }
            None
        };
        if self.fault_plan.is_some() {
            if elastic.is_none() {
                crate::bail!(
                    "fault_plan without .elastic(true) would just kill the job — enable \
                     elastic membership (the chaos harness tests recovery, not death)"
                );
            }
            if self.backend != Backend::Sim {
                crate::bail!("fault_plan drives the simulated backend only");
            }
        }
        Ok(Job {
            algo,
            data,
            backend: self.backend,
            threads: self.threads,
            partition: self.partition,
            observer: self.observer,
            audit: self.audit,
            stop: self.stop,
            checkpoint: self.checkpoint,
            resume: self.resume,
            token: ControlToken::new(),
            elastic,
            fault_plan: self.fault_plan,
        })
    }

    /// [`JobBuilder::build`] + [`Job::run`] in one call.
    pub fn run(self) -> Result<Outcome> {
        self.build()?.run()
    }

    /// [`JobBuilder::build`] + [`Job::spawn`] in one call.
    pub fn spawn(self) -> Result<JobHandle> {
        self.build()?.spawn()
    }
}

// ---------------------------------------------------------------------------
// Drivers: resolve per-rank data, run every rank, collect
// ---------------------------------------------------------------------------

struct Resolved<'j, 'a> {
    job: &'j Job<'a>,
    rows: usize,
    cols: usize,
    cols_part: Partition,
    /// The run's resolved control plane, shared by reference across every
    /// rank (which is what makes the per-iteration stop poll agree).
    ctl: &'j RunControl,
}

/// One rank's result plus its data-plane statistics (when the rank loaded
/// or synthesised resident blocks).
struct RankResult {
    out: RankOutput,
    load: Option<LoadStats>,
}

enum RankData<'a> {
    Full(&'a Matrix),
    Owned(Box<NodeData>),
    Compressed(Box<crate::data::CompressedBlock>),
}

impl RankData<'_> {
    fn input(&self) -> NodeInput<'_> {
        match self {
            RankData::Full(m) => NodeInput::Full(m),
            RankData::Owned(d) => NodeInput::Shard(d.as_ref()),
            RankData::Compressed(b) => NodeInput::Compressed(b.as_ref()),
        }
    }
}

/// Apply the per-rank intra-node thread cap: the explicit override, or the
/// derived cores/N policy that keeps backends bit-identical.
fn apply_thread_cap(threads: Option<usize>, data_nodes: usize) {
    match threads {
        Some(t) => crate::parallel::set_local_threads(Some(t.max(1))),
        None => crate::dist::apply_node_thread_policy(data_nodes),
    }
}

/// Build this rank's data view and run its share of the algorithm.
fn rank_main<C: Communicator>(
    res: &Resolved<'_, '_>,
    mut comm: C,
    rank: usize,
    joining: bool,
) -> Result<RankResult> {
    let job = res.job;
    let algo = &job.algo;
    let nodes = algo.nodes();
    let (need_rows, need_cols) = algo.block_needs(rank);

    // ---- resolve the rank's data view (blocks only, never the matrix) ----
    let tick = Instant::now();
    let (mut holder, source) = match &job.data {
        DataSource::Full(m) => (RankData::Full(m), None),
        DataSource::SyntheticWindow { dataset, seed, scale } => {
            // every data rank generates its row block (the ordered ‖M‖²
            // chain needs it even when the algorithm won't — it is dropped
            // right after), plus the column block its algorithm iterates on
            let row_range = (rank < nodes).then(|| uniform_partition(res.rows, nodes).range(rank));
            let col_range = need_cols.then(|| match algo {
                Algo::Syn(..) | Algo::Asyn(..) => res.cols_part.range(rank),
                _ => uniform_partition(res.cols, nodes).range(rank),
            });
            let data = NodeData::generate(*dataset, *seed, *scale, row_range, col_range);
            (RankData::Owned(Box::new(data)), Some(LoadSource::SynthShard))
        }
        DataSource::ShardDir(dir) => {
            if rank >= nodes {
                // async parameter server: global metadata only
                let man = shard::read_manifest(dir)?;
                let data = NodeData::metadata(man.rows, man.cols, Some(man.fro_sq));
                (RankData::Owned(Box::new(data)), Some(LoadSource::FileShard))
            } else {
                let (data, _manifest) = NodeData::load(dir, rank, need_rows, need_cols)?;
                (RankData::Owned(Box::new(data)), Some(LoadSource::FileShard))
            }
        }
        DataSource::Compressed(dir) => {
            // build() restricts compressed input to the synchronous data
            // ranks, so every rank here holds a block
            let (block, _manifest) = crate::data::CompressedBlock::load(dir, rank)?;
            (RankData::Compressed(Box::new(block)), Some(LoadSource::CompressedShard))
        }
    };
    let load_secs = tick.elapsed().as_secs_f64();

    let load = match &mut holder {
        RankData::Owned(data) => {
            if data.fro_sq.is_none() {
                if joining {
                    // the survivors are mid-run and will not re-enter the
                    // bootstrap chain; the real value arrives with the
                    // recovered commit ([`crate::dist::elastic`])
                    data.fro_sq = Some(f64::NAN);
                } else {
                    // synth mode: resolve the exact global ‖M‖² with the
                    // ordered chain (bit-identical to the full-matrix value)
                    let fro = shard::exact_fro_sq(&mut comm, nodes, data.m_rows.as_ref())
                        .with_context(|| format!("rank {rank} resolving global ‖M‖²"))?;
                    data.fro_sq = Some(fro);
                }
            }
            if !need_rows {
                data.drop_rows(); // the chain was its only consumer
            }
            source.map(|src| data.load_stats(rank, load_secs, src))
        }
        RankData::Compressed(cb) => source.map(|src| LoadStats {
            rank,
            block_rows: cb.row_range.len(),
            block_cols: cb.col_range.len(),
            // the views are dense: every held value is an explicit one
            nnz: cb.u_view().data().len() + cb.v_view().data().len(),
            bytes: cb.resident_bytes(),
            load_secs,
            source: src,
        }),
        RankData::Full(_) => None,
    };

    // ---- run the rank ----
    let env = RankEnv {
        rank,
        input: holder.input(),
        cols: &res.cols_part,
        observer: if rank == 0 { job.observer } else { None },
        audit: job.audit,
        ctl: res.ctl,
        joining,
    };
    let out = algo.run_rank(comm, env)?;
    Ok(RankResult { out, load })
}

/// Run every rank on the in-process **simulated** mesh (thread per rank,
/// modelled clock). Mirrors [`crate::dist::run_cluster`] exactly — same
/// single-rank inline path, same thread-cap policy — so builder runs stay
/// bit-identical to the legacy free functions.
fn drive_sim(res: &Resolved<'_, '_>) -> Result<Vec<RankResult>> {
    let ranks = res.job.algo.cluster_ranks();
    let nodes = res.job.algo.nodes();
    let cluster = SimCluster::new(ranks);
    if let Some(plan) = &res.job.fault_plan {
        cluster.set_fault_plan(plan.clone());
    }
    {
        // hard-cancel (kill) support: unblock readers waiting on the mesh
        let c = cluster.clone();
        res.ctl.token.register_interrupter(Box::new(move || c.interrupt_all()));
    }
    if ranks == 1 {
        // single rank: run inline with full intra-node parallelism
        if let Some(t) = res.job.threads {
            crate::parallel::set_local_threads(Some(t.max(1)));
        }
        let out = rank_main(res, SimComm::new(0, cluster), 0, false);
        crate::parallel::set_local_threads(None);
        return Ok(vec![out?]);
    }
    let elastic = res.job.elastic.is_some();
    let mut slots: Vec<Option<Result<RankResult>>> = (0..ranks).map(|_| None).collect();
    std::thread::scope(|s| {
        for (rank, slot) in slots.iter_mut().enumerate() {
            let cluster = cluster.clone();
            s.spawn(move || {
                apply_thread_cap(res.job.threads, nodes);
                // First incarnation attaches directly. After a scripted kill
                // (FaultKillSignal) the same thread stands in for the
                // *replacement* process: it re-joins the mesh and re-runs
                // `rank_main` with `joining = true`, exactly like a freshly
                // spawned `worker --join` would over TCP.
                let mut comm = Some(SimComm::new(rank, cluster.clone()));
                let mut joining = false;
                let value = loop {
                    let attached = match comm.take() {
                        Some(c) => c,
                        None => match SimComm::join(&cluster, rank) {
                            Ok(c) => c,
                            Err(e) => break Err(e),
                        },
                    };
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        rank_main(res, attached, rank, joining)
                    })) {
                        Ok(v) => break v,
                        Err(payload) => {
                            if elastic && payload.downcast_ref::<FaultKillSignal>().is_some() {
                                joining = true;
                                continue;
                            }
                            std::panic::resume_unwind(payload);
                        }
                    }
                };
                *slot = Some(value);
                crate::parallel::set_local_threads(None);
            });
        }
    });
    slots.into_iter().map(|o| o.expect("rank produced no output")).collect()
}

/// Run every rank over **real localhost TCP** (rendezvous + full peer
/// mesh), one thread per rank inside this process.
fn drive_tcp(res: &Resolved<'_, '_>, port: u16) -> Result<Vec<RankResult>> {
    let ranks = res.job.algo.cluster_ranks();
    let nodes = res.job.algo.nodes();
    let rdv = Rendezvous::bind(port)?;
    let addr = rdv.addr();
    let mut slots: Vec<Option<Result<RankResult>>> = (0..ranks).map(|_| None).collect();
    let rdv_result = std::thread::scope(|s| {
        let coord = s.spawn(move || rdv.wait_workers(ranks, Duration::from_secs(30)));
        for (rank, slot) in slots.iter_mut().enumerate() {
            let addr = addr.clone();
            s.spawn(move || {
                let run = (|| {
                    let comm = TcpComm::connect(&addr, rank, ranks, &TcpOptions::default())?;
                    // hard-cancel (kill) support: unblock this rank's reads
                    res.ctl.token.register_interrupter(Box::new(comm.interrupter()));
                    apply_thread_cap(res.job.threads, nodes);
                    let value = rank_main(res, comm, rank, false);
                    crate::parallel::set_local_threads(None);
                    value
                })();
                *slot = Some(run);
            });
        }
        // hold the coordinator-side connections until every rank finished
        coord.join().expect("rendezvous thread panicked")
    });
    rdv_result?;
    slots.into_iter().map(|o| o.expect("rank produced no output")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed as u128, 0);
        let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
        Matrix::Dense(u.matmul_nt(&v))
    }

    #[test]
    fn labels_match_the_legacy_scheme() {
        let cfg = ExperimentConfig::default();
        assert_eq!(Algo::Dsanls(dsanls_options(&cfg)).label(), "DSANLS/S");
        assert_eq!(
            Algo::DistAnls(dist_anls_options(&cfg, SolverKind::Hals)).label(),
            "MPI-FAUN-HALS"
        );
        assert_eq!(Algo::Syn(syn_options(&cfg), SecureAlgo::SynSd).label(), "Syn-SD");
        assert_eq!(Algo::Asyn(asyn_options(&cfg), SecureAlgo::AsynSsdV).label(), "Asyn-SSD-V");
    }

    #[test]
    fn cluster_shape_and_needs() {
        let cfg = ExperimentConfig::default();
        let dsanls = Algo::Dsanls(dsanls_options(&cfg));
        assert_eq!(dsanls.cluster_ranks(), dsanls.nodes());
        assert_eq!(dsanls.block_needs(0), (true, true));
        let asyn = Algo::Asyn(asyn_options(&cfg), SecureAlgo::AsynSd);
        assert_eq!(asyn.cluster_ranks(), asyn.nodes() + 1);
        assert_eq!(asyn.block_needs(asyn.nodes()), (false, false), "server holds no data");
        let syn = Algo::Syn(syn_options(&cfg), SecureAlgo::SynSsdUv);
        assert_eq!(syn.block_needs(0), (false, true));
    }

    #[test]
    fn builder_requires_algorithm_and_data() {
        let err = Job::builder().build().unwrap_err();
        assert!(err.to_string().contains("algorithm"), "{err}");
        let m = low_rank(10, 8, 2, 1);
        let err = Job::builder().data(DataSource::Full(&m)).build().unwrap_err();
        assert!(err.to_string().contains("algorithm"), "{err}");
        let cfg = ExperimentConfig::default();
        let err = Job::builder().algorithm(Algo::Dsanls(dsanls_options(&cfg))).build().unwrap_err();
        assert!(err.to_string().contains("data source"), "{err}");
    }

    #[test]
    fn variant_mismatches_are_typed_errors() {
        let cfg = ExperimentConfig::default();
        assert!(Algo::Syn(syn_options(&cfg), SecureAlgo::AsynSd).validate().is_err());
        assert!(Algo::Asyn(asyn_options(&cfg), SecureAlgo::SynSd).validate().is_err());
        let mut o = asyn_options(&cfg);
        o.nodes = 1;
        let err = Algo::Asyn(o, SecureAlgo::AsynSd).validate().unwrap_err();
        assert!(err.to_string().contains("2 parties"), "{err}");
        let mut d = dsanls_options(&cfg);
        d.solver = SolverKind::Hals;
        assert!(Algo::Dsanls(d).validate().is_err(), "non-Theorem-1 solver must be rejected");
    }

    #[test]
    fn partition_misuse_is_a_typed_error() {
        let m = low_rank(20, 16, 2, 3);
        let mut opts = dsanls_options(&ExperimentConfig::default());
        opts.nodes = 2;
        opts.iterations = 1;
        let err = Job::builder()
            .algorithm(Algo::Dsanls(opts))
            .data(DataSource::Full(&m))
            .secure_partition(uniform_partition(16, 2))
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("secure"), "{err}");

        let mut syn = syn_options(&ExperimentConfig::default());
        syn.nodes = 2;
        let err = Job::builder()
            .algorithm(Algo::Syn(syn, SecureAlgo::SynSd))
            .data(DataSource::Full(&m))
            .secure_partition(uniform_partition(16, 3)) // 3 parties, 2 nodes
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("parties"), "{err}");
    }

    #[test]
    fn builder_flags_apply_overlap_and_precision() {
        let cfg = ExperimentConfig::default();
        let m = low_rank(10, 8, 2, 5);
        let job = Job::builder()
            .algorithm(Algo::Dsanls(dsanls_options(&cfg)))
            .data(DataSource::Full(&m))
            .overlap_comm(true)
            .wire_precision(Wire::Bf16)
            .build()
            .unwrap();
        match &job.algo {
            Algo::Dsanls(o) => {
                assert!(o.overlap);
                assert_eq!(o.precision, Wire::Bf16);
            }
            other => panic!("unexpected algo {other:?}"),
        }

        // the asynchronous protocols reject both flags with a typed error
        let err = Job::builder()
            .algorithm(Algo::Asyn(asyn_options(&cfg), SecureAlgo::AsynSd))
            .data(DataSource::Full(&m))
            .overlap_comm(true)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("not supported"), "{err}");

        // config keys flow through the mappers
        let mut cfg = ExperimentConfig::default();
        cfg.apply("network.overlap", "true").unwrap();
        cfg.apply("network.precision", "fp16").unwrap();
        let o = dsanls_options(&cfg);
        assert!(o.overlap);
        assert_eq!(o.precision, Wire::Fp16);
        let o = dist_anls_options(&cfg, SolverKind::Hals);
        assert!(o.overlap);
        assert_eq!(o.precision, Wire::Fp16);
        let o = syn_options(&cfg);
        assert!(o.overlap);
        assert_eq!(o.precision, Wire::Fp16);
    }

    #[test]
    fn from_config_maps_every_family() {
        let mut cfg = ExperimentConfig::default();
        assert!(matches!(Algo::from_config(&cfg), Algo::Dsanls(_)));
        cfg.apply("experiment.algorithm", "hals").unwrap();
        assert!(matches!(
            Algo::from_config(&cfg),
            Algo::DistAnls(DistAnlsOptions { solver: SolverKind::Hals, .. })
        ));
        cfg.apply("experiment.algorithm", "syn-ssd-uv").unwrap();
        assert!(matches!(Algo::from_config(&cfg), Algo::Syn(_, SecureAlgo::SynSsdUv)));
        cfg.apply("experiment.algorithm", "asyn-sd").unwrap();
        assert!(matches!(Algo::from_config(&cfg), Algo::Asyn(_, SecureAlgo::AsynSd)));
    }
}
