//! Centralized NMF: the two-block coordinate descent framework (Alg. 1),
//! its sketched variant SANLS (Sec. 3.2), loss evaluation and factor
//! initialisation. The distributed algorithms in [`crate::algos`] and
//! [`crate::secure`] reuse these pieces per node.
//!
//! The [`job`] submodule is the crate's unified front door: one [`Job`]
//! builder composing every algorithm × transport × data source.

mod anls;
pub mod control;
mod init;
pub mod job;
mod loss;

pub use anls::{update_unsketched, Anls, AnlsOptions, Sanls, SanlsOptions};
pub use control::{ControlToken, StopPolicy, StopReason};
pub use init::{init_factors, init_factors_from, init_scale, init_scale_from};
pub use job::{Algo, Algorithm, Backend, DataSource, Job, JobBuilder, JobHandle, Outcome};
pub use loss::{rel_error, rel_error_parts};

use crate::linalg::Mat;

/// An NMF factorisation result `M ≈ U·Vᵀ` with its convergence trace.
#[derive(Debug, Clone)]
pub struct Factorization {
    pub u: Mat,
    pub v: Mat,
    /// (iteration, elapsed seconds, relative error) samples.
    pub trace: Vec<(usize, f64, f64)>,
}

impl Factorization {
    pub fn final_error(&self) -> f64 {
        self.trace.last().map(|&(_, _, e)| e).unwrap_or(f64::NAN)
    }
}

/// Proximal weight schedule `μ_t = α + β·t` (paper Sec. 5.1, citing [50]).
#[derive(Debug, Clone, Copy)]
pub struct MuSchedule {
    pub alpha: f32,
    pub beta: f32,
}

impl Default for MuSchedule {
    fn default() -> Self {
        // the paper grid-searches α, β ∈ {0.1, 1, 10}; this is the midpoint
        MuSchedule { alpha: 1.0, beta: 1.0 }
    }
}

impl MuSchedule {
    pub fn mu(&self, t: usize) -> f32 {
        self.alpha + self.beta * t as f32
    }
}
