//! Wire format for the TCP backend: connection preamble + length-prefixed
//! binary frames with a tiny zero-copy `f32` codec.
//!
//! Every connection starts with an 8-byte preamble
//! `[MAGIC u32][VERSION u16][sender rank u16]` (little-endian) so stray or
//! mismatched peers are rejected before any frame parsing. Frames then
//! follow, each:
//!
//! ```text
//! [len u32][kind u8][pad u8;3][tag u64][clock f64] [payload: len bytes]
//! ```
//!
//! `len` is the payload byte length (must be a multiple of 4 and at most
//! [`MAX_FRAME_BYTES`]); the payload is a raw little-endian `f32` slice. On
//! little-endian targets (every platform we deploy on) encode/decode are
//! **zero-copy**: the `Vec<f32>` buffer is viewed as bytes for `write_all`
//! and filled in place by `read_exact` — no per-element conversion, no
//! intermediate buffer. A per-element fallback keeps big-endian targets
//! correct.
//!
//! All control data rides in the same frames: small integers (ports, node
//! counts) are stored as exact `f32` values (< 2²⁴), and exact `u64`/`f64`
//! statistics are bit-split across two `f32` lanes via
//! [`push_f64_bits`]/[`take_f64_bits`]. One payload type keeps the codec —
//! and its truncation/oversize error paths — singular.

use std::io::{Read, Write};

use crate::error::{Context, Result};

/// Connection magic: `"DSAN"`.
pub const MAGIC: u32 = 0x4453_414E;
/// Wire protocol version; bumped on any frame-layout change. A mismatch is
/// rejected at the preamble, before any frame parsing — mixing binary
/// versions across hosts surfaces as a clean "version mismatch" error
/// (see DEPLOYMENT.md troubleshooting).
///
/// * v1 — initial frame set; `Hello`/`Roster` carried mesh **ports** only
///   (localhost-only deployment).
/// * v2 — `Hello`/`Roster` carry full `host:port` mesh addresses (the
///   address book), enabling multi-host clusters via `--bind`.
/// * v3 — control plane: the `Result`-stats chunk carries a trailing
///   stop-reason `u64`, and the asynchronous protocols' push/reply frames
///   carry one trailing control `f32` (residual fraction / stop flag).
///   Mixed-version clusters must fail the handshake, not mis-decode.
pub const VERSION: u16 = 3;
/// Refuse frames above 1 GiB — a corrupt length prefix otherwise turns
/// into an attempted huge allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Frame header size on the wire.
pub const HEADER_BYTES: usize = 4 + 4 + 8 + 8;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// A synchronous collective contribution (tag = round sequence).
    Collective = 1,
    /// A tagged point-to-point message.
    P2p = 2,
    /// Worker → coordinator bootstrap (payload = the worker's advertised
    /// mesh `host:port`, text-encoded via [`encode_text`]).
    Hello = 3,
    /// Coordinator → worker address book (payload = comma-joined mesh
    /// addresses in rank order, text-encoded via [`encode_text`]).
    Roster = 4,
    /// Worker → coordinator result chunk (tag = chunk code).
    Result = 5,
    /// Worker → coordinator failure report (payload = message chars).
    Error = 6,
}

impl FrameKind {
    /// Decode the on-wire kind byte.
    pub fn from_u8(v: u8) -> Result<FrameKind> {
        Ok(match v {
            1 => FrameKind::Collective,
            2 => FrameKind::P2p,
            3 => FrameKind::Hello,
            4 => FrameKind::Roster,
            5 => FrameKind::Result,
            6 => FrameKind::Error,
            other => crate::bail!("unknown frame kind {other}"),
        })
    }
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// What the frame carries.
    pub kind: FrameKind,
    /// Kind-specific tag (collective round, P2P tag, result chunk code).
    pub tag: u64,
    /// Sender's virtual clock at send time.
    pub clock: f64,
    /// Raw f32 payload.
    pub payload: Vec<f32>,
}

impl Frame {
    /// Assemble a frame from its parts.
    pub fn new(kind: FrameKind, tag: u64, clock: f64, payload: Vec<f32>) -> Frame {
        Frame { kind, tag, clock, payload }
    }
}

// ---------------------------------------------------------------------------
// f32 slice ⇄ bytes (the zero-copy core)
// ---------------------------------------------------------------------------

/// View an `f32` slice as little-endian wire bytes without copying.
/// Only compiled on little-endian targets, where the in-memory layout *is*
/// the wire layout.
#[cfg(target_endian = "little")]
fn f32s_as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding or invalid bit patterns as bytes; the
    // length is exactly v.len()*4 and the lifetime is tied to `v`.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

/// View an `f32` buffer as a mutable byte buffer to `read_exact` into.
#[cfg(target_endian = "little")]
fn f32s_as_bytes_mut(v: &mut [f32]) -> &mut [u8] {
    // SAFETY: any byte pattern is a valid f32 bit pattern (NaNs included),
    // so filling via read_exact cannot create an invalid value.
    unsafe {
        std::slice::from_raw_parts_mut(v.as_mut_ptr().cast::<u8>(), std::mem::size_of_val(v))
    }
}

// ---------------------------------------------------------------------------
// Preamble
// ---------------------------------------------------------------------------

/// Write the connection preamble: magic, version, sender rank.
pub fn write_preamble<W: Write>(w: &mut W, rank: u16) -> Result<()> {
    let mut buf = [0u8; 8];
    buf[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    buf[4..6].copy_from_slice(&VERSION.to_le_bytes());
    buf[6..8].copy_from_slice(&rank.to_le_bytes());
    w.write_all(&buf).context("writing preamble")?;
    w.flush().context("flushing preamble")?;
    Ok(())
}

/// Read and validate a connection preamble; returns the sender's rank.
pub fn read_preamble<R: Read>(r: &mut R) -> Result<u16> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf).context("reading preamble (truncated handshake)")?;
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        crate::bail!("bad magic 0x{magic:08x} (expected 0x{MAGIC:08x}) — not a dsanls peer");
    }
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    if version != VERSION {
        crate::bail!("protocol version mismatch: peer {version}, local {VERSION}");
    }
    Ok(u16::from_le_bytes(buf[6..8].try_into().unwrap()))
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

/// Encode and write one frame. The payload bytes go straight from the f32
/// slice to the socket on little-endian targets.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    write_frame_parts(w, frame.kind, frame.tag, frame.clock, &frame.payload)
}

/// [`write_frame`] without requiring an owned [`Frame`] — the send path
/// borrows the caller's buffer, so fanning one payload out to N peers
/// performs zero payload copies.
pub fn write_frame_parts<W: Write>(
    w: &mut W,
    kind: FrameKind,
    tag: u64,
    clock: f64,
    payload: &[f32],
) -> Result<()> {
    let len = payload.len() * 4;
    if len > MAX_FRAME_BYTES {
        crate::bail!("refusing to send oversized frame ({len} bytes > {MAX_FRAME_BYTES})");
    }
    let mut header = [0u8; HEADER_BYTES];
    header[0..4].copy_from_slice(&(len as u32).to_le_bytes());
    header[4] = kind as u8;
    header[8..16].copy_from_slice(&tag.to_le_bytes());
    header[16..24].copy_from_slice(&clock.to_bits().to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    #[cfg(target_endian = "little")]
    w.write_all(f32s_as_bytes(payload)).context("writing frame payload")?;
    #[cfg(not(target_endian = "little"))]
    for v in payload {
        w.write_all(&v.to_le_bytes()).context("writing frame payload")?;
    }
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read and decode one frame, enforcing the length sanity checks. A peer
/// hanging up mid-frame surfaces as a truncation error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut header = [0u8; HEADER_BYTES];
    r.read_exact(&mut header).context("reading frame header (connection closed or truncated)")?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        crate::bail!("oversized frame: {len} bytes (max {MAX_FRAME_BYTES})");
    }
    if len % 4 != 0 {
        crate::bail!("corrupt frame: payload length {len} is not a multiple of 4");
    }
    let kind = FrameKind::from_u8(header[4])?;
    let tag = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let clock = f64::from_bits(u64::from_le_bytes(header[16..24].try_into().unwrap()));
    let mut payload = vec![0f32; len / 4];
    #[cfg(target_endian = "little")]
    r.read_exact(f32s_as_bytes_mut(&mut payload))
        .context("reading frame payload (truncated frame)")?;
    #[cfg(not(target_endian = "little"))]
    {
        let mut buf = [0u8; 4];
        for v in payload.iter_mut() {
            r.read_exact(&mut buf).context("reading frame payload (truncated frame)")?;
            *v = f32::from_le_bytes(buf);
        }
    }
    Ok(Frame { kind, tag, clock, payload })
}

// ---------------------------------------------------------------------------
// Exact scalar packing inside f32 payloads
// ---------------------------------------------------------------------------

/// Append an `f64` to an f32 payload *exactly* (bit-split across two f32
/// lanes). Use for statistics/counters that must survive the wire intact.
pub fn push_f64_bits(payload: &mut Vec<f32>, x: f64) {
    let bits = x.to_bits();
    payload.push(f32::from_bits((bits >> 32) as u32));
    payload.push(f32::from_bits(bits as u32));
}

/// Inverse of [`push_f64_bits`]; advances `pos` by 2.
pub fn take_f64_bits(payload: &[f32], pos: &mut usize) -> Result<f64> {
    if *pos + 2 > payload.len() {
        crate::bail!("payload underrun decoding f64 at {}", *pos);
    }
    let hi = payload[*pos].to_bits() as u64;
    let lo = payload[*pos + 1].to_bits() as u64;
    *pos += 2;
    Ok(f64::from_bits((hi << 32) | lo))
}

/// Append a `u64` exactly (via the f64-bits channel).
pub fn push_u64_bits(payload: &mut Vec<f32>, x: u64) {
    payload.push(f32::from_bits((x >> 32) as u32));
    payload.push(f32::from_bits(x as u32));
}

/// Inverse of [`push_u64_bits`].
pub fn take_u64_bits(payload: &[f32], pos: &mut usize) -> Result<u64> {
    if *pos + 2 > payload.len() {
        crate::bail!("payload underrun decoding u64 at {}", *pos);
    }
    let hi = payload[*pos].to_bits() as u64;
    let lo = payload[*pos + 1].to_bits() as u64;
    *pos += 2;
    Ok((hi << 32) | lo)
}

/// Encode an error message as a frame payload (one char per f32 lane —
/// control path only, never hot).
pub fn encode_text(msg: &str) -> Vec<f32> {
    msg.chars().map(|c| c as u32 as f32).collect()
}

/// Inverse of [`encode_text`].
pub fn decode_text(payload: &[f32]) -> String {
    payload.iter().filter_map(|&v| char::from_u32(v as u32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        read_frame(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn frame_roundtrip_various_payloads() {
        for payload in [
            Vec::new(),
            vec![1.5f32],
            vec![0.0, -0.0, f32::MIN_POSITIVE, 3.25e7, -1.0e-30],
            (0..1000).map(|i| i as f32 * 0.5).collect::<Vec<_>>(),
        ] {
            let f = Frame::new(FrameKind::Collective, 0xDEAD_BEEF_CAFE, -2.5e-4, payload);
            let back = roundtrip(&f);
            assert_eq!(back.kind, f.kind);
            assert_eq!(back.tag, f.tag);
            assert_eq!(back.clock.to_bits(), f.clock.to_bits());
            // bit-exact payload (NaN-safe comparison via bits)
            assert_eq!(back.payload.len(), f.payload.len());
            for (a, b) in back.payload.iter().zip(f.payload.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn truncated_header_and_payload_error() {
        let f = Frame::new(FrameKind::P2p, 7, 1.0, vec![1.0, 2.0, 3.0]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        // chop the stream at every prefix length: all must fail cleanly,
        // none may panic or return a partial frame
        for cut in 0..buf.len() {
            let err = read_frame(&mut Cursor::new(&buf[..cut]));
            assert!(err.is_err(), "cut at {cut} did not error");
        }
        // the full buffer still parses
        assert_eq!(roundtrip(&f).payload, f.payload);
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut header = [0u8; HEADER_BYTES];
        header[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        header[4] = FrameKind::P2p as u8;
        let err = read_frame(&mut Cursor::new(header.to_vec())).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
    }

    #[test]
    fn misaligned_length_rejected() {
        let mut header = [0u8; HEADER_BYTES];
        header[0..4].copy_from_slice(&6u32.to_le_bytes());
        header[4] = FrameKind::P2p as u8;
        let err = read_frame(&mut Cursor::new(header.to_vec())).unwrap_err();
        assert!(err.to_string().contains("multiple of 4"), "{err}");
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut header = [0u8; HEADER_BYTES];
        header[4] = 99;
        let err = read_frame(&mut Cursor::new(header.to_vec())).unwrap_err();
        assert!(err.to_string().contains("unknown frame kind"), "{err}");
    }

    #[test]
    fn preamble_roundtrip_and_rejection() {
        let mut buf = Vec::new();
        write_preamble(&mut buf, 11).unwrap();
        assert_eq!(read_preamble(&mut Cursor::new(buf.clone())).unwrap(), 11);
        // corrupt magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(read_preamble(&mut Cursor::new(bad)).is_err());
        // wrong version
        let mut badv = buf.clone();
        badv[4] = badv[4].wrapping_add(1);
        let err = read_preamble(&mut Cursor::new(badv)).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // truncated
        assert!(read_preamble(&mut Cursor::new(&buf[..5])).is_err());
    }

    #[test]
    fn exact_scalar_packing() {
        let mut p = Vec::new();
        push_f64_bits(&mut p, 1.0 / 3.0);
        push_f64_bits(&mut p, f64::NAN);
        push_u64_bits(&mut p, u64::MAX - 12345);
        let mut pos = 0;
        assert_eq!(take_f64_bits(&p, &mut pos).unwrap(), 1.0 / 3.0);
        assert!(take_f64_bits(&p, &mut pos).unwrap().is_nan());
        assert_eq!(take_u64_bits(&p, &mut pos).unwrap(), u64::MAX - 12345);
        assert!(take_f64_bits(&p, &mut pos).is_err(), "underrun must error");
    }

    #[test]
    fn text_roundtrip() {
        let msg = "worker 3 failed: peer 1 disconnected — ‖M‖ unavailable";
        assert_eq!(decode_text(&encode_text(msg)), msg);
    }
}
