//! Wire format for the TCP backend: connection preamble + length-prefixed
//! binary frames with a tiny zero-copy `f32` codec.
//!
//! Every connection starts with an 8-byte preamble
//! `[MAGIC u32][VERSION u16][sender rank u16]` (little-endian) so stray or
//! mismatched peers are rejected before any frame parsing. Frames then
//! follow, each:
//!
//! ```text
//! [len u32][kind u8][pad u8;3][tag u64][clock f64] [payload: len bytes]
//! ```
//!
//! `len` is the payload byte length (must be a multiple of 4 and at most
//! [`MAX_FRAME_BYTES`]); the payload is a raw little-endian `f32` slice. On
//! little-endian targets (every platform we deploy on) encode/decode are
//! **zero-copy**: the `Vec<f32>` buffer is viewed as bytes for `write_all`
//! and filled in place by `read_exact` — no per-element conversion, no
//! intermediate buffer. A per-element fallback keeps big-endian targets
//! correct.
//!
//! All control data rides in the same frames: small integers (ports, node
//! counts) are stored as exact `f32` values (< 2²⁴), and exact `u64`/`f64`
//! statistics are bit-split across two `f32` lanes via
//! [`push_f64_bits`]/[`take_f64_bits`]. One payload type keeps the codec —
//! and its truncation/oversize error paths — singular.

use std::io::{Read, Write};

use crate::error::{Context, Result};

/// Connection magic: `"DSAN"`.
pub const MAGIC: u32 = 0x4453_414E;
/// Wire protocol version; bumped on any frame-layout change. A mismatch is
/// rejected at the preamble, before any frame parsing — mixing binary
/// versions across hosts surfaces as a clean "version mismatch" error
/// (see DEPLOYMENT.md troubleshooting).
///
/// * v1 — initial frame set; `Hello`/`Roster` carried mesh **ports** only
///   (localhost-only deployment).
/// * v2 — `Hello`/`Roster` carry full `host:port` mesh addresses (the
///   address book), enabling multi-host clusters via `--bind`.
/// * v3 — control plane: the `Result`-stats chunk carries a trailing
///   stop-reason `u64`, and the asynchronous protocols' push/reply frames
///   carry one trailing control `f32` (residual fraction / stop flag).
///   Mixed-version clusters must fail the handshake, not mis-decode.
/// * v4 — quantized collective frames: [`FrameKind::CollectiveF16`] and
///   [`FrameKind::CollectiveBf16`] carry 2-byte-per-element factor
///   payloads (`--wire-precision fp16|bf16`). A v3 peer would mis-parse
///   the half-width payload length, so the handshake must reject the mix
///   even when the flag is off.
/// * v5 — serving plane: [`FrameKind::Request`] / [`FrameKind::Response`]
///   query frames for `dsanls serve` (`crate::serve`). A v4 peer rejects
///   kinds 9/10 as unknown mid-stream; the handshake refuses the mix up
///   front instead.
/// * v6 — membership epochs: [`FrameKind::Join`] / [`FrameKind::EpochAck`]
///   carry the elastic re-join handshake (`dsanls worker --join`), and
///   collective tags are epoch-qualified (epoch in the top 16 bits — the
///   tag of every pre-v6 collective decodes as epoch 0, but a v5 peer
///   would treat an epoch-1 tag as a garbled round number, so the
///   handshake refuses the mix).
pub const VERSION: u16 = 6;
/// Refuse frames above 1 GiB — a corrupt length prefix otherwise turns
/// into an attempted huge allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Frame header size on the wire.
pub const HEADER_BYTES: usize = 4 + 4 + 8 + 8;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// A synchronous collective contribution (tag = round sequence).
    Collective = 1,
    /// A tagged point-to-point message.
    P2p = 2,
    /// Worker → coordinator bootstrap (payload = the worker's advertised
    /// mesh `host:port`, text-encoded via [`encode_text`]).
    Hello = 3,
    /// Coordinator → worker address book (payload = comma-joined mesh
    /// addresses in rank order, text-encoded via [`encode_text`]).
    Roster = 4,
    /// Worker → coordinator result chunk (tag = chunk code).
    Result = 5,
    /// Worker → coordinator failure report (payload = message chars).
    Error = 6,
    /// A collective contribution quantized to IEEE 754 binary16 on the
    /// wire (2 bytes/element); decoded back to `f32` at the reader.
    CollectiveF16 = 7,
    /// A collective contribution quantized to bfloat16 on the wire
    /// (2 bytes/element); decoded back to `f32` at the reader.
    CollectiveBf16 = 8,
    /// Client → server serving-plane query (tag = client request id; see
    /// [`crate::serve::protocol`] for the payload encoding).
    Request = 9,
    /// Server → client serving-plane reply (tag echoes the request id).
    Response = 10,
    /// Elastic re-join request (joiner → coordinator / joiner → survivor;
    /// tag = the epoch the joiner believes is forming, `u64::MAX` = "any";
    /// payload = the joiner's advertised mesh address, text-encoded).
    Join = 11,
    /// Survivor → joiner admission (tag = the new membership epoch). A
    /// rejected join gets a [`FrameKind::Error`] frame instead.
    EpochAck = 12,
}

impl FrameKind {
    /// Decode the on-wire kind byte.
    pub fn from_u8(v: u8) -> Result<FrameKind> {
        Ok(match v {
            1 => FrameKind::Collective,
            2 => FrameKind::P2p,
            3 => FrameKind::Hello,
            4 => FrameKind::Roster,
            5 => FrameKind::Result,
            6 => FrameKind::Error,
            7 => FrameKind::CollectiveF16,
            8 => FrameKind::CollectiveBf16,
            9 => FrameKind::Request,
            10 => FrameKind::Response,
            11 => FrameKind::Join,
            12 => FrameKind::EpochAck,
            other => crate::bail!("unknown frame kind {other}"),
        })
    }

    /// On-wire bytes per payload element for this kind (the quantized
    /// collective kinds halve the element width).
    pub fn element_bytes(self) -> usize {
        match self {
            FrameKind::CollectiveF16 | FrameKind::CollectiveBf16 => 2,
            _ => 4,
        }
    }
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// What the frame carries.
    pub kind: FrameKind,
    /// Kind-specific tag (collective round, P2P tag, result chunk code).
    pub tag: u64,
    /// Sender's virtual clock at send time.
    pub clock: f64,
    /// Raw f32 payload.
    pub payload: Vec<f32>,
}

impl Frame {
    /// Assemble a frame from its parts.
    pub fn new(kind: FrameKind, tag: u64, clock: f64, payload: Vec<f32>) -> Frame {
        Frame { kind, tag, clock, payload }
    }
}

// ---------------------------------------------------------------------------
// f32 slice ⇄ bytes (the zero-copy core)
// ---------------------------------------------------------------------------

/// View an `f32` slice as little-endian wire bytes without copying.
/// Only compiled on little-endian targets, where the in-memory layout *is*
/// the wire layout.
#[cfg(target_endian = "little")]
fn f32s_as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding or invalid bit patterns as bytes; the
    // length is exactly v.len()*4 and the lifetime is tied to `v`.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

/// View an `f32` buffer as a mutable byte buffer to `read_exact` into.
#[cfg(target_endian = "little")]
fn f32s_as_bytes_mut(v: &mut [f32]) -> &mut [u8] {
    // SAFETY: any byte pattern is a valid f32 bit pattern (NaNs included),
    // so filling via read_exact cannot create an invalid value.
    unsafe {
        std::slice::from_raw_parts_mut(v.as_mut_ptr().cast::<u8>(), std::mem::size_of_val(v))
    }
}

// ---------------------------------------------------------------------------
// Reduced-precision factor codec (fp16 / bf16)
// ---------------------------------------------------------------------------

/// Wire precision for factor-exchange payloads (`--wire-precision`).
///
/// Control/stats lanes always stay `f32`; only the collective factor
/// payloads are quantized. Quantization is applied **sender-side to the
/// sender's own contribution as well** (every rank observes rank *r*'s
/// payload through the same round-trip), which keeps the Sim and TCP
/// backends bit-identical to each other at every precision even though
/// only TCP ships real 2-byte frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// Exact `f32` payloads (the default; existing wire format).
    #[default]
    F32,
    /// IEEE 754 binary16: 10 mantissa bits, ~3 decimal digits, max ≈ 65504.
    Fp16,
    /// bfloat16: `f32`'s full exponent range, 7 mantissa bits.
    Bf16,
}

impl Precision {
    /// Canonical flag spelling.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Fp16 => "fp16",
            Precision::Bf16 => "bf16",
        }
    }

    /// On-wire bytes per payload element.
    pub fn bytes_per_element(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Fp16 | Precision::Bf16 => 2,
        }
    }

    /// Collective frame kind carrying this precision.
    pub fn collective_kind(self) -> FrameKind {
        match self {
            Precision::F32 => FrameKind::Collective,
            Precision::Fp16 => FrameKind::CollectiveF16,
            Precision::Bf16 => FrameKind::CollectiveBf16,
        }
    }

    /// Quantize one value to this precision and decode it back — exactly
    /// what a receiver on the other end of the wire would observe.
    /// Idempotent: `round_trip(round_trip(x)) == round_trip(x)` bit-for-bit,
    /// which is what lets SimComm skip the 2-byte encoding entirely.
    pub fn round_trip(self, x: f32) -> f32 {
        match self {
            Precision::F32 => x,
            Precision::Fp16 => f16_bits_to_f32(f32_to_f16_bits(x)),
            Precision::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
        }
    }

    /// [`Precision::round_trip`] over a whole buffer, in place.
    pub fn round_trip_slice(self, xs: &mut [f32]) {
        if self == Precision::F32 {
            return;
        }
        for x in xs.iter_mut() {
            *x = self.round_trip(*x);
        }
    }
}

impl std::str::FromStr for Precision {
    type Err = crate::error::Error;

    fn from_str(s: &str) -> Result<Precision> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "exact" => Precision::F32,
            "fp16" | "f16" | "half" => Precision::Fp16,
            "bf16" | "bfloat16" => Precision::Bf16,
            other => crate::bail!("unknown wire precision '{other}' (expected f32, fp16 or bf16)"),
        })
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Drop the low `shift` bits of `x`, rounding to nearest with ties to
/// even — the IEEE default rounding every narrowing conversion here uses.
fn rne_shift(x: u32, shift: u32) -> u32 {
    let truncated = x >> shift;
    let rem = x & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && truncated & 1 == 1) {
        truncated + 1
    } else {
        truncated
    }
}

/// Narrow an `f32` to IEEE 754 binary16 bits (round-to-nearest-even;
/// overflow → ±Inf, NaN payload collapsed to a quiet NaN, gradual
/// underflow through the binary16 subnormal range).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        // Inf / NaN: keep the class, collapse NaN payloads to quiet
        return if abs > 0x7F80_0000 { sign | 0x7E00 } else { sign | 0x7C00 };
    }
    let exp = (abs >> 23) as i32; // biased f32 exponent, 0 for subnormal/zero
    let half_exp = exp - 127 + 15;
    if half_exp >= 31 {
        return sign | 0x7C00; // overflow → Inf
    }
    if half_exp <= 0 {
        // binary16 subnormal (or zero): shift the full significand —
        // with its implicit leading 1 restored — past the 13-bit narrowing
        if half_exp < -10 {
            return sign; // too small even for subnormals → signed zero
        }
        let man = (abs & 0x7F_FFFF) | 0x80_0000;
        let shift = (13 + 1 - half_exp) as u32;
        // a round-up that carries out of the subnormal range lands on the
        // smallest normal (0x0400) — the `+` arithmetic is exactly right
        return sign | rne_shift(man, shift) as u16;
    }
    let man = rne_shift(abs & 0x7F_FFFF, 13);
    // mantissa round-up may carry into the exponent (and from the top
    // exponent into Inf); plain addition handles both
    let out = ((half_exp as u32) << 10) + man;
    if out >= 0x7C00 {
        return sign | 0x7C00;
    }
    sign | out as u16
}

/// Widen IEEE 754 binary16 bits to `f32` (exact — every binary16 value is
/// representable in `f32`).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // Inf / NaN
    } else if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // subnormal: normalise by locating the top set mantissa bit
            let msb = 31 - man.leading_zeros(); // 0..=9
            let exp32 = msb + 103; // (msb - 10) - 15 + 1 + 127
            let man32 = (man << (23 - msb)) & 0x7F_FFFF;
            sign | (exp32 << 23) | man32
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13) // 112 = 127 - 15
    };
    f32::from_bits(bits)
}

/// Narrow an `f32` to bfloat16 bits (round-to-nearest-even). bf16 keeps
/// `f32`'s exponent, so there is no overflow/underflow special-casing —
/// only NaN needs care (a payload that rounds to zero must not become Inf).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // force a quiet-NaN bit
    }
    // finite values cannot carry into the sign bit; a carry out of the top
    // exponent value correctly produces Inf
    rne_shift(bits, 16) as u16
}

/// Widen bfloat16 bits to `f32` (exact: bf16 is a truncated f32).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Encode an `f32` payload into `precision`-width little-endian wire
/// bytes. `F32` is rejected — callers take the zero-copy
/// [`write_frame_parts`] path for exact payloads.
pub fn quantize_payload(precision: Precision, payload: &[f32]) -> Vec<u8> {
    assert!(precision != Precision::F32, "quantize_payload is for the 2-byte precisions");
    let mut out = Vec::with_capacity(payload.len() * 2);
    for &v in payload {
        let h = match precision {
            Precision::Fp16 => f32_to_f16_bits(v),
            Precision::Bf16 => f32_to_bf16_bits(v),
            Precision::F32 => unreachable!(),
        };
        out.extend_from_slice(&h.to_le_bytes());
    }
    out
}

/// Write one quantized collective frame from pre-encoded wire bytes (see
/// [`quantize_payload`] — encode once, fan out to N peers).
pub fn write_quantized_frame<W: Write>(
    w: &mut W,
    precision: Precision,
    tag: u64,
    clock: f64,
    bytes: &[u8],
) -> Result<()> {
    let len = bytes.len();
    if len > MAX_FRAME_BYTES {
        crate::bail!("refusing to send oversized frame ({len} bytes > {MAX_FRAME_BYTES})");
    }
    let mut header = [0u8; HEADER_BYTES];
    header[0..4].copy_from_slice(&(len as u32).to_le_bytes());
    header[4] = precision.collective_kind() as u8;
    header[8..16].copy_from_slice(&tag.to_le_bytes());
    header[16..24].copy_from_slice(&clock.to_bits().to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    w.write_all(bytes).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Preamble
// ---------------------------------------------------------------------------

/// Write the connection preamble: magic, version, sender rank.
pub fn write_preamble<W: Write>(w: &mut W, rank: u16) -> Result<()> {
    let mut buf = [0u8; 8];
    buf[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    buf[4..6].copy_from_slice(&VERSION.to_le_bytes());
    buf[6..8].copy_from_slice(&rank.to_le_bytes());
    w.write_all(&buf).context("writing preamble")?;
    w.flush().context("flushing preamble")?;
    Ok(())
}

/// Read and validate a connection preamble; returns the sender's rank.
pub fn read_preamble<R: Read>(r: &mut R) -> Result<u16> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf).context("reading preamble (truncated handshake)")?;
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        crate::bail!("bad magic 0x{magic:08x} (expected 0x{MAGIC:08x}) — not a dsanls peer");
    }
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    if version != VERSION {
        crate::bail!("protocol version mismatch: peer {version}, local {VERSION}");
    }
    Ok(u16::from_le_bytes(buf[6..8].try_into().unwrap()))
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

/// Encode and write one frame. The payload bytes go straight from the f32
/// slice to the socket on little-endian targets.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    write_frame_parts(w, frame.kind, frame.tag, frame.clock, &frame.payload)
}

/// [`write_frame`] without requiring an owned [`Frame`] — the send path
/// borrows the caller's buffer, so fanning one payload out to N peers
/// performs zero payload copies.
pub fn write_frame_parts<W: Write>(
    w: &mut W,
    kind: FrameKind,
    tag: u64,
    clock: f64,
    payload: &[f32],
) -> Result<()> {
    let len = payload.len() * 4;
    if len > MAX_FRAME_BYTES {
        crate::bail!("refusing to send oversized frame ({len} bytes > {MAX_FRAME_BYTES})");
    }
    let mut header = [0u8; HEADER_BYTES];
    header[0..4].copy_from_slice(&(len as u32).to_le_bytes());
    header[4] = kind as u8;
    header[8..16].copy_from_slice(&tag.to_le_bytes());
    header[16..24].copy_from_slice(&clock.to_bits().to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    #[cfg(target_endian = "little")]
    w.write_all(f32s_as_bytes(payload)).context("writing frame payload")?;
    #[cfg(not(target_endian = "little"))]
    for v in payload {
        w.write_all(&v.to_le_bytes()).context("writing frame payload")?;
    }
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read and decode one frame, enforcing the length sanity checks. A peer
/// hanging up mid-frame surfaces as a truncation error. Quantized
/// collective frames are decoded back to `f32` here, so everything
/// downstream of the codec (inboxes, reductions) stays a single payload
/// type.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut header = [0u8; HEADER_BYTES];
    r.read_exact(&mut header).context("reading frame header (connection closed or truncated)")?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        crate::bail!("oversized frame: {len} bytes (max {MAX_FRAME_BYTES})");
    }
    let kind = FrameKind::from_u8(header[4])?;
    let elem = kind.element_bytes();
    if len % elem != 0 {
        crate::bail!("corrupt frame: payload length {len} is not a multiple of {elem}");
    }
    let tag = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let clock = f64::from_bits(u64::from_le_bytes(header[16..24].try_into().unwrap()));
    if elem == 2 {
        let mut bytes = vec![0u8; len];
        r.read_exact(&mut bytes).context("reading frame payload (truncated frame)")?;
        let mut payload = Vec::with_capacity(len / 2);
        for c in bytes.chunks_exact(2) {
            let h = u16::from_le_bytes([c[0], c[1]]);
            payload.push(match kind {
                FrameKind::CollectiveF16 => f16_bits_to_f32(h),
                _ => bf16_bits_to_f32(h),
            });
        }
        return Ok(Frame { kind, tag, clock, payload });
    }
    let mut payload = vec![0f32; len / 4];
    #[cfg(target_endian = "little")]
    r.read_exact(f32s_as_bytes_mut(&mut payload))
        .context("reading frame payload (truncated frame)")?;
    #[cfg(not(target_endian = "little"))]
    {
        let mut buf = [0u8; 4];
        for v in payload.iter_mut() {
            r.read_exact(&mut buf).context("reading frame payload (truncated frame)")?;
            *v = f32::from_le_bytes(buf);
        }
    }
    Ok(Frame { kind, tag, clock, payload })
}

// ---------------------------------------------------------------------------
// Exact scalar packing inside f32 payloads
// ---------------------------------------------------------------------------

/// Append an `f64` to an f32 payload *exactly* (bit-split across two f32
/// lanes). Use for statistics/counters that must survive the wire intact.
pub fn push_f64_bits(payload: &mut Vec<f32>, x: f64) {
    let bits = x.to_bits();
    payload.push(f32::from_bits((bits >> 32) as u32));
    payload.push(f32::from_bits(bits as u32));
}

/// Inverse of [`push_f64_bits`]; advances `pos` by 2.
pub fn take_f64_bits(payload: &[f32], pos: &mut usize) -> Result<f64> {
    if *pos + 2 > payload.len() {
        crate::bail!("payload underrun decoding f64 at {}", *pos);
    }
    let hi = payload[*pos].to_bits() as u64;
    let lo = payload[*pos + 1].to_bits() as u64;
    *pos += 2;
    Ok(f64::from_bits((hi << 32) | lo))
}

/// Append a `u64` exactly (via the f64-bits channel).
pub fn push_u64_bits(payload: &mut Vec<f32>, x: u64) {
    payload.push(f32::from_bits((x >> 32) as u32));
    payload.push(f32::from_bits(x as u32));
}

/// Inverse of [`push_u64_bits`].
pub fn take_u64_bits(payload: &[f32], pos: &mut usize) -> Result<u64> {
    if *pos + 2 > payload.len() {
        crate::bail!("payload underrun decoding u64 at {}", *pos);
    }
    let hi = payload[*pos].to_bits() as u64;
    let lo = payload[*pos + 1].to_bits() as u64;
    *pos += 2;
    Ok((hi << 32) | lo)
}

/// Encode an error message as a frame payload (one char per f32 lane —
/// control path only, never hot).
pub fn encode_text(msg: &str) -> Vec<f32> {
    msg.chars().map(|c| c as u32 as f32).collect()
}

/// Inverse of [`encode_text`].
pub fn decode_text(payload: &[f32]) -> String {
    payload.iter().filter_map(|&v| char::from_u32(v as u32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        read_frame(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn frame_roundtrip_various_payloads() {
        for payload in [
            Vec::new(),
            vec![1.5f32],
            vec![0.0, -0.0, f32::MIN_POSITIVE, 3.25e7, -1.0e-30],
            (0..1000).map(|i| i as f32 * 0.5).collect::<Vec<_>>(),
        ] {
            let f = Frame::new(FrameKind::Collective, 0xDEAD_BEEF_CAFE, -2.5e-4, payload);
            let back = roundtrip(&f);
            assert_eq!(back.kind, f.kind);
            assert_eq!(back.tag, f.tag);
            assert_eq!(back.clock.to_bits(), f.clock.to_bits());
            // bit-exact payload (NaN-safe comparison via bits)
            assert_eq!(back.payload.len(), f.payload.len());
            for (a, b) in back.payload.iter().zip(f.payload.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn truncated_header_and_payload_error() {
        let f = Frame::new(FrameKind::P2p, 7, 1.0, vec![1.0, 2.0, 3.0]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        // chop the stream at every prefix length: all must fail cleanly,
        // none may panic or return a partial frame
        for cut in 0..buf.len() {
            let err = read_frame(&mut Cursor::new(&buf[..cut]));
            assert!(err.is_err(), "cut at {cut} did not error");
        }
        // the full buffer still parses
        assert_eq!(roundtrip(&f).payload, f.payload);
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut header = [0u8; HEADER_BYTES];
        header[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        header[4] = FrameKind::P2p as u8;
        let err = read_frame(&mut Cursor::new(header.to_vec())).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
    }

    #[test]
    fn misaligned_length_rejected() {
        let mut header = [0u8; HEADER_BYTES];
        header[0..4].copy_from_slice(&6u32.to_le_bytes());
        header[4] = FrameKind::P2p as u8;
        let err = read_frame(&mut Cursor::new(header.to_vec())).unwrap_err();
        assert!(err.to_string().contains("multiple of 4"), "{err}");
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut header = [0u8; HEADER_BYTES];
        header[4] = 99;
        let err = read_frame(&mut Cursor::new(header.to_vec())).unwrap_err();
        assert!(err.to_string().contains("unknown frame kind"), "{err}");
    }

    #[test]
    fn preamble_roundtrip_and_rejection() {
        let mut buf = Vec::new();
        write_preamble(&mut buf, 11).unwrap();
        assert_eq!(read_preamble(&mut Cursor::new(buf.clone())).unwrap(), 11);
        // corrupt magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(read_preamble(&mut Cursor::new(bad)).is_err());
        // wrong version
        let mut badv = buf.clone();
        badv[4] = badv[4].wrapping_add(1);
        let err = read_preamble(&mut Cursor::new(badv)).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // truncated
        assert!(read_preamble(&mut Cursor::new(&buf[..5])).is_err());
    }

    #[test]
    fn exact_scalar_packing() {
        let mut p = Vec::new();
        push_f64_bits(&mut p, 1.0 / 3.0);
        push_f64_bits(&mut p, f64::NAN);
        push_u64_bits(&mut p, u64::MAX - 12345);
        let mut pos = 0;
        assert_eq!(take_f64_bits(&p, &mut pos).unwrap(), 1.0 / 3.0);
        assert!(take_f64_bits(&p, &mut pos).unwrap().is_nan());
        assert_eq!(take_u64_bits(&p, &mut pos).unwrap(), u64::MAX - 12345);
        assert!(take_f64_bits(&p, &mut pos).is_err(), "underrun must error");
    }

    #[test]
    fn text_roundtrip() {
        let msg = "worker 3 failed: peer 1 disconnected — ‖M‖ unavailable";
        assert_eq!(decode_text(&encode_text(msg)), msg);
    }

    // -- quantized codec ----------------------------------------------------

    #[test]
    fn f16_exact_values_survive() {
        // values exactly representable in binary16 must round-trip bit-for-bit
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.103_515_6e-5] {
            let back = Precision::Fp16.round_trip(v);
            assert_eq!(back.to_bits(), v.to_bits(), "{v} -> {back}");
        }
        for v in [1.0f32, -2.5, 128.0, 3.0e38, 1.17549435e-38] {
            let back = Precision::Bf16.round_trip(v);
            assert_eq!(back.to_bits(), v.to_bits(), "{v} -> {back}");
        }
    }

    #[test]
    fn quantization_relative_error_bounds() {
        // fp16: 11-bit significand → rel err ≤ 2^-11; bf16: 8 bits → ≤ 2^-8
        // (bounds hold in fp16's normal range, so the sweep stays within it)
        let mut x = 1.000_123f32;
        for _ in 0..200 {
            let v16 = Precision::Fp16.round_trip(x);
            assert!(((v16 - x) / x).abs() <= 1.0 / 2048.0, "fp16 {x} -> {v16}");
            let vb = Precision::Bf16.round_trip(x);
            assert!(((vb - x) / x).abs() <= 1.0 / 256.0, "bf16 {x} -> {vb}");
            x *= -1.37; // sweep magnitudes and signs
            if !(1e-3..1e3).contains(&x.abs()) {
                x = 1.0 / x; // reflect back toward 1 before leaving fp16 range
            }
        }
    }

    #[test]
    fn quantization_special_values() {
        for p in [Precision::Fp16, Precision::Bf16] {
            assert!(p.round_trip(f32::NAN).is_nan(), "{p} NaN");
            assert_eq!(p.round_trip(f32::INFINITY), f32::INFINITY, "{p} +Inf");
            assert_eq!(p.round_trip(f32::NEG_INFINITY), f32::NEG_INFINITY, "{p} -Inf");
            assert_eq!(p.round_trip(0.0).to_bits(), 0.0f32.to_bits(), "{p} +0");
            assert_eq!(p.round_trip(-0.0).to_bits(), (-0.0f32).to_bits(), "{p} -0");
        }
        // fp16 overflow saturates to Inf; bf16 keeps f32's range
        assert_eq!(Precision::Fp16.round_trip(1.0e6), f32::INFINITY);
        assert_eq!(Precision::Fp16.round_trip(-1.0e6), f32::NEG_INFINITY);
        assert!(Precision::Bf16.round_trip(1.0e6).is_finite());
        // fp16 gradual underflow: smallest subnormal ≈ 5.96e-8 survives,
        // values below half of it flush to (signed) zero
        let tiny = f16_bits_to_f32(1);
        assert_eq!(Precision::Fp16.round_trip(tiny), tiny);
        assert_eq!(Precision::Fp16.round_trip(tiny / 4.0), 0.0);
        assert_eq!(Precision::Fp16.round_trip(-tiny / 4.0).to_bits(), (-0.0f32).to_bits());
        // f32 subnormals are below bf16's smallest normal step but must not panic
        let sub = f32::from_bits(1);
        assert!(Precision::Bf16.round_trip(sub).abs() <= f32::MIN_POSITIVE);
    }

    #[test]
    fn f16_exhaustive_widen_narrow_identity() {
        // narrowing is the exact inverse of widening for every finite f16
        for h in 0u16..=u16::MAX {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(f)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(f), h, "h={h:#06x} f={f}");
            }
        }
        for h in 0u16..=u16::MAX {
            let f = bf16_bits_to_f32(h);
            if f.is_nan() {
                assert!(bf16_bits_to_f32(f32_to_bf16_bits(f)).is_nan());
            } else {
                assert_eq!(f32_to_bf16_bits(f), h, "h={h:#06x} f={f}");
            }
        }
    }

    #[test]
    fn round_trip_is_idempotent() {
        let mut x = 0.739_f32;
        for _ in 0..100 {
            for p in [Precision::Fp16, Precision::Bf16] {
                let once = p.round_trip(x);
                assert_eq!(p.round_trip(once).to_bits(), once.to_bits(), "{p} {x}");
            }
            x *= -2.31;
        }
    }

    #[test]
    fn quantized_frame_roundtrip() {
        let payload = vec![0.5f32, -1.25, 1.0e-3, 42.0, 0.0];
        for p in [Precision::Fp16, Precision::Bf16] {
            let bytes = quantize_payload(p, &payload);
            assert_eq!(bytes.len(), payload.len() * 2);
            let mut buf = Vec::new();
            write_quantized_frame(&mut buf, p, 9, 1.5, &bytes).unwrap();
            let back = read_frame(&mut Cursor::new(buf)).unwrap();
            assert_eq!(back.kind, p.collective_kind());
            assert_eq!(back.tag, 9);
            assert_eq!(back.clock, 1.5);
            let expect: Vec<f32> = payload.iter().map(|&v| p.round_trip(v)).collect();
            for (a, b) in back.payload.iter().zip(expect.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{p}");
            }
        }
    }

    #[test]
    fn quantized_frame_misaligned_and_truncated() {
        // odd byte length is corrupt for 2-byte-element kinds
        let mut header = [0u8; HEADER_BYTES];
        header[0..4].copy_from_slice(&5u32.to_le_bytes());
        header[4] = FrameKind::CollectiveF16 as u8;
        let err = read_frame(&mut Cursor::new(header.to_vec())).unwrap_err();
        assert!(err.to_string().contains("multiple of 2"), "{err}");
        // but length 6 (not a multiple of 4) is fine for a quantized frame
        let bytes = quantize_payload(Precision::Bf16, &[1.0, 2.0, 3.0]);
        let mut buf = Vec::new();
        write_quantized_frame(&mut buf, Precision::Bf16, 0, 0.0, &bytes).unwrap();
        assert_eq!(read_frame(&mut Cursor::new(buf.clone())).unwrap().payload.len(), 3);
        // every truncation point still errors cleanly
        for cut in 0..buf.len() {
            assert!(read_frame(&mut Cursor::new(&buf[..cut])).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn serving_frame_kinds_roundtrip() {
        // v5 query frames are plain 4-byte-element frames: the payload
        // codec, tag echo and length checks all apply unchanged
        for kind in [FrameKind::Request, FrameKind::Response] {
            assert_eq!(kind.element_bytes(), 4);
            let f = Frame::new(kind, 0xC0FFEE, 0.0, vec![1.0, -2.5, 3.0]);
            let back = roundtrip(&f);
            assert_eq!(back.kind, kind);
            assert_eq!(back.tag, 0xC0FFEE);
            assert_eq!(back.payload, f.payload);
        }
    }

    #[test]
    fn mixed_version_handshake_rejected() {
        // a v3 peer (pre-quantization) must be refused at the preamble —
        // it would mis-parse the half-width payload lengths of v4 frames
        let mut pre = Vec::new();
        write_preamble(&mut pre, 2).unwrap();
        pre[4..6].copy_from_slice(&3u16.to_le_bytes());
        let err = read_preamble(&mut Cursor::new(pre)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("version mismatch") && msg.contains("peer 3"), "{msg}");
    }
}
