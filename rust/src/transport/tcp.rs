//! Real multi-process TCP backend (`std::net` only).
//!
//! ## Bootstrap (rendezvous)
//!
//! The coordinator binds a [`Rendezvous`] listener (loopback by default,
//! any interface via [`Rendezvous::bind_on`]) and spawns — or, multi-host,
//! waits for — one worker process per rank. Each worker:
//!
//! 1. binds its own mesh listener ([`TcpOptions::bind`]; loopback +
//!    ephemeral port by default),
//! 2. dials the coordinator, sends the preamble (magic/version/rank) and a
//!    `Hello` frame carrying its advertised mesh `host:port`
//!    ([`TcpOptions::advertise`] overrides, e.g. behind NAT),
//! 3. receives the `Roster` frame — the **address book**: every rank's
//!    mesh address in rank order,
//! 4. forms the full peer mesh: rank `r` dials every rank `s > r` at its
//!    book address (the dialed side learns the dialer's rank from the
//!    connection preamble) and accepts connections from every rank
//!    `s < r`.
//!
//! The worker keeps the rendezvous connection open to stream results back
//! to the coordinator when the run finishes.
//!
//! ## Data plane
//!
//! One reader thread per peer socket decodes frames ([`super::wire`]) into
//! the shared [`Inbox`]; collective and P2P traffic travel in separate
//! queue families so the asynchronous mailbox protocols can interleave
//! with synchronous collectives. Because reader threads always drain their
//! sockets, the naive everyone-writes-then-reads collective cannot
//! deadlock on kernel buffers.
//!
//! The collective `exchange` is an all-gather over the mesh with a
//! sequence-number check; the rank-ordered deterministic *reduction*
//! happens in [`crate::dist::NodeCtx`], shared with the simulated backend,
//! which is what makes results bit-identical across backends.
//!
//! Failure paths (handshake mismatch, peer death, receive timeout) all
//! surface as [`crate::error::Error`]; a worker that loses a peer
//! mid-collective fails with a typed peer-lost diagnostic rather than
//! hanging.
//!
//! ## Elastic membership
//!
//! With [`TcpOptions::elastic`] the mesh listener stays open after
//! bootstrap. When a peer dies, survivors call [`Communicator::rebuild`]:
//! each parks on its listener and admits a replacement worker that dials
//! back in via [`TcpComm::connect_join`] (a `Join` frame answered by an
//! `EpochAck` carrying the new epoch). Collective frames are tagged
//! `epoch << 48 | seq`, so stragglers from the aborted round of the old
//! epoch are skipped on receive instead of corrupting the new one.

use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::wire::{self, decode_text, encode_text, Frame, FrameKind, Precision};
use super::{
    epoch_tag, recv_collective, Communicator, Gathered, Inbox, Membership, P2pMsg,
    PendingExchange, Timing,
};
use crate::error::{Context, Result};

/// Timeouts and addressing for the TCP backend.
#[derive(Debug, Clone)]
pub struct TcpOptions {
    /// Deadline for the whole bootstrap (rendezvous dial + mesh formation).
    pub connect_timeout: Duration,
    /// Maximum wait for a collective contribution or an expected P2P reply
    /// (`None` = wait forever). [`Communicator::recv_any`] never times out:
    /// an idle parameter server legitimately waits on its clients.
    pub io_timeout: Option<Duration>,
    /// Mesh-listener bind address, `IP` or `IP:PORT` (default
    /// `127.0.0.1:0`). For multi-host clusters, bind an interface the
    /// peers can reach (the worker CLI's `--bind`).
    pub bind: Option<String>,
    /// Address advertised to peers in the roster, `HOST` or `HOST:PORT`
    /// (default: the bind IP plus the actual listener port). Required when
    /// binding a wildcard address (`0.0.0.0` / `::`), or when peers reach
    /// this host through NAT/port-forwarding.
    pub advertise: Option<String>,
    /// Keep the mesh listener open after bootstrap so this endpoint can
    /// accept elastic re-joins ([`Communicator::rebuild`]); off by default
    /// — fixed-membership runs close it once the mesh is formed.
    pub elastic: bool,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            connect_timeout: Duration::from_secs(30),
            io_timeout: Some(Duration::from_secs(120)),
            bind: None,
            advertise: None,
            elastic: false,
        }
    }
}

/// Split `IP[:PORT]` into `(ip, port)`, defaulting the port to 0
/// (ephemeral). Unbracketed IPv6 literals are treated as a bare host
/// (bracket them — `[::1]:4000` — to pin a port). A malformed port is an
/// error, not a silent fallback: an operator pinning a firewall-opened
/// port must not end up on an ephemeral one.
fn split_bind(spec: &str) -> Result<(String, u16)> {
    let parse_port = |p: &str| {
        p.parse::<u16>()
            .map_err(|e| crate::err!("invalid port {p:?} in bind/advertise spec {spec:?}: {e}"))
    };
    if let Some(rest) = spec.strip_prefix('[') {
        // [v6]:port or [v6]
        if let Some((ip, port)) = rest.split_once("]:") {
            return Ok((ip.to_string(), parse_port(port)?));
        }
        return Ok((rest.trim_end_matches(']').to_string(), 0));
    }
    if spec.matches(':').count() > 1 {
        // unbracketed IPv6 literal: all of it is the host
        return Ok((spec.to_string(), 0));
    }
    match spec.rsplit_once(':') {
        Some((ip, port)) if !ip.is_empty() => Ok((ip.to_string(), parse_port(port)?)),
        _ => Ok((spec.to_string(), 0)),
    }
}

/// Render a `(host, port)` pair as a dialable address (bracketing IPv6
/// literals).
fn join_addr(host: &str, port: u16) -> String {
    if host.contains(':') && !host.starts_with('[') {
        format!("[{host}]:{port}")
    } else {
        format!("{host}:{port}")
    }
}

/// Resolve the address this rank advertises in the address book.
fn advertised_addr(opts: &TcpOptions, bind_ip: &str, port: u16) -> Result<String> {
    if let Some(a) = &opts.advertise {
        let (host, advert_port) = split_bind(a)?;
        let advert_port = if advert_port == 0 { port } else { advert_port };
        return Ok(join_addr(&host, advert_port));
    }
    if bind_ip == "0.0.0.0" || bind_ip == "::" {
        crate::bail!(
            "binding the wildcard address {bind_ip} requires an explicit \
             --advertise HOST[:PORT] so peers know where to dial"
        );
    }
    Ok(join_addr(bind_ip, port))
}

/// One rank's endpoint on a real TCP cluster.
pub struct TcpComm {
    rank: usize,
    nodes: usize,
    /// Write half per peer (`None` at own index).
    writers: Vec<Option<TcpStream>>,
    inbox: Arc<Inbox>,
    /// Collective round counter (skew detector).
    seq: u64,
    /// Membership epoch this endpoint currently speaks (0 at bootstrap).
    epoch: u64,
    io_timeout: Option<Duration>,
    /// Handshake deadline budget (joiner-side reads, survivor re-join
    /// dial acceptance).
    connect_timeout: Duration,
    /// Mesh listener retained in elastic mode so survivors can accept
    /// re-joining replacements; `None` on fixed-membership endpoints.
    listener: Option<TcpListener>,
    /// Connection back to the coordinator (result reporting); taken by the
    /// worker via [`TcpComm::take_rendezvous`].
    rendezvous: Option<TcpStream>,
}

fn dial_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    crate::bail!("connecting to {addr} timed out ({e})");
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

fn reader_loop(mut sock: TcpStream, peer: usize, inbox: Arc<Inbox>) {
    loop {
        match wire::read_frame(&mut sock) {
            Ok(f) => {
                let msg =
                    P2pMsg { from: peer, tag: f.tag, sent_at: f.clock, payload: f.payload };
                match f.kind {
                    // quantized collective payloads are already decoded
                    // back to f32 by `wire::read_frame`
                    FrameKind::Collective
                    | FrameKind::CollectiveF16
                    | FrameKind::CollectiveBf16 => inbox.push_coll(peer, msg),
                    FrameKind::P2p => inbox.push_p2p(peer, msg),
                    // anything else on a mesh link is a protocol violation
                    _ => break,
                }
            }
            // EOF (clean peer shutdown) and hard errors end the link alike;
            // pending receives from this peer then fail with a diagnostic
            Err(_) => break,
        }
    }
    inbox.close(peer);
}

impl TcpComm {
    /// Join the cluster: dial the coordinator at `rendezvous_addr`,
    /// handshake as `rank` of `nodes`, and form the peer mesh.
    pub fn connect(
        rendezvous_addr: &str,
        rank: usize,
        nodes: usize,
        opts: &TcpOptions,
    ) -> Result<TcpComm> {
        if rank >= nodes {
            crate::bail!("rank {rank} outside cluster of {nodes}");
        }
        let deadline = Instant::now() + opts.connect_timeout;

        // mesh listener first, so the advertised address is live before the
        // address book ever mentions it
        let (bind_ip, bind_port) =
            split_bind(opts.bind.as_deref().unwrap_or("127.0.0.1:0"))?;
        let listener = TcpListener::bind((bind_ip.as_str(), bind_port))
            .with_context(|| format!("binding mesh listener on {bind_ip}:{bind_port}"))?;
        let port = listener.local_addr().context("mesh listener addr")?.port();
        let advert = advertised_addr(opts, &bind_ip, port)?;

        let mut rdv = dial_retry(rendezvous_addr, deadline)
            .with_context(|| format!("rank {rank} reaching coordinator"))?;
        rdv.set_nodelay(true).ok();
        // bound every bootstrap read by the connect deadline so a hung
        // coordinator/peer turns into an error, not a stuck worker
        rdv.set_read_timeout(Some(opts.connect_timeout)).ok();
        wire::write_preamble(&mut rdv, rank as u16)?;
        wire::write_frame(
            &mut rdv,
            &Frame::new(FrameKind::Hello, rank as u64, 0.0, encode_text(&advert)),
        )
        .context("sending hello")?;

        let roster = wire::read_frame(&mut rdv).context("waiting for address book")?;
        if roster.kind != FrameKind::Roster {
            crate::bail!("expected the address-book roster, got {:?}", roster.kind);
        }
        let book: Vec<String> =
            decode_text(&roster.payload).split(',').map(str::to_string).collect();
        if book.len() != nodes {
            crate::bail!("address book lists {} ranks, expected {nodes}", book.len());
        }

        // mesh: dial every higher rank, accept from every lower rank
        let mut sockets: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        for (peer, peer_addr) in book.iter().enumerate().skip(rank + 1) {
            let mut s = dial_retry(peer_addr, deadline)
                .with_context(|| format!("rank {rank} dialing peer {peer} at {peer_addr}"))?;
            s.set_nodelay(true).ok();
            wire::write_preamble(&mut s, rank as u16)?;
            sockets[peer] = Some(s);
        }
        listener.set_nonblocking(true).context("mesh listener nonblocking")?;
        let mut accepted = 0;
        while accepted < rank {
            match listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false).context("peer socket blocking")?;
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(opts.connect_timeout)).ok();
                    let peer = wire::read_preamble(&mut s)? as usize;
                    s.set_read_timeout(None).ok(); // data plane blocks freely
                    if peer >= nodes || peer == rank {
                        crate::bail!("mesh hello from invalid rank {peer}");
                    }
                    if sockets[peer].is_some() {
                        crate::bail!("duplicate mesh connection from rank {peer}");
                    }
                    sockets[peer] = Some(s);
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        crate::bail!(
                            "rank {rank} timed out waiting for mesh peers ({accepted}/{rank} connected)"
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("mesh accept failed: {e}")),
            }
        }

        // data plane: one reader thread per peer (own slot starts closed —
        // no self link — so all-peers-disconnected detection can fire)
        let inbox = Arc::new(Inbox::new(nodes, rank));
        let mut writers: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        for (peer, sock) in sockets.into_iter().enumerate() {
            if let Some(sock) = sock {
                let reader = sock.try_clone().context("cloning peer socket")?;
                writers[peer] = Some(sock);
                let inbox2 = inbox.clone();
                std::thread::Builder::new()
                    .name(format!("dsanls-net-r{rank}p{peer}"))
                    .spawn(move || reader_loop(reader, peer, inbox2))
                    .context("spawning reader thread")?;
            }
        }

        Ok(TcpComm {
            rank,
            nodes,
            writers,
            inbox,
            seq: 0,
            epoch: 0,
            io_timeout: opts.io_timeout,
            connect_timeout: opts.connect_timeout,
            listener: opts.elastic.then_some(listener),
            rendezvous: Some(rdv),
        })
    }

    /// Re-join a running elastic cluster as a replacement for a dead rank:
    /// dial the coordinator with a `Join` hello, receive the (updated)
    /// address-book roster, then dial every survivor's mesh listener and
    /// collect their `EpochAck`s. The survivors are parked in
    /// [`Communicator::rebuild`] when this succeeds, and everyone resumes
    /// at round 0 of the acknowledged epoch.
    ///
    /// `claim` pins the epoch this worker believes is forming (`None` =
    /// accept whatever the survivors are at); a mismatched claim is
    /// refused by the survivors with a typed error.
    pub fn connect_join(
        rendezvous_addr: &str,
        rank: usize,
        nodes: usize,
        opts: &TcpOptions,
        claim: Option<u64>,
    ) -> Result<TcpComm> {
        if rank >= nodes {
            crate::bail!("rank {rank} outside cluster of {nodes}");
        }
        let deadline = Instant::now() + opts.connect_timeout;
        let claim_tag = claim.unwrap_or(u64::MAX);

        let (bind_ip, bind_port) =
            split_bind(opts.bind.as_deref().unwrap_or("127.0.0.1:0"))?;
        let listener = TcpListener::bind((bind_ip.as_str(), bind_port))
            .with_context(|| format!("binding mesh listener on {bind_ip}:{bind_port}"))?;
        let port = listener.local_addr().context("mesh listener addr")?.port();
        let advert = advertised_addr(opts, &bind_ip, port)?;

        let mut rdv = dial_retry(rendezvous_addr, deadline)
            .with_context(|| format!("re-joining rank {rank} reaching coordinator"))?;
        rdv.set_nodelay(true).ok();
        rdv.set_read_timeout(Some(opts.connect_timeout)).ok();
        wire::write_preamble(&mut rdv, rank as u16)?;
        wire::write_frame(
            &mut rdv,
            &Frame::new(FrameKind::Join, claim_tag, 0.0, encode_text(&advert)),
        )
        .context("sending join hello")?;

        let roster = wire::read_frame(&mut rdv).context("waiting for re-join address book")?;
        if roster.kind == FrameKind::Error {
            crate::bail!("coordinator refused the join: {}", decode_text(&roster.payload));
        }
        if roster.kind != FrameKind::Roster {
            crate::bail!("expected the address-book roster, got {:?}", roster.kind);
        }
        let book: Vec<String> =
            decode_text(&roster.payload).split(',').map(str::to_string).collect();
        if book.len() != nodes {
            crate::bail!("address book lists {} ranks, expected {nodes}", book.len());
        }

        // dial every survivor; each answers with an EpochAck (or a typed
        // refusal in an Error frame)
        let mut sockets: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        let mut acked_epoch: Option<u64> = None;
        for (peer, peer_addr) in book.iter().enumerate() {
            if peer == rank {
                continue;
            }
            let mut s = dial_retry(peer_addr, deadline).with_context(|| {
                format!("re-joining rank {rank} dialing survivor {peer} at {peer_addr}")
            })?;
            s.set_nodelay(true).ok();
            s.set_read_timeout(Some(opts.connect_timeout)).ok();
            wire::write_preamble(&mut s, rank as u16)?;
            wire::write_frame(&mut s, &Frame::new(FrameKind::Join, claim_tag, 0.0, Vec::new()))
                .with_context(|| format!("sending join request to survivor {peer}"))?;
            let ack = wire::read_frame(&mut s)
                .with_context(|| format!("waiting for epoch ack from survivor {peer}"))?;
            match ack.kind {
                FrameKind::EpochAck => {}
                FrameKind::Error => crate::bail!(
                    "survivor {peer} refused the join: {}",
                    decode_text(&ack.payload)
                ),
                other => crate::bail!("expected an epoch ack from survivor {peer}, got {other:?}"),
            }
            if let Some(e) = acked_epoch {
                if e != ack.tag {
                    crate::bail!(
                        "survivors disagree on the forming epoch ({e} vs {} from rank {peer})",
                        ack.tag
                    );
                }
            }
            acked_epoch = Some(ack.tag);
            s.set_read_timeout(None).ok();
            sockets[peer] = Some(s);
        }
        let epoch = acked_epoch
            .ok_or_else(|| crate::err!("re-join of a single-rank cluster has no survivors"))?;

        let inbox = Arc::new(Inbox::new(nodes, rank));
        let mut writers: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        for (peer, sock) in sockets.into_iter().enumerate() {
            if let Some(sock) = sock {
                let reader = sock.try_clone().context("cloning peer socket")?;
                writers[peer] = Some(sock);
                let inbox2 = inbox.clone();
                std::thread::Builder::new()
                    .name(format!("dsanls-net-r{rank}p{peer}"))
                    .spawn(move || reader_loop(reader, peer, inbox2))
                    .context("spawning reader thread")?;
            }
        }

        Ok(TcpComm {
            rank,
            nodes,
            writers,
            inbox,
            seq: 0,
            epoch,
            io_timeout: opts.io_timeout,
            connect_timeout: opts.connect_timeout,
            listener: Some(listener), // a joiner is always elastic
            rendezvous: Some(rdv),
        })
    }

    /// Detach the connection back to the coordinator (worker result
    /// reporting) so the mesh communicator can be consumed by the
    /// algorithm layer independently. Returns `None` on a second call.
    pub fn take_rendezvous(&mut self) -> Option<TcpStream> {
        self.rendezvous.take()
    }

    /// A closure that interrupts this endpoint's inbox (all blocked and
    /// future receives fail immediately) — registered with a job's
    /// [`crate::nmf::control::ControlToken`] so `kill()` unblocks a rank
    /// that would otherwise hang in a TCP read.
    pub fn interrupter(&self) -> impl Fn() + Send + Sync + 'static {
        let inbox = self.inbox.clone();
        move || inbox.interrupt()
    }

    fn writer(&mut self, peer: usize) -> Result<&mut TcpStream> {
        if peer >= self.nodes || peer == self.rank {
            crate::bail!("no link to rank {peer} (self = {}, nodes = {})", self.rank, self.nodes);
        }
        self.writers[peer]
            .as_mut()
            .ok_or_else(|| crate::err!("link to rank {peer} is down"))
    }
}

/// Survivor-side admission check for a re-join request. `claimed` is the
/// epoch the joiner believes is forming (`u64::MAX` = wildcard, accept
/// whatever the survivors decide).
fn validate_join(
    peer: usize,
    claimed: u64,
    next_epoch: u64,
    nodes: usize,
    dead: &[usize],
    joined: &[usize],
) -> Result<()> {
    if peer >= nodes {
        crate::bail!("join from unknown rank {peer}, cluster size is {nodes}");
    }
    if joined.contains(&peer) {
        crate::bail!("rank {peer} already re-joined this epoch — double-join refused");
    }
    if !dead.contains(&peer) {
        crate::bail!("rank {peer} is still connected — double-join refused");
    }
    if claimed != u64::MAX && claimed != next_epoch {
        if claimed < next_epoch {
            crate::bail!(
                "stale-epoch join: rank {peer} claims epoch {claimed}, cluster is \
                 forming epoch {next_epoch}"
            );
        }
        crate::bail!(
            "future-epoch join: rank {peer} claims epoch {claimed}, cluster is \
             forming epoch {next_epoch}"
        );
    }
    Ok(())
}

impl Communicator for TcpComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn timing(&self) -> Timing {
        Timing::Measured
    }

    fn exchange(&mut self, clock: f64, payload: &[f32]) -> Result<Gathered> {
        let seq = self.seq;
        self.seq += 1;
        let tag = epoch_tag(self.epoch, seq);
        for peer in 0..self.nodes {
            if peer == self.rank {
                continue;
            }
            let w = self.writer(peer)?;
            // a failed write to a dead peer is a membership event, same as
            // a failed read — the write side often notices first
            wire::write_frame_parts(w, FrameKind::Collective, tag, clock, payload).map_err(
                |e| crate::error::Error::peer_lost(peer, format_args!("collective send to rank {peer}: {e}")),
            )?;
        }
        let mut parts: Vec<Vec<f32>> = Vec::with_capacity(self.nodes);
        let mut max_clock = clock;
        for peer in 0..self.nodes {
            if peer == self.rank {
                parts.push(payload.to_vec());
                continue;
            }
            let msg = recv_collective(&self.inbox, peer, self.epoch, seq, self.io_timeout)
                .with_context(|| format!("collective round {seq}, rank {}", self.rank))?;
            max_clock = max_clock.max(msg.sent_at);
            parts.push(msg.payload);
        }
        Ok(Gathered { parts, max_clock })
    }

    fn exchange_start(&mut self, clock: f64, payload: &[f32]) -> Result<PendingExchange> {
        let seq = self.seq;
        self.seq += 1;
        let tag = epoch_tag(self.epoch, seq);
        // sends go out now; the per-peer reader threads accumulate the
        // replies so wait() only blocks on stragglers
        for peer in 0..self.nodes {
            if peer == self.rank {
                continue;
            }
            let w = self.writer(peer)?;
            wire::write_frame_parts(w, FrameKind::Collective, tag, clock, payload).map_err(
                |e| crate::error::Error::peer_lost(peer, format_args!("collective send to rank {peer}: {e}")),
            )?;
        }
        Ok(PendingExchange::tcp(
            self.epoch,
            seq,
            clock,
            payload.to_vec(),
            self.rank,
            self.nodes,
            self.inbox.clone(),
            self.io_timeout,
        ))
    }

    fn exchange_start_q(
        &mut self,
        clock: f64,
        payload: &[f32],
        precision: Precision,
    ) -> Result<PendingExchange> {
        if precision == Precision::F32 {
            return self.exchange_start(clock, payload);
        }
        let seq = self.seq;
        self.seq += 1;
        let tag = epoch_tag(self.epoch, seq);
        // encode once, fan the same wire bytes out to every peer
        let bytes = wire::quantize_payload(precision, payload);
        for peer in 0..self.nodes {
            if peer == self.rank {
                continue;
            }
            let w = self.writer(peer)?;
            wire::write_quantized_frame(w, precision, tag, clock, &bytes).map_err(
                |e| crate::error::Error::peer_lost(peer, format_args!("collective send to rank {peer}: {e}")),
            )?;
        }
        // the local contribution must pass through the same codec the
        // peers decode with, or ranks would disagree on rank r's part
        let mut own = payload.to_vec();
        precision.round_trip_slice(&mut own);
        Ok(PendingExchange::tcp(
            self.epoch,
            seq,
            clock,
            own,
            self.rank,
            self.nodes,
            self.inbox.clone(),
            self.io_timeout,
        ))
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn membership(&self) -> Membership {
        let mut ranks: Vec<usize> = (0..self.nodes).collect();
        let closed = self.inbox.closed_peers();
        ranks.retain(|r| *r == self.rank || !closed.contains(r));
        Membership { epoch: self.epoch, ranks }
    }

    fn rebuild(&mut self, min_ranks: usize) -> Result<Membership> {
        let dead = self.inbox.closed_peers();
        let alive = self.nodes - dead.len();
        if alive < min_ranks {
            crate::bail!(
                "cluster fell to {alive} surviving rank(s), below min_ranks {min_ranks}"
            );
        }
        let listener = self.listener.as_ref().ok_or_else(|| {
            crate::err!("elastic membership is not enabled on this endpoint")
        })?;
        listener.set_nonblocking(true).context("mesh listener nonblocking")?;
        let next_epoch = self.epoch + 1;
        let budget = self.io_timeout.unwrap_or(self.connect_timeout);
        let deadline = Instant::now() + budget;
        let mut joined: Vec<usize> = Vec::new();
        let mut pending: Vec<(usize, TcpStream)> = Vec::new();
        // survivors park here accepting the replacement's re-dial; the
        // joiner's connections queue in the listener backlog until we reach
        // this loop, so no cross-rank coordination is needed
        while joined.len() < dead.len() {
            match listener.accept() {
                Ok((mut s, _)) => {
                    if s.set_nonblocking(false).is_err() {
                        continue;
                    }
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(self.connect_timeout)).ok();
                    // a version-mismatched joiner is refused right here
                    let peer = match wire::read_preamble(&mut s) {
                        Ok(p) => p as usize,
                        Err(_) => continue,
                    };
                    let frame = match wire::read_frame(&mut s) {
                        Ok(f) if f.kind == FrameKind::Join => f,
                        _ => continue,
                    };
                    match validate_join(peer, frame.tag, next_epoch, self.nodes, &dead, &joined)
                    {
                        Ok(()) => {
                            let ack =
                                Frame::new(FrameKind::EpochAck, next_epoch, 0.0, Vec::new());
                            if wire::write_frame(&mut s, &ack).is_err() {
                                continue;
                            }
                            s.set_read_timeout(None).ok();
                            joined.push(peer);
                            pending.push((peer, s));
                        }
                        Err(e) => {
                            let refusal = Frame::new(
                                FrameKind::Error,
                                0,
                                0.0,
                                encode_text(&e.to_string()),
                            );
                            let _ = wire::write_frame(&mut s, &refusal);
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        crate::bail!(
                            "membership rebuild timed out after {budget:?}: {}/{} \
                             replacement(s) joined for dead rank(s) {dead:?}",
                            joined.len(),
                            dead.len()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("rebuild accept failed: {e}")),
            }
        }
        for (peer, sock) in pending {
            // reopen the inbox slot *before* the reader thread starts so the
            // replacement's first frames land in a live queue
            self.inbox.reopen(peer);
            let reader = sock.try_clone().context("cloning replacement socket")?;
            self.writers[peer] = Some(sock);
            let inbox2 = self.inbox.clone();
            std::thread::Builder::new()
                .name(format!("dsanls-net-r{rank}p{peer}", rank = self.rank))
                .spawn(move || reader_loop(reader, peer, inbox2))
                .context("spawning replacement reader thread")?;
        }
        self.epoch = next_epoch;
        self.seq = 0;
        Ok(self.membership())
    }

    fn send(&mut self, to: usize, tag: u64, clock: f64, payload: &[f32]) -> Result<()> {
        let w = self.writer(to)?;
        wire::write_frame_parts(w, FrameKind::P2p, tag, clock, payload)
            .with_context(|| format!("p2p send to rank {to}"))
    }

    fn recv_from(&mut self, from: usize) -> Result<P2pMsg> {
        self.inbox.recv_p2p_from(from, self.io_timeout)
    }

    fn recv_any(&mut self) -> Result<P2pMsg> {
        // no timeout: an idle parameter server waits on its clients
        self.inbox.recv_p2p_any(None)
    }
}

impl Drop for TcpComm {
    fn drop(&mut self) {
        // half-close every mesh link so peers' reader threads observe EOF
        // and release their pending receives promptly
        for w in self.writers.iter().flatten() {
            let _ = w.shutdown(Shutdown::Write);
        }
        if let Some(r) = &self.rendezvous {
            let _ = r.shutdown(Shutdown::Write);
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator side of the bootstrap
// ---------------------------------------------------------------------------

/// Coordinator's rendezvous point: accepts worker handshakes, assigns the
/// address-book roster, and hands back one result channel per rank.
pub struct Rendezvous {
    listener: TcpListener,
    host: String,
    port: u16,
}

/// An accepted, handshaken worker connection.
pub struct WorkerConn {
    /// The worker's announced rank.
    pub rank: usize,
    /// The rendezvous connection (used for result streaming).
    pub stream: TcpStream,
    /// The mesh address the worker advertised.
    pub mesh_addr: String,
}

impl Rendezvous {
    /// Listen on `127.0.0.1:port` (`0` = ephemeral) — single-host runs.
    pub fn bind(port: u16) -> Result<Rendezvous> {
        Rendezvous::bind_on("127.0.0.1", port)
    }

    /// Listen on `host:port` (`0` = ephemeral). Bind a reachable interface
    /// (or `0.0.0.0`) for multi-host clusters; workers dial this address
    /// via `--rendezvous`.
    pub fn bind_on(host: &str, port: u16) -> Result<Rendezvous> {
        let listener = TcpListener::bind((host, port))
            .with_context(|| format!("binding rendezvous {host}:{port}"))?;
        let port = listener.local_addr().context("rendezvous addr")?.port();
        Ok(Rendezvous { listener, host: host.to_string(), port })
    }

    /// The bound rendezvous port.
    pub fn port(&self) -> u16 {
        self.port
    }

    /// The bound `host:port` (note: when bound to `0.0.0.0`, workers must
    /// dial a concrete reachable host, not this string).
    pub fn addr(&self) -> String {
        format!("{}:{}", self.host, self.port)
    }

    /// Accept `nodes` workers (validating magic/version, rank uniqueness
    /// and the announced mesh address), broadcast the address-book roster,
    /// and return the connections in rank order.
    pub fn wait_workers(&self, nodes: usize, timeout: Duration) -> Result<Vec<WorkerConn>> {
        self.listener.set_nonblocking(true).context("rendezvous nonblocking")?;
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<Option<(TcpStream, String)>> = (0..nodes).map(|_| None).collect();
        let mut got = 0;
        while got < nodes {
            match self.listener.accept() {
                Ok((mut s, addr)) => {
                    s.set_nonblocking(false).context("worker socket blocking")?;
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(timeout)).ok();
                    let rank = wire::read_preamble(&mut s)
                        .with_context(|| format!("handshake from {addr}"))? as usize;
                    if rank >= nodes {
                        crate::bail!("worker announced rank {rank}, cluster size is {nodes}");
                    }
                    let hello = wire::read_frame(&mut s).context("reading hello")?;
                    s.set_read_timeout(None).ok();
                    let mesh_addr = decode_text(&hello.payload);
                    if hello.kind == FrameKind::Join {
                        // a straggling joiner from an aborted elastic attempt
                        // must not poison this rendezvous (the listener is
                        // bound once and reused across launch retries) —
                        // refuse it and keep waiting for real workers
                        let refusal = Frame::new(
                            FrameKind::Error,
                            0,
                            0.0,
                            encode_text("no elastic join in flight"),
                        );
                        let _ = wire::write_frame(&mut s, &refusal);
                        continue;
                    }
                    if slots[rank].is_some() {
                        crate::bail!(
                            "two workers announced rank {rank} (rank collision — check the \
                             --rank each worker was started with)"
                        );
                    }
                    if hello.kind != FrameKind::Hello || !mesh_addr.contains(':') {
                        crate::bail!("malformed hello from rank {rank}");
                    }
                    slots[rank] = Some((s, mesh_addr));
                    got += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        crate::bail!("rendezvous timed out: {got}/{nodes} workers connected");
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("rendezvous accept failed: {e}")),
            }
        }
        let book: Vec<String> =
            slots.iter().map(|c| c.as_ref().unwrap().1.clone()).collect();
        let payload = encode_text(&book.join(","));
        let mut out = Vec::with_capacity(nodes);
        for (rank, slot) in slots.into_iter().enumerate() {
            let (mut s, mesh_addr) = slot.unwrap();
            wire::write_frame(
                &mut s,
                &Frame::new(FrameKind::Roster, nodes as u64, 0.0, payload.clone()),
            )
            .with_context(|| format!("sending address book to rank {rank}"))?;
            out.push(WorkerConn { rank, stream: s, mesh_addr });
        }
        Ok(out)
    }

    /// Accept one elastic re-join handshake, if any arrives within `wait`:
    /// a replacement worker dials with a `Join` frame carrying its fresh
    /// mesh address, the coordinator patches the address book and replies
    /// with the updated roster. Returns `Ok(None)` when nothing dialed in
    /// (poll again), `Ok(Some(conn))` for an admitted joiner.
    ///
    /// Malformed or out-of-range joins are refused with an `Error` frame
    /// and do not fail the coordinator.
    pub fn accept_join(
        &self,
        book: &mut [String],
        wait: Duration,
    ) -> Result<Option<WorkerConn>> {
        self.listener.set_nonblocking(true).context("rendezvous nonblocking")?;
        let deadline = Instant::now() + wait;
        loop {
            match self.listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false).context("joiner socket blocking")?;
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(Duration::from_secs(10))).ok();
                    // a version-mismatched joiner fails the preamble read;
                    // a half-open dial fails the frame read — drop both
                    let rank = match wire::read_preamble(&mut s) {
                        Ok(r) => r as usize,
                        Err(_) => continue,
                    };
                    let frame = match wire::read_frame(&mut s) {
                        Ok(f) => f,
                        Err(_) => continue,
                    };
                    let mesh_addr = decode_text(&frame.payload);
                    if frame.kind != FrameKind::Join
                        || !mesh_addr.contains(':')
                        || rank >= book.len()
                    {
                        let refusal = Frame::new(
                            FrameKind::Error,
                            0,
                            0.0,
                            encode_text(&format!("malformed join from rank {rank}")),
                        );
                        let _ = wire::write_frame(&mut s, &refusal);
                        continue;
                    }
                    book[rank] = mesh_addr.clone();
                    let payload = encode_text(&book.join(","));
                    wire::write_frame(
                        &mut s,
                        &Frame::new(FrameKind::Roster, book.len() as u64, 0.0, payload),
                    )
                    .with_context(|| format!("sending re-join address book to rank {rank}"))?;
                    s.set_read_timeout(None).ok();
                    return Ok(Some(WorkerConn { rank, stream: s, mesh_addr }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("re-join accept failed: {e}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `f` once per rank on its own thread over a real localhost TCP
    /// mesh (rendezvous included).
    fn tcp_ranks<T: Send>(n: usize, f: impl Fn(TcpComm) -> T + Sync) -> Vec<T> {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(n, Duration::from_secs(10)).unwrap());
            for (rank, slot) in out.iter_mut().enumerate() {
                let addr = addr.clone();
                let f = &f;
                s.spawn(move || {
                    let comm =
                        TcpComm::connect(&addr, rank, n, &TcpOptions::default()).unwrap();
                    *slot = Some(f(comm));
                });
            }
            // keep coordinator-side result channels alive until ranks finish
            let _conns = coord.join().unwrap();
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    #[test]
    fn tcp_exchange_matches_rank_order() {
        for n in [1usize, 2, 4] {
            let results = tcp_ranks(n, |mut c| {
                let mut rounds = Vec::new();
                for round in 0..5 {
                    let g = c
                        .exchange(c.rank() as f64, &[(round * 10 + c.rank()) as f32; 2])
                        .unwrap();
                    assert_eq!(g.parts.len(), n);
                    for (r, p) in g.parts.iter().enumerate() {
                        assert!(p.iter().all(|&v| v == (round * 10 + r) as f32));
                    }
                    rounds.push(g.max_clock);
                }
                rounds
            });
            for clocks in results {
                assert!(clocks.iter().all(|&c| c == (n - 1) as f64));
            }
        }
    }

    #[test]
    fn tcp_ragged_all_gather() {
        let results = tcp_ranks(3, |mut c| {
            let mine = vec![c.rank() as f32; c.rank() + 1];
            c.exchange(0.0, &mine).unwrap().parts
        });
        for parts in results {
            for (r, p) in parts.iter().enumerate() {
                assert_eq!(p.len(), r + 1);
                assert!(p.iter().all(|&v| v == r as f32));
            }
        }
    }

    #[test]
    fn tcp_exchange_start_and_quantized_match_sim_semantics() {
        let results = tcp_ranks(3, |mut c| {
            let rank = c.rank();
            // non-blocking round 0 with round 1 posted before waiting 0
            let p0 = c.exchange_start(0.0, &[rank as f32]).unwrap();
            let p1 = c.exchange_start(0.0, &[(rank + 10) as f32]).unwrap();
            let g0 = p0.wait().unwrap();
            let g1 = p1.wait().unwrap();
            // quantized round: real 2-byte frames on the wire, and the own
            // contribution goes through the same codec as the peers'
            let v = 0.1f32 + rank as f32;
            let gq = c.exchange_start_q(0.0, &[v], Precision::Bf16).unwrap().wait().unwrap();
            // a blocking exchange still lines up afterwards
            let g2 = c.exchange(0.0, &[rank as f32 * 2.0]).unwrap();
            (g0, g1, gq, g2)
        });
        for (g0, g1, gq, g2) in results {
            for r in 0..3 {
                assert_eq!(g0.parts[r][0], r as f32);
                assert_eq!(g1.parts[r][0], (r + 10) as f32);
                let expect = Precision::Bf16.round_trip(0.1f32 + r as f32);
                assert_eq!(gq.parts[r][0].to_bits(), expect.to_bits(), "rank {r}");
                assert_eq!(g2.parts[r][0], r as f32 * 2.0);
            }
        }
    }

    #[test]
    fn tcp_p2p_parameter_server_shape() {
        let results = tcp_ranks(3, |mut c| {
            if c.rank() == 0 {
                for _ in 0..2 {
                    let m = c.recv_any().unwrap();
                    let doubled: Vec<f32> = m.payload.iter().map(|v| v * 2.0).collect();
                    c.send(m.from, m.tag, 0.0, &doubled).unwrap();
                }
                Vec::new()
            } else {
                c.send(0, c.rank() as u64, 0.25, &[c.rank() as f32, 10.0]).unwrap();
                let reply = c.recv_from(0).unwrap();
                assert_eq!(reply.tag, c.rank() as u64);
                reply.payload
            }
        });
        assert_eq!(results[1], vec![2.0, 20.0]);
        assert_eq!(results[2], vec![4.0, 20.0]);
    }

    #[test]
    fn rendezvous_rejects_rank_out_of_range() {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(1, Duration::from_secs(5)));
            s.spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                wire::write_preamble(&mut sock, 7).unwrap(); // rank 7 of 1
                // the coordinator rejects on the preamble rank, so it may
                // close before (or while) the hello lands — don't unwrap
                let _ = wire::write_frame(
                    &mut sock,
                    &Frame::new(FrameKind::Hello, 7, 0.0, encode_text("127.0.0.1:9")),
                );
            });
            let err = coord.join().unwrap().unwrap_err();
            assert!(err.to_string().contains("rank 7"), "{err}");
        });
    }

    #[test]
    fn connect_timeout_is_clean_error() {
        // nothing listens on this port (bound then dropped)
        let port = {
            let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
        };
        let opts = TcpOptions {
            connect_timeout: Duration::from_millis(100),
            io_timeout: Some(Duration::from_millis(100)),
            ..TcpOptions::default()
        };
        let err = TcpComm::connect(&format!("127.0.0.1:{port}"), 0, 2, &opts).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn bind_spec_parsing() {
        assert_eq!(split_bind("127.0.0.1").unwrap(), ("127.0.0.1".into(), 0));
        assert_eq!(split_bind("10.1.2.3:4100").unwrap(), ("10.1.2.3".into(), 4100));
        assert_eq!(split_bind("0.0.0.0:0").unwrap(), ("0.0.0.0".into(), 0));
        assert_eq!(split_bind("[::1]:9").unwrap(), ("::1".into(), 9));
        // unbracketed IPv6 is a bare host, not host:port
        assert_eq!(split_bind("fe80::8").unwrap(), ("fe80::8".into(), 0));
        // malformed / out-of-range ports must error, not silently go ephemeral
        assert!(split_bind("10.0.0.1:47O10").is_err());
        assert!(split_bind("10.0.0.1:70000").is_err());
    }

    #[test]
    fn wildcard_bind_requires_advertise() {
        let opts = TcpOptions { bind: Some("0.0.0.0".into()), ..TcpOptions::default() };
        let err = advertised_addr(&opts, "0.0.0.0", 1234).unwrap_err();
        assert!(err.to_string().contains("--advertise"), "{err}");
        let opts = TcpOptions {
            bind: Some("0.0.0.0".into()),
            advertise: Some("worker-3.cluster".into()),
            ..TcpOptions::default()
        };
        assert_eq!(advertised_addr(&opts, "0.0.0.0", 1234).unwrap(), "worker-3.cluster:1234");
        assert_eq!(advertised_addr(&TcpOptions::default(), "10.0.0.8", 7).unwrap(), "10.0.0.8:7");
        // a bare IPv6 advertise host still gets the listener port, bracketed
        let opts = TcpOptions {
            bind: Some("::".into()),
            advertise: Some("fe80::8".into()),
            ..TcpOptions::default()
        };
        assert_eq!(advertised_addr(&opts, "::", 4100).unwrap(), "[fe80::8]:4100");
    }

    #[test]
    fn validate_join_admission_rules() {
        let dead = [1usize];
        let joined: [usize; 0] = [];
        // wildcard claim on a dead slot: admitted
        assert!(validate_join(1, u64::MAX, 3, 2, &dead, &joined).is_ok());
        // exact claim of the forming epoch: admitted
        assert!(validate_join(1, 3, 3, 2, &dead, &joined).is_ok());
        // stale epoch claim: typed refusal
        let err = validate_join(1, 2, 3, 2, &dead, &joined).unwrap_err();
        assert!(err.to_string().contains("stale-epoch join"), "{err}");
        // future epoch claim: typed refusal
        let err = validate_join(1, 9, 3, 2, &dead, &joined).unwrap_err();
        assert!(err.to_string().contains("future-epoch join"), "{err}");
        // live rank: double-join refused
        let err = validate_join(0, u64::MAX, 3, 2, &dead, &joined).unwrap_err();
        assert!(err.to_string().contains("double-join refused"), "{err}");
        // second join of an already-admitted slot: double-join refused
        let err = validate_join(1, u64::MAX, 3, 2, &dead, &[1]).unwrap_err();
        assert!(err.to_string().contains("double-join refused"), "{err}");
        // unknown rank
        let err = validate_join(5, u64::MAX, 3, 2, &dead, &joined).unwrap_err();
        assert!(err.to_string().contains("unknown rank"), "{err}");
    }

    #[test]
    fn tcp_dead_rank_rejoins_at_next_epoch() {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        let opts = TcpOptions {
            elastic: true,
            io_timeout: Some(Duration::from_secs(10)),
            ..TcpOptions::default()
        };
        std::thread::scope(|s| {
            // coordinator: bootstrap both ranks, then serve the re-join
            let rdv_opts = &rdv;
            let coord = s.spawn(move || {
                let conns = rdv_opts.wait_workers(2, Duration::from_secs(10)).unwrap();
                let mut book: Vec<String> =
                    conns.iter().map(|c| c.mesh_addr.clone()).collect();
                let deadline = Instant::now() + Duration::from_secs(10);
                let joined = loop {
                    if let Some(j) =
                        rdv_opts.accept_join(&mut book, Duration::from_millis(50)).unwrap()
                    {
                        break j;
                    }
                    assert!(Instant::now() < deadline, "no re-join arrived");
                };
                assert_eq!(joined.rank, 1);
                (conns, joined)
            });

            // rank 0: survive the death, rebuild, exchange at the new epoch
            let addr0 = addr.clone();
            let opts0 = opts.clone();
            let survivor = s.spawn(move || {
                let mut c = TcpComm::connect(&addr0, 0, 2, &opts0).unwrap();
                let g = c.exchange(0.0, &[100.0]).unwrap();
                assert_eq!(g.parts, vec![vec![100.0f32], vec![101.0f32]]);
                // keep exchanging until rank 1's death surfaces (its round-0
                // frame may still be queued when the link drops)
                let err = loop {
                    match c.exchange(0.0, &[0.0]) {
                        Ok(_) => continue,
                        Err(e) => break e,
                    }
                };
                assert_eq!(err.lost_peer(), Some(Some(1)), "{err}");
                let m = c.rebuild(1).unwrap();
                assert_eq!(m.epoch, 1);
                assert_eq!(m.ranks, vec![0, 1]);
                assert_eq!(c.epoch(), 1);
                let g = c.exchange(0.0, &[200.0]).unwrap();
                assert_eq!(g.parts, vec![vec![200.0f32], vec![201.0f32]]);
            });

            // rank 1: exchange once, die (drop = socket close), re-join
            let addr1 = addr.clone();
            let opts1 = opts.clone();
            s.spawn(move || {
                {
                    let mut c = TcpComm::connect(&addr1, 1, 2, &opts1).unwrap();
                    let g = c.exchange(0.0, &[101.0]).unwrap();
                    assert_eq!(g.parts, vec![vec![100.0f32], vec![101.0f32]]);
                } // death
                let mut c = TcpComm::connect_join(&addr1, 1, 2, &opts1, None).unwrap();
                assert_eq!(c.epoch(), 1);
                let g = c.exchange(0.0, &[201.0]).unwrap();
                assert_eq!(g.parts, vec![vec![200.0f32], vec![201.0f32]]);
            });

            survivor.join().unwrap();
            let _conns = coord.join().unwrap();
        });
    }

    #[test]
    fn rendezvous_tolerates_stale_join_hello() {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(1, Duration::from_secs(10)).unwrap());
            // a straggling joiner from some aborted elastic attempt dials in
            // first; it must be refused without failing the rendezvous
            let stale = {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut sock = TcpStream::connect(addr).unwrap();
                    sock.set_read_timeout(Some(Duration::from_secs(10))).ok();
                    wire::write_preamble(&mut sock, 0).unwrap();
                    wire::write_frame(
                        &mut sock,
                        &Frame::new(FrameKind::Join, u64::MAX, 0.0, encode_text("127.0.0.1:9")),
                    )
                    .unwrap();
                    let reply = wire::read_frame(&mut sock).unwrap();
                    assert_eq!(reply.kind, FrameKind::Error);
                    assert!(
                        decode_text(&reply.payload).contains("no elastic join in flight"),
                        "{}",
                        decode_text(&reply.payload)
                    );
                })
            };
            stale.join().unwrap();
            // the real worker still bootstraps fine afterwards
            s.spawn(move || {
                let c = TcpComm::connect(&addr, 0, 1, &TcpOptions::default()).unwrap();
                drop(c);
            });
            let conns = coord.join().unwrap();
            assert_eq!(conns.len(), 1);
        });
    }

    #[test]
    fn rebuild_without_listener_is_a_typed_error() {
        // fixed-membership endpoints refuse rebuild outright
        let results = tcp_ranks(2, |mut c| {
            if c.rank() == 0 {
                let err = c.rebuild(1).unwrap_err();
                assert!(
                    err.to_string().contains("elastic membership is not enabled"),
                    "{err}"
                );
            }
            c.exchange(0.0, &[c.rank() as f32]).unwrap().parts
        });
        for parts in results {
            assert_eq!(parts, vec![vec![0.0f32], vec![1.0f32]]);
        }
    }

    #[test]
    fn explicit_bind_forms_mesh() {
        // --bind with an explicit loopback IP must bootstrap exactly like
        // the default ephemeral path (the address book carries host:port)
        let rdv = Rendezvous::bind_on("127.0.0.1", 0).unwrap();
        let addr = rdv.addr();
        let n = 2;
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(n, Duration::from_secs(10)).unwrap());
            for rank in 0..n {
                let addr = addr.clone();
                s.spawn(move || {
                    let opts =
                        TcpOptions { bind: Some("127.0.0.1".into()), ..TcpOptions::default() };
                    let mut c = TcpComm::connect(&addr, rank, n, &opts).unwrap();
                    let g = c.exchange(0.0, &[rank as f32]).unwrap();
                    assert_eq!(g.parts, vec![vec![0.0f32], vec![1.0f32]]);
                });
            }
            let conns = coord.join().unwrap();
            for c in &conns {
                assert!(c.mesh_addr.starts_with("127.0.0.1:"), "{}", c.mesh_addr);
            }
        });
    }
}
