//! Real multi-process TCP backend (`std::net` only).
//!
//! ## Bootstrap (rendezvous)
//!
//! The coordinator binds a [`Rendezvous`] listener and spawns one worker
//! process per rank. Each worker:
//!
//! 1. binds its own mesh listener on an ephemeral port,
//! 2. dials the coordinator, sends the preamble (magic/version/rank) and a
//!    `Hello` frame carrying its mesh port,
//! 3. receives the `Roster` frame (every rank's mesh port),
//! 4. forms the full peer mesh: rank `r` dials every rank `s > r` (the
//!    dialed side learns the dialer's rank from the connection preamble)
//!    and accepts connections from every rank `s < r`.
//!
//! The worker keeps the rendezvous connection open to stream results back
//! to the coordinator when the run finishes.
//!
//! ## Data plane
//!
//! One reader thread per peer socket decodes frames ([`super::wire`]) into
//! the shared [`Inbox`]; collective and P2P traffic travel in separate
//! queue families so the asynchronous mailbox protocols can interleave
//! with synchronous collectives. Because reader threads always drain their
//! sockets, the naive everyone-writes-then-reads collective cannot
//! deadlock on kernel buffers.
//!
//! The collective `exchange` is an all-gather over the mesh with a
//! sequence-number check; the rank-ordered deterministic *reduction*
//! happens in [`crate::dist::NodeCtx`], shared with the simulated backend,
//! which is what makes results bit-identical across backends.
//!
//! Failure paths (handshake mismatch, peer death, receive timeout) all
//! surface as [`crate::error::Error`]; a worker that loses a peer
//! mid-collective aborts with a diagnostic rather than hanging.

use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::wire::{self, Frame, FrameKind};
use super::{Communicator, Gathered, Inbox, P2pMsg, Timing};
use crate::error::{Context, Result};

/// Timeouts for the TCP backend.
#[derive(Debug, Clone, Copy)]
pub struct TcpOptions {
    /// Deadline for the whole bootstrap (rendezvous dial + mesh formation).
    pub connect_timeout: Duration,
    /// Maximum wait for a collective contribution or an expected P2P reply
    /// (`None` = wait forever). [`Communicator::recv_any`] never times out:
    /// an idle parameter server legitimately waits on its clients.
    pub io_timeout: Option<Duration>,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            connect_timeout: Duration::from_secs(30),
            io_timeout: Some(Duration::from_secs(120)),
        }
    }
}

/// One rank's endpoint on a real TCP cluster.
pub struct TcpComm {
    rank: usize,
    nodes: usize,
    /// Write half per peer (`None` at own index).
    writers: Vec<Option<TcpStream>>,
    inbox: Arc<Inbox>,
    /// Collective round counter (skew detector).
    seq: u64,
    io_timeout: Option<Duration>,
    /// Connection back to the coordinator (result reporting); taken by the
    /// worker via [`TcpComm::take_rendezvous`].
    rendezvous: Option<TcpStream>,
}

fn dial_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    crate::bail!("connecting to {addr} timed out ({e})");
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

fn reader_loop(mut sock: TcpStream, peer: usize, inbox: Arc<Inbox>) {
    loop {
        match wire::read_frame(&mut sock) {
            Ok(f) => {
                let msg =
                    P2pMsg { from: peer, tag: f.tag, sent_at: f.clock, payload: f.payload };
                match f.kind {
                    FrameKind::Collective => inbox.push_coll(peer, msg),
                    FrameKind::P2p => inbox.push_p2p(peer, msg),
                    // anything else on a mesh link is a protocol violation
                    _ => break,
                }
            }
            // EOF (clean peer shutdown) and hard errors end the link alike;
            // pending receives from this peer then fail with a diagnostic
            Err(_) => break,
        }
    }
    inbox.close(peer);
}

impl TcpComm {
    /// Join the cluster: dial the coordinator at `rendezvous_addr`,
    /// handshake as `rank` of `nodes`, and form the peer mesh.
    pub fn connect(
        rendezvous_addr: &str,
        rank: usize,
        nodes: usize,
        opts: &TcpOptions,
    ) -> Result<TcpComm> {
        if rank >= nodes {
            crate::bail!("rank {rank} outside cluster of {nodes}");
        }
        let deadline = Instant::now() + opts.connect_timeout;

        // mesh listener first, so the advertised port is live before the
        // roster ever mentions it
        let listener = TcpListener::bind(("127.0.0.1", 0)).context("binding mesh listener")?;
        let port = listener.local_addr().context("mesh listener addr")?.port();

        let mut rdv = dial_retry(rendezvous_addr, deadline)
            .with_context(|| format!("rank {rank} reaching coordinator"))?;
        rdv.set_nodelay(true).ok();
        // bound every bootstrap read by the connect deadline so a hung
        // coordinator/peer turns into an error, not a stuck worker
        rdv.set_read_timeout(Some(opts.connect_timeout)).ok();
        wire::write_preamble(&mut rdv, rank as u16)?;
        wire::write_frame(
            &mut rdv,
            &Frame::new(FrameKind::Hello, rank as u64, 0.0, vec![f32::from(port)]),
        )
        .context("sending hello")?;

        let roster = wire::read_frame(&mut rdv).context("waiting for roster")?;
        if roster.kind != FrameKind::Roster {
            crate::bail!("expected roster, got {:?}", roster.kind);
        }
        if roster.payload.len() != nodes {
            crate::bail!("roster lists {} ranks, expected {nodes}", roster.payload.len());
        }
        let ports: Vec<u16> = roster.payload.iter().map(|&p| p as u16).collect();

        // mesh: dial every higher rank, accept from every lower rank
        let mut sockets: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        for (peer, &peer_port) in ports.iter().enumerate().skip(rank + 1) {
            let mut s = dial_retry(&format!("127.0.0.1:{peer_port}"), deadline)
                .with_context(|| format!("rank {rank} dialing peer {peer}"))?;
            s.set_nodelay(true).ok();
            wire::write_preamble(&mut s, rank as u16)?;
            sockets[peer] = Some(s);
        }
        listener.set_nonblocking(true).context("mesh listener nonblocking")?;
        let mut accepted = 0;
        while accepted < rank {
            match listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false).context("peer socket blocking")?;
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(opts.connect_timeout)).ok();
                    let peer = wire::read_preamble(&mut s)? as usize;
                    s.set_read_timeout(None).ok(); // data plane blocks freely
                    if peer >= nodes || peer == rank {
                        crate::bail!("mesh hello from invalid rank {peer}");
                    }
                    if sockets[peer].is_some() {
                        crate::bail!("duplicate mesh connection from rank {peer}");
                    }
                    sockets[peer] = Some(s);
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        crate::bail!(
                            "rank {rank} timed out waiting for mesh peers ({accepted}/{rank} connected)"
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("mesh accept failed: {e}")),
            }
        }

        // data plane: one reader thread per peer (own slot starts closed —
        // no self link — so all-peers-disconnected detection can fire)
        let inbox = Arc::new(Inbox::new(nodes, rank));
        let mut writers: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        for (peer, sock) in sockets.into_iter().enumerate() {
            if let Some(sock) = sock {
                let reader = sock.try_clone().context("cloning peer socket")?;
                writers[peer] = Some(sock);
                let inbox2 = inbox.clone();
                std::thread::Builder::new()
                    .name(format!("dsanls-net-r{rank}p{peer}"))
                    .spawn(move || reader_loop(reader, peer, inbox2))
                    .context("spawning reader thread")?;
            }
        }

        Ok(TcpComm {
            rank,
            nodes,
            writers,
            inbox,
            seq: 0,
            io_timeout: opts.io_timeout,
            rendezvous: Some(rdv),
        })
    }

    /// Detach the connection back to the coordinator (worker result
    /// reporting) so the mesh communicator can be consumed by the
    /// algorithm layer independently. Returns `None` on a second call.
    pub fn take_rendezvous(&mut self) -> Option<TcpStream> {
        self.rendezvous.take()
    }

    fn writer(&mut self, peer: usize) -> Result<&mut TcpStream> {
        if peer >= self.nodes || peer == self.rank {
            crate::bail!("no link to rank {peer} (self = {}, nodes = {})", self.rank, self.nodes);
        }
        self.writers[peer]
            .as_mut()
            .ok_or_else(|| crate::err!("link to rank {peer} is down"))
    }
}

impl Communicator for TcpComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn timing(&self) -> Timing {
        Timing::Measured
    }

    fn exchange(&mut self, clock: f64, payload: &[f32]) -> Result<Gathered> {
        let seq = self.seq;
        self.seq += 1;
        for peer in 0..self.nodes {
            if peer == self.rank {
                continue;
            }
            let w = self.writer(peer)?;
            wire::write_frame_parts(w, FrameKind::Collective, seq, clock, payload)
                .with_context(|| format!("collective send to rank {peer}"))?;
        }
        let mut parts: Vec<Vec<f32>> = Vec::with_capacity(self.nodes);
        let mut max_clock = clock;
        for peer in 0..self.nodes {
            if peer == self.rank {
                parts.push(payload.to_vec());
                continue;
            }
            let msg = self
                .inbox
                .recv_coll(peer, self.io_timeout)
                .with_context(|| format!("collective round {seq}, rank {}", self.rank))?;
            if msg.tag != seq {
                crate::bail!(
                    "collective sequence skew: rank {peer} is at round {}, local round {seq}",
                    msg.tag
                );
            }
            max_clock = max_clock.max(msg.sent_at);
            parts.push(msg.payload);
        }
        Ok(Gathered { parts, max_clock })
    }

    fn send(&mut self, to: usize, tag: u64, clock: f64, payload: &[f32]) -> Result<()> {
        let w = self.writer(to)?;
        wire::write_frame_parts(w, FrameKind::P2p, tag, clock, payload)
            .with_context(|| format!("p2p send to rank {to}"))
    }

    fn recv_from(&mut self, from: usize) -> Result<P2pMsg> {
        self.inbox.recv_p2p_from(from, self.io_timeout)
    }

    fn recv_any(&mut self) -> Result<P2pMsg> {
        // no timeout: an idle parameter server waits on its clients
        self.inbox.recv_p2p_any(None)
    }
}

impl Drop for TcpComm {
    fn drop(&mut self) {
        // half-close every mesh link so peers' reader threads observe EOF
        // and release their pending receives promptly
        for w in self.writers.iter().flatten() {
            let _ = w.shutdown(Shutdown::Write);
        }
        if let Some(r) = &self.rendezvous {
            let _ = r.shutdown(Shutdown::Write);
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator side of the bootstrap
// ---------------------------------------------------------------------------

/// Coordinator's rendezvous point: accepts worker handshakes, assigns the
/// roster, and hands back one result channel per rank.
pub struct Rendezvous {
    listener: TcpListener,
    port: u16,
}

/// An accepted, handshaken worker connection.
pub struct WorkerConn {
    pub rank: usize,
    pub stream: TcpStream,
}

impl Rendezvous {
    /// Listen on `127.0.0.1:port` (`0` = ephemeral).
    pub fn bind(port: u16) -> Result<Rendezvous> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding rendezvous port {port}"))?;
        let port = listener.local_addr().context("rendezvous addr")?.port();
        Ok(Rendezvous { listener, port })
    }

    pub fn port(&self) -> u16 {
        self.port
    }

    pub fn addr(&self) -> String {
        format!("127.0.0.1:{}", self.port)
    }

    /// Accept `nodes` workers (validating magic/version and rank
    /// uniqueness), broadcast the roster, and return the connections in
    /// rank order.
    pub fn wait_workers(&self, nodes: usize, timeout: Duration) -> Result<Vec<WorkerConn>> {
        self.listener.set_nonblocking(true).context("rendezvous nonblocking")?;
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<Option<(TcpStream, u16)>> = (0..nodes).map(|_| None).collect();
        let mut got = 0;
        while got < nodes {
            match self.listener.accept() {
                Ok((mut s, addr)) => {
                    s.set_nonblocking(false).context("worker socket blocking")?;
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(timeout)).ok();
                    let rank = wire::read_preamble(&mut s)
                        .with_context(|| format!("handshake from {addr}"))? as usize;
                    let hello = wire::read_frame(&mut s).context("reading hello")?;
                    s.set_read_timeout(None).ok();
                    if hello.kind != FrameKind::Hello || hello.payload.len() != 1 {
                        crate::bail!("malformed hello from rank {rank}");
                    }
                    if rank >= nodes {
                        crate::bail!("worker announced rank {rank}, cluster size is {nodes}");
                    }
                    if slots[rank].is_some() {
                        crate::bail!("two workers announced rank {rank}");
                    }
                    slots[rank] = Some((s, hello.payload[0] as u16));
                    got += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        crate::bail!("rendezvous timed out: {got}/{nodes} workers connected");
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("rendezvous accept failed: {e}")),
            }
        }
        let ports: Vec<f32> =
            slots.iter().map(|c| f32::from(c.as_ref().unwrap().1)).collect();
        let mut out = Vec::with_capacity(nodes);
        for (rank, slot) in slots.into_iter().enumerate() {
            let (mut s, _) = slot.unwrap();
            wire::write_frame(&mut s, &Frame::new(FrameKind::Roster, nodes as u64, 0.0, ports.clone()))
                .with_context(|| format!("sending roster to rank {rank}"))?;
            out.push(WorkerConn { rank, stream: s });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `f` once per rank on its own thread over a real localhost TCP
    /// mesh (rendezvous included).
    fn tcp_ranks<T: Send>(n: usize, f: impl Fn(TcpComm) -> T + Sync) -> Vec<T> {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(n, Duration::from_secs(10)).unwrap());
            for (rank, slot) in out.iter_mut().enumerate() {
                let addr = addr.clone();
                let f = &f;
                s.spawn(move || {
                    let comm =
                        TcpComm::connect(&addr, rank, n, &TcpOptions::default()).unwrap();
                    *slot = Some(f(comm));
                });
            }
            // keep coordinator-side result channels alive until ranks finish
            let _conns = coord.join().unwrap();
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    #[test]
    fn tcp_exchange_matches_rank_order() {
        for n in [1usize, 2, 4] {
            let results = tcp_ranks(n, |mut c| {
                let mut rounds = Vec::new();
                for round in 0..5 {
                    let g = c
                        .exchange(c.rank() as f64, &[(round * 10 + c.rank()) as f32; 2])
                        .unwrap();
                    assert_eq!(g.parts.len(), n);
                    for (r, p) in g.parts.iter().enumerate() {
                        assert!(p.iter().all(|&v| v == (round * 10 + r) as f32));
                    }
                    rounds.push(g.max_clock);
                }
                rounds
            });
            for clocks in results {
                assert!(clocks.iter().all(|&c| c == (n - 1) as f64));
            }
        }
    }

    #[test]
    fn tcp_ragged_all_gather() {
        let results = tcp_ranks(3, |mut c| {
            let mine = vec![c.rank() as f32; c.rank() + 1];
            c.exchange(0.0, &mine).unwrap().parts
        });
        for parts in results {
            for (r, p) in parts.iter().enumerate() {
                assert_eq!(p.len(), r + 1);
                assert!(p.iter().all(|&v| v == r as f32));
            }
        }
    }

    #[test]
    fn tcp_p2p_parameter_server_shape() {
        let results = tcp_ranks(3, |mut c| {
            if c.rank() == 0 {
                for _ in 0..2 {
                    let m = c.recv_any().unwrap();
                    let doubled: Vec<f32> = m.payload.iter().map(|v| v * 2.0).collect();
                    c.send(m.from, m.tag, 0.0, &doubled).unwrap();
                }
                Vec::new()
            } else {
                c.send(0, c.rank() as u64, 0.25, &[c.rank() as f32, 10.0]).unwrap();
                let reply = c.recv_from(0).unwrap();
                assert_eq!(reply.tag, c.rank() as u64);
                reply.payload
            }
        });
        assert_eq!(results[1], vec![2.0, 20.0]);
        assert_eq!(results[2], vec![4.0, 20.0]);
    }

    #[test]
    fn rendezvous_rejects_rank_out_of_range() {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(1, Duration::from_secs(5)));
            s.spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                wire::write_preamble(&mut sock, 7).unwrap(); // rank 7 of 1
                wire::write_frame(
                    &mut sock,
                    &Frame::new(FrameKind::Hello, 7, 0.0, vec![1.0]),
                )
                .unwrap();
            });
            let err = coord.join().unwrap().unwrap_err();
            assert!(err.to_string().contains("rank 7"), "{err}");
        });
    }

    #[test]
    fn connect_timeout_is_clean_error() {
        // nothing listens on this port (bound then dropped)
        let port = {
            let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
        };
        let opts = TcpOptions {
            connect_timeout: Duration::from_millis(100),
            io_timeout: Some(Duration::from_millis(100)),
        };
        let err = TcpComm::connect(&format!("127.0.0.1:{port}"), 0, 2, &opts).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }
}
