//! Real multi-process TCP backend (`std::net` only).
//!
//! ## Bootstrap (rendezvous)
//!
//! The coordinator binds a [`Rendezvous`] listener (loopback by default,
//! any interface via [`Rendezvous::bind_on`]) and spawns — or, multi-host,
//! waits for — one worker process per rank. Each worker:
//!
//! 1. binds its own mesh listener ([`TcpOptions::bind`]; loopback +
//!    ephemeral port by default),
//! 2. dials the coordinator, sends the preamble (magic/version/rank) and a
//!    `Hello` frame carrying its advertised mesh `host:port`
//!    ([`TcpOptions::advertise`] overrides, e.g. behind NAT),
//! 3. receives the `Roster` frame — the **address book**: every rank's
//!    mesh address in rank order,
//! 4. forms the full peer mesh: rank `r` dials every rank `s > r` at its
//!    book address (the dialed side learns the dialer's rank from the
//!    connection preamble) and accepts connections from every rank
//!    `s < r`.
//!
//! The worker keeps the rendezvous connection open to stream results back
//! to the coordinator when the run finishes.
//!
//! ## Data plane
//!
//! One reader thread per peer socket decodes frames ([`super::wire`]) into
//! the shared [`Inbox`]; collective and P2P traffic travel in separate
//! queue families so the asynchronous mailbox protocols can interleave
//! with synchronous collectives. Because reader threads always drain their
//! sockets, the naive everyone-writes-then-reads collective cannot
//! deadlock on kernel buffers.
//!
//! The collective `exchange` is an all-gather over the mesh with a
//! sequence-number check; the rank-ordered deterministic *reduction*
//! happens in [`crate::dist::NodeCtx`], shared with the simulated backend,
//! which is what makes results bit-identical across backends.
//!
//! Failure paths (handshake mismatch, peer death, receive timeout) all
//! surface as [`crate::error::Error`]; a worker that loses a peer
//! mid-collective aborts with a diagnostic rather than hanging.

use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::wire::{self, decode_text, encode_text, Frame, FrameKind, Precision};
use super::{Communicator, Gathered, Inbox, P2pMsg, PendingExchange, Timing};
use crate::error::{Context, Result};

/// Timeouts and addressing for the TCP backend.
#[derive(Debug, Clone)]
pub struct TcpOptions {
    /// Deadline for the whole bootstrap (rendezvous dial + mesh formation).
    pub connect_timeout: Duration,
    /// Maximum wait for a collective contribution or an expected P2P reply
    /// (`None` = wait forever). [`Communicator::recv_any`] never times out:
    /// an idle parameter server legitimately waits on its clients.
    pub io_timeout: Option<Duration>,
    /// Mesh-listener bind address, `IP` or `IP:PORT` (default
    /// `127.0.0.1:0`). For multi-host clusters, bind an interface the
    /// peers can reach (the worker CLI's `--bind`).
    pub bind: Option<String>,
    /// Address advertised to peers in the roster, `HOST` or `HOST:PORT`
    /// (default: the bind IP plus the actual listener port). Required when
    /// binding a wildcard address (`0.0.0.0` / `::`), or when peers reach
    /// this host through NAT/port-forwarding.
    pub advertise: Option<String>,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            connect_timeout: Duration::from_secs(30),
            io_timeout: Some(Duration::from_secs(120)),
            bind: None,
            advertise: None,
        }
    }
}

/// Split `IP[:PORT]` into `(ip, port)`, defaulting the port to 0
/// (ephemeral). Unbracketed IPv6 literals are treated as a bare host
/// (bracket them — `[::1]:4000` — to pin a port). A malformed port is an
/// error, not a silent fallback: an operator pinning a firewall-opened
/// port must not end up on an ephemeral one.
fn split_bind(spec: &str) -> Result<(String, u16)> {
    let parse_port = |p: &str| {
        p.parse::<u16>()
            .map_err(|e| crate::err!("invalid port {p:?} in bind/advertise spec {spec:?}: {e}"))
    };
    if let Some(rest) = spec.strip_prefix('[') {
        // [v6]:port or [v6]
        if let Some((ip, port)) = rest.split_once("]:") {
            return Ok((ip.to_string(), parse_port(port)?));
        }
        return Ok((rest.trim_end_matches(']').to_string(), 0));
    }
    if spec.matches(':').count() > 1 {
        // unbracketed IPv6 literal: all of it is the host
        return Ok((spec.to_string(), 0));
    }
    match spec.rsplit_once(':') {
        Some((ip, port)) if !ip.is_empty() => Ok((ip.to_string(), parse_port(port)?)),
        _ => Ok((spec.to_string(), 0)),
    }
}

/// Render a `(host, port)` pair as a dialable address (bracketing IPv6
/// literals).
fn join_addr(host: &str, port: u16) -> String {
    if host.contains(':') && !host.starts_with('[') {
        format!("[{host}]:{port}")
    } else {
        format!("{host}:{port}")
    }
}

/// Resolve the address this rank advertises in the address book.
fn advertised_addr(opts: &TcpOptions, bind_ip: &str, port: u16) -> Result<String> {
    if let Some(a) = &opts.advertise {
        let (host, advert_port) = split_bind(a)?;
        let advert_port = if advert_port == 0 { port } else { advert_port };
        return Ok(join_addr(&host, advert_port));
    }
    if bind_ip == "0.0.0.0" || bind_ip == "::" {
        crate::bail!(
            "binding the wildcard address {bind_ip} requires an explicit \
             --advertise HOST[:PORT] so peers know where to dial"
        );
    }
    Ok(join_addr(bind_ip, port))
}

/// One rank's endpoint on a real TCP cluster.
pub struct TcpComm {
    rank: usize,
    nodes: usize,
    /// Write half per peer (`None` at own index).
    writers: Vec<Option<TcpStream>>,
    inbox: Arc<Inbox>,
    /// Collective round counter (skew detector).
    seq: u64,
    io_timeout: Option<Duration>,
    /// Connection back to the coordinator (result reporting); taken by the
    /// worker via [`TcpComm::take_rendezvous`].
    rendezvous: Option<TcpStream>,
}

fn dial_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    crate::bail!("connecting to {addr} timed out ({e})");
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

fn reader_loop(mut sock: TcpStream, peer: usize, inbox: Arc<Inbox>) {
    loop {
        match wire::read_frame(&mut sock) {
            Ok(f) => {
                let msg =
                    P2pMsg { from: peer, tag: f.tag, sent_at: f.clock, payload: f.payload };
                match f.kind {
                    // quantized collective payloads are already decoded
                    // back to f32 by `wire::read_frame`
                    FrameKind::Collective
                    | FrameKind::CollectiveF16
                    | FrameKind::CollectiveBf16 => inbox.push_coll(peer, msg),
                    FrameKind::P2p => inbox.push_p2p(peer, msg),
                    // anything else on a mesh link is a protocol violation
                    _ => break,
                }
            }
            // EOF (clean peer shutdown) and hard errors end the link alike;
            // pending receives from this peer then fail with a diagnostic
            Err(_) => break,
        }
    }
    inbox.close(peer);
}

impl TcpComm {
    /// Join the cluster: dial the coordinator at `rendezvous_addr`,
    /// handshake as `rank` of `nodes`, and form the peer mesh.
    pub fn connect(
        rendezvous_addr: &str,
        rank: usize,
        nodes: usize,
        opts: &TcpOptions,
    ) -> Result<TcpComm> {
        if rank >= nodes {
            crate::bail!("rank {rank} outside cluster of {nodes}");
        }
        let deadline = Instant::now() + opts.connect_timeout;

        // mesh listener first, so the advertised address is live before the
        // address book ever mentions it
        let (bind_ip, bind_port) =
            split_bind(opts.bind.as_deref().unwrap_or("127.0.0.1:0"))?;
        let listener = TcpListener::bind((bind_ip.as_str(), bind_port))
            .with_context(|| format!("binding mesh listener on {bind_ip}:{bind_port}"))?;
        let port = listener.local_addr().context("mesh listener addr")?.port();
        let advert = advertised_addr(opts, &bind_ip, port)?;

        let mut rdv = dial_retry(rendezvous_addr, deadline)
            .with_context(|| format!("rank {rank} reaching coordinator"))?;
        rdv.set_nodelay(true).ok();
        // bound every bootstrap read by the connect deadline so a hung
        // coordinator/peer turns into an error, not a stuck worker
        rdv.set_read_timeout(Some(opts.connect_timeout)).ok();
        wire::write_preamble(&mut rdv, rank as u16)?;
        wire::write_frame(
            &mut rdv,
            &Frame::new(FrameKind::Hello, rank as u64, 0.0, encode_text(&advert)),
        )
        .context("sending hello")?;

        let roster = wire::read_frame(&mut rdv).context("waiting for address book")?;
        if roster.kind != FrameKind::Roster {
            crate::bail!("expected the address-book roster, got {:?}", roster.kind);
        }
        let book: Vec<String> =
            decode_text(&roster.payload).split(',').map(str::to_string).collect();
        if book.len() != nodes {
            crate::bail!("address book lists {} ranks, expected {nodes}", book.len());
        }

        // mesh: dial every higher rank, accept from every lower rank
        let mut sockets: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        for (peer, peer_addr) in book.iter().enumerate().skip(rank + 1) {
            let mut s = dial_retry(peer_addr, deadline)
                .with_context(|| format!("rank {rank} dialing peer {peer} at {peer_addr}"))?;
            s.set_nodelay(true).ok();
            wire::write_preamble(&mut s, rank as u16)?;
            sockets[peer] = Some(s);
        }
        listener.set_nonblocking(true).context("mesh listener nonblocking")?;
        let mut accepted = 0;
        while accepted < rank {
            match listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false).context("peer socket blocking")?;
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(opts.connect_timeout)).ok();
                    let peer = wire::read_preamble(&mut s)? as usize;
                    s.set_read_timeout(None).ok(); // data plane blocks freely
                    if peer >= nodes || peer == rank {
                        crate::bail!("mesh hello from invalid rank {peer}");
                    }
                    if sockets[peer].is_some() {
                        crate::bail!("duplicate mesh connection from rank {peer}");
                    }
                    sockets[peer] = Some(s);
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        crate::bail!(
                            "rank {rank} timed out waiting for mesh peers ({accepted}/{rank} connected)"
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("mesh accept failed: {e}")),
            }
        }

        // data plane: one reader thread per peer (own slot starts closed —
        // no self link — so all-peers-disconnected detection can fire)
        let inbox = Arc::new(Inbox::new(nodes, rank));
        let mut writers: Vec<Option<TcpStream>> = (0..nodes).map(|_| None).collect();
        for (peer, sock) in sockets.into_iter().enumerate() {
            if let Some(sock) = sock {
                let reader = sock.try_clone().context("cloning peer socket")?;
                writers[peer] = Some(sock);
                let inbox2 = inbox.clone();
                std::thread::Builder::new()
                    .name(format!("dsanls-net-r{rank}p{peer}"))
                    .spawn(move || reader_loop(reader, peer, inbox2))
                    .context("spawning reader thread")?;
            }
        }

        Ok(TcpComm {
            rank,
            nodes,
            writers,
            inbox,
            seq: 0,
            io_timeout: opts.io_timeout,
            rendezvous: Some(rdv),
        })
    }

    /// Detach the connection back to the coordinator (worker result
    /// reporting) so the mesh communicator can be consumed by the
    /// algorithm layer independently. Returns `None` on a second call.
    pub fn take_rendezvous(&mut self) -> Option<TcpStream> {
        self.rendezvous.take()
    }

    /// A closure that interrupts this endpoint's inbox (all blocked and
    /// future receives fail immediately) — registered with a job's
    /// [`crate::nmf::control::ControlToken`] so `kill()` unblocks a rank
    /// that would otherwise hang in a TCP read.
    pub fn interrupter(&self) -> impl Fn() + Send + Sync + 'static {
        let inbox = self.inbox.clone();
        move || inbox.interrupt()
    }

    fn writer(&mut self, peer: usize) -> Result<&mut TcpStream> {
        if peer >= self.nodes || peer == self.rank {
            crate::bail!("no link to rank {peer} (self = {}, nodes = {})", self.rank, self.nodes);
        }
        self.writers[peer]
            .as_mut()
            .ok_or_else(|| crate::err!("link to rank {peer} is down"))
    }
}

impl Communicator for TcpComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn timing(&self) -> Timing {
        Timing::Measured
    }

    fn exchange(&mut self, clock: f64, payload: &[f32]) -> Result<Gathered> {
        let seq = self.seq;
        self.seq += 1;
        for peer in 0..self.nodes {
            if peer == self.rank {
                continue;
            }
            let w = self.writer(peer)?;
            wire::write_frame_parts(w, FrameKind::Collective, seq, clock, payload)
                .with_context(|| format!("collective send to rank {peer}"))?;
        }
        let mut parts: Vec<Vec<f32>> = Vec::with_capacity(self.nodes);
        let mut max_clock = clock;
        for peer in 0..self.nodes {
            if peer == self.rank {
                parts.push(payload.to_vec());
                continue;
            }
            let msg = self
                .inbox
                .recv_coll(peer, self.io_timeout)
                .with_context(|| format!("collective round {seq}, rank {}", self.rank))?;
            if msg.tag != seq {
                crate::bail!(
                    "collective sequence skew: rank {peer} is at round {}, local round {seq}",
                    msg.tag
                );
            }
            max_clock = max_clock.max(msg.sent_at);
            parts.push(msg.payload);
        }
        Ok(Gathered { parts, max_clock })
    }

    fn exchange_start(&mut self, clock: f64, payload: &[f32]) -> Result<PendingExchange> {
        let seq = self.seq;
        self.seq += 1;
        // sends go out now; the per-peer reader threads accumulate the
        // replies so wait() only blocks on stragglers
        for peer in 0..self.nodes {
            if peer == self.rank {
                continue;
            }
            let w = self.writer(peer)?;
            wire::write_frame_parts(w, FrameKind::Collective, seq, clock, payload)
                .with_context(|| format!("collective send to rank {peer}"))?;
        }
        Ok(PendingExchange::tcp(
            seq,
            clock,
            payload.to_vec(),
            self.rank,
            self.nodes,
            self.inbox.clone(),
            self.io_timeout,
        ))
    }

    fn exchange_start_q(
        &mut self,
        clock: f64,
        payload: &[f32],
        precision: Precision,
    ) -> Result<PendingExchange> {
        if precision == Precision::F32 {
            return self.exchange_start(clock, payload);
        }
        let seq = self.seq;
        self.seq += 1;
        // encode once, fan the same wire bytes out to every peer
        let bytes = wire::quantize_payload(precision, payload);
        for peer in 0..self.nodes {
            if peer == self.rank {
                continue;
            }
            let w = self.writer(peer)?;
            wire::write_quantized_frame(w, precision, seq, clock, &bytes)
                .with_context(|| format!("collective send to rank {peer}"))?;
        }
        // the local contribution must pass through the same codec the
        // peers decode with, or ranks would disagree on rank r's part
        let mut own = payload.to_vec();
        precision.round_trip_slice(&mut own);
        Ok(PendingExchange::tcp(
            seq,
            clock,
            own,
            self.rank,
            self.nodes,
            self.inbox.clone(),
            self.io_timeout,
        ))
    }

    fn send(&mut self, to: usize, tag: u64, clock: f64, payload: &[f32]) -> Result<()> {
        let w = self.writer(to)?;
        wire::write_frame_parts(w, FrameKind::P2p, tag, clock, payload)
            .with_context(|| format!("p2p send to rank {to}"))
    }

    fn recv_from(&mut self, from: usize) -> Result<P2pMsg> {
        self.inbox.recv_p2p_from(from, self.io_timeout)
    }

    fn recv_any(&mut self) -> Result<P2pMsg> {
        // no timeout: an idle parameter server waits on its clients
        self.inbox.recv_p2p_any(None)
    }
}

impl Drop for TcpComm {
    fn drop(&mut self) {
        // half-close every mesh link so peers' reader threads observe EOF
        // and release their pending receives promptly
        for w in self.writers.iter().flatten() {
            let _ = w.shutdown(Shutdown::Write);
        }
        if let Some(r) = &self.rendezvous {
            let _ = r.shutdown(Shutdown::Write);
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator side of the bootstrap
// ---------------------------------------------------------------------------

/// Coordinator's rendezvous point: accepts worker handshakes, assigns the
/// address-book roster, and hands back one result channel per rank.
pub struct Rendezvous {
    listener: TcpListener,
    host: String,
    port: u16,
}

/// An accepted, handshaken worker connection.
pub struct WorkerConn {
    /// The worker's announced rank.
    pub rank: usize,
    /// The rendezvous connection (used for result streaming).
    pub stream: TcpStream,
    /// The mesh address the worker advertised.
    pub mesh_addr: String,
}

impl Rendezvous {
    /// Listen on `127.0.0.1:port` (`0` = ephemeral) — single-host runs.
    pub fn bind(port: u16) -> Result<Rendezvous> {
        Rendezvous::bind_on("127.0.0.1", port)
    }

    /// Listen on `host:port` (`0` = ephemeral). Bind a reachable interface
    /// (or `0.0.0.0`) for multi-host clusters; workers dial this address
    /// via `--rendezvous`.
    pub fn bind_on(host: &str, port: u16) -> Result<Rendezvous> {
        let listener = TcpListener::bind((host, port))
            .with_context(|| format!("binding rendezvous {host}:{port}"))?;
        let port = listener.local_addr().context("rendezvous addr")?.port();
        Ok(Rendezvous { listener, host: host.to_string(), port })
    }

    /// The bound rendezvous port.
    pub fn port(&self) -> u16 {
        self.port
    }

    /// The bound `host:port` (note: when bound to `0.0.0.0`, workers must
    /// dial a concrete reachable host, not this string).
    pub fn addr(&self) -> String {
        format!("{}:{}", self.host, self.port)
    }

    /// Accept `nodes` workers (validating magic/version, rank uniqueness
    /// and the announced mesh address), broadcast the address-book roster,
    /// and return the connections in rank order.
    pub fn wait_workers(&self, nodes: usize, timeout: Duration) -> Result<Vec<WorkerConn>> {
        self.listener.set_nonblocking(true).context("rendezvous nonblocking")?;
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<Option<(TcpStream, String)>> = (0..nodes).map(|_| None).collect();
        let mut got = 0;
        while got < nodes {
            match self.listener.accept() {
                Ok((mut s, addr)) => {
                    s.set_nonblocking(false).context("worker socket blocking")?;
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(timeout)).ok();
                    let rank = wire::read_preamble(&mut s)
                        .with_context(|| format!("handshake from {addr}"))? as usize;
                    if rank >= nodes {
                        crate::bail!("worker announced rank {rank}, cluster size is {nodes}");
                    }
                    if slots[rank].is_some() {
                        crate::bail!(
                            "two workers announced rank {rank} (rank collision — check the \
                             --rank each worker was started with)"
                        );
                    }
                    let hello = wire::read_frame(&mut s).context("reading hello")?;
                    s.set_read_timeout(None).ok();
                    let mesh_addr = decode_text(&hello.payload);
                    if hello.kind != FrameKind::Hello || !mesh_addr.contains(':') {
                        crate::bail!("malformed hello from rank {rank}");
                    }
                    slots[rank] = Some((s, mesh_addr));
                    got += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        crate::bail!("rendezvous timed out: {got}/{nodes} workers connected");
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("rendezvous accept failed: {e}")),
            }
        }
        let book: Vec<String> =
            slots.iter().map(|c| c.as_ref().unwrap().1.clone()).collect();
        let payload = encode_text(&book.join(","));
        let mut out = Vec::with_capacity(nodes);
        for (rank, slot) in slots.into_iter().enumerate() {
            let (mut s, mesh_addr) = slot.unwrap();
            wire::write_frame(
                &mut s,
                &Frame::new(FrameKind::Roster, nodes as u64, 0.0, payload.clone()),
            )
            .with_context(|| format!("sending address book to rank {rank}"))?;
            out.push(WorkerConn { rank, stream: s, mesh_addr });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `f` once per rank on its own thread over a real localhost TCP
    /// mesh (rendezvous included).
    fn tcp_ranks<T: Send>(n: usize, f: impl Fn(TcpComm) -> T + Sync) -> Vec<T> {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(n, Duration::from_secs(10)).unwrap());
            for (rank, slot) in out.iter_mut().enumerate() {
                let addr = addr.clone();
                let f = &f;
                s.spawn(move || {
                    let comm =
                        TcpComm::connect(&addr, rank, n, &TcpOptions::default()).unwrap();
                    *slot = Some(f(comm));
                });
            }
            // keep coordinator-side result channels alive until ranks finish
            let _conns = coord.join().unwrap();
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    #[test]
    fn tcp_exchange_matches_rank_order() {
        for n in [1usize, 2, 4] {
            let results = tcp_ranks(n, |mut c| {
                let mut rounds = Vec::new();
                for round in 0..5 {
                    let g = c
                        .exchange(c.rank() as f64, &[(round * 10 + c.rank()) as f32; 2])
                        .unwrap();
                    assert_eq!(g.parts.len(), n);
                    for (r, p) in g.parts.iter().enumerate() {
                        assert!(p.iter().all(|&v| v == (round * 10 + r) as f32));
                    }
                    rounds.push(g.max_clock);
                }
                rounds
            });
            for clocks in results {
                assert!(clocks.iter().all(|&c| c == (n - 1) as f64));
            }
        }
    }

    #[test]
    fn tcp_ragged_all_gather() {
        let results = tcp_ranks(3, |mut c| {
            let mine = vec![c.rank() as f32; c.rank() + 1];
            c.exchange(0.0, &mine).unwrap().parts
        });
        for parts in results {
            for (r, p) in parts.iter().enumerate() {
                assert_eq!(p.len(), r + 1);
                assert!(p.iter().all(|&v| v == r as f32));
            }
        }
    }

    #[test]
    fn tcp_exchange_start_and_quantized_match_sim_semantics() {
        let results = tcp_ranks(3, |mut c| {
            let rank = c.rank();
            // non-blocking round 0 with round 1 posted before waiting 0
            let p0 = c.exchange_start(0.0, &[rank as f32]).unwrap();
            let p1 = c.exchange_start(0.0, &[(rank + 10) as f32]).unwrap();
            let g0 = p0.wait().unwrap();
            let g1 = p1.wait().unwrap();
            // quantized round: real 2-byte frames on the wire, and the own
            // contribution goes through the same codec as the peers'
            let v = 0.1f32 + rank as f32;
            let gq = c.exchange_start_q(0.0, &[v], Precision::Bf16).unwrap().wait().unwrap();
            // a blocking exchange still lines up afterwards
            let g2 = c.exchange(0.0, &[rank as f32 * 2.0]).unwrap();
            (g0, g1, gq, g2)
        });
        for (g0, g1, gq, g2) in results {
            for r in 0..3 {
                assert_eq!(g0.parts[r][0], r as f32);
                assert_eq!(g1.parts[r][0], (r + 10) as f32);
                let expect = Precision::Bf16.round_trip(0.1f32 + r as f32);
                assert_eq!(gq.parts[r][0].to_bits(), expect.to_bits(), "rank {r}");
                assert_eq!(g2.parts[r][0], r as f32 * 2.0);
            }
        }
    }

    #[test]
    fn tcp_p2p_parameter_server_shape() {
        let results = tcp_ranks(3, |mut c| {
            if c.rank() == 0 {
                for _ in 0..2 {
                    let m = c.recv_any().unwrap();
                    let doubled: Vec<f32> = m.payload.iter().map(|v| v * 2.0).collect();
                    c.send(m.from, m.tag, 0.0, &doubled).unwrap();
                }
                Vec::new()
            } else {
                c.send(0, c.rank() as u64, 0.25, &[c.rank() as f32, 10.0]).unwrap();
                let reply = c.recv_from(0).unwrap();
                assert_eq!(reply.tag, c.rank() as u64);
                reply.payload
            }
        });
        assert_eq!(results[1], vec![2.0, 20.0]);
        assert_eq!(results[2], vec![4.0, 20.0]);
    }

    #[test]
    fn rendezvous_rejects_rank_out_of_range() {
        let rdv = Rendezvous::bind(0).unwrap();
        let addr = rdv.addr();
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(1, Duration::from_secs(5)));
            s.spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                wire::write_preamble(&mut sock, 7).unwrap(); // rank 7 of 1
                // the coordinator rejects on the preamble rank, so it may
                // close before (or while) the hello lands — don't unwrap
                let _ = wire::write_frame(
                    &mut sock,
                    &Frame::new(FrameKind::Hello, 7, 0.0, encode_text("127.0.0.1:9")),
                );
            });
            let err = coord.join().unwrap().unwrap_err();
            assert!(err.to_string().contains("rank 7"), "{err}");
        });
    }

    #[test]
    fn connect_timeout_is_clean_error() {
        // nothing listens on this port (bound then dropped)
        let port = {
            let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
        };
        let opts = TcpOptions {
            connect_timeout: Duration::from_millis(100),
            io_timeout: Some(Duration::from_millis(100)),
            ..TcpOptions::default()
        };
        let err = TcpComm::connect(&format!("127.0.0.1:{port}"), 0, 2, &opts).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn bind_spec_parsing() {
        assert_eq!(split_bind("127.0.0.1").unwrap(), ("127.0.0.1".into(), 0));
        assert_eq!(split_bind("10.1.2.3:4100").unwrap(), ("10.1.2.3".into(), 4100));
        assert_eq!(split_bind("0.0.0.0:0").unwrap(), ("0.0.0.0".into(), 0));
        assert_eq!(split_bind("[::1]:9").unwrap(), ("::1".into(), 9));
        // unbracketed IPv6 is a bare host, not host:port
        assert_eq!(split_bind("fe80::8").unwrap(), ("fe80::8".into(), 0));
        // malformed / out-of-range ports must error, not silently go ephemeral
        assert!(split_bind("10.0.0.1:47O10").is_err());
        assert!(split_bind("10.0.0.1:70000").is_err());
    }

    #[test]
    fn wildcard_bind_requires_advertise() {
        let opts = TcpOptions { bind: Some("0.0.0.0".into()), ..TcpOptions::default() };
        let err = advertised_addr(&opts, "0.0.0.0", 1234).unwrap_err();
        assert!(err.to_string().contains("--advertise"), "{err}");
        let opts = TcpOptions {
            bind: Some("0.0.0.0".into()),
            advertise: Some("worker-3.cluster".into()),
            ..TcpOptions::default()
        };
        assert_eq!(advertised_addr(&opts, "0.0.0.0", 1234).unwrap(), "worker-3.cluster:1234");
        assert_eq!(advertised_addr(&TcpOptions::default(), "10.0.0.8", 7).unwrap(), "10.0.0.8:7");
        // a bare IPv6 advertise host still gets the listener port, bracketed
        let opts = TcpOptions {
            bind: Some("::".into()),
            advertise: Some("fe80::8".into()),
            ..TcpOptions::default()
        };
        assert_eq!(advertised_addr(&opts, "::", 4100).unwrap(), "[fe80::8]:4100");
    }

    #[test]
    fn explicit_bind_forms_mesh() {
        // --bind with an explicit loopback IP must bootstrap exactly like
        // the default ephemeral path (the address book carries host:port)
        let rdv = Rendezvous::bind_on("127.0.0.1", 0).unwrap();
        let addr = rdv.addr();
        let n = 2;
        std::thread::scope(|s| {
            let coord = s.spawn(move || rdv.wait_workers(n, Duration::from_secs(10)).unwrap());
            for rank in 0..n {
                let addr = addr.clone();
                s.spawn(move || {
                    let opts =
                        TcpOptions { bind: Some("127.0.0.1".into()), ..TcpOptions::default() };
                    let mut c = TcpComm::connect(&addr, rank, n, &opts).unwrap();
                    let g = c.exchange(0.0, &[rank as f32]).unwrap();
                    assert_eq!(g.parts, vec![vec![0.0f32], vec![1.0f32]]);
                });
            }
            let conns = coord.join().unwrap();
            for c in &conns {
                assert!(c.mesh_addr.starts_with("127.0.0.1:"), "{}", c.mesh_addr);
            }
        });
    }
}
