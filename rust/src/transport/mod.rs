//! Pluggable cluster transports: the collective/P2P surface the distributed
//! algorithms run on, with two interchangeable backends.
//!
//! The paper's algorithms need exactly four communication primitives:
//!
//! * a synchronous rank-ordered **exchange** (all-gather of one payload per
//!   rank) from which all-reduce, all-gather and barrier are derived;
//! * tagged **send**/**recv** point-to-point mailboxes (the asynchronous
//!   parameter-server protocols, Alg. 6/7);
//! * **rank** / **nodes** identity.
//!
//! [`Communicator`] captures that surface. Backends:
//!
//! * [`sim::SimComm`] — the in-process simulated cluster (N node threads,
//!   in-memory mailboxes). Keeps the virtual clock / stall model of
//!   [`crate::dist`]: payloads are stamped with the sender's virtual clock
//!   so synchronous collectives can model barrier stalls.
//! * [`tcp::TcpComm`] — real multi-process deployment over localhost (or
//!   any reachable) TCP, `std::net` only: length-prefixed binary frames
//!   ([`wire`]), a rendezvous/bootstrap handshake (coordinator listens,
//!   workers connect with rank + magic/version), then a full peer mesh.
//!
//! **Determinism contract**: `exchange` returns every rank's payload in
//! *rank order*, and the reductions built on top (e.g.
//! [`crate::dist::NodeCtx::all_reduce_sum`]) sum those parts in rank order
//! on every node. Because the summation code is identical for both
//! backends, a seeded run produces **bit-identical factors over threads or
//! over TCP processes** — asserted by `tests/dist_equivalence.rs` and the
//! `dsanls launch --verify-sim` CLI path.
//!
//! Transport failures (peer death, handshake mismatch, timeout) surface as
//! [`crate::error::Error`] from the `Communicator` methods. The algorithm
//! layer ([`crate::dist::NodeCtx`]) treats them as fatal to the node: a
//! rank that lost a collective peer cannot make progress, so it panics
//! with the transport error and the process/driver reports the failure.

#![warn(missing_docs)]

pub mod sim;
pub mod tcp;
pub mod wire;

pub use sim::{SimCluster, SimComm};
pub use tcp::{Rendezvous, TcpComm, TcpOptions};

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::error::{Context, Result};

/// Tag marking a client's final message to the parameter server in the
/// asynchronous protocols.
pub const TAG_SHUTDOWN: u64 = u64::MAX;

/// A tagged point-to-point message.
#[derive(Debug, Clone)]
pub struct P2pMsg {
    /// Sender rank.
    pub from: usize,
    /// Application tag ([`TAG_SHUTDOWN`] is reserved).
    pub tag: u64,
    /// Sender's virtual clock when the message left.
    pub sent_at: f64,
    /// Message body (the crate's single wire payload type).
    pub payload: Vec<f32>,
}

/// Result of a synchronous exchange: every rank's payload in rank order
/// plus the maximum virtual clock observed across the barrier.
#[derive(Debug)]
pub struct Gathered {
    /// One payload per rank, in rank order.
    pub parts: Vec<Vec<f32>>,
    /// Maximum sender virtual clock observed across the barrier.
    pub max_clock: f64,
}

/// How the algorithm layer should account communication time on this
/// backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Timing {
    /// Wire time comes from the analytic [`crate::dist::CommModel`]
    /// (simulated backend).
    Modelled,
    /// Wire time is measured wall-clock around the blocking call (real
    /// TCP backend).
    Measured,
}

/// An in-flight non-blocking collective started by
/// [`Communicator::exchange_start`].
///
/// The sends are already posted when this value exists; only the receives
/// are deferred. [`PendingExchange::wait`] blocks on stragglers and
/// returns the same rank-ordered [`Gathered`] the blocking `exchange`
/// would have — including the same sequence-skew and disconnect
/// diagnostics, so the two paths are interchangeable failure-wise.
///
/// **Ordering discipline**: collective frames are consumed from per-peer
/// FIFO queues, so pending exchanges must be waited in the order they
/// were started, and every pending exchange must be waited before the
/// next blocking `exchange` call.
pub struct PendingExchange {
    seq: u64,
    clock: f64,
    own: Vec<f32>,
    rank: usize,
    nodes: usize,
    source: PendingSource,
}

enum PendingSource {
    /// Completed at start time (single-rank clusters, or a backend without
    /// a true non-blocking path falling back to the blocking exchange).
    Ready(Gathered),
    /// Receives drain from this simulated cluster's own inbox.
    Sim(Arc<sim::SimCluster>),
    /// Receives drain from the TCP reader threads' shared inbox.
    Tcp {
        /// This rank's frame inbox (fed by the reader threads).
        inbox: Arc<Inbox>,
        /// Per-receive I/O timeout (mirrors the blocking exchange).
        timeout: Option<Duration>,
    },
}

impl PendingExchange {
    /// A pending exchange that already holds its result.
    pub(crate) fn ready(g: Gathered) -> PendingExchange {
        let nodes = g.parts.len();
        PendingExchange {
            seq: 0,
            clock: g.max_clock,
            own: Vec::new(),
            rank: 0,
            nodes,
            source: PendingSource::Ready(g),
        }
    }

    /// A pending exchange whose receives drain from a simulated cluster.
    pub(crate) fn sim(
        seq: u64,
        clock: f64,
        own: Vec<f32>,
        rank: usize,
        nodes: usize,
        cluster: Arc<sim::SimCluster>,
    ) -> PendingExchange {
        PendingExchange { seq, clock, own, rank, nodes, source: PendingSource::Sim(cluster) }
    }

    /// A pending exchange whose receives drain from a TCP inbox.
    pub(crate) fn tcp(
        seq: u64,
        clock: f64,
        own: Vec<f32>,
        rank: usize,
        nodes: usize,
        inbox: Arc<Inbox>,
        timeout: Option<Duration>,
    ) -> PendingExchange {
        PendingExchange { seq, clock, own, rank, nodes, source: PendingSource::Tcp { inbox, timeout } }
    }

    /// Block until every rank's round-`seq` payload has arrived; return all
    /// payloads in rank order plus the max clock (exactly the blocking
    /// [`Communicator::exchange`] contract).
    pub fn wait(self) -> Result<Gathered> {
        let PendingExchange { seq, clock, own, rank, nodes, source } = self;
        match source {
            PendingSource::Ready(g) => Ok(g),
            PendingSource::Sim(cluster) => {
                let inbox = cluster.inbox_of(rank);
                let mut own = Some(own);
                let mut parts: Vec<Vec<f32>> = Vec::with_capacity(nodes);
                let mut max_clock = clock;
                for r in 0..nodes {
                    if r == rank {
                        parts.push(own.take().unwrap());
                    } else {
                        let msg = inbox.recv_coll(r, None)?;
                        if msg.tag != seq {
                            crate::bail!(
                                "collective sequence skew: rank {} sent round {}, expected {seq}",
                                r,
                                msg.tag
                            );
                        }
                        max_clock = max_clock.max(msg.sent_at);
                        parts.push(msg.payload);
                    }
                }
                Ok(Gathered { parts, max_clock })
            }
            PendingSource::Tcp { inbox, timeout } => {
                let mut own = Some(own);
                let mut parts: Vec<Vec<f32>> = Vec::with_capacity(nodes);
                let mut max_clock = clock;
                for peer in 0..nodes {
                    if peer == rank {
                        parts.push(own.take().unwrap());
                    } else {
                        let msg = inbox
                            .recv_coll(peer, timeout)
                            .with_context(|| format!("collective round {seq}, rank {rank}"))?;
                        if msg.tag != seq {
                            crate::bail!(
                                "collective sequence skew: rank {peer} is at round {}, \
                                 local round {seq}",
                                msg.tag
                            );
                        }
                        max_clock = max_clock.max(msg.sent_at);
                        parts.push(msg.payload);
                    }
                }
                Ok(Gathered { parts, max_clock })
            }
        }
    }
}

/// The collective/P2P surface the distributed algorithms are generic over.
///
/// All synchronous ranks of a cluster must issue the same sequence of
/// `exchange` calls (it is a barrier); P2P calls are unordered. Payload
/// lengths may differ per rank (all-gather semantics); equal-length
/// payloads give all-reduce semantics via the caller's rank-ordered sum.
pub trait Communicator {
    /// This rank's id in `0..nodes`.
    fn rank(&self) -> usize;

    /// Cluster size.
    fn nodes(&self) -> usize;

    /// Timing discipline for [`crate::dist::NodeCtx`] accounting.
    fn timing(&self) -> Timing;

    /// Synchronous barrier-exchange: deposit `payload` stamped with the
    /// local virtual `clock`; block until every rank's round-`t` payload
    /// arrived; return all payloads in rank order plus the max clock.
    fn exchange(&mut self, clock: f64, payload: &[f32]) -> Result<Gathered>;

    /// Non-blocking variant of [`Communicator::exchange`]: post the sends
    /// immediately and return a [`PendingExchange`] whose `wait()` blocks
    /// only on stragglers. The caller may run local compute between start
    /// and wait, but must wait pendings in start order and drain them all
    /// before the next blocking `exchange` (see [`PendingExchange`]).
    ///
    /// The default implementation completes the exchange eagerly (correct,
    /// just without overlap); both bundled backends override it.
    fn exchange_start(&mut self, clock: f64, payload: &[f32]) -> Result<PendingExchange> {
        Ok(PendingExchange::ready(self.exchange(clock, payload)?))
    }

    /// [`Communicator::exchange_start`] with the payload quantized to
    /// `precision` on the wire. Quantization is **sender-side, applied to
    /// the local contribution too**: every rank observes rank *r*'s part
    /// through the same `f32 → half → f32` round-trip, so backends that
    /// never serialise (the simulated cluster) stay bit-identical to ones
    /// that ship real 2-byte frames (TCP, which overrides this).
    ///
    /// `Precision::F32` is exactly [`Communicator::exchange_start`].
    fn exchange_start_q(
        &mut self,
        clock: f64,
        payload: &[f32],
        precision: wire::Precision,
    ) -> Result<PendingExchange> {
        if precision == wire::Precision::F32 {
            return self.exchange_start(clock, payload);
        }
        let mut q = payload.to_vec();
        precision.round_trip_slice(&mut q);
        self.exchange_start(clock, &q)
    }

    /// Send a tagged message to rank `to` (non-blocking hand-off).
    fn send(&mut self, to: usize, tag: u64, clock: f64, payload: &[f32]) -> Result<()>;

    /// Block until the next message *from rank `from`* arrives.
    fn recv_from(&mut self, from: usize) -> Result<P2pMsg>;

    /// Block until a message from *any* rank arrives.
    fn recv_any(&mut self) -> Result<P2pMsg>;

    /// Synchronisation barrier (an empty exchange). Returns the max clock.
    fn barrier(&mut self, clock: f64) -> Result<f64> {
        Ok(self.exchange(clock, &[])?.max_clock)
    }
}

// ---------------------------------------------------------------------------
// Inbox: per-peer FIFO queues shared by both backends
// ---------------------------------------------------------------------------

/// Frames a peer can deliver land in one of two queue families: collective
/// frames (consumed strictly in rank order by `exchange`) and P2P frames
/// (consumed by `recv_from`/`recv_any`). Keeping the families separate lets
/// the asynchronous mailbox traffic interleave with synchronous collectives
/// without corrupting either.
pub(crate) struct Inbox {
    state: Mutex<InboxState>,
    cv: Condvar,
}

struct InboxState {
    coll: Vec<VecDeque<P2pMsg>>,
    p2p: Vec<VecDeque<P2pMsg>>,
    closed: Vec<bool>,
    /// Set by [`Inbox::interrupt`]: every pending and future receive fails
    /// immediately (the hard-cancel path of the job control plane — a
    /// blocked reader must unblock rather than hang in `read`).
    interrupted: bool,
}

impl Inbox {
    /// An inbox for rank `me` of an `n`-rank cluster. The own slot starts
    /// closed (no rank has a link to itself), so the
    /// all-peers-disconnected check in [`Inbox::recv_p2p_any`] can actually
    /// fire once every real peer is gone.
    pub(crate) fn new(n: usize, me: usize) -> Inbox {
        let mut closed = vec![false; n];
        if me < n {
            closed[me] = true;
        }
        Inbox {
            state: Mutex::new(InboxState {
                coll: (0..n).map(|_| VecDeque::new()).collect(),
                p2p: (0..n).map(|_| VecDeque::new()).collect(),
                closed,
                interrupted: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Interrupt every blocked and future receive on this inbox: they fail
    /// immediately with a "interrupted by job control" error instead of
    /// blocking (or waiting out an I/O timeout). Used by
    /// [`crate::nmf::control::ControlToken::kill`] — a reader thread
    /// blocked in a TCP `read` stays blocked, but the *algorithm* side
    /// waiting on the inbox unblocks at once, which is what lets a killed
    /// job abort promptly.
    pub(crate) fn interrupt(&self) {
        let mut st = self.state.lock().unwrap();
        st.interrupted = true;
        self.cv.notify_all();
    }

    pub(crate) fn push_coll(&self, from: usize, msg: P2pMsg) {
        let mut st = self.state.lock().unwrap();
        st.coll[from].push_back(msg);
        self.cv.notify_all();
    }

    pub(crate) fn push_p2p(&self, from: usize, msg: P2pMsg) {
        let mut st = self.state.lock().unwrap();
        st.p2p[from].push_back(msg);
        self.cv.notify_all();
    }

    /// Mark a peer as disconnected; pending receives from it fail once its
    /// queues drain.
    pub(crate) fn close(&self, from: usize) {
        let mut st = self.state.lock().unwrap();
        st.closed[from] = true;
        self.cv.notify_all();
    }

    /// Next collective frame from `from`, FIFO.
    pub(crate) fn recv_coll(&self, from: usize, timeout: Option<Duration>) -> Result<P2pMsg> {
        self.wait(timeout, |st| {
            if let Some(m) = st.coll[from].pop_front() {
                return Some(Ok(m));
            }
            if st.closed[from] {
                return Some(Err(crate::err!("peer {from} disconnected mid-collective")));
            }
            None
        })
    }

    /// Next P2P frame from `from`, FIFO.
    pub(crate) fn recv_p2p_from(&self, from: usize, timeout: Option<Duration>) -> Result<P2pMsg> {
        self.wait(timeout, |st| {
            if let Some(m) = st.p2p[from].pop_front() {
                return Some(Ok(m));
            }
            if st.closed[from] {
                return Some(Err(crate::err!("peer {from} disconnected")));
            }
            None
        })
    }

    /// Next P2P frame from any peer (lowest rank with pending traffic
    /// first).
    pub(crate) fn recv_p2p_any(&self, timeout: Option<Duration>) -> Result<P2pMsg> {
        self.wait(timeout, |st| {
            for q in st.p2p.iter_mut() {
                if let Some(m) = q.pop_front() {
                    return Some(Ok(m));
                }
            }
            if st.closed.iter().all(|&c| c) {
                return Some(Err(crate::err!("all peers disconnected")));
            }
            None
        })
    }

    fn wait<F>(&self, timeout: Option<Duration>, mut try_take: F) -> Result<P2pMsg>
    where
        F: FnMut(&mut InboxState) -> Option<Result<P2pMsg>>,
    {
        let deadline = timeout.map(|t| std::time::Instant::now() + t);
        let mut st = self.state.lock().unwrap();
        loop {
            if st.interrupted {
                return Err(crate::err!("transport receive interrupted by job control"));
            }
            if let Some(out) = try_take(&mut st) {
                return out;
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        return Err(crate::err!(
                            "transport receive timed out after {:?}",
                            timeout.unwrap()
                        ));
                    }
                    let (guard, _) = self.cv.wait_timeout(st, d - now).unwrap();
                    st = guard;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inbox_fifo_per_peer_and_any() {
        let inbox = Inbox::new(3, 2);
        for tag in 0..3u64 {
            inbox.push_p2p(1, P2pMsg { from: 1, tag, sent_at: 0.0, payload: vec![tag as f32] });
        }
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 9, sent_at: 0.0, payload: vec![] });
        for tag in 0..3u64 {
            let m = inbox.recv_p2p_from(1, None).unwrap();
            assert_eq!(m.tag, tag, "FIFO order violated");
        }
        let any = inbox.recv_p2p_any(None).unwrap();
        assert_eq!(any.from, 0);
    }

    #[test]
    fn inbox_close_fails_pending_recv() {
        let inbox = Inbox::new(2, 1);
        inbox.close(0);
        assert!(inbox.recv_p2p_from(0, None).is_err());
        assert!(inbox.recv_coll(0, None).is_err());
        // own slot (1) starts closed, peer 0 now closed → all disconnected
        assert!(inbox.recv_p2p_any(None).is_err());
    }

    #[test]
    fn inbox_queued_frames_survive_peer_close() {
        // frames delivered before the link died must still be readable
        let inbox = Inbox::new(2, 1);
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 3, sent_at: 0.0, payload: vec![1.0] });
        inbox.close(0);
        assert_eq!(inbox.recv_p2p_from(0, None).unwrap().tag, 3);
        assert!(inbox.recv_p2p_from(0, None).is_err());
    }

    #[test]
    fn inbox_timeout_errors() {
        let inbox = Inbox::new(2, 1);
        let err = inbox.recv_p2p_from(0, Some(Duration::from_millis(20))).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn inbox_interrupt_unblocks_pending_and_future_waits() {
        let inbox = std::sync::Arc::new(Inbox::new(2, 1));
        let i2 = inbox.clone();
        // a receive blocked with NO timeout must unblock on interrupt
        let h = std::thread::spawn(move || i2.recv_p2p_from(0, None));
        std::thread::sleep(Duration::from_millis(30));
        inbox.interrupt();
        let err = h.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("interrupted"), "{err}");
        // future receives fail immediately, even with frames queued
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 1, sent_at: 0.0, payload: vec![] });
        assert!(inbox.recv_p2p_from(0, None).is_err());
        assert!(inbox.recv_coll(0, None).is_err());
    }

    #[test]
    fn inbox_cross_thread_wakeup() {
        let inbox = std::sync::Arc::new(Inbox::new(2, 1));
        let i2 = inbox.clone();
        let h = std::thread::spawn(move || i2.recv_p2p_from(0, Some(Duration::from_secs(5))));
        std::thread::sleep(Duration::from_millis(30));
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 7, sent_at: 1.5, payload: vec![2.0] });
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.tag, 7);
        assert_eq!(got.payload, vec![2.0]);
    }
}
