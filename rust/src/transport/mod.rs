//! Pluggable cluster transports: the collective/P2P surface the distributed
//! algorithms run on, with two interchangeable backends.
//!
//! The paper's algorithms need exactly four communication primitives:
//!
//! * a synchronous rank-ordered **exchange** (all-gather of one payload per
//!   rank) from which all-reduce, all-gather and barrier are derived;
//! * tagged **send**/**recv** point-to-point mailboxes (the asynchronous
//!   parameter-server protocols, Alg. 6/7);
//! * **rank** / **nodes** identity.
//!
//! [`Communicator`] captures that surface. Backends:
//!
//! * [`sim::SimComm`] — the in-process simulated cluster (N node threads,
//!   in-memory mailboxes). Keeps the virtual clock / stall model of
//!   [`crate::dist`]: payloads are stamped with the sender's virtual clock
//!   so synchronous collectives can model barrier stalls.
//! * [`tcp::TcpComm`] — real multi-process deployment over localhost (or
//!   any reachable) TCP, `std::net` only: length-prefixed binary frames
//!   ([`wire`]), a rendezvous/bootstrap handshake (coordinator listens,
//!   workers connect with rank + magic/version), then a full peer mesh.
//!
//! **Determinism contract**: `exchange` returns every rank's payload in
//! *rank order*, and the reductions built on top (e.g.
//! [`crate::dist::NodeCtx::all_reduce_sum`]) sum those parts in rank order
//! on every node. Because the summation code is identical for both
//! backends, a seeded run produces **bit-identical factors over threads or
//! over TCP processes** — asserted by `tests/dist_equivalence.rs` and the
//! `dsanls launch --verify-sim` CLI path.
//!
//! Transport failures (peer death, handshake mismatch, timeout) surface as
//! [`crate::error::Error`] from the `Communicator` methods. A rank that
//! lost a collective peer cannot make progress on its own, so
//! [`crate::dist::NodeCtx`] unwinds with a typed [`PeerLostSignal`]. On a
//! fixed-membership run that is fatal and the driver reports the failure;
//! on an **elastic** run the iteration loop catches the signal, calls
//! [`Communicator::rebuild`] to form the next [`Membership`] epoch with a
//! replacement rank, and resumes from the last replicated commit — the
//! survivors never restart.
//!
//! **Membership epochs**: every collective frame's tag is an
//! [`epoch_tag`] — epoch in the top 16 bits, round sequence below. Frames
//! from a lower epoch are stale leftovers of a round that a rank death
//! aborted and are skipped on receive; a higher epoch (or a sequence
//! mismatch within the epoch) is a protocol error. Non-elastic runs live
//! their whole life in epoch 0, where the tag equals the plain sequence
//! number and the wire format is unchanged.

#![warn(missing_docs)]

pub mod sim;
pub mod tcp;
pub mod wire;

pub use sim::{FaultPlan, SimCluster, SimComm};
pub use tcp::{Rendezvous, TcpComm, TcpOptions, WorkerConn};

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::error::{Context, Result};

/// Tag marking a client's final message to the parameter server in the
/// asynchronous protocols.
pub const TAG_SHUTDOWN: u64 = u64::MAX;

/// Bits of a collective tag holding the round sequence; the membership
/// epoch lives above them.
pub const EPOCH_SHIFT: u32 = 48;

/// Pack a membership epoch and a round sequence into one collective tag.
/// Epoch 0 tags are numerically identical to the plain pre-epoch sequence
/// numbers, so fixed-membership runs are wire-compatible by construction.
pub fn epoch_tag(epoch: u64, seq: u64) -> u64 {
    debug_assert!(epoch < (1 << 16), "membership epoch overflow");
    (epoch << EPOCH_SHIFT) | (seq & ((1u64 << EPOCH_SHIFT) - 1))
}

/// Split a collective tag into `(epoch, seq)`.
pub fn split_epoch_tag(tag: u64) -> (u64, u64) {
    (tag >> EPOCH_SHIFT, tag & ((1u64 << EPOCH_SHIFT) - 1))
}

/// The cluster's membership view: which ranks participate in collectives,
/// and which epoch of membership this is. The epoch bumps every time the
/// member set is rebuilt (a dead rank replaced by a re-joined worker).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Membership {
    /// Monotonic epoch counter; 0 for the founding membership.
    pub epoch: u64,
    /// Participating ranks, ascending.
    pub ranks: Vec<usize>,
}

/// Typed panic payload unwound through a rank's iteration loop when a
/// collective peer vanished. Elastic loops catch it (via
/// `std::panic::catch_unwind`) and rebuild membership; fixed-membership
/// runs let it propagate to the driver, where
/// [`crate::nmf::job`]'s panic handling turns it back into an error.
#[derive(Debug, Clone)]
pub struct PeerLostSignal {
    /// The lost rank, when a single peer died; `None` when every peer
    /// disconnected at once.
    pub peer: Option<usize>,
    /// Human-readable failure description (carries the original transport
    /// error, marker included).
    pub detail: String,
}

/// Typed panic payload raised by a scripted [`sim::FaultPlan`] kill: the
/// rank abandons its iteration mid-run exactly as a crashed process would,
/// and its dropped [`sim::SimComm`] closes the peer links. The in-process
/// driver catches this signal and re-joins the rank as a replacement
/// worker.
#[derive(Debug, Clone, Copy)]
pub struct FaultKillSignal {
    /// The killed rank.
    pub rank: usize,
    /// The iteration boundary the kill fired at.
    pub iteration: usize,
}

/// A tagged point-to-point message.
#[derive(Debug, Clone)]
pub struct P2pMsg {
    /// Sender rank.
    pub from: usize,
    /// Application tag ([`TAG_SHUTDOWN`] is reserved).
    pub tag: u64,
    /// Sender's virtual clock when the message left.
    pub sent_at: f64,
    /// Message body (the crate's single wire payload type).
    pub payload: Vec<f32>,
}

/// Result of a synchronous exchange: every rank's payload in rank order
/// plus the maximum virtual clock observed across the barrier.
#[derive(Debug)]
pub struct Gathered {
    /// One payload per rank, in rank order.
    pub parts: Vec<Vec<f32>>,
    /// Maximum sender virtual clock observed across the barrier.
    pub max_clock: f64,
}

/// How the algorithm layer should account communication time on this
/// backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Timing {
    /// Wire time comes from the analytic [`crate::dist::CommModel`]
    /// (simulated backend).
    Modelled,
    /// Wire time is measured wall-clock around the blocking call (real
    /// TCP backend).
    Measured,
}

/// An in-flight non-blocking collective started by
/// [`Communicator::exchange_start`].
///
/// The sends are already posted when this value exists; only the receives
/// are deferred. [`PendingExchange::wait`] blocks on stragglers and
/// returns the same rank-ordered [`Gathered`] the blocking `exchange`
/// would have — including the same sequence-skew and disconnect
/// diagnostics, so the two paths are interchangeable failure-wise.
///
/// **Ordering discipline**: collective frames are consumed from per-peer
/// FIFO queues, so pending exchanges must be waited in the order they
/// were started, and every pending exchange must be waited before the
/// next blocking `exchange` call.
pub struct PendingExchange {
    epoch: u64,
    seq: u64,
    clock: f64,
    own: Vec<f32>,
    rank: usize,
    nodes: usize,
    source: PendingSource,
}

enum PendingSource {
    /// Completed at start time (single-rank clusters, or a backend without
    /// a true non-blocking path falling back to the blocking exchange).
    Ready(Gathered),
    /// Receives drain from this simulated cluster's own inbox.
    Sim(Arc<sim::SimCluster>),
    /// Receives drain from the TCP reader threads' shared inbox.
    Tcp {
        /// This rank's frame inbox (fed by the reader threads).
        inbox: Arc<Inbox>,
        /// Per-receive I/O timeout (mirrors the blocking exchange).
        timeout: Option<Duration>,
    },
}

impl PendingExchange {
    /// A pending exchange that already holds its result.
    pub(crate) fn ready(g: Gathered) -> PendingExchange {
        let nodes = g.parts.len();
        PendingExchange {
            epoch: 0,
            seq: 0,
            clock: g.max_clock,
            own: Vec::new(),
            rank: 0,
            nodes,
            source: PendingSource::Ready(g),
        }
    }

    /// A pending exchange whose receives drain from a simulated cluster.
    pub(crate) fn sim(
        epoch: u64,
        seq: u64,
        clock: f64,
        own: Vec<f32>,
        rank: usize,
        nodes: usize,
        cluster: Arc<sim::SimCluster>,
    ) -> PendingExchange {
        PendingExchange { epoch, seq, clock, own, rank, nodes, source: PendingSource::Sim(cluster) }
    }

    /// A pending exchange whose receives drain from a TCP inbox.
    pub(crate) fn tcp(
        epoch: u64,
        seq: u64,
        clock: f64,
        own: Vec<f32>,
        rank: usize,
        nodes: usize,
        inbox: Arc<Inbox>,
        timeout: Option<Duration>,
    ) -> PendingExchange {
        PendingExchange {
            epoch,
            seq,
            clock,
            own,
            rank,
            nodes,
            source: PendingSource::Tcp { inbox, timeout },
        }
    }

    /// Block until every rank's round-`seq` payload has arrived; return all
    /// payloads in rank order plus the max clock (exactly the blocking
    /// [`Communicator::exchange`] contract).
    pub fn wait(self) -> Result<Gathered> {
        let PendingExchange { epoch, seq, clock, own, rank, nodes, source } = self;
        match source {
            PendingSource::Ready(g) => Ok(g),
            PendingSource::Sim(cluster) => {
                let inbox = cluster.inbox_of(rank);
                let mut own = Some(own);
                let mut parts: Vec<Vec<f32>> = Vec::with_capacity(nodes);
                let mut max_clock = clock;
                for r in 0..nodes {
                    if r == rank {
                        parts.push(own.take().unwrap());
                    } else {
                        let msg = recv_collective(inbox, r, epoch, seq, None)?;
                        max_clock = max_clock.max(msg.sent_at);
                        parts.push(msg.payload);
                    }
                }
                Ok(Gathered { parts, max_clock })
            }
            PendingSource::Tcp { inbox, timeout } => {
                let mut own = Some(own);
                let mut parts: Vec<Vec<f32>> = Vec::with_capacity(nodes);
                let mut max_clock = clock;
                for peer in 0..nodes {
                    if peer == rank {
                        parts.push(own.take().unwrap());
                    } else {
                        let msg = recv_collective(&inbox, peer, epoch, seq, timeout)
                            .with_context(|| format!("collective round {seq}, rank {rank}"))?;
                        max_clock = max_clock.max(msg.sent_at);
                        parts.push(msg.payload);
                    }
                }
                Ok(Gathered { parts, max_clock })
            }
        }
    }
}

/// Drain the next collective frame from `from` that belongs to the local
/// `(epoch, seq)` round. Stale frames from an older epoch — leftovers of a
/// round that a rank death aborted before everyone consumed it — are
/// silently skipped; a frame from a *newer* epoch or a different round of
/// the same epoch is a protocol error (some rank ran ahead).
pub(crate) fn recv_collective(
    inbox: &Inbox,
    from: usize,
    epoch: u64,
    seq: u64,
    timeout: Option<Duration>,
) -> Result<P2pMsg> {
    loop {
        let msg = inbox.recv_coll(from, timeout)?;
        let (e, s) = split_epoch_tag(msg.tag);
        if e < epoch {
            continue; // stale: aborted round from before the last rebuild
        }
        if e > epoch {
            crate::bail!(
                "membership epoch skew: rank {from} is at epoch {e}, local epoch {epoch}"
            );
        }
        if s != seq {
            crate::bail!(
                "collective sequence skew: rank {from} is at round {s}, local round {seq}"
            );
        }
        return Ok(msg);
    }
}

/// The collective/P2P surface the distributed algorithms are generic over.
///
/// All synchronous ranks of a cluster must issue the same sequence of
/// `exchange` calls (it is a barrier); P2P calls are unordered. Payload
/// lengths may differ per rank (all-gather semantics); equal-length
/// payloads give all-reduce semantics via the caller's rank-ordered sum.
pub trait Communicator {
    /// This rank's id in `0..nodes`.
    fn rank(&self) -> usize;

    /// Cluster size.
    fn nodes(&self) -> usize;

    /// Timing discipline for [`crate::dist::NodeCtx`] accounting.
    fn timing(&self) -> Timing;

    /// Synchronous barrier-exchange: deposit `payload` stamped with the
    /// local virtual `clock`; block until every rank's round-`t` payload
    /// arrived; return all payloads in rank order plus the max clock.
    fn exchange(&mut self, clock: f64, payload: &[f32]) -> Result<Gathered>;

    /// Non-blocking variant of [`Communicator::exchange`]: post the sends
    /// immediately and return a [`PendingExchange`] whose `wait()` blocks
    /// only on stragglers. The caller may run local compute between start
    /// and wait, but must wait pendings in start order and drain them all
    /// before the next blocking `exchange` (see [`PendingExchange`]).
    ///
    /// The default implementation completes the exchange eagerly (correct,
    /// just without overlap); both bundled backends override it.
    fn exchange_start(&mut self, clock: f64, payload: &[f32]) -> Result<PendingExchange> {
        Ok(PendingExchange::ready(self.exchange(clock, payload)?))
    }

    /// [`Communicator::exchange_start`] with the payload quantized to
    /// `precision` on the wire. Quantization is **sender-side, applied to
    /// the local contribution too**: every rank observes rank *r*'s part
    /// through the same `f32 → half → f32` round-trip, so backends that
    /// never serialise (the simulated cluster) stay bit-identical to ones
    /// that ship real 2-byte frames (TCP, which overrides this).
    ///
    /// `Precision::F32` is exactly [`Communicator::exchange_start`].
    fn exchange_start_q(
        &mut self,
        clock: f64,
        payload: &[f32],
        precision: wire::Precision,
    ) -> Result<PendingExchange> {
        if precision == wire::Precision::F32 {
            return self.exchange_start(clock, payload);
        }
        let mut q = payload.to_vec();
        precision.round_trip_slice(&mut q);
        self.exchange_start(clock, &q)
    }

    /// Send a tagged message to rank `to` (non-blocking hand-off).
    fn send(&mut self, to: usize, tag: u64, clock: f64, payload: &[f32]) -> Result<()>;

    /// Block until the next message *from rank `from`* arrives.
    fn recv_from(&mut self, from: usize) -> Result<P2pMsg>;

    /// Block until a message from *any* rank arrives.
    fn recv_any(&mut self) -> Result<P2pMsg>;

    /// Synchronisation barrier (an empty exchange). Returns the max clock.
    fn barrier(&mut self, clock: f64) -> Result<f64> {
        Ok(self.exchange(clock, &[])?.max_clock)
    }

    /// The current membership view. Fixed-membership backends report
    /// epoch 0 with every rank present.
    fn membership(&self) -> Membership {
        Membership { epoch: self.epoch(), ranks: (0..self.nodes()).collect() }
    }

    /// The current membership epoch (0 until the first rebuild).
    fn epoch(&self) -> u64 {
        0
    }

    /// Survivor side of an elastic membership change: block until every
    /// dead rank has been replaced by a re-joined worker, then bump the
    /// epoch and reset the collective sequence. Errors (typed, bounded by
    /// the backend's re-join timeout) if fewer than `min_ranks` ranks
    /// survive or no replacement arrives in time.
    ///
    /// Backends without elastic support refuse outright.
    fn rebuild(&mut self, _min_ranks: usize) -> Result<Membership> {
        crate::bail!("this transport does not support membership epochs")
    }

    /// Scripted fault hook, polled by elastic iteration loops at every
    /// iteration boundary. The simulated backend consults its
    /// [`sim::FaultPlan`] here and unwinds with a [`FaultKillSignal`] when
    /// this rank is scheduled to die at `iteration`; other backends do
    /// nothing (real processes die by exiting).
    fn fault_check(&mut self, _iteration: usize) {}
}

// ---------------------------------------------------------------------------
// Inbox: per-peer FIFO queues shared by both backends
// ---------------------------------------------------------------------------

/// Frames a peer can deliver land in one of two queue families: collective
/// frames (consumed strictly in rank order by `exchange`) and P2P frames
/// (consumed by `recv_from`/`recv_any`). Keeping the families separate lets
/// the asynchronous mailbox traffic interleave with synchronous collectives
/// without corrupting either.
pub(crate) struct Inbox {
    me: usize,
    state: Mutex<InboxState>,
    cv: Condvar,
}

struct InboxState {
    coll: Vec<VecDeque<P2pMsg>>,
    p2p: Vec<VecDeque<P2pMsg>>,
    closed: Vec<bool>,
    /// Set by [`Inbox::interrupt`]: every pending and future receive fails
    /// immediately (the hard-cancel path of the job control plane — a
    /// blocked reader must unblock rather than hang in `read`).
    interrupted: bool,
}

impl Inbox {
    /// An inbox for rank `me` of an `n`-rank cluster. The own slot starts
    /// closed (no rank has a link to itself), so the
    /// all-peers-disconnected check in [`Inbox::recv_p2p_any`] can actually
    /// fire once every real peer is gone.
    pub(crate) fn new(n: usize, me: usize) -> Inbox {
        let mut closed = vec![false; n];
        if me < n {
            closed[me] = true;
        }
        Inbox {
            me,
            state: Mutex::new(InboxState {
                coll: (0..n).map(|_| VecDeque::new()).collect(),
                p2p: (0..n).map(|_| VecDeque::new()).collect(),
                closed,
                interrupted: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Interrupt every blocked and future receive on this inbox: they fail
    /// immediately with a "interrupted by job control" error instead of
    /// blocking (or waiting out an I/O timeout). Used by
    /// [`crate::nmf::control::ControlToken::kill`] — a reader thread
    /// blocked in a TCP `read` stays blocked, but the *algorithm* side
    /// waiting on the inbox unblocks at once, which is what lets a killed
    /// job abort promptly.
    pub(crate) fn interrupt(&self) {
        let mut st = self.state.lock().unwrap();
        st.interrupted = true;
        self.cv.notify_all();
    }

    pub(crate) fn push_coll(&self, from: usize, msg: P2pMsg) {
        let mut st = self.state.lock().unwrap();
        st.coll[from].push_back(msg);
        self.cv.notify_all();
    }

    pub(crate) fn push_p2p(&self, from: usize, msg: P2pMsg) {
        let mut st = self.state.lock().unwrap();
        st.p2p[from].push_back(msg);
        self.cv.notify_all();
    }

    /// Mark a peer as disconnected; pending receives from it fail once its
    /// queues drain.
    pub(crate) fn close(&self, from: usize) {
        let mut st = self.state.lock().unwrap();
        st.closed[from] = true;
        self.cv.notify_all();
    }

    /// Re-admit a peer after an elastic re-join: clear its disconnected
    /// flag and drop any stale frames the dead incarnation left behind.
    pub(crate) fn reopen(&self, from: usize) {
        let mut st = self.state.lock().unwrap();
        st.closed[from] = false;
        st.coll[from].clear();
        st.p2p[from].clear();
        self.cv.notify_all();
    }

    /// Ranks currently marked disconnected (own slot excluded — it is
    /// always closed by construction).
    pub(crate) fn closed_peers(&self) -> Vec<usize> {
        let st = self.state.lock().unwrap();
        st.closed
            .iter()
            .enumerate()
            .filter(|&(r, &c)| c && r != self.me)
            .map(|(r, _)| r)
            .collect()
    }

    /// Next collective frame from `from`, FIFO.
    pub(crate) fn recv_coll(&self, from: usize, timeout: Option<Duration>) -> Result<P2pMsg> {
        self.wait(timeout, |st| {
            if let Some(m) = st.coll[from].pop_front() {
                return Some(Ok(m));
            }
            if st.closed[from] {
                return Some(Err(crate::error::Error::peer_lost(
                    from,
                    format_args!("peer {from} disconnected mid-collective"),
                )));
            }
            None
        })
    }

    /// Next P2P frame from `from`, FIFO.
    pub(crate) fn recv_p2p_from(&self, from: usize, timeout: Option<Duration>) -> Result<P2pMsg> {
        self.wait(timeout, |st| {
            if let Some(m) = st.p2p[from].pop_front() {
                return Some(Ok(m));
            }
            if st.closed[from] {
                return Some(Err(crate::error::Error::peer_lost(
                    from,
                    format_args!("peer {from} disconnected"),
                )));
            }
            None
        })
    }

    /// Next P2P frame from any peer (lowest rank with pending traffic
    /// first).
    pub(crate) fn recv_p2p_any(&self, timeout: Option<Duration>) -> Result<P2pMsg> {
        self.wait(timeout, |st| {
            for q in st.p2p.iter_mut() {
                if let Some(m) = q.pop_front() {
                    return Some(Ok(m));
                }
            }
            if st.closed.iter().all(|&c| c) {
                return Some(Err(crate::error::Error::peer_lost_all("all peers disconnected")));
            }
            None
        })
    }

    fn wait<F>(&self, timeout: Option<Duration>, mut try_take: F) -> Result<P2pMsg>
    where
        F: FnMut(&mut InboxState) -> Option<Result<P2pMsg>>,
    {
        let deadline = timeout.map(|t| std::time::Instant::now() + t);
        let mut st = self.state.lock().unwrap();
        loop {
            if st.interrupted {
                return Err(crate::err!("transport receive interrupted by job control"));
            }
            if let Some(out) = try_take(&mut st) {
                return out;
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        return Err(crate::err!(
                            "transport receive timed out after {:?}",
                            timeout.unwrap()
                        ));
                    }
                    let (guard, _) = self.cv.wait_timeout(st, d - now).unwrap();
                    st = guard;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inbox_fifo_per_peer_and_any() {
        let inbox = Inbox::new(3, 2);
        for tag in 0..3u64 {
            inbox.push_p2p(1, P2pMsg { from: 1, tag, sent_at: 0.0, payload: vec![tag as f32] });
        }
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 9, sent_at: 0.0, payload: vec![] });
        for tag in 0..3u64 {
            let m = inbox.recv_p2p_from(1, None).unwrap();
            assert_eq!(m.tag, tag, "FIFO order violated");
        }
        let any = inbox.recv_p2p_any(None).unwrap();
        assert_eq!(any.from, 0);
    }

    #[test]
    fn inbox_close_fails_pending_recv() {
        let inbox = Inbox::new(2, 1);
        inbox.close(0);
        assert!(inbox.recv_p2p_from(0, None).is_err());
        assert!(inbox.recv_coll(0, None).is_err());
        // own slot (1) starts closed, peer 0 now closed → all disconnected
        assert!(inbox.recv_p2p_any(None).is_err());
    }

    #[test]
    fn inbox_queued_frames_survive_peer_close() {
        // frames delivered before the link died must still be readable
        let inbox = Inbox::new(2, 1);
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 3, sent_at: 0.0, payload: vec![1.0] });
        inbox.close(0);
        assert_eq!(inbox.recv_p2p_from(0, None).unwrap().tag, 3);
        assert!(inbox.recv_p2p_from(0, None).is_err());
    }

    #[test]
    fn inbox_timeout_errors() {
        let inbox = Inbox::new(2, 1);
        let err = inbox.recv_p2p_from(0, Some(Duration::from_millis(20))).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn inbox_interrupt_unblocks_pending_and_future_waits() {
        let inbox = std::sync::Arc::new(Inbox::new(2, 1));
        let i2 = inbox.clone();
        // a receive blocked with NO timeout must unblock on interrupt
        let h = std::thread::spawn(move || i2.recv_p2p_from(0, None));
        std::thread::sleep(Duration::from_millis(30));
        inbox.interrupt();
        let err = h.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("interrupted"), "{err}");
        // future receives fail immediately, even with frames queued
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 1, sent_at: 0.0, payload: vec![] });
        assert!(inbox.recv_p2p_from(0, None).is_err());
        assert!(inbox.recv_coll(0, None).is_err());
    }

    #[test]
    fn epoch_tag_round_trips_and_epoch_zero_is_plain_seq() {
        assert_eq!(epoch_tag(0, 41), 41);
        let tag = epoch_tag(3, 12345);
        assert_eq!(split_epoch_tag(tag), (3, 12345));
        assert_ne!(tag, 12345);
    }

    #[test]
    fn inbox_disconnect_errors_carry_peer_lost_markers() {
        let inbox = Inbox::new(2, 1);
        inbox.close(0);
        let err = inbox.recv_coll(0, None).unwrap_err();
        assert!(err.to_string().contains("peer 0 disconnected"), "{err}");
        assert_eq!(err.lost_peer(), Some(Some(0)));
        let err = inbox.recv_p2p_any(None).unwrap_err();
        assert_eq!(err.lost_peer(), Some(None));
    }

    #[test]
    fn inbox_reopen_readmits_peer_and_drops_stale_frames() {
        let inbox = Inbox::new(3, 2);
        inbox.push_coll(0, P2pMsg { from: 0, tag: 7, sent_at: 0.0, payload: vec![1.0] });
        inbox.close(0);
        assert_eq!(inbox.closed_peers(), vec![0]);
        inbox.reopen(0);
        assert!(inbox.closed_peers().is_empty());
        // the stale pre-death frame is gone; a fresh one is readable
        inbox.push_coll(0, P2pMsg { from: 0, tag: 9, sent_at: 0.0, payload: vec![2.0] });
        assert_eq!(inbox.recv_coll(0, None).unwrap().tag, 9);
    }

    #[test]
    fn recv_collective_skips_stale_epochs_and_rejects_skew() {
        let inbox = Inbox::new(2, 1);
        // a leftover frame from epoch 0 round 5, then the real epoch 1 round 0
        inbox.push_coll(0, P2pMsg { from: 0, tag: epoch_tag(0, 5), sent_at: 0.0, payload: vec![] });
        inbox.push_coll(
            0,
            P2pMsg { from: 0, tag: epoch_tag(1, 0), sent_at: 0.0, payload: vec![3.0] },
        );
        let got = recv_collective(&inbox, 0, 1, 0, None).unwrap();
        assert_eq!(got.payload, vec![3.0]);

        // a frame from a *future* epoch is a protocol error
        inbox.push_coll(0, P2pMsg { from: 0, tag: epoch_tag(2, 0), sent_at: 0.0, payload: vec![] });
        let err = recv_collective(&inbox, 0, 1, 1, None).unwrap_err();
        assert!(err.to_string().contains("epoch skew"), "{err}");

        // same epoch, wrong round: sequence skew
        inbox.push_coll(0, P2pMsg { from: 0, tag: epoch_tag(1, 4), sent_at: 0.0, payload: vec![] });
        let err = recv_collective(&inbox, 0, 1, 1, None).unwrap_err();
        assert!(err.to_string().contains("sequence skew"), "{err}");
    }

    #[test]
    fn inbox_cross_thread_wakeup() {
        let inbox = std::sync::Arc::new(Inbox::new(2, 1));
        let i2 = inbox.clone();
        let h = std::thread::spawn(move || i2.recv_p2p_from(0, Some(Duration::from_secs(5))));
        std::thread::sleep(Duration::from_millis(30));
        inbox.push_p2p(0, P2pMsg { from: 0, tag: 7, sent_at: 1.5, payload: vec![2.0] });
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.tag, 7);
        assert_eq!(got.payload, vec![2.0]);
    }
}
