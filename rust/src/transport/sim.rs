//! In-process simulated backend: rank-per-thread over shared-memory
//! mailboxes.
//!
//! The collective `exchange` is a mesh: every rank deposits its payload
//! (stamped with its virtual clock) into each peer's inbox and then blocks
//! until it holds the matching round's payload from every peer. Per-peer
//! FIFO queues make rounds self-synchronising — a fast rank's round-`t+1`
//! deposit queues *behind* its round-`t` one, so rounds can never mix (the
//! collective sequence number is additionally asserted). This is exactly
//! the logic of the TCP backend minus the sockets, which is what makes the
//! two backends bit-identical.
//!
//! The virtual-clock / stall accounting itself lives in
//! [`crate::dist::NodeCtx`]; this layer only transports the clock stamps.

use std::sync::Arc;

use super::{Communicator, Gathered, Inbox, P2pMsg, PendingExchange, Timing};
use crate::error::Result;

/// Shared state of one simulated cluster: an inbox per rank.
pub struct SimCluster {
    inboxes: Vec<Inbox>,
}

impl SimCluster {
    /// A cluster of `n` ranks. Hand one [`SimComm`] per node thread via
    /// [`SimComm::new`].
    pub fn new(n: usize) -> Arc<SimCluster> {
        assert!(n > 0, "cluster needs at least one rank");
        Arc::new(SimCluster { inboxes: (0..n).map(|r| Inbox::new(n, r)).collect() })
    }

    /// Cluster size.
    pub fn nodes(&self) -> usize {
        self.inboxes.len()
    }

    /// Rank `r`'s inbox (for [`PendingExchange`] to drain deferred
    /// collective receives).
    pub(crate) fn inbox_of(&self, r: usize) -> &Inbox {
        &self.inboxes[r]
    }

    /// Interrupt every rank's inbox: all blocked and future receives fail
    /// immediately. The hard-cancel path of
    /// [`crate::nmf::control::ControlToken::kill`] — cooperative
    /// cancellation never needs this.
    pub fn interrupt_all(&self) {
        for inbox in &self.inboxes {
            inbox.interrupt();
        }
    }
}

/// One rank's endpoint on a [`SimCluster`].
pub struct SimComm {
    rank: usize,
    cluster: Arc<SimCluster>,
    /// Collective round counter (sanity check against protocol skew).
    seq: u64,
}

impl SimComm {
    /// Endpoint for `rank` of `cluster`.
    pub fn new(rank: usize, cluster: Arc<SimCluster>) -> SimComm {
        assert!(rank < cluster.nodes(), "rank {rank} outside cluster");
        SimComm { rank, cluster, seq: 0 }
    }
}

impl Communicator for SimComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nodes(&self) -> usize {
        self.cluster.nodes()
    }

    fn timing(&self) -> Timing {
        Timing::Modelled
    }

    fn exchange(&mut self, clock: f64, payload: &[f32]) -> Result<Gathered> {
        let n = self.nodes();
        let seq = self.seq;
        self.seq += 1;
        if n == 1 {
            return Ok(Gathered { parts: vec![payload.to_vec()], max_clock: clock });
        }
        for (r, inbox) in self.cluster.inboxes.iter().enumerate() {
            if r != self.rank {
                inbox.push_coll(
                    self.rank,
                    P2pMsg { from: self.rank, tag: seq, sent_at: clock, payload: payload.to_vec() },
                );
            }
        }
        let own = &self.cluster.inboxes[self.rank];
        let mut parts: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut max_clock = clock;
        for r in 0..n {
            if r == self.rank {
                parts.push(payload.to_vec());
            } else {
                let msg = own.recv_coll(r, None)?;
                if msg.tag != seq {
                    crate::bail!(
                        "collective sequence skew: rank {} sent round {}, expected {seq}",
                        r,
                        msg.tag
                    );
                }
                max_clock = max_clock.max(msg.sent_at);
                parts.push(msg.payload);
            }
        }
        Ok(Gathered { parts, max_clock })
    }

    fn exchange_start(&mut self, clock: f64, payload: &[f32]) -> Result<PendingExchange> {
        let n = self.nodes();
        let seq = self.seq;
        self.seq += 1;
        if n == 1 {
            return Ok(PendingExchange::ready(Gathered {
                parts: vec![payload.to_vec()],
                max_clock: clock,
            }));
        }
        // deposits happen now — a peer already waiting on this round
        // unblocks without us reaching our own wait()
        for (r, inbox) in self.cluster.inboxes.iter().enumerate() {
            if r != self.rank {
                inbox.push_coll(
                    self.rank,
                    P2pMsg { from: self.rank, tag: seq, sent_at: clock, payload: payload.to_vec() },
                );
            }
        }
        Ok(PendingExchange::sim(seq, clock, payload.to_vec(), self.rank, n, self.cluster.clone()))
    }

    fn send(&mut self, to: usize, tag: u64, clock: f64, payload: &[f32]) -> Result<()> {
        if to >= self.nodes() {
            crate::bail!("send to rank {to} outside cluster of {}", self.nodes());
        }
        self.cluster.inboxes[to].push_p2p(
            self.rank,
            P2pMsg { from: self.rank, tag, sent_at: clock, payload: payload.to_vec() },
        );
        Ok(())
    }

    fn recv_from(&mut self, from: usize) -> Result<P2pMsg> {
        self.cluster.inboxes[self.rank].recv_p2p_from(from, None)
    }

    fn recv_any(&mut self) -> Result<P2pMsg> {
        self.cluster.inboxes[self.rank].recv_p2p_any(None)
    }
}

impl Drop for SimComm {
    /// Mark this rank disconnected in every peer's inbox. Frames already
    /// queued are still consumed first (FIFO-before-closed), so a clean
    /// exit is unaffected — but a rank that dies (panics) mid-protocol now
    /// fails its peers' pending receives instead of deadlocking the
    /// cluster (mirrors the TCP backend's reader-EOF behaviour).
    fn drop(&mut self) {
        for inbox in &self.cluster.inboxes {
            inbox.close(self.rank);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ranks<T: Send>(
        n: usize,
        f: impl Fn(SimComm) -> T + Sync,
    ) -> Vec<T> {
        let cluster = SimCluster::new(n);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            for (rank, slot) in out.iter_mut().enumerate() {
                let comm = SimComm::new(rank, cluster.clone());
                let f = &f;
                s.spawn(move || *slot = Some(f(comm)));
            }
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    #[test]
    fn exchange_rank_order_and_max_clock() {
        for n in [1usize, 2, 5] {
            let results = run_ranks(n, |mut c| {
                let rank = c.rank();
                let g = c.exchange(rank as f64, &[rank as f32; 3]).unwrap();
                (g.parts, g.max_clock)
            });
            for (parts, max_clock) in results {
                assert_eq!(parts.len(), n);
                for (r, p) in parts.iter().enumerate() {
                    assert!(p.iter().all(|&v| v == r as f32));
                }
                assert_eq!(max_clock, (n - 1) as f64);
            }
        }
    }

    #[test]
    fn rounds_never_mix() {
        let results = run_ranks(3, |mut c| {
            let mut sums = Vec::new();
            for round in 0..50 {
                let g = c.exchange(0.0, &[(round * 10 + c.rank()) as f32]).unwrap();
                sums.push(g.parts.iter().map(|p| p[0]).sum::<f32>());
            }
            sums
        });
        for sums in results {
            for (round, s) in sums.iter().enumerate() {
                let expect: f32 = (0..3).map(|r| (round * 10 + r) as f32).sum();
                assert_eq!(*s, expect, "round {round}");
            }
        }
    }

    #[test]
    fn exchange_start_matches_blocking_exchange() {
        for n in [1usize, 2, 4] {
            let results = run_ranks(n, |mut c| {
                let rank = c.rank();
                // round 0 posted non-blocking, round 1 blocking after the
                // wait — both must see rank-ordered parts and agree on seq
                let pending = c.exchange_start(rank as f64, &[rank as f32; 2]).unwrap();
                let g0 = pending.wait().unwrap();
                let g1 = c.exchange(0.0, &[(rank * 10) as f32]).unwrap();
                (g0, g1)
            });
            for (g0, g1) in results {
                assert_eq!(g0.parts.len(), n);
                for (r, p) in g0.parts.iter().enumerate() {
                    assert!(p.iter().all(|&v| v == r as f32));
                }
                assert_eq!(g0.max_clock, (n - 1) as f64);
                for (r, p) in g1.parts.iter().enumerate() {
                    assert_eq!(p[0], (r * 10) as f32);
                }
            }
        }
    }

    #[test]
    fn quantized_exchange_round_trips_every_contribution() {
        use crate::transport::wire::Precision;
        let results = run_ranks(3, |mut c| {
            let v = 0.1f32 + c.rank() as f32; // 0.1, 1.1, 2.1 — inexact in bf16
            c.exchange_start_q(0.0, &[v], Precision::Bf16).unwrap().wait().unwrap()
        });
        for g in results {
            for (r, p) in g.parts.iter().enumerate() {
                let expect = Precision::Bf16.round_trip(0.1f32 + r as f32);
                assert_eq!(p[0].to_bits(), expect.to_bits(), "rank {r} part not round-tripped");
                assert_ne!(p[0].to_bits(), (0.1f32 + r as f32).to_bits(), "bf16 should be lossy");
            }
        }
    }

    #[test]
    fn two_pendings_in_flight_resolve_in_post_order() {
        let results = run_ranks(2, |mut c| {
            let p0 = c.exchange_start(0.0, &[c.rank() as f32]).unwrap();
            let p1 = c.exchange_start(0.0, &[(c.rank() + 10) as f32]).unwrap();
            let g0 = p0.wait().unwrap();
            let g1 = p1.wait().unwrap();
            (g0.parts[0][0], g0.parts[1][0], g1.parts[0][0], g1.parts[1][0])
        });
        for (a, b, c, d) in results {
            assert_eq!((a, b, c, d), (0.0, 1.0, 10.0, 11.0));
        }
    }

    #[test]
    fn p2p_star_roundtrip() {
        // ranks 1..n push to rank 0, which doubles and replies — the
        // parameter-server shape of the asynchronous protocols
        let results = run_ranks(3, |mut c| {
            if c.rank() == 0 {
                let mut served = 0;
                while served < 2 {
                    let m = c.recv_any().unwrap();
                    let doubled: Vec<f32> = m.payload.iter().map(|v| v * 2.0).collect();
                    c.send(m.from, m.tag, 0.0, &doubled).unwrap();
                    served += 1;
                }
                Vec::new()
            } else {
                c.send(0, 7, 0.5, &[c.rank() as f32]).unwrap();
                let reply = c.recv_from(0).unwrap();
                assert_eq!(reply.tag, 7);
                reply.payload
            }
        });
        assert_eq!(results[1], vec![2.0]);
        assert_eq!(results[2], vec![4.0]);
    }
}
