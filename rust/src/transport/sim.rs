//! In-process simulated backend: rank-per-thread over shared-memory
//! mailboxes.
//!
//! The collective `exchange` is a mesh: every rank deposits its payload
//! (stamped with its virtual clock) into each peer's inbox and then blocks
//! until it holds the matching round's payload from every peer. Per-peer
//! FIFO queues make rounds self-synchronising — a fast rank's round-`t+1`
//! deposit queues *behind* its round-`t` one, so rounds can never mix (the
//! collective sequence number is additionally asserted). This is exactly
//! the logic of the TCP backend minus the sockets, which is what makes the
//! two backends bit-identical.
//!
//! The virtual-clock / stall accounting itself lives in
//! [`crate::dist::NodeCtx`]; this layer only transports the clock stamps.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{
    epoch_tag, recv_collective, Communicator, FaultKillSignal, Gathered, Inbox, Membership,
    P2pMsg, PendingExchange, Timing,
};
use crate::error::Result;

/// A scripted fault schedule for the simulated cluster: "kill rank `r` at
/// iteration boundary `k`". Each entry fires exactly once (consumed on
/// fire), so a re-joined rank replaying the same iteration is not killed
/// again — which is what makes every chaos scenario deterministic and
/// seed-reproducible.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    kills: Vec<(usize, usize)>,
}

impl FaultPlan {
    /// An empty plan (no scripted faults).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Schedule rank `rank` to die at iteration boundary `iteration`.
    pub fn kill(mut self, rank: usize, iteration: usize) -> FaultPlan {
        self.kills.push((rank, iteration));
        self
    }

    /// Whether the plan schedules anything at all.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
    }
}

/// Lifecycle of one rank slot in an elastic simulated cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RankStatus {
    /// Running (or never started — the founding state).
    Alive,
    /// Died mid-run (its [`SimComm`] was dropped while unwinding); the
    /// slot is eligible for [`SimComm::join`].
    Dead,
    /// Completed its run normally; the slot cannot be re-joined.
    Finished,
    /// A replacement claimed the slot and is waiting for the survivors'
    /// rebuild to admit it.
    Joining,
}

struct EpochState {
    epoch: u64,
    status: Vec<RankStatus>,
    /// Which Alive ranks are parked in [`Communicator::rebuild`].
    waiting: Vec<bool>,
}

/// Shared state of one simulated cluster: an inbox per rank, plus the
/// elastic-membership epoch machinery and the scripted fault plan.
pub struct SimCluster {
    inboxes: Vec<Inbox>,
    epochs: Mutex<EpochState>,
    epoch_cv: Condvar,
    faults: Mutex<Vec<(usize, usize)>>,
    rejoin_timeout: Mutex<Duration>,
}

impl SimCluster {
    /// A cluster of `n` ranks. Hand one [`SimComm`] per node thread via
    /// [`SimComm::new`].
    pub fn new(n: usize) -> Arc<SimCluster> {
        assert!(n > 0, "cluster needs at least one rank");
        Arc::new(SimCluster {
            inboxes: (0..n).map(|r| Inbox::new(n, r)).collect(),
            epochs: Mutex::new(EpochState {
                epoch: 0,
                status: vec![RankStatus::Alive; n],
                waiting: vec![false; n],
            }),
            epoch_cv: Condvar::new(),
            faults: Mutex::new(Vec::new()),
            rejoin_timeout: Mutex::new(Duration::from_secs(30)),
        })
    }

    /// Cluster size.
    pub fn nodes(&self) -> usize {
        self.inboxes.len()
    }

    /// Install a scripted fault plan (replaces any previous one).
    pub fn set_fault_plan(&self, plan: FaultPlan) {
        *self.faults.lock().unwrap() = plan.kills;
    }

    /// Bound how long a survivor's rebuild (and a joiner's admission wait)
    /// may block before failing with a typed timeout. Default 30s.
    pub fn set_rejoin_timeout(&self, t: Duration) {
        *self.rejoin_timeout.lock().unwrap() = t;
    }

    fn rejoin_deadline(&self) -> (Instant, Duration) {
        let t = *self.rejoin_timeout.lock().unwrap();
        (Instant::now() + t, t)
    }

    /// Rank `r`'s inbox (for [`PendingExchange`] to drain deferred
    /// collective receives).
    pub(crate) fn inbox_of(&self, r: usize) -> &Inbox {
        &self.inboxes[r]
    }

    /// Interrupt every rank's inbox: all blocked and future receives fail
    /// immediately. The hard-cancel path of
    /// [`crate::nmf::control::ControlToken::kill`] — cooperative
    /// cancellation never needs this.
    pub fn interrupt_all(&self) {
        for inbox in &self.inboxes {
            inbox.interrupt();
        }
        self.epoch_cv.notify_all();
    }
}

/// One rank's endpoint on a [`SimCluster`].
pub struct SimComm {
    rank: usize,
    cluster: Arc<SimCluster>,
    /// Collective round counter (sanity check against protocol skew).
    seq: u64,
    /// Membership epoch this endpoint currently speaks.
    epoch: u64,
}

impl SimComm {
    /// Endpoint for `rank` of `cluster`.
    pub fn new(rank: usize, cluster: Arc<SimCluster>) -> SimComm {
        assert!(rank < cluster.nodes(), "rank {rank} outside cluster");
        SimComm { rank, cluster, seq: 0, epoch: 0 }
    }

    /// Claim a dead rank's slot as a replacement worker and block until
    /// the survivors' [`Communicator::rebuild`] admits it into the next
    /// membership epoch. Typed errors — never a hang — for a slot that is
    /// still alive (double-join), already finished, or already being
    /// re-joined, and for an admission that outwaits the cluster's
    /// re-join timeout.
    pub fn join(cluster: &Arc<SimCluster>, rank: usize) -> Result<SimComm> {
        if rank >= cluster.nodes() {
            crate::bail!("cannot join as rank {rank}: cluster has {} ranks", cluster.nodes());
        }
        let (deadline, budget) = cluster.rejoin_deadline();
        let mut st = cluster.epochs.lock().unwrap();
        match st.status[rank] {
            RankStatus::Dead => st.status[rank] = RankStatus::Joining,
            RankStatus::Alive => {
                crate::bail!("rank {rank} is still alive — double-join refused")
            }
            RankStatus::Joining => {
                crate::bail!("rank {rank} is already re-joining — double-join refused")
            }
            RankStatus::Finished => {
                crate::bail!("rank {rank} already finished its run — nothing to re-join")
            }
        }
        cluster.epoch_cv.notify_all();
        loop {
            if st.status[rank] == RankStatus::Alive {
                let epoch = st.epoch;
                drop(st);
                return Ok(SimComm { rank, cluster: cluster.clone(), seq: 0, epoch });
            }
            let now = Instant::now();
            if now >= deadline {
                st.status[rank] = RankStatus::Dead; // release the claim
                crate::bail!(
                    "re-join of rank {rank} timed out after {budget:?} \
                     waiting for survivors to rebuild"
                );
            }
            let (guard, _) = cluster.epoch_cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

impl Communicator for SimComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nodes(&self) -> usize {
        self.cluster.nodes()
    }

    fn timing(&self) -> Timing {
        Timing::Modelled
    }

    fn exchange(&mut self, clock: f64, payload: &[f32]) -> Result<Gathered> {
        let n = self.nodes();
        let seq = self.seq;
        let tag = epoch_tag(self.epoch, seq);
        self.seq += 1;
        if n == 1 {
            return Ok(Gathered { parts: vec![payload.to_vec()], max_clock: clock });
        }
        for (r, inbox) in self.cluster.inboxes.iter().enumerate() {
            if r != self.rank {
                inbox.push_coll(
                    self.rank,
                    P2pMsg { from: self.rank, tag, sent_at: clock, payload: payload.to_vec() },
                );
            }
        }
        let own = &self.cluster.inboxes[self.rank];
        let mut parts: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut max_clock = clock;
        for r in 0..n {
            if r == self.rank {
                parts.push(payload.to_vec());
            } else {
                let msg = recv_collective(own, r, self.epoch, seq, None)?;
                max_clock = max_clock.max(msg.sent_at);
                parts.push(msg.payload);
            }
        }
        Ok(Gathered { parts, max_clock })
    }

    fn exchange_start(&mut self, clock: f64, payload: &[f32]) -> Result<PendingExchange> {
        let n = self.nodes();
        let seq = self.seq;
        let tag = epoch_tag(self.epoch, seq);
        self.seq += 1;
        if n == 1 {
            return Ok(PendingExchange::ready(Gathered {
                parts: vec![payload.to_vec()],
                max_clock: clock,
            }));
        }
        // deposits happen now — a peer already waiting on this round
        // unblocks without us reaching our own wait()
        for (r, inbox) in self.cluster.inboxes.iter().enumerate() {
            if r != self.rank {
                inbox.push_coll(
                    self.rank,
                    P2pMsg { from: self.rank, tag, sent_at: clock, payload: payload.to_vec() },
                );
            }
        }
        Ok(PendingExchange::sim(
            self.epoch,
            seq,
            clock,
            payload.to_vec(),
            self.rank,
            n,
            self.cluster.clone(),
        ))
    }

    fn send(&mut self, to: usize, tag: u64, clock: f64, payload: &[f32]) -> Result<()> {
        if to >= self.nodes() {
            crate::bail!("send to rank {to} outside cluster of {}", self.nodes());
        }
        self.cluster.inboxes[to].push_p2p(
            self.rank,
            P2pMsg { from: self.rank, tag, sent_at: clock, payload: payload.to_vec() },
        );
        Ok(())
    }

    fn recv_from(&mut self, from: usize) -> Result<P2pMsg> {
        self.cluster.inboxes[self.rank].recv_p2p_from(from, None)
    }

    fn recv_any(&mut self) -> Result<P2pMsg> {
        self.cluster.inboxes[self.rank].recv_p2p_any(None)
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn membership(&self) -> Membership {
        let st = self.cluster.epochs.lock().unwrap();
        let ranks = st
            .status
            .iter()
            .enumerate()
            .filter(|&(_, &s)| s != RankStatus::Dead)
            .map(|(r, _)| r)
            .collect();
        Membership { epoch: self.epoch, ranks }
    }

    fn fault_check(&mut self, iteration: usize) {
        let fire = {
            let mut faults = self.cluster.faults.lock().unwrap();
            match faults.iter().position(|&(r, it)| r == self.rank && it == iteration) {
                Some(i) => {
                    faults.remove(i);
                    true
                }
                None => false,
            }
        };
        if fire {
            std::panic::panic_any(FaultKillSignal { rank: self.rank, iteration });
        }
    }

    /// Survivor side of an elastic membership change: park until every
    /// dead rank's slot has a [`SimComm::join`] claimant and every
    /// surviving rank has parked here too, then (exactly one arbitrary
    /// survivor performs the transition) bump the epoch, admit the
    /// joiners, reset their inboxes, and resume everyone at round 0 of the
    /// new epoch.
    fn rebuild(&mut self, min_ranks: usize) -> Result<Membership> {
        let entry_epoch = self.epoch;
        let (deadline, budget) = self.cluster.rejoin_deadline();
        let mut st = self.cluster.epochs.lock().unwrap();
        st.waiting[self.rank] = true;
        self.cluster.epoch_cv.notify_all();
        loop {
            // Someone already completed the transition while we slept.
            if st.epoch > entry_epoch {
                st.waiting[self.rank] = false;
                self.epoch = st.epoch;
                self.seq = 0;
                break;
            }
            let alive =
                st.status.iter().filter(|&&s| s == RankStatus::Alive).count();
            if alive < min_ranks {
                st.waiting[self.rank] = false;
                crate::bail!(
                    "cluster fell to {alive} surviving rank(s), below min_ranks {min_ranks}"
                );
            }
            if let Some(r) = st.status.iter().position(|&s| s == RankStatus::Finished) {
                st.waiting[self.rank] = false;
                crate::bail!(
                    "rank {r} already finished its run — membership cannot be rebuilt mid-exit"
                );
            }
            let no_dead = st.status.iter().all(|&s| s != RankStatus::Dead);
            let all_parked = st
                .status
                .iter()
                .enumerate()
                .all(|(r, &s)| s != RankStatus::Alive || st.waiting[r]);
            if no_dead && all_parked {
                // This survivor performs the transition for everyone.
                st.epoch += 1;
                for r in 0..st.status.len() {
                    if st.status[r] == RankStatus::Joining {
                        st.status[r] = RankStatus::Alive;
                        // fresh mailbox for the joiner, and re-admit it
                        // everywhere else
                        for (i, inbox) in self.cluster.inboxes.iter().enumerate() {
                            if i == r {
                                for peer in 0..self.cluster.nodes() {
                                    if peer != r {
                                        inbox.reopen(peer);
                                    }
                                }
                            } else {
                                inbox.reopen(r);
                            }
                        }
                    }
                    st.waiting[r] = false;
                }
                self.epoch = st.epoch;
                self.seq = 0;
                self.cluster.epoch_cv.notify_all();
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                st.waiting[self.rank] = false;
                let dead: Vec<usize> = st
                    .status
                    .iter()
                    .enumerate()
                    .filter(|&(_, &s)| s == RankStatus::Dead)
                    .map(|(r, _)| r)
                    .collect();
                crate::bail!(
                    "membership rebuild timed out after {budget:?}: \
                     no replacement joined for rank(s) {dead:?}"
                );
            }
            let (guard, _) = self.cluster.epoch_cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        drop(st);
        Ok(self.membership())
    }
}

impl Drop for SimComm {
    /// Mark this rank disconnected in every peer's inbox. Frames already
    /// queued are still consumed first (FIFO-before-closed), so a clean
    /// exit is unaffected — but a rank that dies (panics) mid-protocol now
    /// fails its peers' pending receives instead of deadlocking the
    /// cluster (mirrors the TCP backend's reader-EOF behaviour). The
    /// epoch ledger records *how* the endpoint went away: unwinding means
    /// the rank died and its slot is eligible for [`SimComm::join`]; a
    /// normal drop means it finished.
    fn drop(&mut self) {
        // Status flip and inbox closes are one atomic event under the
        // epoch lock: a replacement can only claim the slot (status Dead)
        // after every peer link is closed, and the rebuild transition's
        // reopens also run under this lock — so a straggling close can
        // never clobber a freshly re-admitted slot.
        let mut st = self.cluster.epochs.lock().unwrap();
        // Only a live incarnation may retire the slot — a failed joiner's
        // endpoint never got admitted.
        if st.status[self.rank] == RankStatus::Alive {
            st.status[self.rank] = if std::thread::panicking() {
                RankStatus::Dead
            } else {
                RankStatus::Finished
            };
        }
        for inbox in &self.cluster.inboxes {
            inbox.close(self.rank);
        }
        drop(st);
        self.cluster.epoch_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ranks<T: Send>(
        n: usize,
        f: impl Fn(SimComm) -> T + Sync,
    ) -> Vec<T> {
        let cluster = SimCluster::new(n);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            for (rank, slot) in out.iter_mut().enumerate() {
                let comm = SimComm::new(rank, cluster.clone());
                let f = &f;
                s.spawn(move || *slot = Some(f(comm)));
            }
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    #[test]
    fn exchange_rank_order_and_max_clock() {
        for n in [1usize, 2, 5] {
            let results = run_ranks(n, |mut c| {
                let rank = c.rank();
                let g = c.exchange(rank as f64, &[rank as f32; 3]).unwrap();
                (g.parts, g.max_clock)
            });
            for (parts, max_clock) in results {
                assert_eq!(parts.len(), n);
                for (r, p) in parts.iter().enumerate() {
                    assert!(p.iter().all(|&v| v == r as f32));
                }
                assert_eq!(max_clock, (n - 1) as f64);
            }
        }
    }

    #[test]
    fn rounds_never_mix() {
        let results = run_ranks(3, |mut c| {
            let mut sums = Vec::new();
            for round in 0..50 {
                let g = c.exchange(0.0, &[(round * 10 + c.rank()) as f32]).unwrap();
                sums.push(g.parts.iter().map(|p| p[0]).sum::<f32>());
            }
            sums
        });
        for sums in results {
            for (round, s) in sums.iter().enumerate() {
                let expect: f32 = (0..3).map(|r| (round * 10 + r) as f32).sum();
                assert_eq!(*s, expect, "round {round}");
            }
        }
    }

    #[test]
    fn exchange_start_matches_blocking_exchange() {
        for n in [1usize, 2, 4] {
            let results = run_ranks(n, |mut c| {
                let rank = c.rank();
                // round 0 posted non-blocking, round 1 blocking after the
                // wait — both must see rank-ordered parts and agree on seq
                let pending = c.exchange_start(rank as f64, &[rank as f32; 2]).unwrap();
                let g0 = pending.wait().unwrap();
                let g1 = c.exchange(0.0, &[(rank * 10) as f32]).unwrap();
                (g0, g1)
            });
            for (g0, g1) in results {
                assert_eq!(g0.parts.len(), n);
                for (r, p) in g0.parts.iter().enumerate() {
                    assert!(p.iter().all(|&v| v == r as f32));
                }
                assert_eq!(g0.max_clock, (n - 1) as f64);
                for (r, p) in g1.parts.iter().enumerate() {
                    assert_eq!(p[0], (r * 10) as f32);
                }
            }
        }
    }

    #[test]
    fn quantized_exchange_round_trips_every_contribution() {
        use crate::transport::wire::Precision;
        let results = run_ranks(3, |mut c| {
            let v = 0.1f32 + c.rank() as f32; // 0.1, 1.1, 2.1 — inexact in bf16
            c.exchange_start_q(0.0, &[v], Precision::Bf16).unwrap().wait().unwrap()
        });
        for g in results {
            for (r, p) in g.parts.iter().enumerate() {
                let expect = Precision::Bf16.round_trip(0.1f32 + r as f32);
                assert_eq!(p[0].to_bits(), expect.to_bits(), "rank {r} part not round-tripped");
                assert_ne!(p[0].to_bits(), (0.1f32 + r as f32).to_bits(), "bf16 should be lossy");
            }
        }
    }

    #[test]
    fn two_pendings_in_flight_resolve_in_post_order() {
        let results = run_ranks(2, |mut c| {
            let p0 = c.exchange_start(0.0, &[c.rank() as f32]).unwrap();
            let p1 = c.exchange_start(0.0, &[(c.rank() + 10) as f32]).unwrap();
            let g0 = p0.wait().unwrap();
            let g1 = p1.wait().unwrap();
            (g0.parts[0][0], g0.parts[1][0], g1.parts[0][0], g1.parts[1][0])
        });
        for (a, b, c, d) in results {
            assert_eq!((a, b, c, d), (0.0, 1.0, 10.0, 11.0));
        }
    }

    /// Kill a live endpoint the way a scripted fault does: unwind with a
    /// [`FaultKillSignal`] while the comm is in scope, so its `Drop` runs
    /// with `thread::panicking() == true` and the slot is marked Dead.
    fn die_holding(comm: SimComm) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _hold = comm;
            std::panic::panic_any(FaultKillSignal { rank: 0, iteration: 0 });
        }));
    }

    #[test]
    fn dead_rank_rejoins_at_next_epoch_and_stale_frames_are_skipped() {
        let cluster = SimCluster::new(2);
        let c0 = cluster.clone();
        let c1 = cluster.clone();
        let survivor = std::thread::spawn(move || {
            let mut comm = SimComm::new(0, c0);
            let g = comm.exchange(0.0, &[10.0]).unwrap();
            assert_eq!(g.parts[1], vec![11.0]);
            // rank 1 dies before answering round 1 → typed peer loss
            let err = loop {
                match comm.exchange(0.0, &[20.0]) {
                    Err(e) => break e,
                    Ok(_) => panic!("round 1 should fail once rank 1 dies"),
                }
            };
            assert_eq!(err.lost_peer(), Some(Some(1)));
            let m = comm.rebuild(1).unwrap();
            assert_eq!(m.epoch, 1);
            assert_eq!(m.ranks, vec![0, 1]);
            // round 0 of epoch 1 — the joiner's payload comes through even
            // though our stale round-1 deposit from epoch 0 is still queued
            let g = comm.exchange(0.0, &[30.0]).unwrap();
            assert_eq!(g.parts[1], vec![31.0]);
            assert_eq!(comm.epoch(), 1);
        });
        let dying = std::thread::spawn(move || {
            let mut comm = SimComm::new(1, c1.clone());
            let g = comm.exchange(0.0, &[11.0]).unwrap();
            assert_eq!(g.parts[0], vec![10.0]);
            die_holding(comm);
            // ... and come back as the replacement
            let mut comm = SimComm::join(&c1, 1).unwrap();
            assert_eq!(comm.epoch(), 1);
            let g = comm.exchange(0.0, &[31.0]).unwrap();
            assert_eq!(g.parts[0], vec![30.0]);
        });
        survivor.join().unwrap();
        dying.join().unwrap();
    }

    #[test]
    fn join_of_live_rank_is_a_typed_error() {
        let cluster = SimCluster::new(2);
        let _keep = SimComm::new(0, cluster.clone());
        let err = SimComm::join(&cluster, 0).unwrap_err();
        assert!(err.to_string().contains("double-join"), "{err}");
    }

    #[test]
    fn join_of_finished_rank_is_a_typed_error() {
        let cluster = SimCluster::new(1);
        drop(SimComm::new(0, cluster.clone())); // clean exit → Finished
        let err = SimComm::join(&cluster, 0).unwrap_err();
        assert!(err.to_string().contains("already finished"), "{err}");
    }

    #[test]
    fn double_join_of_claimed_slot_is_refused() {
        let cluster = SimCluster::new(2);
        cluster.set_rejoin_timeout(Duration::from_secs(5));
        die_holding(SimComm::new(1, cluster.clone()));
        let c1 = cluster.clone();
        let (hold_tx, hold_rx) = std::sync::mpsc::channel::<()>();
        let first = std::thread::spawn(move || {
            let mut comm = SimComm::join(&c1, 1).unwrap();
            comm.exchange(0.0, &[1.0]).unwrap();
            hold_rx.recv().unwrap(); // keep the slot alive until checked
        });
        let mut comm = SimComm::new(0, cluster.clone());
        comm.rebuild(1).unwrap();
        comm.exchange(0.0, &[0.0]).unwrap();
        // the admitted replacement owns the slot — a second join is refused
        let err = SimComm::join(&cluster, 1).unwrap_err();
        assert!(err.to_string().contains("double-join"), "{err}");
        hold_tx.send(()).unwrap();
        first.join().unwrap();
    }

    #[test]
    fn rebuild_without_replacement_times_out_with_typed_error() {
        let cluster = SimCluster::new(2);
        cluster.set_rejoin_timeout(Duration::from_millis(60));
        die_holding(SimComm::new(1, cluster.clone()));
        let mut comm = SimComm::new(0, cluster.clone());
        let err = comm.rebuild(1).unwrap_err();
        assert!(err.to_string().contains("rebuild timed out"), "{err}");
        assert_eq!(comm.epoch(), 0, "epoch must not advance on a failed rebuild");
    }

    #[test]
    fn rebuild_below_min_ranks_is_a_typed_error() {
        let cluster = SimCluster::new(3);
        die_holding(SimComm::new(1, cluster.clone()));
        die_holding(SimComm::new(2, cluster.clone()));
        let mut comm = SimComm::new(0, cluster.clone());
        let err = comm.rebuild(2).unwrap_err();
        assert!(err.to_string().contains("below min_ranks"), "{err}");
    }

    #[test]
    fn fault_plan_entries_fire_exactly_once() {
        let cluster = SimCluster::new(1);
        cluster.set_fault_plan(FaultPlan::new().kill(0, 3));
        let mut comm = SimComm::new(0, cluster.clone());
        comm.fault_check(2); // not scheduled — no-op
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            comm.fault_check(3)
        }));
        let sig = unwound.unwrap_err().downcast::<FaultKillSignal>().unwrap();
        assert_eq!((sig.rank, sig.iteration), (0, 3));
        // consumed: the replayed boundary does not re-kill
        comm.fault_check(3);
    }

    #[test]
    fn p2p_star_roundtrip() {
        // ranks 1..n push to rank 0, which doubles and replies — the
        // parameter-server shape of the asynchronous protocols
        let results = run_ranks(3, |mut c| {
            if c.rank() == 0 {
                let mut served = 0;
                while served < 2 {
                    let m = c.recv_any().unwrap();
                    let doubled: Vec<f32> = m.payload.iter().map(|v| v * 2.0).collect();
                    c.send(m.from, m.tag, 0.0, &doubled).unwrap();
                    served += 1;
                }
                Vec::new()
            } else {
                c.send(0, 7, 0.5, &[c.rank() as f32]).unwrap();
                let reply = c.recv_from(0).unwrap();
                assert_eq!(reply.tag, 7);
                reply.payload
            }
        });
        assert_eq!(results[1], vec![2.0]);
        assert_eq!(results[2], vec![4.0]);
    }
}
