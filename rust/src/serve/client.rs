//! Blocking client for the serving plane — the counterpart `dsanls
//! query`, the end-to-end tests and `benches/serve_latency.rs` speak
//! through.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::time::Duration;

use crate::error::{Context, Result};
use crate::linalg::Mat;
use crate::serve::protocol::{self, Query, Reply};
use crate::transport::wire;

/// One connection to a `dsanls serve` server (or a `dsanls route` router
/// — the two speak the identical protocol, which is the point).
#[derive(Debug)]
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_tag: u64,
    /// Generation advertised by the most recent reply's clock lane
    /// (0 until the first reply arrives).
    generation: u64,
}

impl ServeClient {
    /// Connect and handshake (magic/version preamble both ways — a
    /// mixed-version binary pair fails here, not mid-query).
    pub fn connect(addr: &str) -> Result<ServeClient> {
        ServeClient::connect_with(addr, None)
    }

    /// [`ServeClient::connect`] with an I/O deadline on every read and
    /// write — what the router's connection pool uses so one dead replica
    /// stalls a forwarded query for at most `timeout`, not forever.
    pub fn connect_with(addr: &str, timeout: Option<Duration>) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to serve endpoint {addr}"))?;
        let _ = stream.set_nodelay(true);
        if timeout.is_some() {
            let _ = stream.set_read_timeout(timeout);
            let _ = stream.set_write_timeout(timeout);
        }
        let reader =
            BufReader::new(stream.try_clone().context("cloning serve connection")?);
        let mut writer = BufWriter::new(stream);
        wire::write_preamble(&mut writer, 0)?;
        let mut client = ServeClient { reader, writer, next_tag: 1, generation: 0 };
        wire::read_preamble(&mut client.reader)
            .context("serve handshake (is the endpoint a dsanls serve server?)")?;
        Ok(client)
    }

    /// The model generation the most recent reply was answered against
    /// (0 before the first reply). Operators compare this across queries
    /// to confirm a rolling update actually took.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Send one query and block for its reply, **including**
    /// [`Reply::Error`] — the router needs to distinguish a semantic
    /// error (the replica answered: do NOT fail over) from a transport
    /// failure (`Err`: the replica is unreachable, try the next ring
    /// node).
    pub fn query_reply(&mut self, q: &Query) -> Result<Reply> {
        let tag = self.next_tag;
        self.next_tag += 1;
        let payload = protocol::encode_query(q);
        wire::write_frame_parts(&mut self.writer, protocol::REQUEST, tag, 0.0, &payload)?;
        loop {
            let frame = wire::read_frame(&mut self.reader)?;
            if frame.kind != wire::FrameKind::Response || frame.tag != tag {
                continue; // a pipelined sibling's reply; not ours
            }
            self.generation = frame.clock as u64;
            return protocol::decode_reply(&frame.payload);
        }
    }

    /// Send one query and block for its reply. [`Reply::Error`] from the
    /// server is surfaced as a typed error here, so the convenience
    /// wrappers below only ever see successful payloads.
    pub fn query(&mut self, q: &Query) -> Result<Reply> {
        match self.query_reply(q)? {
            Reply::Error(msg) => Err(crate::err!("serve error: {msg}")),
            reply => Ok(reply),
        }
    }

    /// Top-`n` items for each user id.
    pub fn top_k(&mut self, users: &[u64], n: usize) -> Result<Vec<Vec<(u64, f32)>>> {
        match self.query(&Query::TopK { users: users.to_vec(), n })? {
            Reply::TopK(rows) => Ok(rows),
            other => Err(crate::err!("unexpected reply {other:?} to a top-k query")),
        }
    }

    /// Full reconstruction rows `uᵢ·Vᵀ` for each user id.
    pub fn reconstruct(&mut self, users: &[u64]) -> Result<Mat> {
        match self.query(&Query::Reconstruct { users: users.to_vec() })? {
            Reply::Scores { rows, cols, data } => Ok(Mat::from_vec(rows, cols, data)),
            other => Err(crate::err!("unexpected reply {other:?} to a reconstruct query")),
        }
    }

    /// Fold a new user in from a sparse `(item, rating)` row; returns the
    /// embedding and (when `n > 0`) its top-`n` items.
    pub fn fold_in(
        &mut self,
        entries: &[(u64, f32)],
        n: usize,
    ) -> Result<(Vec<f32>, Vec<(u64, f32)>)> {
        match self.query(&Query::FoldIn { entries: entries.to_vec(), n })? {
            Reply::FoldIn { w, top } => Ok((w, top)),
            other => Err(crate::err!("unexpected reply {other:?} to a fold-in query")),
        }
    }

    /// Fold a new **item** in from a sparse `(user, rating)` column;
    /// returns the embedding and (when `n > 0`) its top-`n` users.
    pub fn fold_in_item(
        &mut self,
        entries: &[(u64, f32)],
        n: usize,
    ) -> Result<(Vec<f32>, Vec<(u64, f32)>)> {
        match self.query(&Query::FoldInItem { entries: entries.to_vec(), n })? {
            Reply::FoldInItem { h, top } => Ok((h, top)),
            other => Err(crate::err!("unexpected reply {other:?} to an item fold-in query")),
        }
    }

    /// Server metrics snapshot (JSON text).
    pub fn stats(&mut self) -> Result<String> {
        match self.query(&Query::Stats)? {
            Reply::Stats(text) => Ok(text),
            other => Err(crate::err!("unexpected reply {other:?} to a stats query")),
        }
    }

    /// Ask the server to re-read its checkpoint and hot-swap the model.
    /// Returns `(generation, checkpoint iteration)` now serving. Errors
    /// if the server was started from an in-memory model (nothing to
    /// re-read) or the re-read checkpoint fails its identity gate.
    pub fn reload(&mut self) -> Result<(u64, u64)> {
        match self.query(&Query::Reload)? {
            Reply::Reload { generation, iteration } => Ok((generation, iteration)),
            other => Err(crate::err!("unexpected reply {other:?} to a reload query")),
        }
    }
}
