//! [`FactorModel`]: checkpoint-loaded factors and the query kernels.
//!
//! The model holds the trained factors `U` (users×k) and `V` (items×k)
//! plus the precomputed fold-in gram `VᵀV` (k×k). Scoring is one GEMM:
//! gather the queried user rows into a `batch×k` block `W`, then
//! `scores = W·Vᵀ` through [`crate::linalg::gemm_nt`] — which is why the
//! server batches concurrent queries before touching the kernels.
//! Fold-in solves `min_{w≥0} ‖a − w·Vᵀ‖²` for one sparse row `a` with the
//! same [`crate::solvers`] update the training loop uses, against the
//! cached gram, with zero steady-state allocations ([`FoldIn`]). The
//! mirrored item-side fold-in (`min_{h≥0} ‖a − h·Uᵀ‖²` for a sparse
//! *column* of user ratings — a brand-new item) runs against the cached
//! `UᵀU` through the same workspace.

use std::path::Path;

use crate::error::{Context, Result};
use crate::linalg::{gemm_nt, gemm_tn, saxpy, Mat};
use crate::nmf::control::{read_checkpoint, Checkpoint, CheckpointMeta};
use crate::nmf::MuSchedule;
use crate::solvers::{self, Normal, SolverKind};

/// Every fold-in iterate starts from this constant vector (`w⁰ = 1`), so
/// a fold-in and its fixed-`V` reference solve are comparable bit-for-bit
/// when seeded with the same row.
pub const FOLD_IN_INIT: f32 = 1.0;

/// Trained factors loaded from a [`crate::nmf::control`] checkpoint,
/// ready to answer reconstruction / top-k / fold-in queries.
#[derive(Debug, Clone)]
pub struct FactorModel {
    meta: CheckpointMeta,
    iteration: usize,
    /// Row (user) factor, `users×k`.
    u: Mat,
    /// Column (item) factor, `items×k` — the `H` every query runs against.
    v: Mat,
    /// `VᵀV` (k×k), precomputed once at load: the gram every fold-in
    /// solve shares, byte-identical to what
    /// [`crate::solvers::Workspace::normal_unsketched`] would recompute.
    gram: Mat,
    /// `UᵀU` (k×k), the mirrored gram item-side fold-ins solve against.
    gram_u: Mat,
}

impl FactorModel {
    /// Load a model from a checkpoint file. Corrupt, truncated or
    /// version-mismatched files surface as the typed errors of
    /// [`read_checkpoint`] (bad magic, format version, missing footer,
    /// implausible shapes), with the serving context attached.
    pub fn load(path: &Path) -> Result<FactorModel> {
        let ck = read_checkpoint(path)
            .with_context(|| format!("loading factor model from {}", path.display()))?;
        Ok(FactorModel::from_checkpoint(ck))
    }

    /// Build a model from an already-read (or synthetic) checkpoint.
    pub fn from_checkpoint(ck: Checkpoint) -> FactorModel {
        let mut gram = Mat::zeros(ck.meta.k, ck.meta.k);
        gemm_tn(&ck.state.v, &ck.state.v, &mut gram);
        let mut gram_u = Mat::zeros(ck.meta.k, ck.meta.k);
        gemm_tn(&ck.state.u, &ck.state.u, &mut gram_u);
        FactorModel {
            meta: ck.meta,
            iteration: ck.state.iteration,
            u: ck.state.u,
            v: ck.state.v,
            gram,
            gram_u,
        }
    }

    /// Assert the loaded checkpoint belongs to the run the operator
    /// expects (`dsanls serve --expect-algo/--expect-params`). Serving a
    /// checkpoint trained with different options is silent garbage, so a
    /// mismatch is a typed error naming both sides.
    pub fn check_identity(
        &self,
        expect_algo: Option<&str>,
        expect_params: Option<u64>,
    ) -> Result<()> {
        if let Some(algo) = expect_algo {
            if self.meta.algo != algo {
                crate::bail!(
                    "checkpoint was written by algorithm {} but the server expects {algo}",
                    self.meta.algo
                );
            }
        }
        if let Some(params) = expect_params {
            if self.meta.params != params {
                crate::bail!(
                    "checkpoint params fingerprint {:#018x} does not match the expected \
                     {params:#018x} — the factors were trained with different options",
                    self.meta.params
                );
            }
        }
        Ok(())
    }

    /// Run identity recorded at training time.
    pub fn meta(&self) -> &CheckpointMeta {
        &self.meta
    }

    /// Training iteration the factors were snapshotted at.
    pub fn iteration(&self) -> usize {
        self.iteration
    }

    /// Factorisation rank.
    pub fn k(&self) -> usize {
        self.meta.k
    }

    /// Known users (rows of `U`).
    pub fn users(&self) -> usize {
        self.u.rows()
    }

    /// Items (rows of `V`).
    pub fn items(&self) -> usize {
        self.v.rows()
    }

    /// The user factor `U` (users×k).
    pub fn u(&self) -> &Mat {
        &self.u
    }

    /// The item factor `V` (items×k).
    pub fn v(&self) -> &Mat {
        &self.v
    }

    /// The precomputed fold-in gram `VᵀV` (k×k).
    pub fn gram(&self) -> &Mat {
        &self.gram
    }

    /// The precomputed item-side fold-in gram `UᵀU` (k×k).
    pub fn gram_u(&self) -> &Mat {
        &self.gram_u
    }

    /// Gather the factor rows of `users` into `w` (`len×k`), validating
    /// every id. Unknown ids are a typed error (they would otherwise index
    /// another user's factors).
    pub fn gather_users(&self, users: &[u64], w: &mut Mat) -> Result<()> {
        let k = self.k();
        w.resize_to(users.len(), k);
        for (slot, &id) in users.iter().enumerate() {
            if id >= self.u.rows() as u64 {
                crate::bail!(
                    "unknown user id {id} (model has {} users; fold-in embeds new ones)",
                    self.u.rows()
                );
            }
            w.row_mut(slot).copy_from_slice(self.u.row(id as usize));
        }
        Ok(())
    }

    /// Score a batch of known users against every item:
    /// `scores = W·Vᵀ` (`len×items`). `w` and `scores` are caller scratch
    /// (the server reuses them across batches).
    pub fn scores_into(&self, users: &[u64], w: &mut Mat, scores: &mut Mat) -> Result<()> {
        self.gather_users(users, w)?;
        self.scores_for_w(w, scores);
        Ok(())
    }

    /// Score arbitrary embedding rows (`w: n×k`, e.g. fold-in results)
    /// against every item: `scores = w·Vᵀ`.
    pub fn scores_for_w(&self, w: &Mat, scores: &mut Mat) {
        assert_eq!(w.cols(), self.k(), "embedding width != model rank");
        scores.resize_to(w.rows(), self.v.rows());
        gemm_nt(w, &self.v, scores);
    }

    /// Score arbitrary item-side embedding rows (`h: n×k`, e.g. item
    /// fold-in results) against every *user*: `scores = h·Uᵀ` — who would
    /// rate the new item highest.
    pub fn scores_for_h(&self, h: &Mat, scores: &mut Mat) {
        assert_eq!(h.cols(), self.k(), "embedding width != model rank");
        scores.resize_to(h.rows(), self.u.rows());
        gemm_nt(h, &self.u, scores);
    }
}

/// Select the `n` largest entries of `scores` into `out` as
/// `(item, score)`, best first. Ties break towards the lower item id and
/// NaNs are skipped, so the selection is deterministic. `O(items·n)` with
/// `n` small — no allocation beyond `out`'s capacity.
pub fn top_n(scores: &[f32], n: usize, out: &mut Vec<(usize, f32)>) {
    out.clear();
    if n == 0 {
        return;
    }
    for (i, &s) in scores.iter().enumerate() {
        if s.is_nan() {
            continue;
        }
        if out.len() == n {
            if s <= out[n - 1].1 {
                continue;
            }
            out.pop();
        }
        let pos = out.partition_point(|&(_, v)| v >= s);
        out.insert(pos, (i, s));
    }
}

/// Reusable fold-in workspace: solves one sparse row against the fixed
/// item factor with **zero steady-state allocations** — the sorted-entry
/// buffer, the `1×k` cross row and the `1×k` iterate are all owned here
/// and regrown only when shapes change (asserted by
/// `tests/serve_alloc.rs`). One instance per serving thread, exactly like
/// [`crate::solvers::Workspace`] in the training loop.
#[derive(Debug, Default)]
pub struct FoldIn {
    entries: Vec<(usize, f32)>,
    cross: Mat,
    x: Mat,
}

impl FoldIn {
    /// An empty workspace (buffers size themselves on first use).
    pub fn new() -> FoldIn {
        FoldIn { entries: Vec::new(), cross: Mat::zeros(0, 0), x: Mat::zeros(0, 0) }
    }

    /// Embed a new user from a sparse rating row: solve
    /// `min_{w≥0} ‖a − w·Vᵀ‖²` with `sweeps` passes of `solver` at
    /// schedule step `t`, starting from [`FOLD_IN_INIT`]. Returns the
    /// `k`-length embedding, borrowed from this workspace.
    ///
    /// The cross row accumulates `Σ aⱼ·V[j,:]` in ascending item order —
    /// the same per-row accumulation [`crate::linalg::Csr::spmm_into`]
    /// performs — and the gram is the model's cached `VᵀV`, so for a
    /// duplicate-free row the result is **bit-identical** to the
    /// unsketched reference solve
    /// ([`crate::nmf::update_unsketched`] on a `1×items` sparse matrix
    /// with `V` fixed). Duplicate item ids are merged additively.
    pub fn solve(
        &mut self,
        model: &FactorModel,
        row: &[(usize, f32)],
        solver: SolverKind,
        sweeps: usize,
        t: usize,
    ) -> Result<&[f32]> {
        self.solve_against(&model.v, &model.gram, "item", row, solver, sweeps, t)
    }

    /// Embed a new **item** from a sparse `(user, rating)` column: solve
    /// `min_{h≥0} ‖a − h·Uᵀ‖²` against the fixed user factor and the
    /// cached `UᵀU` gram — the exact mirror of [`FoldIn::solve`] with the
    /// sides swapped. Returns the `k`-length embedding, borrowed from
    /// this workspace.
    pub fn solve_item(
        &mut self,
        model: &FactorModel,
        col: &[(usize, f32)],
        solver: SolverKind,
        sweeps: usize,
        t: usize,
    ) -> Result<&[f32]> {
        self.solve_against(&model.u, &model.gram_u, "user", col, solver, sweeps, t)
    }

    /// Shared fold-in core: solve one sparse row against `factor` (n×k)
    /// with its cached `gram = factorᵀ·factor`. `id_name` names the id
    /// space in range errors ("item" for user-side fold-ins, "user" for
    /// item-side ones).
    #[allow(clippy::too_many_arguments)]
    fn solve_against(
        &mut self,
        factor: &Mat,
        gram: &Mat,
        id_name: &str,
        row: &[(usize, f32)],
        solver: SolverKind,
        sweeps: usize,
        t: usize,
    ) -> Result<&[f32]> {
        let k = gram.rows();
        let bound = factor.rows();
        self.entries.clear();
        self.entries.extend_from_slice(row);
        for &(j, _) in &self.entries {
            if j >= bound {
                crate::bail!(
                    "fold-in {id_name} id {j} out of range (model has {bound} {id_name}s)"
                );
            }
        }
        // canonicalise like Csr::from_triplets: sorted by item, duplicates
        // summed (unstable sort allocates nothing, unlike the stable one)
        self.entries.sort_unstable_by_key(|&(j, _)| j);
        let mut keep = 0usize;
        for i in 1..self.entries.len() {
            if self.entries[i].0 == self.entries[keep].0 {
                self.entries[keep].1 += self.entries[i].1;
            } else {
                keep += 1;
                self.entries[keep] = self.entries[i];
            }
        }
        self.entries.truncate(if self.entries.is_empty() { 0 } else { keep + 1 });

        self.cross.resize_to(1, k);
        let crow = self.cross.row_mut(0);
        crow.fill(0.0);
        for &(j, val) in &self.entries {
            saxpy(val, factor.row(j), crow);
        }

        self.x.resize_to(1, k);
        self.x.data_mut().fill(FOLD_IN_INIT);
        let nrm = Normal::new(gram, &self.cross);
        for _ in 0..sweeps.max(1) {
            solvers::update_auto(solver, &mut self.x, &nrm, &MuSchedule::default(), t);
        }
        Ok(self.x.row(0))
    }

    /// Buffer identities (cross ptr, iterate ptr) — lets the allocation
    /// audit assert steady-state reuse, mirroring
    /// [`crate::solvers::Workspace::scratch_ptrs`].
    pub fn scratch_ptrs(&self) -> (usize, usize) {
        (self.cross.data().as_ptr() as usize, self.x.data().as_ptr() as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmf::control::ResumeState;
    use crate::rng::Pcg64;

    fn toy_model(users: usize, items: usize, k: usize, seed: u128) -> FactorModel {
        let mut rng = Pcg64::new(seed, 0);
        let u = Mat::rand_uniform(users, k, 1.0, &mut rng);
        let v = Mat::rand_uniform(items, k, 1.0, &mut rng);
        FactorModel::from_checkpoint(Checkpoint {
            meta: CheckpointMeta {
                algo: "dsanls".into(),
                seed: 1,
                k,
                rows: users,
                cols: items,
                params: 42,
            },
            state: ResumeState { iteration: 5, u, v },
        })
    }

    #[test]
    fn top_n_selects_and_orders() {
        let scores = [0.1f32, 0.9, 0.3, 0.9, 0.05, 0.7];
        let mut out = Vec::new();
        top_n(&scores, 3, &mut out);
        assert_eq!(out, vec![(1, 0.9), (3, 0.9), (5, 0.7)]);
        top_n(&scores, 0, &mut out);
        assert!(out.is_empty());
        top_n(&scores, 10, &mut out);
        assert_eq!(out.len(), scores.len());
        assert!(out.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn scores_match_per_row_dot_products() {
        let m = toy_model(8, 12, 4, 0xF00D);
        let (mut w, mut scores) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        m.scores_into(&[3, 0], &mut w, &mut scores).unwrap();
        assert_eq!((scores.rows(), scores.cols()), (2, 12));
        let want = crate::linalg::dot(m.u().row(3), m.v().row(7));
        assert_eq!(scores.get(0, 7), want);
    }

    #[test]
    fn unknown_user_and_item_are_typed_errors() {
        let m = toy_model(8, 12, 4, 0xF00D);
        let (mut w, mut scores) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        let err = m.scores_into(&[99], &mut w, &mut scores).unwrap_err().to_string();
        assert!(err.contains("unknown user id 99"), "{err}");
        let mut fold = FoldIn::new();
        let err = fold
            .solve(&m, &[(12, 1.0)], SolverKind::ProximalCd, 2, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn item_fold_in_is_the_transposed_user_fold_in() {
        // folding an item into (U, V) must be bit-identical to folding a
        // user into the transposed model (U↔V, users↔items)
        let m = toy_model(6, 9, 3, 0xBEEF);
        let mut swapped = toy_model(9, 6, 3, 0xBEEF);
        swapped.u = m.v.clone();
        swapped.v = m.u.clone();
        let mut g = Mat::zeros(3, 3);
        gemm_tn(&swapped.v, &swapped.v, &mut g);
        swapped.gram = g.clone();
        gemm_tn(&swapped.u, &swapped.u, &mut g);
        swapped.gram_u = g;
        let col = [(1usize, 0.75f32), (4, 2.0)];
        let mut fold = FoldIn::new();
        let h = fold.solve_item(&m, &col, SolverKind::Hals, 3, 0).unwrap().to_vec();
        let w = fold.solve(&swapped, &col, SolverKind::Hals, 3, 0).unwrap();
        assert_eq!(h, w);
        // and user ids are validated against the user axis
        let err =
            fold.solve_item(&m, &[(6, 1.0)], SolverKind::Hals, 1, 0).unwrap_err().to_string();
        assert!(err.contains("fold-in user id 6"), "{err}");
    }

    #[test]
    fn fold_in_merges_duplicates_and_reuses_buffers() {
        let m = toy_model(4, 10, 3, 7);
        let mut fold = FoldIn::new();
        let merged =
            fold.solve(&m, &[(2, 0.5), (2, 0.5), (7, 1.0)], SolverKind::ProximalCd, 3, 0).unwrap();
        let merged = merged.to_vec();
        let direct = fold.solve(&m, &[(2, 1.0), (7, 1.0)], SolverKind::ProximalCd, 3, 0).unwrap();
        assert_eq!(merged, direct);
        let ptrs = fold.scratch_ptrs();
        let _ = fold.solve(&m, &[(2, 1.0), (7, 1.0)], SolverKind::ProximalCd, 3, 0).unwrap();
        assert_eq!(fold.scratch_ptrs(), ptrs, "fold-in scratch reallocated in steady state");
    }
}
