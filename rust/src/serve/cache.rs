//! Hot/cold LRU cache for fold-in embeddings.
//!
//! Fold-in traffic is heavy-tailed: the same anonymous rating rows (hot
//! landing-page sessions, retried requests) recur far more often than a
//! uniform draw, so the server keeps the most recent embeddings and
//! evicts least-recently-used ones. Keys are the **exact** canonical row
//! — the serving **model generation** followed by `(item,
//! rating.to_bits())` pairs sorted by item — not a hash, so a hit can
//! never return another row's embedding, and a checkpoint hot-swap can
//! never serve an embedding solved against retired factors (the swapped
//! generation changes every key; stale entries age out through the LRU).
//! Hand-rolled on `HashMap` + an index-linked list (no external crates),
//! O(1) per operation.

use std::collections::HashMap;

/// Canonical cache key for a sparse rating row: the model generation the
/// embedding was solved against, then the entries sorted by item id with
/// rating bits preserved exactly (`f32` is not `Hash`; its bit pattern
/// is).
pub type RowKey = Vec<(u64, u32)>;

/// Tag pairing the leading generation lane of a [`RowKey`] — distinct
/// from any `rating.to_bits()` the sort could place first, because the
/// generation pair is *prepended*, never sorted with the entries.
const GEN_TAG: u32 = 0x4745_4E00; // "GEN\0"

/// Build the canonical [`RowKey`] for a user-side query row solved
/// against model generation `generation`.
pub fn row_key(generation: u64, entries: &[(u64, f32)]) -> RowKey {
    let mut key: RowKey = Vec::with_capacity(entries.len() + 1);
    key.push((generation, GEN_TAG));
    key.extend(entries.iter().map(|&(i, v)| (i, v.to_bits())));
    key[1..].sort_unstable();
    key
}

/// Build the canonical [`RowKey`] for an **item-side** fold-in column.
/// The key carries a trailing sentinel pair so an item column can never
/// collide with a user row of the same `(id, rating)` entries — the two
/// sides solve against different factors, and a cross-side cache hit
/// would return the wrong embedding. The sentinel id is `u64::MAX`,
/// unreachable for a validated id (ids are checked against the model's
/// axis length before any cache lookup).
pub fn item_row_key(generation: u64, entries: &[(u64, f32)]) -> RowKey {
    let mut key = row_key(generation, entries);
    key.push((u64::MAX, u32::MAX));
    key
}

const NIL: usize = usize::MAX;

#[derive(Debug)]
struct Slot {
    key: RowKey,
    value: Vec<f32>,
    prev: usize,
    next: usize,
}

/// LRU cache from canonical rating rows to fold-in embeddings.
#[derive(Debug)]
pub struct FoldCache {
    cap: usize,
    map: HashMap<RowKey, usize>,
    slots: Vec<Slot>,
    /// Most-recently-used slot (NIL when empty).
    head: usize,
    /// Least-recently-used slot (NIL when empty).
    tail: usize,
    hits: u64,
    misses: u64,
}

impl FoldCache {
    /// A cache holding at most `cap` embeddings (`cap = 0` disables it:
    /// every lookup misses and inserts are dropped).
    pub fn new(cap: usize) -> FoldCache {
        FoldCache {
            cap,
            map: HashMap::with_capacity(cap.min(1 << 20)),
            slots: Vec::with_capacity(cap.min(1 << 20)),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
        }
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Configured capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Lookups answered from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that fell through to a solve so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.slots[idx].prev, self.slots[idx].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.slots[idx].prev = NIL;
        self.slots[idx].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Look a row up, promoting it to most-recently-used on a hit.
    pub fn get(&mut self, key: &RowKey) -> Option<&[f32]> {
        match self.map.get(key).copied() {
            Some(idx) => {
                self.hits += 1;
                if self.head != idx {
                    self.unlink(idx);
                    self.push_front(idx);
                }
                Some(&self.slots[idx].value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) an embedding, evicting the least-recently-used
    /// entry when full.
    pub fn insert(&mut self, key: RowKey, value: Vec<f32>) {
        if self.cap == 0 {
            return;
        }
        if let Some(&idx) = self.map.get(&key) {
            self.slots[idx].value = value;
            if self.head != idx {
                self.unlink(idx);
                self.push_front(idx);
            }
            return;
        }
        let idx = if self.map.len() >= self.cap {
            // recycle the LRU slot in place (no allocation churn)
            let idx = self.tail;
            self.unlink(idx);
            let old = std::mem::replace(&mut self.slots[idx].key, key.clone());
            self.map.remove(&old);
            self.slots[idx].value = value;
            idx
        } else {
            self.slots.push(Slot { key: key.clone(), value, prev: NIL, next: NIL });
            self.slots.len() - 1
        };
        self.map.insert(key, idx);
        self.push_front(idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used() {
        let mut c = FoldCache::new(2);
        let (ka, kb, kc) =
            (row_key(1, &[(1, 1.0)]), row_key(1, &[(2, 1.0)]), row_key(1, &[(3, 1.0)]));
        c.insert(ka.clone(), vec![1.0]);
        c.insert(kb.clone(), vec![2.0]);
        assert_eq!(c.get(&ka), Some(&[1.0f32][..])); // promotes A over B
        c.insert(kc.clone(), vec![3.0]); // evicts B
        assert_eq!(c.get(&kb), None);
        assert_eq!(c.get(&ka), Some(&[1.0f32][..]));
        assert_eq!(c.get(&kc), Some(&[3.0f32][..]));
        assert_eq!(c.len(), 2);
        assert_eq!((c.hits(), c.misses()), (3, 1));
    }

    #[test]
    fn key_is_order_insensitive_but_value_exact() {
        // same row in a different order must hit …
        assert_eq!(row_key(1, &[(5, 1.5), (2, 0.5)]), row_key(1, &[(2, 0.5), (5, 1.5)]));
        // … but a different rating (even by one ulp) must miss
        assert_ne!(row_key(1, &[(2, 0.5)]), row_key(1, &[(2, 0.5000001)]));
        let mut c = FoldCache::new(4);
        c.insert(row_key(1, &[(5, 1.5), (2, 0.5)]), vec![9.0]);
        assert_eq!(c.get(&row_key(1, &[(2, 0.5), (5, 1.5)])), Some(&[9.0f32][..]));
    }

    #[test]
    fn item_keys_never_collide_with_user_keys() {
        // same (id, rating) entries, different sides → distinct keys
        let entries = [(2u64, 0.5f32), (5, 1.5)];
        assert_ne!(row_key(1, &entries), item_row_key(1, &entries));
        // item keys stay order-insensitive like user keys
        assert_eq!(item_row_key(1, &[(5, 1.5), (2, 0.5)]), item_row_key(1, &entries));
        let mut c = FoldCache::new(4);
        c.insert(row_key(1, &entries), vec![1.0]);
        c.insert(item_row_key(1, &entries), vec![2.0]);
        assert_eq!(c.get(&row_key(1, &entries)), Some(&[1.0f32][..]));
        assert_eq!(c.get(&item_row_key(1, &entries)), Some(&[2.0f32][..]));
    }

    #[test]
    fn generation_invalidates_without_cross_talk() {
        // a hot-swap bumps the generation: the identical row must MISS
        // (the cached embedding was solved against retired factors) …
        let entries = [(2u64, 0.5f32), (5, 1.5)];
        assert_ne!(row_key(1, &entries), row_key(2, &entries));
        assert_ne!(item_row_key(1, &entries), item_row_key(2, &entries));
        let mut c = FoldCache::new(8);
        c.insert(row_key(1, &entries), vec![1.0]);
        assert_eq!(c.get(&row_key(2, &entries)), None);
        // … and a generation pair can never alias an entry pair: a row
        // whose first sorted entry happens to equal (gen, GEN_TAG-as-bits)
        // still keys distinctly, because the generation lane is prepended
        // ahead of the sorted region rather than mixed into it
        let tricky = [(2u64, f32::from_bits(GEN_TAG))];
        assert_ne!(row_key(2, &tricky), row_key(2, &[]));
        // both generations coexist until the LRU ages the old one out
        c.insert(row_key(2, &entries), vec![2.0]);
        assert_eq!(c.get(&row_key(1, &entries)), Some(&[1.0f32][..]));
        assert_eq!(c.get(&row_key(2, &entries)), Some(&[2.0f32][..]));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = FoldCache::new(0);
        let k = row_key(1, &[(1, 1.0)]);
        c.insert(k.clone(), vec![1.0]);
        assert_eq!(c.get(&k), None);
        assert!(c.is_empty());
    }
}
