//! Serving-plane payload codec for [`FrameKind::Request`] /
//! [`FrameKind::Response`] frames (wire v5).
//!
//! Queries and replies ride the existing length-prefixed f32 framing of
//! [`crate::transport::wire`]: lane 0 carries the op code, exact integers
//! (ids, counts) are bit-split across two f32 lanes via
//! [`wire::push_u64_bits`] — an id cast to f32 would silently corrupt
//! above 2²⁴ — and scores travel as native f32 lanes. The frame `tag` is
//! the client's request id; the server echoes it on the reply, which is
//! what lets one connection pipeline queries.
//!
//! Reply lane 0 is `0.0` for a server-side error (rest of the payload is
//! the message, [`wire::encode_text`]-encoded); otherwise it echoes the
//! request op code.

use crate::error::Result;
use crate::transport::wire::{self, FrameKind};

/// Op code for a top-k recommendation query.
pub const OP_TOP_K: f32 = 1.0;
/// Op code for a full-row reconstruction query.
pub const OP_RECONSTRUCT: f32 = 2.0;
/// Op code for a fold-in query.
pub const OP_FOLD_IN: f32 = 3.0;
/// Op code for a server-statistics query.
pub const OP_STATS: f32 = 4.0;
/// Op code for an item-side fold-in query (embed a new item).
pub const OP_FOLD_IN_ITEM: f32 = 5.0;
/// Op code for the admin hot-swap request: re-read the server's
/// checkpoint file and atomically swap the model generation.
pub const OP_RELOAD: f32 = 6.0;
/// Reply status lane for a failed query.
pub const STATUS_ERROR: f32 = 0.0;

/// One serving-plane query.
#[derive(Debug, Clone, PartialEq)]
pub enum Query {
    /// Best `n` items for each of `users` (known user ids).
    TopK {
        /// Queried user ids.
        users: Vec<u64>,
        /// Items to return per user.
        n: usize,
    },
    /// Full score rows `uᵢ·Vᵀ` for each of `users`.
    Reconstruct {
        /// Queried user ids.
        users: Vec<u64>,
    },
    /// Embed a new user from a sparse `(item, rating)` row; when `n > 0`
    /// the reply also carries the top-`n` items for the embedding.
    FoldIn {
        /// Sparse rating row.
        entries: Vec<(u64, f32)>,
        /// Items to recommend for the folded-in user (0 = embedding only).
        n: usize,
    },
    /// Embed a new **item** from a sparse `(user, rating)` column; when
    /// `n > 0` the reply also carries the top-`n` *users* for the item.
    FoldInItem {
        /// Sparse rating column (user ids).
        entries: Vec<(u64, f32)>,
        /// Users to suggest for the folded-in item (0 = embedding only).
        n: usize,
    },
    /// Server metrics snapshot (JSON text reply).
    Stats,
    /// Admin hot-swap: reload the checkpoint the server was started from
    /// and swap the next model generation in without dropping queries.
    Reload,
}

/// One serving-plane reply.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// Per-user `(item, score)` lists, best first (answers [`Query::TopK`]).
    TopK(Vec<Vec<(u64, f32)>>),
    /// Dense score rows, row-major (answers [`Query::Reconstruct`]).
    Scores {
        /// Number of score rows.
        rows: usize,
        /// Items per row.
        cols: usize,
        /// Row-major scores (`rows·cols` lanes).
        data: Vec<f32>,
    },
    /// Fold-in embedding plus optional recommendations
    /// (answers [`Query::FoldIn`]).
    FoldIn {
        /// The `k`-length nonnegative embedding.
        w: Vec<f32>,
        /// Top items for the embedding (empty when `n = 0` was asked).
        top: Vec<(u64, f32)>,
    },
    /// Item-side fold-in embedding plus optional top users
    /// (answers [`Query::FoldInItem`]).
    FoldInItem {
        /// The `k`-length nonnegative item embedding.
        h: Vec<f32>,
        /// Top users for the embedding (empty when `n = 0` was asked).
        top: Vec<(u64, f32)>,
    },
    /// Metrics snapshot as JSON text (answers [`Query::Stats`]).
    Stats(String),
    /// Hot-swap confirmation (answers [`Query::Reload`]).
    Reload {
        /// The model generation now serving.
        generation: u64,
        /// Training iteration recorded in the reloaded checkpoint.
        iteration: u64,
    },
    /// The query failed server-side; the message names the cause.
    Error(String),
}

fn take_len(payload: &[f32], pos: &mut usize, what: &str) -> Result<usize> {
    let n = wire::take_u64_bits(payload, pos)?;
    // a corrupt length would otherwise turn into a huge allocation
    if n > wire::MAX_FRAME_BYTES as u64 {
        crate::bail!("implausible {what} count {n} in serving frame");
    }
    Ok(n as usize)
}

fn take_f32(payload: &[f32], pos: &mut usize) -> Result<f32> {
    let v = *payload
        .get(*pos)
        .ok_or_else(|| crate::err!("payload underrun decoding f32 at {}", *pos))?;
    *pos += 1;
    Ok(v)
}

/// Encode a query into a [`FrameKind::Request`] payload.
pub fn encode_query(q: &Query) -> Vec<f32> {
    let mut p = Vec::new();
    match q {
        Query::TopK { users, n } => {
            p.push(OP_TOP_K);
            wire::push_u64_bits(&mut p, *n as u64);
            wire::push_u64_bits(&mut p, users.len() as u64);
            for &id in users {
                wire::push_u64_bits(&mut p, id);
            }
        }
        Query::Reconstruct { users } => {
            p.push(OP_RECONSTRUCT);
            wire::push_u64_bits(&mut p, users.len() as u64);
            for &id in users {
                wire::push_u64_bits(&mut p, id);
            }
        }
        Query::FoldIn { entries, n } => {
            p.push(OP_FOLD_IN);
            wire::push_u64_bits(&mut p, *n as u64);
            wire::push_u64_bits(&mut p, entries.len() as u64);
            for &(item, val) in entries {
                wire::push_u64_bits(&mut p, item);
                p.push(val);
            }
        }
        Query::FoldInItem { entries, n } => {
            p.push(OP_FOLD_IN_ITEM);
            wire::push_u64_bits(&mut p, *n as u64);
            wire::push_u64_bits(&mut p, entries.len() as u64);
            for &(user, val) in entries {
                wire::push_u64_bits(&mut p, user);
                p.push(val);
            }
        }
        Query::Stats => p.push(OP_STATS),
        Query::Reload => p.push(OP_RELOAD),
    }
    p
}

/// Decode a [`FrameKind::Request`] payload.
pub fn decode_query(payload: &[f32]) -> Result<Query> {
    let mut pos = 0usize;
    let op = take_f32(payload, &mut pos)?;
    if op == OP_TOP_K {
        let n = take_len(payload, &mut pos, "top-k")?;
        let count = take_len(payload, &mut pos, "user")?;
        let mut users = Vec::with_capacity(count);
        for _ in 0..count {
            users.push(wire::take_u64_bits(payload, &mut pos)?);
        }
        Ok(Query::TopK { users, n })
    } else if op == OP_RECONSTRUCT {
        let count = take_len(payload, &mut pos, "user")?;
        let mut users = Vec::with_capacity(count);
        for _ in 0..count {
            users.push(wire::take_u64_bits(payload, &mut pos)?);
        }
        Ok(Query::Reconstruct { users })
    } else if op == OP_FOLD_IN {
        let n = take_len(payload, &mut pos, "top-k")?;
        let nnz = take_len(payload, &mut pos, "entry")?;
        let mut entries = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let item = wire::take_u64_bits(payload, &mut pos)?;
            let val = take_f32(payload, &mut pos)?;
            entries.push((item, val));
        }
        Ok(Query::FoldIn { entries, n })
    } else if op == OP_FOLD_IN_ITEM {
        let n = take_len(payload, &mut pos, "top-user")?;
        let nnz = take_len(payload, &mut pos, "entry")?;
        let mut entries = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let user = wire::take_u64_bits(payload, &mut pos)?;
            let val = take_f32(payload, &mut pos)?;
            entries.push((user, val));
        }
        Ok(Query::FoldInItem { entries, n })
    } else if op == OP_STATS {
        Ok(Query::Stats)
    } else if op == OP_RELOAD {
        Ok(Query::Reload)
    } else {
        crate::bail!("unknown serving op code {op}")
    }
}

/// Encode a reply into a [`FrameKind::Response`] payload.
pub fn encode_reply(r: &Reply) -> Vec<f32> {
    let mut p = Vec::new();
    match r {
        Reply::TopK(rows) => {
            p.push(OP_TOP_K);
            wire::push_u64_bits(&mut p, rows.len() as u64);
            for row in rows {
                wire::push_u64_bits(&mut p, row.len() as u64);
                for &(item, score) in row {
                    wire::push_u64_bits(&mut p, item);
                    p.push(score);
                }
            }
        }
        Reply::Scores { rows, cols, data } => {
            p.push(OP_RECONSTRUCT);
            wire::push_u64_bits(&mut p, *rows as u64);
            wire::push_u64_bits(&mut p, *cols as u64);
            p.extend_from_slice(data);
        }
        Reply::FoldIn { w, top } => {
            p.push(OP_FOLD_IN);
            wire::push_u64_bits(&mut p, w.len() as u64);
            p.extend_from_slice(w);
            wire::push_u64_bits(&mut p, top.len() as u64);
            for &(item, score) in top {
                wire::push_u64_bits(&mut p, item);
                p.push(score);
            }
        }
        Reply::FoldInItem { h, top } => {
            p.push(OP_FOLD_IN_ITEM);
            wire::push_u64_bits(&mut p, h.len() as u64);
            p.extend_from_slice(h);
            wire::push_u64_bits(&mut p, top.len() as u64);
            for &(user, score) in top {
                wire::push_u64_bits(&mut p, user);
                p.push(score);
            }
        }
        Reply::Stats(text) => {
            p.push(OP_STATS);
            p.extend(wire::encode_text(text));
        }
        Reply::Reload { generation, iteration } => {
            p.push(OP_RELOAD);
            wire::push_u64_bits(&mut p, *generation);
            wire::push_u64_bits(&mut p, *iteration);
        }
        Reply::Error(msg) => {
            p.push(STATUS_ERROR);
            p.extend(wire::encode_text(msg));
        }
    }
    p
}

/// Decode a [`FrameKind::Response`] payload.
pub fn decode_reply(payload: &[f32]) -> Result<Reply> {
    let mut pos = 0usize;
    let op = take_f32(payload, &mut pos)?;
    if op == STATUS_ERROR {
        return Ok(Reply::Error(wire::decode_text(&payload[pos..])));
    }
    if op == OP_TOP_K {
        let nrows = take_len(payload, &mut pos, "reply row")?;
        let mut rows = Vec::with_capacity(nrows);
        for _ in 0..nrows {
            let len = take_len(payload, &mut pos, "reply item")?;
            let mut row = Vec::with_capacity(len);
            for _ in 0..len {
                let item = wire::take_u64_bits(payload, &mut pos)?;
                let score = take_f32(payload, &mut pos)?;
                row.push((item, score));
            }
            rows.push(row);
        }
        Ok(Reply::TopK(rows))
    } else if op == OP_RECONSTRUCT {
        let rows = take_len(payload, &mut pos, "score row")?;
        let cols = take_len(payload, &mut pos, "score col")?;
        let n = rows
            .checked_mul(cols)
            .filter(|&n| pos + n <= payload.len())
            .ok_or_else(|| crate::err!("score reply shorter than its {rows}x{cols} header"))?;
        Ok(Reply::Scores { rows, cols, data: payload[pos..pos + n].to_vec() })
    } else if op == OP_FOLD_IN {
        let k = take_len(payload, &mut pos, "embedding lane")?;
        if pos + k > payload.len() {
            crate::bail!("fold-in reply shorter than its k={k} header");
        }
        let w = payload[pos..pos + k].to_vec();
        pos += k;
        let len = take_len(payload, &mut pos, "reply item")?;
        let mut top = Vec::with_capacity(len);
        for _ in 0..len {
            let item = wire::take_u64_bits(payload, &mut pos)?;
            let score = take_f32(payload, &mut pos)?;
            top.push((item, score));
        }
        Ok(Reply::FoldIn { w, top })
    } else if op == OP_FOLD_IN_ITEM {
        let k = take_len(payload, &mut pos, "embedding lane")?;
        if pos + k > payload.len() {
            crate::bail!("item fold-in reply shorter than its k={k} header");
        }
        let h = payload[pos..pos + k].to_vec();
        pos += k;
        let len = take_len(payload, &mut pos, "reply user")?;
        let mut top = Vec::with_capacity(len);
        for _ in 0..len {
            let user = wire::take_u64_bits(payload, &mut pos)?;
            let score = take_f32(payload, &mut pos)?;
            top.push((user, score));
        }
        Ok(Reply::FoldInItem { h, top })
    } else if op == OP_STATS {
        Ok(Reply::Stats(wire::decode_text(&payload[pos..])))
    } else if op == OP_RELOAD {
        let generation = wire::take_u64_bits(payload, &mut pos)?;
        let iteration = wire::take_u64_bits(payload, &mut pos)?;
        Ok(Reply::Reload { generation, iteration })
    } else {
        crate::bail!("unknown serving reply op {op}")
    }
}

/// The frame kind a query travels as (always [`FrameKind::Request`] —
/// named here so call sites read as protocol, not transport).
pub const REQUEST: FrameKind = FrameKind::Request;
/// The frame kind a reply travels as.
pub const RESPONSE: FrameKind = FrameKind::Response;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_roundtrip() {
        // ids beyond 2^24 must survive exactly (f32-cast would corrupt)
        let big = (1u64 << 40) + 12345;
        for q in [
            Query::TopK { users: vec![0, big, 7], n: 10 },
            Query::Reconstruct { users: vec![big] },
            Query::FoldIn { entries: vec![(3, 0.5), (big, -1.25)], n: 5 },
            Query::FoldInItem { entries: vec![(big, 4.5), (0, 1.0)], n: 3 },
            Query::Stats,
            Query::Reload,
        ] {
            assert_eq!(decode_query(&encode_query(&q)).unwrap(), q);
        }
    }

    #[test]
    fn reply_roundtrip() {
        let big = (1u64 << 33) + 9;
        for r in [
            Reply::TopK(vec![vec![(big, 0.75), (2, 0.5)], vec![]]),
            Reply::Scores { rows: 2, cols: 3, data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] },
            Reply::FoldIn { w: vec![0.1, 0.2], top: vec![(1, 0.9)] },
            Reply::FoldInItem { h: vec![0.3, 0.4], top: vec![(big, 0.8), (0, 0.1)] },
            Reply::Stats("{\"queries\":3}".into()),
            Reply::Reload { generation: (1u64 << 34) + 2, iteration: 450 },
            Reply::Error("unknown user id 9".into()),
        ] {
            assert_eq!(decode_reply(&encode_reply(&r)).unwrap(), r);
        }
    }

    #[test]
    fn corrupt_payloads_are_typed_errors() {
        assert!(decode_query(&[]).is_err());
        assert!(decode_query(&[99.0]).is_err());
        // truncated user list
        let mut p = encode_query(&Query::TopK { users: vec![1, 2, 3], n: 4 });
        p.truncate(p.len() - 1);
        assert!(decode_query(&p).is_err());
        // score reply shorter than its shape header
        let mut p = encode_reply(&Reply::Scores { rows: 2, cols: 2, data: vec![0.0; 4] });
        p.truncate(p.len() - 2);
        assert!(decode_reply(&p).is_err());
    }
}
