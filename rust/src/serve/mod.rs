//! Serving plane: factor-model inference for trained NMF factors.
//!
//! Training ends at [`crate::nmf::job::Outcome`] factors; this subsystem is
//! their production consumer. A [`FactorModel`] loads the versioned
//! checkpoint format written by [`crate::nmf::control`] and answers three
//! query families:
//!
//! * **top-k recommendation** — score a batch of known users against every
//!   item (`W·Vᵀ` through the packed SIMD GEMM) and return the best `n`
//!   item ids per user;
//! * **reconstruction** — the full score row `uᵢ·Vᵀ` for a batch of users
//!   (matrix-completion reads);
//! * **fold-in** — embed a *new* user from a sparse rating row by solving a
//!   single NNLS row against the fixed item factor `V` (sklearn's
//!   `non_negative_factorization(update_H=False)` shape), reusing the
//!   [`crate::solvers`] machinery with a zero-allocation steady state. The
//!   mirrored **item fold-in** embeds a new *item* from a sparse user
//!   column against the fixed `U` (cached under a side-disambiguated key),
//!   and optionally returns the top users for the new item.
//!
//! The [`server`] module fronts a model with a request/response server on
//! the [`crate::transport::wire`] length-prefixed framing (frame kinds
//! [`crate::transport::wire::FrameKind::Request`] /
//! [`crate::transport::wire::FrameKind::Response`], wire v5): a concurrent
//! batcher coalesces in-flight score queries into one GEMM, fold-in
//! results go through an LRU hot/cold cache, and per-query
//! latency/throughput counters surface as [`crate::metrics::JsonValue`]
//! reports. [`client::ServeClient`] is the matching client used by
//! `dsanls query`, the end-to-end tests and `benches/serve_latency.rs`.
//!
//! The server holds its model behind an atomic **generation pointer**:
//! a checkpoint hot-swap ([`ServerHandle::swap_model`], the `OP_RELOAD`
//! wire op, or `dsanls serve --watch-checkpoint`) publishes new factors
//! between batches with zero dropped queries and no batch ever mixing
//! generations — in-flight batches drain against the `Arc` they
//! snapshotted. Every reply advertises its generation on the wire, and
//! the fold-in cache keys on it so retired factors can never serve. A
//! replicated tier fronts several such servers through
//! [`crate::router`] (`dsanls route`) without clients changing at all.
//!
//! CLI surface: `dsanls serve --checkpoint <file> --bind <addr>
//! [--watch-checkpoint]` and `dsanls query --addr <host:port>`
//! ([`crate::coordinator::serve_cli`]; walkthrough in DEPLOYMENT.md).

#![warn(missing_docs)]

pub mod cache;
pub mod client;
pub mod model;
pub mod protocol;
pub mod server;

pub use cache::FoldCache;
pub use client::ServeClient;
pub use model::{top_n, FactorModel, FoldIn, FOLD_IN_INIT};
pub use protocol::{Query, Reply};
pub use server::{serve, CheckpointSource, ServeOptions, ServerHandle, FIRST_GENERATION};
