//! The `dsanls serve` request/response server.
//!
//! Topology: one acceptor thread, one reader thread per client
//! connection, and a single **batcher** thread that owns the compute. The
//! readers decode [`Query`] frames and push them onto a shared queue; the
//! batcher drains up to [`ServeOptions::batch_max`] pending queries at a
//! time (lingering [`ServeOptions::batch_wait_us`] to let concurrent
//! clients coalesce), gathers every queried user row into **one**
//! `W·Vᵀ` GEMM, and answers each query from its slice of the shared
//! score block. Fold-ins consult the LRU [`FoldCache`] before solving;
//! misses reuse one [`FoldIn`] workspace so the steady state allocates
//! nothing in the solver path. Replies go back over the writer half of
//! each client's connection, tagged with the request id, so one
//! connection can pipeline queries.
//!
//! Every reply is timed from enqueue to write; the counters surface as a
//! [`crate::metrics::JsonValue`] snapshot via [`ServerHandle::metrics_json`]
//! and the `Stats` query (what `dsanls query --stats` prints).
//!
//! ## Zero-downtime hot-swap
//!
//! The model lives behind an atomic **generation pointer**
//! ([`ModelGen`] in an `Arc` swapped under a mutex): the batcher
//! snapshots the pointer **once per batch**, so every query in a batch —
//! scores, fold-ins, stats — is answered against exactly one generation,
//! and a swap never blocks on in-flight work (draining falls out of the
//! `Arc`: the old generation is freed when its last batch finishes). New
//! queries land on the next generation at the following batch boundary;
//! nothing is dropped. Swaps come from [`ServerHandle::swap_model`], the
//! `OP_RELOAD` admin wire op (re-reads the checkpoint recorded in
//! [`ServeOptions::source`], regenerating both fold-in grams), or
//! `dsanls serve --watch-checkpoint`. Reloads run on the requesting
//! connection's reader thread — the checkpoint read plus two gram GEMMs
//! never stall the batcher. The fold-in cache keys carry the generation
//! ([`crate::serve::cache::row_key`]), so a swap invalidates every
//! cached embedding without a flush.

use std::collections::VecDeque;
use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Context, Result};
use crate::linalg::Mat;
use crate::metrics::JsonValue;
use crate::serve::cache::{item_row_key, row_key, FoldCache};
use crate::serve::model::{top_n, FactorModel, FoldIn};
use crate::serve::protocol::{self, Query, Reply};
use crate::solvers::SolverKind;
use crate::transport::wire;

/// Where a live server can re-read its model from on an `OP_RELOAD` /
/// [`ServerHandle::reload`] — the checkpoint path plus the identity gate
/// the operator started the server with (a rolling update must never
/// swap in a checkpoint the startup gate would have refused).
#[derive(Debug, Clone)]
pub struct CheckpointSource {
    /// The versioned checkpoint file to re-read.
    pub path: PathBuf,
    /// `--expect-algo` carried over from startup.
    pub expect_algo: Option<String>,
    /// `--expect-params` carried over from startup.
    pub expect_params: Option<u64>,
}

/// Tuning knobs for [`serve`].
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Most queries coalesced into one batch (≥ 1).
    pub batch_max: usize,
    /// How long the batcher lingers for more in-flight queries before
    /// running a partial batch (0 = never wait; lowest latency, least
    /// coalescing).
    pub batch_wait_us: u64,
    /// Fold-in LRU cache capacity (entries; 0 disables caching).
    pub cache_cap: usize,
    /// Subproblem solver for fold-in rows. Defaults to HALS — an exact
    /// cyclic-CD solve is the right call for a one-shot embedding (the
    /// proximal anchor that stabilises *training* iterations would bias a
    /// single serve-time solve toward its initialiser).
    pub solver: SolverKind,
    /// Solver sweeps per fold-in.
    pub sweeps: usize,
    /// Pool width for the batcher's GEMMs (None = crate default).
    pub threads: Option<usize>,
    /// Checkpoint the model can be hot-reloaded from (None = in-memory
    /// model only; `OP_RELOAD` is refused with a typed error).
    pub source: Option<CheckpointSource>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            batch_max: 64,
            batch_wait_us: 200,
            cache_cap: 4096,
            solver: SolverKind::Hals,
            sweeps: 5,
            threads: None,
            source: None,
        }
    }
}

/// Latency samples kept for the percentile snapshot.
const LATENCY_WINDOW: usize = 4096;

/// Lock a mutex, recovering the guard if a peer thread panicked while
/// holding it (a poisoned serving queue must degrade, not cascade).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Per-query latency/throughput counters (lock-free on the count path, a
/// small ring of samples for percentiles).
#[derive(Debug)]
pub struct ServeMetrics {
    queries: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    rows_scored: AtomicU64,
    fold_solves: AtomicU64,
    /// Mirror of the serving generation (the authoritative value lives in
    /// the [`ModelGen`] pointer; this lets stats read it lock-free).
    generation: AtomicU64,
    /// Completed hot-swaps since startup.
    swaps: AtomicU64,
    latency: Mutex<LatencyRing>,
    started: Instant,
}

#[derive(Debug)]
struct LatencyRing {
    ring: Vec<f64>,
    next: usize,
    total: f64,
    count: u64,
}

impl ServeMetrics {
    fn new() -> ServeMetrics {
        ServeMetrics {
            queries: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows_scored: AtomicU64::new(0),
            fold_solves: AtomicU64::new(0),
            generation: AtomicU64::new(FIRST_GENERATION),
            swaps: AtomicU64::new(0),
            latency: Mutex::new(LatencyRing {
                ring: Vec::with_capacity(LATENCY_WINDOW),
                next: 0,
                total: 0.0,
                count: 0,
            }),
            started: Instant::now(),
        }
    }

    /// Queries answered so far (including error replies).
    pub fn queries(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    fn record_latency(&self, secs: f64) {
        let mut l = lock(&self.latency);
        if l.ring.len() < LATENCY_WINDOW {
            l.ring.push(secs);
        } else {
            let slot = l.next;
            l.ring[slot] = secs;
        }
        l.next = (l.next + 1) % LATENCY_WINDOW;
        l.total += secs;
        l.count += 1;
    }

    /// Snapshot the counters as a JSON object; `cache` contributes the
    /// hot/cold hit counters.
    pub fn json(&self, cache: &FoldCache) -> JsonValue {
        let (p50, p99, mean) = {
            let l = lock(&self.latency);
            let mut sorted = l.ring.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            let pct = |q: f64| {
                if sorted.is_empty() {
                    0.0
                } else {
                    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
                }
            };
            (pct(0.50), pct(0.99), if l.count == 0 { 0.0 } else { l.total / l.count as f64 })
        };
        let uptime = self.started.elapsed().as_secs_f64();
        let queries = self.queries.load(Ordering::Relaxed);
        JsonValue::Object(vec![
            ("queries".into(), JsonValue::Number(queries as f64)),
            ("errors".into(), JsonValue::Number(self.errors.load(Ordering::Relaxed) as f64)),
            ("batches".into(), JsonValue::Number(self.batches.load(Ordering::Relaxed) as f64)),
            (
                "rows_scored".into(),
                JsonValue::Number(self.rows_scored.load(Ordering::Relaxed) as f64),
            ),
            (
                "fold_in_solves".into(),
                JsonValue::Number(self.fold_solves.load(Ordering::Relaxed) as f64),
            ),
            (
                "generation".into(),
                JsonValue::Number(self.generation.load(Ordering::Relaxed) as f64),
            ),
            ("swaps".into(), JsonValue::Number(self.swaps.load(Ordering::Relaxed) as f64)),
            ("cache_hits".into(), JsonValue::Number(cache.hits() as f64)),
            ("cache_misses".into(), JsonValue::Number(cache.misses() as f64)),
            ("cache_len".into(), JsonValue::Number(cache.len() as f64)),
            ("cache_cap".into(), JsonValue::Number(cache.cap() as f64)),
            ("latency_p50_ms".into(), JsonValue::Number(p50 * 1e3)),
            ("latency_p99_ms".into(), JsonValue::Number(p99 * 1e3)),
            ("latency_mean_ms".into(), JsonValue::Number(mean * 1e3)),
            ("uptime_s".into(), JsonValue::Number(uptime)),
            (
                "queries_per_s".into(),
                JsonValue::Number(if uptime > 0.0 { queries as f64 / uptime } else { 0.0 }),
            ),
        ])
    }
}

/// Writer half of one client connection (replies are frame-atomic under
/// the lock, so the batcher and a reader's decode-error reply can share
/// it).
type Out = Arc<Mutex<BufWriter<TcpStream>>>;

struct Pending {
    query: Query,
    tag: u64,
    out: Out,
    enq: Instant,
}

/// The first generation a server boots at (0 is "no reply seen yet" on
/// the client side).
pub const FIRST_GENERATION: u64 = 1;

/// One immutable model generation: the factors plus the counter a reply
/// advertises in its frame clock lane. Swaps replace the whole `Arc`, so
/// an in-flight batch keeps its snapshot alive until it finishes.
struct ModelGen {
    generation: u64,
    model: FactorModel,
}

struct Shared {
    /// The atomic model-generation pointer. `Mutex<Arc<..>>` rather than
    /// a lone `Arc` because swap must read-modify-write the generation
    /// counter; readers only ever clone the `Arc` (one brief lock, no
    /// contention with compute).
    model: Mutex<Arc<ModelGen>>,
    opts: ServeOptions,
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    stop: AtomicBool,
    metrics: ServeMetrics,
    cache: Mutex<FoldCache>,
}

impl Shared {
    fn metrics_json(&self) -> JsonValue {
        let cache = lock(&self.cache);
        self.metrics.json(&cache)
    }

    /// Snapshot the serving generation (what every query in the caller's
    /// batch is answered against).
    fn current(&self) -> Arc<ModelGen> {
        lock(&self.model).clone()
    }

    fn generation(&self) -> u64 {
        self.metrics.generation.load(Ordering::Relaxed)
    }

    /// Swap `model` in as the next generation. In-flight batches keep
    /// their `Arc` snapshot; new batches pick the swapped pointer up at
    /// their next snapshot — zero queries dropped, none mixed.
    fn swap_model(&self, model: FactorModel) -> u64 {
        let mut cur = lock(&self.model);
        let generation = cur.generation + 1;
        *cur = Arc::new(ModelGen { generation, model });
        self.metrics.generation.store(generation, Ordering::Relaxed);
        self.metrics.swaps.fetch_add(1, Ordering::Relaxed);
        generation
    }

    /// Re-read the configured checkpoint source and swap it in. The
    /// identity gate from startup re-applies: a checkpoint from another
    /// run/algorithm is refused and the old generation keeps serving.
    fn reload(&self) -> Result<(u64, usize)> {
        let src = self.opts.source.as_ref().ok_or_else(|| {
            crate::err!(
                "reload refused: this server was started from an in-memory model, \
                 not a checkpoint file (no --checkpoint source to re-read)"
            )
        })?;
        let model = FactorModel::load(&src.path)?;
        model.check_identity(src.expect_algo.as_deref(), src.expect_params)?;
        let iteration = model.iteration();
        Ok((self.swap_model(model), iteration))
    }
}

fn send_reply(out: &Out, tag: u64, generation: u64, reply: &Reply) {
    let payload = protocol::encode_reply(reply);
    let mut w = lock(out);
    // a vanished client is the client's problem, not the server's; the
    // clock lane carries the generation the reply was answered against
    let _ =
        wire::write_frame_parts(&mut *w, protocol::RESPONSE, tag, generation as f64, &payload);
}

fn finish(shared: &Shared, generation: u64, p: &Pending, reply: &Reply) {
    if matches!(reply, Reply::Error(_)) {
        shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
    }
    send_reply(&p.out, p.tag, generation, reply);
    shared.metrics.record_latency(p.enq.elapsed().as_secs_f64());
}

/// Batcher-owned scratch: every buffer here is reused across batches.
#[derive(Default)]
struct Scratch {
    users: Vec<u64>,
    w: Mat,
    scores: Mat,
    fold: FoldIn,
    fold_row: Vec<(usize, f32)>,
    fw: Mat,
    fscores: Mat,
    topk: Vec<(usize, f32)>,
}

fn fold_in_reply(
    shared: &Shared,
    gen: &ModelGen,
    s: &mut Scratch,
    entries: &[(u64, f32)],
    n: usize,
) -> Reply {
    let items = gen.model.items() as u64;
    if let Some(&(bad, _)) = entries.iter().find(|&&(i, _)| i >= items) {
        return Reply::Error(format!(
            "fold-in item id {bad} out of range (model has {items} items)"
        ));
    }
    let key = row_key(gen.generation, entries);
    let cached = lock(&shared.cache).get(&key).map(<[f32]>::to_vec);
    let w = match cached {
        Some(w) => w,
        None => {
            s.fold_row.clear();
            s.fold_row.extend(entries.iter().map(|&(i, v)| (i as usize, v)));
            match s.fold.solve(
                &gen.model,
                &s.fold_row,
                shared.opts.solver,
                shared.opts.sweeps,
                0,
            ) {
                Ok(w) => {
                    let w = w.to_vec();
                    shared.metrics.fold_solves.fetch_add(1, Ordering::Relaxed);
                    lock(&shared.cache).insert(key, w.clone());
                    w
                }
                Err(e) => return Reply::Error(e.to_string()),
            }
        }
    };
    let top = if n > 0 {
        s.fw.resize_to(1, w.len());
        s.fw.data_mut().copy_from_slice(&w);
        gen.model.scores_for_w(&s.fw, &mut s.fscores);
        top_n(s.fscores.row(0), n, &mut s.topk);
        s.topk.iter().map(|&(i, v)| (i as u64, v)).collect()
    } else {
        Vec::new()
    };
    Reply::FoldIn { w, top }
}

/// Item-side mirror of [`fold_in_reply`]: embed a new item from a sparse
/// user-rating column, cached under a side-disambiguated key, optionally
/// scoring every *user* for the new item.
fn fold_in_item_reply(
    shared: &Shared,
    gen: &ModelGen,
    s: &mut Scratch,
    entries: &[(u64, f32)],
    n: usize,
) -> Reply {
    let users = gen.model.users() as u64;
    if let Some(&(bad, _)) = entries.iter().find(|&&(i, _)| i >= users) {
        return Reply::Error(format!(
            "fold-in user id {bad} out of range (model has {users} users)"
        ));
    }
    let key = item_row_key(gen.generation, entries);
    let cached = lock(&shared.cache).get(&key).map(<[f32]>::to_vec);
    let h = match cached {
        Some(h) => h,
        None => {
            s.fold_row.clear();
            s.fold_row.extend(entries.iter().map(|&(i, v)| (i as usize, v)));
            match s.fold.solve_item(
                &gen.model,
                &s.fold_row,
                shared.opts.solver,
                shared.opts.sweeps,
                0,
            ) {
                Ok(h) => {
                    let h = h.to_vec();
                    shared.metrics.fold_solves.fetch_add(1, Ordering::Relaxed);
                    lock(&shared.cache).insert(key, h.clone());
                    h
                }
                Err(e) => return Reply::Error(e.to_string()),
            }
        }
    };
    let top = if n > 0 {
        s.fw.resize_to(1, h.len());
        s.fw.data_mut().copy_from_slice(&h);
        gen.model.scores_for_h(&s.fw, &mut s.fscores);
        top_n(s.fscores.row(0), n, &mut s.topk);
        s.topk.iter().map(|&(i, v)| (i as u64, v)).collect()
    } else {
        Vec::new()
    };
    Reply::FoldInItem { h, top }
}

fn process_batch(shared: &Shared, s: &mut Scratch, batch: Vec<Pending>) {
    shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
    shared.metrics.queries.fetch_add(batch.len() as u64, Ordering::Relaxed);

    // ONE generation snapshot for the whole batch: every query below —
    // scores, fold-ins, stats — answers against exactly this model, no
    // matter when a concurrent swap lands. The `Arc` keeps the snapshot
    // alive until the batch finishes (the draining protocol).
    let gen = shared.current();

    // phase 1 — coalesce every score query in the batch into ONE GEMM:
    // each query's users become a row range of the shared score block
    s.users.clear();
    let mut jobs: Vec<(usize, Range<usize>, Option<usize>)> = Vec::new();
    let mut failed: Vec<Option<String>> = Vec::new();
    failed.resize_with(batch.len(), || None);
    for (bi, p) in batch.iter().enumerate() {
        let (users, kind) = match &p.query {
            Query::TopK { users, n } => (users, Some(*n)),
            Query::Reconstruct { users } => (users, None),
            _ => continue,
        };
        if let Some(&bad) = users.iter().find(|&&id| id >= gen.model.users() as u64) {
            failed[bi] = Some(format!(
                "unknown user id {bad} (model has {} users; fold-in embeds new ones)",
                gen.model.users()
            ));
            continue;
        }
        let start = s.users.len();
        s.users.extend_from_slice(users);
        jobs.push((bi, start..s.users.len(), kind));
    }
    if !s.users.is_empty() {
        // ids were validated above, so the gather cannot fail
        gen.model
            .scores_into(&s.users, &mut s.w, &mut s.scores)
            .expect("validated user batch failed to score");
        shared.metrics.rows_scored.fetch_add(s.users.len() as u64, Ordering::Relaxed);
    }
    for (bi, range, kind) in jobs {
        let reply = match kind {
            Some(n) => {
                let mut rows = Vec::with_capacity(range.len());
                for r in range {
                    top_n(s.scores.row(r), n, &mut s.topk);
                    rows.push(s.topk.iter().map(|&(i, v)| (i as u64, v)).collect());
                }
                Reply::TopK(rows)
            }
            None => {
                let mut data = Vec::with_capacity(range.len() * gen.model.items());
                for r in range.clone() {
                    data.extend_from_slice(s.scores.row(r));
                }
                Reply::Scores { rows: range.len(), cols: gen.model.items(), data }
            }
        };
        finish(shared, gen.generation, &batch[bi], &reply);
    }

    // phase 2 — fold-ins (through the cache), stats, and the failures
    for (bi, p) in batch.iter().enumerate() {
        if let Some(msg) = failed[bi].take() {
            finish(shared, gen.generation, p, &Reply::Error(msg));
            continue;
        }
        match &p.query {
            Query::FoldIn { entries, n } => {
                let reply = fold_in_reply(shared, &gen, s, entries, *n);
                finish(shared, gen.generation, p, &reply);
            }
            Query::FoldInItem { entries, n } => {
                let reply = fold_in_item_reply(shared, &gen, s, entries, *n);
                finish(shared, gen.generation, p, &reply);
            }
            Query::Stats => finish(
                shared,
                gen.generation,
                p,
                &Reply::Stats(shared.metrics_json().to_string()),
            ),
            _ => {} // score queries were answered in phase 1
        }
    }
}

fn batcher_loop(shared: Arc<Shared>) {
    if let Some(t) = shared.opts.threads {
        crate::parallel::set_local_threads(Some(t));
    }
    let mut scratch = Scratch::default();
    loop {
        let batch: Vec<Pending> = {
            let mut q = lock(&shared.queue);
            while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap_or_else(|p| p.into_inner());
            }
            if q.is_empty() {
                return; // stopped and drained
            }
            let cap = shared.opts.batch_max.max(1);
            if q.len() < cap
                && shared.opts.batch_wait_us > 0
                && !shared.stop.load(Ordering::SeqCst)
            {
                // linger briefly so concurrent clients coalesce into one GEMM
                let wait = Duration::from_micros(shared.opts.batch_wait_us);
                q = shared
                    .cv
                    .wait_timeout(q, wait)
                    .unwrap_or_else(|p| p.into_inner())
                    .0;
            }
            let take = q.len().min(cap);
            q.drain(..take).collect()
        };
        process_batch(&shared, &mut scratch, batch);
    }
}

fn connection_loop(shared: Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let mut reader = match stream.try_clone() {
        Ok(s) => std::io::BufReader::new(s),
        Err(_) => return,
    };
    // version gate: a peer speaking another wire version is refused here,
    // before any Request frame is parsed
    if wire::read_preamble(&mut reader).is_err() {
        return;
    }
    let out: Out = Arc::new(Mutex::new(BufWriter::new(stream)));
    if wire::write_preamble(&mut *lock(&out), 0).is_err() {
        return;
    }
    loop {
        let frame = match wire::read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return, // client hung up (or sent garbage)
        };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        if frame.kind != wire::FrameKind::Request {
            send_reply(
                &out,
                frame.tag,
                shared.generation(),
                &Reply::Error(format!(
                    "unexpected {:?} frame on a serving connection",
                    frame.kind
                )),
            );
            continue;
        }
        match protocol::decode_query(&frame.payload) {
            // the admin hot-swap runs HERE, on the requesting connection's
            // reader thread: the checkpoint read + two gram GEMMs must
            // never stall the batcher, and the swap itself is one pointer
            // store — in-flight batches drain against their snapshot
            Ok(Query::Reload) => {
                let enq = Instant::now();
                shared.metrics.queries.fetch_add(1, Ordering::Relaxed);
                let (generation, reply) = match shared.reload() {
                    Ok((generation, iteration)) => (
                        generation,
                        Reply::Reload { generation, iteration: iteration as u64 },
                    ),
                    Err(e) => {
                        shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        (shared.generation(), Reply::Error(e.to_string()))
                    }
                };
                send_reply(&out, frame.tag, generation, &reply);
                shared.metrics.record_latency(enq.elapsed().as_secs_f64());
            }
            Ok(query) => {
                lock(&shared.queue).push_back(Pending {
                    query,
                    tag: frame.tag,
                    out: out.clone(),
                    enq: Instant::now(),
                });
                shared.cv.notify_all();
            }
            Err(e) => {
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                send_reply(&out, frame.tag, shared.generation(), &Reply::Error(e.to_string()));
            }
        }
    }
}

/// A running server. Dropping the handle shuts the server down.
#[derive(Debug)]
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    batcher: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for Shared {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let gen = self.current();
        write!(
            f,
            "Shared(gen {} model {}x{} k={})",
            gen.generation,
            gen.model.users(),
            gen.model.items(),
            gen.model.k()
        )
    }
}

impl ServerHandle {
    /// The address the server actually bound (port resolved for `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the per-query latency/throughput counters.
    pub fn metrics_json(&self) -> JsonValue {
        self.shared.metrics_json()
    }

    /// The model generation currently serving (starts at
    /// [`FIRST_GENERATION`], bumps on every swap).
    pub fn generation(&self) -> u64 {
        self.shared.generation()
    }

    /// Atomically swap `model` in as the next generation. In-flight
    /// batches finish against the generation they snapshotted; queries
    /// enqueued after the swap answer from `model`. Returns the new
    /// generation.
    pub fn swap_model(&self, model: FactorModel) -> u64 {
        self.shared.swap_model(model)
    }

    /// Re-read the checkpoint this server was started from
    /// ([`ServeOptions::source`]) and swap it in — what `dsanls serve
    /// --watch-checkpoint` calls when the file changes, and what the
    /// `OP_RELOAD` wire op runs server-side. Returns `(generation,
    /// checkpoint iteration)`; on error the old generation keeps serving.
    pub fn reload(&self) -> Result<(u64, usize)> {
        self.shared.reload()
    }

    /// Stop accepting, drain the queue, and join the worker threads.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        // poke the acceptor out of its blocking accept()
        let poke = if self.addr.ip().is_unspecified() {
            SocketAddr::from(([127, 0, 0, 1], self.addr.port()))
        } else {
            self.addr
        };
        let _ = TcpStream::connect_timeout(&poke, Duration::from_millis(250));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:7878"`, port 0 for an ephemeral one) and
/// serve `model` until the returned handle is shut down or dropped.
pub fn serve(addr: &str, model: FactorModel, opts: ServeOptions) -> Result<ServerHandle> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding serve listener on {addr}"))?;
    let bound = listener.local_addr().context("resolving serve listener address")?;
    let cache_cap = opts.cache_cap;
    let shared = Arc::new(Shared {
        model: Mutex::new(Arc::new(ModelGen { generation: FIRST_GENERATION, model })),
        opts,
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        stop: AtomicBool::new(false),
        metrics: ServeMetrics::new(),
        cache: Mutex::new(FoldCache::new(cache_cap)),
    });

    let accept_shared = shared.clone();
    let accept = std::thread::Builder::new()
        .name("dsanls-serve-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if accept_shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    let conn_shared = accept_shared.clone();
                    let _ = std::thread::Builder::new()
                        .name("dsanls-serve-conn".into())
                        .spawn(move || connection_loop(conn_shared, stream));
                }
            }
        })
        .context("spawning serve accept thread")?;

    let batch_shared = shared.clone();
    let batcher = std::thread::Builder::new()
        .name("dsanls-serve-batch".into())
        .spawn(move || batcher_loop(batch_shared))
        .context("spawning serve batcher thread")?;

    Ok(ServerHandle { addr: bound, shared, accept: Some(accept), batcher: Some(batcher) })
}
