//! Secure distributed NMF over federated data (paper Sec. 4).
//!
//! Setting: `M = [M₁ … M_N]` column-federated across N honest-but-curious
//! parties; party r must only ever see `M_{:J_r}`, the shared factor `U`
//! (public output) and its own `V_{J_r:}`. The protocols are
//! (N−1)-private (Definition 1): any N−1 colluding parties learn nothing
//! beyond their own outputs.
//!
//! Protocols (Sec. 4.2–4.3), all driven through the
//! [`crate::nmf::job::Job`] builder (`Algo::Syn` / `Algo::Asyn`):
//! * [`syn::syn_rank`] with [`SecureAlgo::SynSd`] — Alg. 4: local NMF +
//!   periodic full-`U` all-reduce averaging every `T₂` inner iterations.
//! * [`syn::syn_rank`] with an SSD variant — Alg. 5: sketched exchange
//!   every inner iteration (variants: sketch the U-consensus, the
//!   V-subproblem, or both — Syn-SSD-U / -V / -UV).
//! * [`asyn::server_loop`] / [`asyn::client_rank`] — Alg. 6/7:
//!   parameter-server architecture with relaxation weight `ωᵗ → 0`;
//!   Asyn-SD (unsketched) and Asyn-SSD-V (sketched V-subproblem; U cannot
//!   be sketched asynchronously because a shared `S₂ᵗ` would reintroduce
//!   the synchronisation barrier).
//! * [`privacy`]           — the audit harness (outbound-payload check) and
//!   the Theorem-2/3 sketch-inversion attack.
//!
//! Why DSANLS itself is *not* secure here (Sec. 4.1): it would all-reduce
//! `M·Sᵗ`, and Theorem 3 shows `M` is recoverable by Gaussian elimination
//! once enough `(Sᵗ, M·Sᵗ)` pairs accumulate — [`privacy::sketch_inversion`]
//! implements exactly that attack, and the tests show it succeeding.

pub mod asyn;
pub mod privacy;
pub mod syn;

pub use asyn::AsynOptions;
pub use privacy::{sketch_inversion, AuditLog, AuditVerdict};
pub use syn::SynOptions;

use crate::algos::TracePoint;
use crate::dist::CommStats;
use crate::linalg::Mat;

/// Which secure protocol variant (for reporting / config parsing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SecureAlgo {
    SynSd,
    SynSsdU,
    SynSsdV,
    SynSsdUv,
    AsynSd,
    AsynSsdV,
}

impl SecureAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            SecureAlgo::SynSd => "Syn-SD",
            SecureAlgo::SynSsdU => "Syn-SSD-U",
            SecureAlgo::SynSsdV => "Syn-SSD-V",
            SecureAlgo::SynSsdUv => "Syn-SSD-UV",
            SecureAlgo::AsynSd => "Asyn-SD",
            SecureAlgo::AsynSsdV => "Asyn-SSD-V",
        }
    }

    pub const ALL: [SecureAlgo; 6] = [
        SecureAlgo::SynSd,
        SecureAlgo::SynSsdU,
        SecureAlgo::SynSsdV,
        SecureAlgo::SynSsdUv,
        SecureAlgo::AsynSd,
        SecureAlgo::AsynSsdV,
    ];
}

impl std::str::FromStr for SecureAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "syn-sd" => Ok(SecureAlgo::SynSd),
            "syn-ssd-u" => Ok(SecureAlgo::SynSsdU),
            "syn-ssd-v" => Ok(SecureAlgo::SynSsdV),
            "syn-ssd-uv" => Ok(SecureAlgo::SynSsdUv),
            "asyn-sd" => Ok(SecureAlgo::AsynSd),
            "asyn-ssd-v" => Ok(SecureAlgo::AsynSsdV),
            other => Err(format!("unknown secure algorithm: {other}")),
        }
    }
}

/// Result of a secure federated run. Unlike [`crate::algos::DistRun`] there
/// is no single assembled `V` owner — each party keeps `V_{J_r:}` — but we
/// assemble for inspection in tests (the *driver* is trusted).
#[derive(Debug, Clone)]
pub struct SecureRun {
    /// Final shared factor (identical across parties for sync; server copy
    /// for async).
    pub u: Mat,
    /// Party-assembled item factor (test/inspection only).
    pub v: Mat,
    pub trace: Vec<TracePoint>,
    pub stats: Vec<CommStats>,
    pub sec_per_iter: f64,
}

impl SecureRun {
    pub fn final_error(&self) -> f64 {
        self.trace.last().map(|t| t.rel_error).unwrap_or(f64::NAN)
    }

    pub fn total_bytes_sent(&self) -> usize {
        self.stats.iter().map(|s| s.bytes_sent).sum()
    }
}
