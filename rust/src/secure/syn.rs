//! Synchronous secure protocols: Syn-SD (Alg. 4) and Syn-SSD (Alg. 5).
//!
//! Each party r holds only its column block `M_{:J_r}`, a local copy
//! `U_(r)` of the shared factor, and its private `V_{J_r:}`. All
//! communication is `U`-related; `M_{:J_r}` and `V_{J_r:}` never leave the
//! party (the [`super::privacy::AuditLog`] records every outbound payload
//! so the tests can verify exactly that).
//!
//! * **Syn-SD**: `T₂` purely local two-block updates, then an `m×k`
//!   all-reduce that averages the `U_(r)` copies (Alg. 4 line 7).
//! * **Syn-SSD**: consensus every inner iteration, but *sketched*: parties
//!   all-reduce `S₃ᵗᵀ·U_(r)` (`d₃×k`, shared subsampling `S₃ᵗ` from the
//!   common seed) and replace the sampled rows with their average —
//!   the same information flow at ~`d₃/m` of the cost. Variants
//!   additionally sketch the local subproblems:
//!   `-U` sketches the U-subproblem (cuts `O(m·|J_r|·k)` → `O(m·d₂·k)`),
//!   `-V` sketches the V-subproblem, `-UV` both. (The paper's Alg. 5
//!   listing is not fully reproducible from the text; DESIGN.md §2
//!   documents this reconstruction — the communication/compute trade-offs
//!   match the paper's Sec. 4.2 narrative and Fig. 6/8 behaviour.)

use std::time::Instant;

use super::{privacy::AuditLog, SecureAlgo, SecureRun};
use crate::algos::{ObserverFn, Trace, TracePoint};
use crate::data::partition::Partition;
use crate::data::shard::NodeInput;
use crate::dist::elastic::{run_step, Elastic};
use crate::dist::{CommModel, CommStats, NodeCtx};
use crate::linalg::{Mat, Matrix};
use crate::nmf::control::{RunControl, StopReason};
use crate::nmf::{init_factors_from, rel_error_parts, MuSchedule};
use crate::rng::{Role, StreamRng};
use crate::sketch::{SketchKind, SketchMatrix};
use crate::solvers::{self, Normal, SolverKind};
use crate::transport::wire::Precision;
use crate::transport::Communicator;

/// Options shared by the synchronous secure protocols.
#[derive(Debug, Clone)]
pub struct SynOptions {
    pub nodes: usize,
    pub rank: usize,
    /// Outer iterations `T₁`.
    pub t1: usize,
    /// Inner iterations `T₂` (local steps between consensus rounds for
    /// Syn-SD; for Syn-SSD consensus happens every inner step).
    pub t2: usize,
    pub solver: SolverKind,
    pub mu: MuSchedule,
    /// Sketch sizes (0 = auto n/10 floored at 2k): d₁ (V-subproblem over
    /// m), d₂ (U-subproblem over |J_r|), d₃ (consensus rows of U).
    pub d1: usize,
    pub d2: usize,
    pub d3: usize,
    pub sketch: SketchKind,
    pub seed: u64,
    pub eval_every: usize,
    pub comm: CommModel,
    /// Overlap the sketched consensus reduction with the factor-independent
    /// half of the next U update (`A = M_{:J_r}·S`). Bit-identical — only
    /// the schedule changes. Applies to the Syn-SSD variants.
    pub overlap: bool,
    /// Wire precision for the consensus `U` payloads ([`Precision::F32`] =
    /// exact). The scalar error-poll lane always travels at f32.
    pub precision: Precision,
}

impl Default for SynOptions {
    fn default() -> Self {
        SynOptions {
            nodes: 4,
            rank: 10,
            t1: 20,
            t2: 5,
            solver: SolverKind::ProximalCd,
            mu: MuSchedule::default(),
            d1: 0,
            d2: 0,
            d3: 0,
            sketch: SketchKind::Subsample,
            seed: 42,
            eval_every: 1,
            comm: CommModel::default(),
            overlap: false,
            precision: Precision::F32,
        }
    }
}

fn auto_d(dim: usize, explicit: usize, k: usize) -> usize {
    if explicit > 0 {
        explicit.min(dim)
    } else {
        ((dim / 10).max(2 * k)).min(dim).max(1)
    }
}

/// Per-party output of one synchronous secure rank.
pub struct SynNodeOutput {
    /// The party's local copy of the shared factor `U_(r)`.
    pub u_local: Mat,
    /// The party-private item factor block `V_{J_r:}`.
    pub v_block: Mat,
    /// Non-empty only on rank 0.
    pub trace: Vec<TracePoint>,
    pub stats: CommStats,
    pub final_clock: f64,
    /// Why this party's loop ended (collectively agreed across parties).
    pub stop: StopReason,
    /// Membership epoch count this party finished at (1 = the founding
    /// membership; >1 means the mesh was rebuilt around a re-joined party).
    pub epochs: usize,
}

/// Assemble per-party outputs into a [`SecureRun`] (the driver is trusted;
/// parties never see each other's V).
pub fn assemble_syn(outputs: Vec<SynNodeOutput>, k: usize, total_iters: usize) -> SecureRun {
    let u = outputs[0].u_local.clone();
    let v_blocks: Vec<Vec<f32>> = outputs.iter().map(|o| o.v_block.data().to_vec()).collect();
    let v = crate::algos::assemble_blocks_pub(&v_blocks, k);
    let trace = outputs[0].trace.clone();
    let stats = outputs.iter().map(|o| o.stats).collect();
    let max_clock = outputs.iter().map(|o| o.final_clock).fold(0.0, f64::max);
    SecureRun { u, v, trace, stats, sec_per_iter: max_clock / total_iters.max(1) as f64 }
}

/// One synchronous secure party over any transport backend — the single
/// per-rank node runner, on a resolved [`NodeInput`]: the full matrix (the
/// party slices its own column block) or a shard-resident view holding
/// only `M_{:J_r}` plus the global shape and exact `‖M‖²` — which is all
/// the protocol touches, so the two views are bit-identical. `opts.nodes`
/// must match both the partition and the communicator's cluster size;
/// `observer` (rank 0 only) streams each traced sample.
#[allow(clippy::too_many_arguments)]
pub fn syn_rank<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    input: NodeInput<'_>,
    cols: &Partition,
    opts: &SynOptions,
    algo: SecureAlgo,
    audit: Option<&AuditLog>,
    observer: Option<&ObserverFn>,
    ctl: &RunControl,
    joining: bool,
) -> SynNodeOutput {
    let (m_rows, m_cols) = input.dims();
    let fro_sq = input.fro_sq();
    let m_col = input.col_block(cols.range(ctx.rank)); // M_{:J_r}, m×|J_r|
    syn_node_on_block(
        ctx, &m_col, m_rows, m_cols, fro_sq, cols, opts, algo, audit, observer, ctl, joining,
    )
}

/// Protocol body over the party's resident column block.
#[allow(clippy::too_many_arguments)]
fn syn_node_on_block<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    m_col: &Matrix,
    m_rows: usize,
    m_cols: usize,
    mut m_fro_sq: f64,
    cols: &Partition,
    opts: &SynOptions,
    algo: SecureAlgo,
    audit: Option<&AuditLog>,
    observer: Option<&ObserverFn>,
    ctl: &RunControl,
    joining: bool,
) -> SynNodeOutput {
    assert_eq!(cols.nodes(), opts.nodes, "partition/node mismatch");
    assert_eq!(opts.nodes, ctx.nodes(), "opts.nodes must match the cluster size");
    let k = opts.rank;
    {
        let rank = ctx.rank;
        let my_cols = cols.range(rank);
        let stream = StreamRng::new(opts.seed);

        // party-private data
        assert_eq!((m_col.rows(), m_col.cols()), (m_rows, my_cols.len()), "column block shape");
        let m_col_t = m_col.transpose(); // |J_r|×m
        let jr = my_cols.len();

        // shared-seed init: identical U_(r) on every party at t=0; private V.
        // A replacement party skips init — its state (and the real ‖M‖²)
        // arrive through the recovery exchange before the first iteration.
        let (mut u_local, mut v_block) = if joining {
            (Mat::zeros(m_rows, k), Mat::zeros(jr, k))
        } else {
            let (u_init, v_full) = {
                let mut rng = stream.for_iteration(0, Role::Init);
                init_factors_from(m_fro_sq, m_rows, m_cols, k, &mut rng)
            };
            let v_block = v_full.row_block(my_cols.clone());
            (u_init, v_block)
        };

        let d1 = auto_d(m_rows, opts.d1, k); // V-subproblem sketch over m
        let d2 = auto_d(jr, opts.d2, k).min(jr); // U-subproblem sketch over |J_r|
        let d3 = auto_d(m_rows, opts.d3, k); // consensus rows

        let sketch_u = matches!(algo, SecureAlgo::SynSsdU | SecureAlgo::SynSsdUv);
        let sketch_v = matches!(algo, SecureAlgo::SynSsdV | SecureAlgo::SynSsdUv);
        let ssd = algo != SecureAlgo::SynSd;

        let mut trace = Trace::new(if rank == 0 { observer } else { None });
        if !joining {
            record_secure_error(ctx, m_col, &u_local, &v_block, m_fro_sq, 0, &mut trace);
        }

        let total = opts.t1 * opts.t2;
        let mut stop = StopReason::Completed;
        // factor-independent half of the next sketched U update, computed
        // behind the consensus reduction when `opts.overlap` is set
        let mut prefetch: Option<(SketchMatrix, Mat)> = None;
        // The loop is flat over the T₁·T₂ inner iterations (a Syn-SD block
        // ends where the running counter hits a multiple of T₂ — identical
        // schedule to the nested form, but elastic recovery can re-enter at
        // any inner boundary).
        let mut elastic = ctl.elastic.map(|e| (Elastic::new(), e.min_ranks));
        let elastic_on = elastic.is_some();
        let mut first_join = joining;
        let mut pending_recovery = joining;
        let mut it = 0usize;
        while it < total {
            // elastic recovery: rebuild membership, adopt the committed
            // boundary wholesale (see `crate::dist::elastic`)
            if pending_recovery {
                let (el, min_ranks) = elastic.as_mut().expect("recovery implies elastic");
                let rec = el
                    .recover(ctx, *min_ranks, first_join)
                    .unwrap_or_else(|e| panic!("rank {rank} elastic recovery: {e}"));
                first_join = false;
                pending_recovery = false;
                it = rec.iteration;
                m_fro_sq = rec.fro_sq.0;
                let u_len = m_rows * k;
                u_local = Mat::from_vec(m_rows, k, rec.state[..u_len].to_vec());
                v_block = Mat::from_vec(jr, k, rec.state[u_len..].to_vec());
                trace.truncate_after(it);
                prefetch = None;
                continue;
            }

            let body = || -> Option<StopReason> {
                if let Some((el, _)) = elastic.as_mut() {
                    // commit this party's state at the start of inner
                    // iteration `it` — U_(r) and the private V block
                    let mut state =
                        Vec::with_capacity(u_local.data().len() + v_block.data().len());
                    state.extend_from_slice(u_local.data());
                    state.extend_from_slice(v_block.data());
                    el.commit(ctx, it, (m_fro_sq, 0.0), &state);
                }
                // chaos harness: a scripted kill for (rank, it) unwinds here
                ctx.comm_mut().fault_check(it);

                let mut iter = it;
                // collective stop decision — every party leaves together
                // (never reached with a pending exchange in flight: each
                // consensus reduction finishes within its own iteration)
                if let Some(reason) = ctl.poll_sync(ctx, iter, trace.last_error()) {
                    return Some(reason);
                }

                // ---- U_(r) update: min ‖M_{:J_r} − U·V_{J_r:}ᵀ‖ ----
                let pre = prefetch.take();
                ctx.compute(|| {
                    if sketch_u && d2 < jr {
                        // per-party sketch over the private column dim; no
                        // cross-party constraint (purely local problem).
                        // `S` and `A = M_{:J_r}·S` may have been prefetched
                        // behind the previous consensus reduction — the
                        // arithmetic is identical either way.
                        let (s, a) = pre.unwrap_or_else(|| {
                            let mut rng = stream
                                .for_node(rank, 0xA11C + iter as u64)
                                .clone();
                            let s = SketchMatrix::generate(opts.sketch, jr, d2, &mut rng);
                            let a = s.mul_right(m_col); // m×d₂
                            (s, a)
                        });
                        let b = s.mul_rows_tn(&v_block, 0); // k×d₂
                        let (gram, cross) = solvers::normal_from(&a, &b);
                        solvers::update_auto(opts.solver, &mut u_local, &Normal::new(&gram, &cross), &opts.mu, iter);
                    } else {
                        let gram = v_block.gram();
                        let cross = match m_col {
                            Matrix::Dense(md) => md.matmul(&v_block),
                            Matrix::Sparse(ms) => ms.spmm(&v_block),
                        };
                        solvers::update_auto(opts.solver, &mut u_local, &Normal::new(&gram, &cross), &opts.mu, iter);
                    }
                });

                // ---- V_{J_r:} update: min ‖M_{:J_r}ᵀ − V·Uᵀ‖ ----
                ctx.compute(|| {
                    if sketch_v && d1 < m_rows {
                        let mut rng = stream.for_node(rank, 0xB22D + iter as u64).clone();
                        let s = SketchMatrix::generate(opts.sketch, m_rows, d1, &mut rng);
                        let a = s.mul_right(&m_col_t); // |J_r|×d₁
                        let b = s.mul_rows_tn(&u_local, 0); // k×d₁
                        let (gram, cross) = solvers::normal_from(&a, &b);
                        solvers::update_auto(opts.solver, &mut v_block, &Normal::new(&gram, &cross), &opts.mu, iter);
                    } else {
                        let gram = u_local.gram();
                        let cross = match &m_col_t {
                            Matrix::Dense(md) => md.matmul(&u_local),
                            Matrix::Sparse(ms) => ms.spmm(&u_local),
                        };
                        solvers::update_auto(opts.solver, &mut v_block, &Normal::new(&gram, &cross), &opts.mu, iter);
                    }
                });

                iter += 1;

                // ---- Syn-SSD: sketched consensus every inner iteration ----
                if ssd {
                    // shared subsampling rows from the common seed
                    let mut rng = stream.for_iteration(iter as u64, Role::SketchU);
                    let rows = rng.sample_without_replacement(m_rows, d3);
                    let mut payload = Vec::with_capacity(d3 * k);
                    for &i in &rows {
                        payload.extend_from_slice(u_local.row(i));
                    }
                    if let Some(a) = audit {
                        a.record(rank, "syn-ssd/u-rows", &payload);
                    }
                    if opts.overlap {
                        // post the reduction, then compute the next U
                        // update's factor-independent sketch product while
                        // it is in flight (rng keyed by `iter`, which is
                        // already the next update's counter)
                        let pending = ctx.all_reduce_start(&payload, opts.precision);
                        if sketch_u && d2 < jr {
                            prefetch = Some(ctx.compute(|| {
                                let mut rng =
                                    stream.for_node(rank, 0xA11C + iter as u64).clone();
                                let s =
                                    SketchMatrix::generate(opts.sketch, jr, d2, &mut rng);
                                let a = s.mul_right(m_col);
                                (s, a)
                            }));
                        }
                        ctx.all_reduce_finish(pending, &mut payload);
                    } else {
                        ctx.all_reduce_sum_q(&mut payload, opts.precision);
                    }
                    let inv_n = 1.0 / opts.nodes as f32;
                    for (p, &i) in rows.iter().enumerate() {
                        let row = u_local.row_mut(i);
                        for (l, x) in row.iter_mut().enumerate() {
                            *x = payload[p * k + l] * inv_n;
                        }
                    }
                }

                if opts.eval_every > 0 && iter % opts.eval_every == 0 {
                    record_secure_error(ctx, m_col, &u_local, &v_block, m_fro_sq, iter, &mut trace);
                }

                // ---- Syn-SD: full U averaging every T₂ (Alg. 4 line 7) ----
                if !ssd && iter % opts.t2 == 0 {
                    let mut payload = u_local.data().to_vec();
                    if let Some(a) = audit {
                        a.record(rank, "syn-sd/u-full", &payload);
                    }
                    ctx.all_reduce_sum_q(&mut payload, opts.precision);
                    let inv_n = 1.0 / opts.nodes as f32;
                    for (dst, src) in u_local.data_mut().iter_mut().zip(payload.iter()) {
                        *dst = src * inv_n;
                    }
                    if opts.eval_every > 0 {
                        record_secure_error(
                            ctx, m_col, &u_local, &v_block, m_fro_sq, iter, &mut trace,
                        );
                    }
                }
                None
            };
            match if elastic_on { run_step(body) } else { Ok(body()) } {
                Ok(Some(reason)) => {
                    stop = reason;
                    break;
                }
                Ok(None) => it += 1,
                Err(_lost) => pending_recovery = true,
            }
        }
        record_secure_error(ctx, m_col, &u_local, &v_block, m_fro_sq, it, &mut trace);

        SynNodeOutput {
            u_local,
            v_block,
            trace: if rank == 0 { trace.into_points() } else { Vec::new() },
            stats: ctx.stats(),
            final_clock: ctx.clock(),
            stop,
            epochs: elastic.as_ref().map_or(1, |(el, _)| el.rebuilds + 1),
        }
    }
}

/// Secure out-of-band error: each party contributes its local residual
/// `‖M_{:J_r} − U_(r)·V_{J_r:}ᵀ‖²` (one scalar — reveals nothing about
/// individual entries); rank 0 records √(Σ residuals / ‖M‖²).
pub(crate) fn record_secure_error<C: Communicator>(
    ctx: &mut NodeCtx<C>,
    m_col: &Matrix,
    u_local: &Mat,
    v_block: &Mat,
    m_fro_sq: f64,
    iteration: usize,
    trace: &mut Trace<'_>,
) {
    let sim_time = ctx.clock();
    let err = ctx.untimed(|ctx| {
        let tick = Instant::now();
        let (_, resid) = rel_error_parts(m_col, u_local, v_block);
        let _ = tick;
        let mut buf = [resid as f32 / m_fro_sq as f32];
        ctx.all_reduce_sum(&mut buf);
        (buf[0].max(0.0) as f64).sqrt()
    });
    trace.record(TracePoint { iteration, sim_time, rel_error: err }, ctx.stats());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{imbalanced_partition, uniform_partition};
    use crate::rng::Pcg64;

    fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed as u128, 0);
        let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
        Matrix::Dense(u.matmul_nt(&v))
    }

    /// Builder-backed shorthands (the deprecated free functions are gone).
    fn run_syn(
        m: &Matrix,
        cols: &Partition,
        opts: &SynOptions,
        algo: SecureAlgo,
        audit: Option<&AuditLog>,
    ) -> SecureRun {
        let mut b = crate::nmf::job::Job::builder()
            .algorithm(crate::nmf::job::Algo::Syn(opts.clone(), algo))
            .data(crate::nmf::job::DataSource::Full(m))
            .secure_partition(cols.clone());
        if let Some(a) = audit {
            b = b.audit(a);
        }
        b.run()
            .unwrap_or_else(|e| panic!("{} job failed: {e}", algo.name()))
            .into_secure_run()
    }

    fn run_syn_sd(
        m: &Matrix,
        cols: &Partition,
        opts: &SynOptions,
        audit: Option<&AuditLog>,
    ) -> SecureRun {
        run_syn(m, cols, opts, SecureAlgo::SynSd, audit)
    }

    fn run_syn_ssd(
        m: &Matrix,
        cols: &Partition,
        opts: &SynOptions,
        variant: SecureAlgo,
        audit: Option<&AuditLog>,
    ) -> SecureRun {
        run_syn(m, cols, opts, variant, audit)
    }

    fn opts(nodes: usize) -> SynOptions {
        SynOptions {
            nodes,
            rank: 3,
            t1: 15,
            t2: 4,
            d1: 20,
            d2: 10,
            d3: 20,
            eval_every: 0,
            ..Default::default()
        }
    }

    #[test]
    fn syn_sd_converges() {
        let m = low_rank(60, 48, 3, 401);
        let cols = uniform_partition(48, 3);
        let run = run_syn_sd(&m, &cols, &opts(3), None);
        let first = run.trace.first().unwrap().rel_error;
        assert!(run.final_error() < 0.6 * first, "{} -> {}", first, run.final_error());
        assert!(run.u.is_nonnegative());
    }

    #[test]
    fn all_ssd_variants_converge() {
        let m = low_rank(60, 48, 3, 403);
        let cols = uniform_partition(48, 3);
        for variant in [SecureAlgo::SynSsdU, SecureAlgo::SynSsdV, SecureAlgo::SynSsdUv] {
            let run = run_syn_ssd(&m, &cols, &opts(3), variant, None);
            let first = run.trace.first().unwrap().rel_error;
            assert!(
                run.final_error() < 0.7 * first,
                "{}: {} -> {}",
                variant.name(),
                first,
                run.final_error()
            );
        }
    }

    #[test]
    fn ssd_consensus_cheaper_than_sd_per_exchange() {
        // Syn-SSD all-reduces d₃×k rows; Syn-SD all-reduces m×k. With the
        // same iteration budget SSD must move fewer bytes per consensus.
        let m = low_rank(120, 40, 3, 405);
        let cols = uniform_partition(40, 2);
        let mut o = opts(2);
        o.t1 = 4;
        o.t2 = 1; // SD averages every iteration too → same frequency
        let sd = run_syn_sd(&m, &cols, &o, None);
        let ssd = run_syn_ssd(&m, &cols, &o, SecureAlgo::SynSsdUv, None);
        assert!(
            ssd.total_bytes_sent() < sd.total_bytes_sent(),
            "SSD {} bytes vs SD {}",
            ssd.total_bytes_sent(),
            sd.total_bytes_sent()
        );
    }

    #[test]
    fn overlap_is_bit_identical_and_quantized_consensus_converges() {
        let m = low_rank(60, 48, 3, 411);
        let cols = uniform_partition(48, 3);
        let base_opts = opts(3);
        let base = run_syn_ssd(&m, &cols, &base_opts, SecureAlgo::SynSsdUv, None);

        let mut o = base_opts.clone();
        o.overlap = true;
        let over = run_syn_ssd(&m, &cols, &o, SecureAlgo::SynSsdUv, None);
        assert_eq!(base.u.data(), over.u.data(), "U diverged under overlap");
        assert_eq!(base.v.data(), over.v.data(), "V diverged under overlap");

        let mut o = base_opts.clone();
        o.precision = Precision::Fp16;
        let quant = run_syn_ssd(&m, &cols, &o, SecureAlgo::SynSsdUv, None);
        assert!(
            quant.total_bytes_sent() < base.total_bytes_sent(),
            "fp16 consensus must shrink traffic: {} vs {}",
            quant.total_bytes_sent(),
            base.total_bytes_sent()
        );
        assert!(
            quant.final_error() < base.final_error() * 1.5 + 0.02,
            "quantized {} vs exact {}",
            quant.final_error(),
            base.final_error()
        );
    }

    #[test]
    fn imbalanced_partition_stalls_sync() {
        // with node 0 holding 50 % of the columns, the others stall at the
        // consensus barrier — stall_time must be significant for them
        let m = low_rank(60, 60, 3, 407);
        let cols = imbalanced_partition(60, 3, 0.5);
        let run = run_syn_sd(&m, &cols, &opts(3), None);
        let s = &run.stats;
        assert!(
            s[1].stall_time + s[2].stall_time > s[0].stall_time,
            "light nodes should stall more: {:?}",
            s.iter().map(|x| x.stall_time).collect::<Vec<_>>()
        );
    }

    #[test]
    fn audit_log_records_only_u_payloads() {
        let m = low_rank(40, 30, 3, 409);
        let cols = uniform_partition(30, 2);
        let audit = AuditLog::new();
        let mut o = opts(2);
        o.t1 = 3;
        let _ = run_syn_ssd(&m, &cols, &o, SecureAlgo::SynSsdUv, Some(&audit));
        assert!(audit.len() > 0);
        for rec in audit.records().iter() {
            assert!(rec.channel.starts_with("syn-ssd/"));
        }
    }
}
