//! Privacy tooling: the outbound-payload audit and the Theorem-2/3
//! sketch-inversion attack.
//!
//! * [`AuditLog`] — protocols record every payload a party puts on the
//!   wire; [`AuditLog::verdict`] then scans for leaked rows of the party's
//!   private matrices (`M_{:J_r}`, `V_{J_r:}`). This operationalises
//!   Definition 1's "learn nothing beyond their own outputs" for the
//!   honest-but-curious model: colluders see exactly the logged payloads.
//! * [`sketch_inversion`] — Theorem 3's constructive attack: given enough
//!   `(Sᵗ, M·Sᵗ)` pairs, recover `M` row-wise by Gaussian elimination.
//!   With fewer pairs than `n` columns the system is underdetermined
//!   (Theorem 2) and the attack fails — both directions are tested.

use std::sync::Mutex;

use crate::linalg::Mat;
use crate::sketch::SketchMatrix;

/// One recorded outbound payload.
#[derive(Debug, Clone)]
pub struct AuditRecord {
    pub from: usize,
    /// Logical channel, e.g. `"syn-sd/u-full"` or `"asyn/u-push"`.
    pub channel: &'static str,
    pub payload: Vec<f32>,
}

/// Thread-safe log of everything the parties transmitted.
#[derive(Debug, Default)]
pub struct AuditLog {
    records: Mutex<Vec<AuditRecord>>,
}

/// Result of the leak scan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AuditVerdict {
    /// No private row appeared in any other party's view.
    Clean,
    /// A private row of `owner` leaked on `channel`.
    Leak { owner: usize, channel: &'static str },
}

impl AuditLog {
    pub fn new() -> Self {
        AuditLog::default()
    }

    pub fn record(&self, from: usize, channel: &'static str, payload: &[f32]) {
        self.records
            .lock()
            .unwrap()
            .push(AuditRecord { from, channel, payload: payload.to_vec() });
    }

    pub fn len(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn records(&self) -> Vec<AuditRecord> {
        self.records.lock().unwrap().clone()
    }

    /// Total bytes transmitted (4 bytes per f32 payload element).
    pub fn bytes(&self) -> usize {
        self.records.lock().unwrap().iter().map(|r| r.payload.len() * 4).sum()
    }

    /// Scan every transmitted payload for contiguous occurrences of any of
    /// `owner`'s secret rows. `secrets[i] = (owner, rows)` where each row is
    /// a private vector (a row of `M_{:J_r}`ᵀ or `V_{J_r:}`).
    ///
    /// A row of length < 3 is skipped (single floats collide by chance).
    pub fn verdict(&self, secrets: &[(usize, Vec<Vec<f32>>)]) -> AuditVerdict {
        let records = self.records.lock().unwrap();
        for (owner, rows) in secrets {
            for row in rows {
                if row.len() < 3 || row.iter().all(|&v| v == 0.0) {
                    continue;
                }
                for rec in records.iter() {
                    // a leak means *another* party could observe it; payloads
                    // sent by the owner itself to the aggregate are still a
                    // leak if they contain the raw row (all-reduce exposes
                    // them pre-aggregation only to the transport, but we take
                    // the conservative view and flag raw rows anywhere)
                    if contains_subsequence(&rec.payload, row, 1e-6) {
                        return AuditVerdict::Leak { owner: *owner, channel: rec.channel };
                    }
                }
            }
        }
        AuditVerdict::Clean
    }
}

/// True iff `needle` occurs as a contiguous subsequence of `haystack`
/// (within `tol` per element).
fn contains_subsequence(haystack: &[f32], needle: &[f32], tol: f32) -> bool {
    if needle.is_empty() || haystack.len() < needle.len() {
        return false;
    }
    'outer: for start in 0..=haystack.len() - needle.len() {
        for (h, n) in haystack[start..].iter().zip(needle.iter()) {
            if (h - n).abs() > tol {
                continue 'outer;
            }
        }
        return true;
    }
    false
}

/// Theorem-3 attack: recover `M` (m×n) from observed sketched products
/// `obs[t] = M·Sᵗ` and the (public, shared-seed) sketches `Sᵗ`.
///
/// Builds the stacked system `M · [S⁰ S¹ …] = [obs⁰ obs¹ …]` and solves
/// each row by Gaussian elimination with partial pivoting on the normal
/// equations. Returns `None` when the stacked sketch has numerical rank
/// < n — Theorem 2's regime, where `M` cannot be recovered.
pub fn sketch_inversion(sketches: &[SketchMatrix], observations: &[Mat]) -> Option<Mat> {
    assert_eq!(sketches.len(), observations.len());
    if sketches.is_empty() {
        return None;
    }
    let n = sketches[0].n();
    let m_rows = observations[0].rows();
    let total_d: usize = sketches.iter().map(|s| s.d()).sum();
    if total_d < n {
        return None; // underdetermined — Theorem 2
    }

    // stacked S (n × total_d) and stacked observations (m × total_d)
    let mut s_stack = Mat::zeros(n, total_d);
    let mut off = 0;
    for s in sketches {
        let sd = s.to_dense();
        for i in 0..n {
            let dst = &mut s_stack.row_mut(i)[off..off + s.d()];
            dst.copy_from_slice(sd.row(i));
        }
        off += s.d();
    }
    let mut obs_stack = Mat::zeros(m_rows, total_d);
    let mut off = 0;
    for o in observations {
        assert_eq!(o.rows(), m_rows);
        for i in 0..m_rows {
            let dst = &mut obs_stack.row_mut(i)[off..off + o.cols()];
            dst.copy_from_slice(o.row(i));
        }
        off += o.cols();
    }

    // Normal equations: M · (S Sᵀ) = obs · Sᵀ; solve the n×n SPD-ish system
    // per row with Gaussian elimination (partial pivoting).
    let g = s_stack.matmul_nt(&s_stack); // n×n
    let rhs = obs_stack.matmul_nt(&s_stack); // m×n
    let mut out = Mat::zeros(m_rows, n);
    let mut work = vec![0.0f64; n * (n + 1)];
    for i in 0..m_rows {
        if !gauss_solve(&g, rhs.row(i), out.row_mut(i), &mut work) {
            return None; // singular — rank-deficient stacked sketch
        }
    }
    Some(out)
}

/// Solve `xᵀ·G = b` i.e. `Gᵀx = bᵀ` (G symmetric here) by Gaussian
/// elimination with partial pivoting, f64 internally. Returns false if the
/// matrix is numerically singular.
fn gauss_solve(g: &Mat, b: &[f32], x: &mut [f32], work: &mut [f64]) -> bool {
    let n = b.len();
    debug_assert_eq!(g.rows(), n);
    let stride = n + 1;
    // augmented matrix [G | b]
    for r in 0..n {
        for c in 0..n {
            work[r * stride + c] = g.get(r, c) as f64;
        }
        work[r * stride + n] = b[r] as f64;
    }
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        let mut best = work[col * stride + col].abs();
        for r in col + 1..n {
            let v = work[r * stride + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-8 {
            return false;
        }
        if piv != col {
            for c in 0..stride {
                work.swap(col * stride + c, piv * stride + c);
            }
        }
        let d = work[col * stride + col];
        for r in col + 1..n {
            let f = work[r * stride + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..stride {
                work[r * stride + c] -= f * work[col * stride + c];
            }
        }
    }
    // back substitution
    for col in (0..n).rev() {
        let mut s = work[col * stride + n];
        for c in col + 1..n {
            s -= work[col * stride + c] * x[c] as f64;
        }
        x[col] = (s / work[col * stride + col]) as f32;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sketch::SketchKind;

    #[test]
    fn subsequence_detection() {
        assert!(contains_subsequence(&[1.0, 2.0, 3.0, 4.0], &[2.0, 3.0, 4.0], 1e-9));
        assert!(!contains_subsequence(&[1.0, 2.0, 3.0], &[3.0, 2.0], 1e-9));
        assert!(!contains_subsequence(&[1.0], &[1.0, 2.0], 1e-9));
    }

    #[test]
    fn audit_flags_raw_row_leak() {
        let log = AuditLog::new();
        let secret_row = vec![0.5f32, 0.25, 0.75, 0.125];
        // a payload that embeds the raw row
        let mut payload = vec![9.0f32, 9.0];
        payload.extend_from_slice(&secret_row);
        log.record(1, "test/leaky", &payload);
        let verdict = log.verdict(&[(1, vec![secret_row])]);
        assert!(matches!(verdict, AuditVerdict::Leak { owner: 1, .. }), "{verdict:?}");
    }

    #[test]
    fn audit_passes_aggregated_payload() {
        let log = AuditLog::new();
        let secret = vec![0.5f32, 0.25, 0.75, 0.125];
        // aggregate = secret + other party's contribution ⇒ not a raw match
        let other = [0.1f32, 0.9, 0.3, 0.7];
        let agg: Vec<f32> = secret.iter().zip(other.iter()).map(|(a, b)| a + b).collect();
        log.record(0, "test/agg", &agg);
        assert_eq!(log.verdict(&[(0, vec![secret])]), AuditVerdict::Clean);
    }

    #[test]
    fn theorem3_attack_succeeds_with_enough_sketches() {
        // n=16 columns, d=8 per sketch ⇒ 2 sketches suffice (rank 16)
        let mut data_rng = Pcg64::new(500, 0);
        let m = Mat::rand_uniform(6, 16, 1.0, &mut data_rng);
        let mut sketches = Vec::new();
        let mut obs = Vec::new();
        for t in 0..3 {
            let mut rng = Pcg64::new(600 + t as u128, 1);
            let s = SketchMatrix::generate(SketchKind::Gaussian, 16, 8, &mut rng);
            obs.push(s.mul_right_dense(&m));
            sketches.push(s);
        }
        let rec = sketch_inversion(&sketches, &obs).expect("attack must succeed");
        assert!(rec.dist_sq(&m) < 1e-4, "reconstruction error {}", rec.dist_sq(&m));
    }

    #[test]
    fn theorem2_attack_fails_with_one_sketch() {
        let mut data_rng = Pcg64::new(501, 0);
        let m = Mat::rand_uniform(6, 16, 1.0, &mut data_rng);
        let mut rng = Pcg64::new(601, 1);
        let s = SketchMatrix::generate(SketchKind::Gaussian, 16, 8, &mut rng);
        let obs = vec![s.mul_right_dense(&m)];
        assert!(sketch_inversion(&[s], &obs).is_none(), "d < n must be unrecoverable");
    }

    #[test]
    fn subsample_sketches_also_invert() {
        // subsampling sketches reveal raw columns — stacking enough of them
        // covers all n columns w.h.p.
        let mut data_rng = Pcg64::new(502, 0);
        let m = Mat::rand_uniform(4, 12, 1.0, &mut data_rng);
        let mut sketches = Vec::new();
        let mut obs = Vec::new();
        for t in 0..8 {
            let mut rng = Pcg64::new(700 + t as u128, 1);
            let s = SketchMatrix::generate(SketchKind::Subsample, 12, 6, &mut rng);
            obs.push(s.mul_right_dense(&m));
            sketches.push(s);
        }
        if let Some(rec) = sketch_inversion(&sketches, &obs) {
            assert!(rec.dist_sq(&m) < 1e-3);
        } else {
            panic!("8×6 subsample draws over 12 columns should cover all w.h.p.");
        }
    }
}
