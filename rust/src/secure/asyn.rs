//! Asynchronous secure protocols: Asyn-SD and Asyn-SSD-V (paper Alg. 6/7).
//!
//! Parameter-server architecture: the server owns the authoritative `U`;
//! client r runs `T` local two-block iterations on `(M_{:J_r}, U_(r),
//! V_{J_r:})`, pushes `U_(r)`, and receives the server's latest `U` — no
//! synchronisation barrier anywhere, which is what rescues scalability
//! under imbalanced workloads (Fig. 9).
//!
//! Server update (Alg. 6): `U ← (1−ωᵗ)·U + ωᵗ·U_(r)` with relaxation
//! `ωᵗ = ω₀/(1 + t/τ) → 0`, so the server copy converges even though
//! updates arrive in arbitrary order.
//!
//! Asyn-SSD-V sketches the client's V-subproblem (Alg. 7 line 7). `U` is
//! **not** sketched: a sketched push would need the same `Sᵗ` at every
//! client to make the server's mixture meaningful, and distributing that
//! `Sᵗ` consistently is exactly the synchronisation the async setting
//! forbids (paper Sec. 4.3).
//!
//! **Transport**: the protocol runs on the tagged P2P surface of
//! [`crate::transport::Communicator`] — a cluster of `N + 1` ranks where
//! ranks `0..N` are the parties and rank `N` ([`server_rank`]) is the
//! parameter server ([`server_loop`] / [`client_rank`]). The
//! [`crate::nmf::job::Job`] drivers wire N+1 ranks over the simulated or
//! in-process TCP backend; the multi-process TCP path (`dsanls launch`)
//! runs the same two loops over [`crate::transport::TcpComm`] workers.
//!
//! Timing: every client keeps a private **virtual clock** (measured local
//! compute + modelled p2p wire time). Error traces merge the clients'
//! locally-logged `(clock, residual²)` samples on the driver — party r only
//! ever reveals a scalar residual, same as the synchronous protocols.

use std::time::Instant;

use super::{privacy::AuditLog, SecureAlgo, SecureRun};
use crate::algos::TracePoint;
use crate::data::partition::Partition;
use crate::data::shard::NodeInput;
use crate::dist::{CommModel, CommStats};
use crate::linalg::{Mat, Matrix};
use crate::nmf::control::{RunControl, StopReason};
use crate::nmf::{rel_error_parts, MuSchedule};
use crate::rng::StreamRng;
use crate::sketch::{SketchKind, SketchMatrix};
use crate::solvers::{self, Normal, SolverKind};
use crate::transport::{Communicator, TAG_SHUTDOWN};

/// Options for the asynchronous protocols.
#[derive(Debug, Clone)]
pub struct AsynOptions {
    pub nodes: usize,
    pub rank: usize,
    /// Outer rounds per client (each ends with a server exchange).
    pub rounds: usize,
    /// Local iterations per round (`T` in Alg. 7).
    pub local_iters: usize,
    pub solver: SolverKind,
    pub mu: MuSchedule,
    /// V-subproblem sketch size (0 = auto m/10; used by Asyn-SSD-V only).
    pub d1: usize,
    pub sketch: SketchKind,
    /// Relaxation schedule `ωᵗ = omega0 / (1 + t/tau)`.
    pub omega0: f64,
    pub tau: f64,
    pub seed: u64,
    pub comm: CommModel,
}

impl Default for AsynOptions {
    fn default() -> Self {
        AsynOptions {
            nodes: 4,
            rank: 10,
            rounds: 20,
            local_iters: 5,
            solver: SolverKind::ProximalCd,
            mu: MuSchedule::default(),
            d1: 0,
            sketch: SketchKind::Subsample,
            omega0: 0.5,
            tau: 10.0,
            seed: 42,
            comm: CommModel::default(),
        }
    }
}

/// The parameter server's rank in an async cluster of `parties` clients
/// (the cluster has `parties + 1` ranks in total).
pub fn server_rank(parties: usize) -> usize {
    parties
}

/// Per-client output of one asynchronous party.
pub struct AsynClientOutput {
    /// The party-private item factor block `V_{J_r:}`.
    pub v_block: Mat,
    /// `(virtual clock, local residual², local iterations done)` samples.
    pub samples: Vec<(f64, f64, usize)>,
    pub stats: CommStats,
    pub final_clock: f64,
    /// Why this client's round loop ended (clients stop independently —
    /// the run-level reason is the merge across clients).
    pub stop: StopReason,
}

/// Merge the server factor and per-client outputs into a [`SecureRun`]
/// (shared by the in-process driver and the TCP launch coordinator).
pub fn assemble_asyn(
    server_u: Mat,
    outs: Vec<AsynClientOutput>,
    opts: &AsynOptions,
    m_fro_sq: f64,
) -> SecureRun {
    let trace = merge_traces(&outs, m_fro_sq);
    let v_blocks: Vec<Vec<f32>> = outs.iter().map(|o| o.v_block.data().to_vec()).collect();
    let v = crate::algos::assemble_blocks_pub(&v_blocks, opts.rank);
    let stats: Vec<CommStats> = outs.iter().map(|o| o.stats).collect();
    let max_clock = outs.iter().map(|o| o.final_clock).fold(0.0, f64::max);
    let total_iters: usize =
        outs.iter().map(|o| o.samples.last().map(|s| s.2).unwrap_or(0)).sum();
    SecureRun {
        u: server_u,
        v,
        trace,
        stats,
        sec_per_iter: max_clock * opts.nodes as f64 / total_iters.max(1) as f64,
    }
}

/// The parameter server (Alg. 6), on rank [`server_rank`] of any transport.
/// Serves relaxation-mixed `U` replies until every client sent
/// [`TAG_SHUTDOWN`]; returns the final server factor.
///
/// **Convergence aggregation (control plane)**: each client push carries
/// one trailing scalar — the client's latest `residual²/‖M‖²` fraction.
/// The asynchronous protocols have no collective in which the parties
/// could agree on a global error, but every fraction flows through the
/// server, so the server is the one place the global relative error
/// `√(Σ_r fraction_r)` exists *during* the run. When the run's
/// [`StopPolicy`](crate::nmf::control::StopPolicy) sets a target error
/// (or the token is cancelled), the server raises the stop flag it
/// appends to every reply, and clients finish their current round and
/// shut down. Only scalar residuals travel — the same disclosure the
/// synchronous protocols already make for their error traces.
pub fn server_loop<C: Communicator>(
    mut comm: C,
    opts: &AsynOptions,
    u_init: Mat,
    ctl: &RunControl,
) -> Mat {
    let parties = comm.nodes() - 1;
    let mut u = u_init;
    let u_len = u.data().len();
    // per-client done flags so a client counts once, whether it left via
    // TAG_SHUTDOWN or a dead link detected on reply
    let mut done = vec![false; parties];
    let mut live = parties;
    let mut t = 0usize;
    // latest residual fraction per client (NaN until first report)
    let mut latest = vec![f64::NAN; parties];
    fn finish(done: &mut [bool], live: &mut usize, who: usize) {
        if who < done.len() && !done[who] {
            done[who] = true;
            *live -= 1;
        }
    }
    // reply buffer reused across rounds: `U` prefix overwritten in place,
    // stop flag in the last lane (no per-reply factor-sized allocation)
    let mut reply = vec![0.0f32; u_len + 1];
    while live > 0 {
        let p = match comm.recv_any() {
            Ok(p) => p,
            // client churn is survivable: a dead link to one client retires
            // that client; losing the whole mesh ends the loop with the
            // best server copy so far. Anything else is still fatal.
            Err(e) => match e.lost_peer() {
                Some(Some(peer)) => {
                    finish(&mut done, &mut live, peer);
                    continue;
                }
                Some(None) => break,
                None => panic!("server inbox closed: {e}"),
            },
        };
        if p.tag == TAG_SHUTDOWN {
            finish(&mut done, &mut live, p.from);
            continue;
        }
        // relaxation: U ← (1−ω)U + ω·U_(r)
        let omega = (opts.omega0 / (1.0 + t as f64 / opts.tau)) as f32;
        for (dst, src) in u.data_mut().iter_mut().zip(p.payload.iter().take(u_len)) {
            *dst = (1.0 - omega) * *dst + omega * src;
        }
        if p.from < parties {
            if let Some(&frac) = p.payload.get(u_len) {
                latest[p.from] = frac as f64;
            }
        }
        t += 1;
        // global error estimate from the clients' scalar fractions
        let converged = ctl.stop.target_error.is_some_and(|target| {
            latest.iter().all(|f| f.is_finite())
                && latest.iter().sum::<f64>().max(0.0).sqrt() <= target
        });
        let stop_flag = if converged || ctl.token.is_cancelled() { 1.0f32 } else { 0.0 };
        // reply with the latest server copy + stop flag, echoing tag/clock
        reply[..u_len].copy_from_slice(u.data());
        reply[u_len] = stop_flag;
        if comm.send(p.from, p.tag, p.sent_at, &reply).is_err() {
            // client died between push and reply — retire it (at most once)
            finish(&mut done, &mut live, p.from);
        }
    }
    u
}

/// One asynchronous client (Alg. 7) on rank `party` of any transport —
/// the single per-rank node runner, on a resolved [`NodeInput`] (full
/// matrix, or a shard view holding only `M_{:J_r}` plus the global row
/// count — the protocol touches nothing else of `M`). `u0`/`v0` are the
/// shared-seed initial factors (the caller derives them so server and
/// clients agree at t=0).
#[allow(clippy::too_many_arguments)]
pub fn client_rank<C: Communicator>(
    comm: C,
    party: usize,
    input: NodeInput<'_>,
    cols: &Partition,
    opts: &AsynOptions,
    variant: SecureAlgo,
    u0: Mat,
    v0: Mat,
    audit: Option<&AuditLog>,
    ctl: &RunControl,
) -> AsynClientOutput {
    let (m_rows, _) = input.dims();
    let fro_sq = input.fro_sq();
    let m_col = input.col_block(cols.range(party));
    client_body(comm, party, &m_col, m_rows, fro_sq, opts, variant, u0, v0, audit, ctl)
}

/// Protocol body over the client's resident column block.
#[allow(clippy::too_many_arguments)]
fn client_body<C: Communicator>(
    mut comm: C,
    party: usize,
    m_col: &Matrix,
    m_rows: usize,
    m_fro_sq: f64,
    opts: &AsynOptions,
    variant: SecureAlgo,
    u0: Mat,
    v0: Mat,
    audit: Option<&AuditLog>,
    ctl: &RunControl,
) -> AsynClientOutput {
    let server = server_rank(comm.nodes() - 1);
    let sketch_v = variant == SecureAlgo::AsynSsdV;
    let k = opts.rank;
    assert_eq!(m_col.rows(), m_rows, "column block must span all rows");
    let stream = StreamRng::new(opts.seed);
    let m_col_t = m_col.transpose();
    let mut u_local = u0;
    let mut v_block = v0;
    let d1 = if opts.d1 > 0 {
        opts.d1.min(m_rows)
    } else {
        ((m_rows / 10).max(2 * k)).min(m_rows)
    };

    let mut clock = 0.0f64;
    let mut stats = CommStats::default();
    let mut samples: Vec<(f64, f64, usize)> = Vec::new();
    let mut iters_done = 0usize;
    let mut stop = StopReason::Completed;
    let mut push = vec![0.0f32; u_local.data().len() + 1];

    // initial local residual
    let (_, r0) = rel_error_parts(m_col, &u_local, &v_block);
    samples.push((0.0, r0, 0));

    for round in 0..opts.rounds {
        // communication-free stop poll: asynchronous clients stop
        // independently (there is no collective to desync), between rounds
        if let Some(reason) = ctl.poll_local(round) {
            stop = reason;
            break;
        }
        let tick = Instant::now();
        for li in 0..opts.local_iters {
            let it = round * opts.local_iters + li;
            // U_(r) update (never sketched in async)
            {
                let gram = v_block.gram();
                let cross = match m_col {
                    Matrix::Dense(md) => md.matmul(&v_block),
                    Matrix::Sparse(ms) => ms.spmm(&v_block),
                };
                solvers::update_auto(
                    opts.solver,
                    &mut u_local,
                    &Normal::new(&gram, &cross),
                    &opts.mu,
                    it,
                );
            }
            // V_{J_r:} update (sketched for Asyn-SSD-V)
            if sketch_v && d1 < m_rows {
                let mut rng = stream.for_node(party, 0xC33E + it as u64);
                let sk = SketchMatrix::generate(opts.sketch, m_rows, d1, &mut rng);
                let a = sk.mul_right(&m_col_t);
                let b = sk.mul_rows_tn(&u_local, 0);
                let (gram, cross) = solvers::normal_from(&a, &b);
                solvers::update_auto(
                    opts.solver,
                    &mut v_block,
                    &Normal::new(&gram, &cross),
                    &opts.mu,
                    it,
                );
            } else {
                let gram = u_local.gram();
                let cross = match &m_col_t {
                    Matrix::Dense(md) => md.matmul(&u_local),
                    Matrix::Sparse(ms) => ms.spmm(&u_local),
                };
                solvers::update_auto(
                    opts.solver,
                    &mut v_block,
                    &Normal::new(&gram, &cross),
                    &opts.mu,
                    it,
                );
            }
            iters_done += 1;
        }
        let dt = tick.elapsed().as_secs_f64();
        clock += dt;
        stats.compute_time += dt;

        // push U_(r) + the latest residual fraction (the server's
        // convergence aggregate), receive latest server U (Alg. 7 l. 8–9);
        // the push buffer is reused across rounds (prefix overwritten)
        let u_len = u_local.data().len();
        let frac = samples.last().map_or(f64::NAN, |s| s.1 / m_fro_sq);
        push[..u_len].copy_from_slice(u_local.data());
        push[u_len] = frac as f32;
        if let Some(a) = audit {
            a.record(party, "asyn/u-push", &push);
        }
        let bytes = push.len() * 4;
        comm.send(server, round as u64, clock, &push)
            .unwrap_or_else(|e| panic!("client {party}: push failed: {e}"));
        let reply = comm
            .recv_from(server)
            .unwrap_or_else(|e| panic!("client {party}: server hung up: {e}"));
        debug_assert_eq!(reply.payload.len(), u_len + 1);
        u_local.data_mut().copy_from_slice(&reply.payload[..u_len]);
        let server_stop = reply.payload.get(u_len).is_some_and(|&f| f > 0.5);
        let wire = 2.0 * opts.comm.p2p_time(bytes);
        clock += wire;
        stats.comm_time += wire;
        stats.bytes_sent += bytes;
        stats.bytes_received += bytes;
        stats.messages += 2;

        // out-of-band residual sample (not timed)
        let (_, resid) = rel_error_parts(m_col, &u_local, &v_block);
        samples.push((clock, resid, iters_done));

        if server_stop {
            // the server saw the global error cross the target (or the
            // token cancelled); finish this round and leave
            stop = if ctl.token.is_cancelled() {
                StopReason::Cancelled
            } else {
                StopReason::TargetReached
            };
            break;
        }
    }
    let _ = comm.send(server, TAG_SHUTDOWN, clock, &[]);
    AsynClientOutput { v_block, samples, stats, final_clock: clock, stop }
}

/// Merge per-client `(clock, residual², iters)` logs: at every event time,
/// the global error is √(Σ_r latest-residual_r / ‖M‖²).
fn merge_traces(outs: &[AsynClientOutput], m_fro_sq: f64) -> Vec<TracePoint> {
    let n = outs.len();
    // event queue over all samples, time-ordered
    let mut events: Vec<(f64, usize, f64, usize)> = Vec::new(); // (time, client, resid, iters)
    for (r, o) in outs.iter().enumerate() {
        for &(t, resid, iters) in &o.samples {
            events.push((t, r, resid, iters));
        }
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut latest = vec![f64::NAN; n];
    let mut iters = vec![0usize; n];
    let mut trace = Vec::with_capacity(events.len());
    for (t, r, resid, it) in events {
        latest[r] = resid;
        iters[r] = it;
        if latest.iter().any(|v| v.is_nan()) {
            continue; // wait until every client reported once
        }
        let err = (latest.iter().sum::<f64>() / m_fro_sq).max(0.0).sqrt();
        trace.push(TracePoint {
            iteration: iters.iter().sum(),
            sim_time: t,
            rel_error: err,
        });
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{imbalanced_partition, uniform_partition};
    use crate::rng::Pcg64;

    fn low_rank(m: usize, n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed as u128, 0);
        let u = Mat::rand_uniform(m, k, 1.0, &mut rng);
        let v = Mat::rand_uniform(n, k, 1.0, &mut rng);
        Matrix::Dense(u.matmul_nt(&v))
    }

    /// Builder-backed shorthand (the deprecated free function is gone).
    fn run_asyn(
        m: &Matrix,
        cols: &Partition,
        opts: &AsynOptions,
        variant: SecureAlgo,
        audit: Option<&AuditLog>,
    ) -> SecureRun {
        let mut b = crate::nmf::job::Job::builder()
            .algorithm(crate::nmf::job::Algo::Asyn(opts.clone(), variant))
            .data(crate::nmf::job::DataSource::Full(m))
            .secure_partition(cols.clone());
        if let Some(a) = audit {
            b = b.audit(a);
        }
        b.run()
            .unwrap_or_else(|e| panic!("{} job failed: {e}", variant.name()))
            .into_secure_run()
    }

    fn opts(nodes: usize) -> AsynOptions {
        AsynOptions {
            nodes,
            rank: 3,
            rounds: 15,
            local_iters: 3,
            d1: 20,
            ..Default::default()
        }
    }

    #[test]
    fn asyn_sd_converges() {
        let m = low_rank(60, 48, 3, 501);
        let cols = uniform_partition(48, 3);
        let run = run_asyn(&m, &cols, &opts(3), SecureAlgo::AsynSd, None);
        let first = run.trace.first().unwrap().rel_error;
        assert!(run.final_error() < 0.7 * first, "{} -> {}", first, run.final_error());
        assert!(run.u.is_nonnegative());
    }

    #[test]
    fn asyn_ssd_v_converges() {
        let m = low_rank(60, 48, 3, 503);
        let cols = uniform_partition(48, 3);
        let run = run_asyn(&m, &cols, &opts(3), SecureAlgo::AsynSsdV, None);
        let first = run.trace.first().unwrap().rel_error;
        assert!(run.final_error() < 0.75 * first, "{} -> {}", first, run.final_error());
    }

    #[test]
    fn trace_times_are_monotone() {
        let m = low_rank(40, 30, 3, 505);
        let cols = uniform_partition(30, 2);
        let run = run_asyn(&m, &cols, &opts(2), SecureAlgo::AsynSd, None);
        for w in run.trace.windows(2) {
            assert!(w[1].sim_time >= w[0].sim_time);
        }
    }

    #[test]
    fn no_barrier_under_imbalance() {
        // async clients never stall: each client's clock is its own
        // compute + p2p time; the light clients complete far more rounds
        // per unit virtual time than the heavy one.
        let m = low_rank(60, 60, 3, 507);
        let cols = imbalanced_partition(60, 3, 0.5);
        let run = run_asyn(&m, &cols, &opts(3), SecureAlgo::AsynSsdV, None);
        for s in &run.stats {
            assert_eq!(s.stall_time, 0.0, "async must not stall");
        }
    }

    #[test]
    fn audit_records_pushes() {
        let m = low_rank(30, 20, 3, 509);
        let cols = uniform_partition(20, 2);
        let audit = AuditLog::new();
        let mut o = opts(2);
        o.rounds = 3;
        let _ = run_asyn(&m, &cols, &o, SecureAlgo::AsynSd, Some(&audit));
        assert_eq!(audit.len(), 2 * 3, "one push per round per client");
    }
}
