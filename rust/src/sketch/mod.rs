//! Sketch operators (the paper's Sec. 3.4 plus the future-work extensions).
//!
//! A sketch is a random `n×d` matrix `S` with `E[S Sᵀ] = I` and bounded
//! variance (Assumption 1), so the sketched NLS gradient is an unbiased
//! estimator of the true gradient (Eq. 16). Four generators:
//!
//! * [`SketchKind::Gaussian`]   — i.i.d. N(0, 1/d) entries. O(m·n·d) apply;
//!   densest information per column (faster per-iteration convergence).
//! * [`SketchKind::Subsample`]  — `√(n/d) ·` d distinct canonical basis
//!   columns. O(m·d) apply, sparsity-preserving (paper's default for RCV1 /
//!   DBLP).
//! * [`SketchKind::CountSketch`] — one ±1 per input row, hashed bucket
//!   (Clarkson–Woodruff). O(nnz) apply.
//! * [`SketchKind::Srht`]       — subsampled randomized Hadamard transform
//!   `√(n/d) · D·H·P` (Ailon–Chazelle). O(m·n·log n) apply via FWHT.
//!
//! Every node regenerates the *same* `S` from the shared seed
//! ([`crate::rng::StreamRng`]), so `S` itself is never communicated —
//! the paper's key communication trick (Sec. 3.3).

use crate::linalg::{gemm_nn, gemm_tn, Csr, Mat};
use crate::rng::Pcg64;

/// Which random matrix family to use (paper Sec. 3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    Gaussian,
    Subsample,
    CountSketch,
    Srht,
}

impl SketchKind {
    pub fn name(&self) -> &'static str {
        match self {
            SketchKind::Gaussian => "gaussian",
            SketchKind::Subsample => "subsample",
            SketchKind::CountSketch => "countsketch",
            SketchKind::Srht => "srht",
        }
    }

    /// Stable on-disk code (compressed shard manifests, `data::compress`).
    pub fn code(self) -> u8 {
        match self {
            SketchKind::Gaussian => 0,
            SketchKind::Subsample => 1,
            SketchKind::CountSketch => 2,
            SketchKind::Srht => 3,
        }
    }

    /// Inverse of [`SketchKind::code`].
    pub fn from_code(c: u8) -> crate::error::Result<SketchKind> {
        match c {
            0 => Ok(SketchKind::Gaussian),
            1 => Ok(SketchKind::Subsample),
            2 => Ok(SketchKind::CountSketch),
            3 => Ok(SketchKind::Srht),
            other => crate::bail!("unknown sketch kind code {other}"),
        }
    }
}

impl std::str::FromStr for SketchKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "g" => Ok(SketchKind::Gaussian),
            "subsample" | "s" | "subsampling" => Ok(SketchKind::Subsample),
            "countsketch" | "cs" => Ok(SketchKind::CountSketch),
            "srht" => Ok(SketchKind::Srht),
            other => Err(format!("unknown sketch kind: {other}")),
        }
    }
}

/// A realised sketch matrix `S ∈ R^{n×d}` for one iteration, stored in the
/// cheapest implicit representation for its family.
#[derive(Debug, Clone)]
pub struct SketchMatrix {
    n: usize,
    d: usize,
    repr: Repr,
}

#[derive(Debug, Clone)]
enum Repr {
    /// Fully materialised n×d (Gaussian).
    Dense(Mat),
    /// Column p of S is `scale · e_{idx[p]}`.
    Subsample { idx: Vec<usize>, scale: f32 },
    /// Row i of S is `sign[i] · e_{bucket[i]}ᵀ` (of length d).
    CountSketch { bucket: Vec<usize>, sign: Vec<f32> },
    /// `S = scale · D·H·P`: row i, col p is `scale · sign[i] · H[i, sel[p]]`
    /// with `H` the 2^q Walsh–Hadamard matrix (n padded up to 2^q).
    Srht { sign: Vec<f32>, sel: Vec<usize>, scale: f32, padded: usize },
}

impl SketchMatrix {
    /// Generate `S ∈ R^{n×d}` of the given family from `rng`.
    /// Deterministic in `rng`: identical across nodes sharing the seed.
    pub fn generate(kind: SketchKind, n: usize, d: usize, rng: &mut Pcg64) -> Self {
        assert!(d > 0 && d <= n, "sketch size d={d} must be in 1..={n}");
        let repr = match kind {
            SketchKind::Gaussian => {
                let sigma = 1.0 / (d as f32).sqrt();
                let mut m = Mat::zeros(n, d);
                crate::rng::Gaussian::fill_from(rng, m.data_mut(), sigma);
                Repr::Dense(m)
            }
            SketchKind::Subsample => {
                let idx = rng.sample_without_replacement(n, d);
                Repr::Subsample { idx, scale: (n as f32 / d as f32).sqrt() }
            }
            SketchKind::CountSketch => {
                let bucket: Vec<usize> = (0..n).map(|_| rng.below(d)).collect();
                let sign: Vec<f32> = (0..n).map(|_| rng.rademacher()).collect();
                Repr::CountSketch { bucket, sign }
            }
            SketchKind::Srht => {
                let padded = n.next_power_of_two();
                let sign: Vec<f32> = (0..n).map(|_| rng.rademacher()).collect();
                let sel = rng.sample_without_replacement(padded, d);
                // E[SSᵀ]=I scaling for a row-sampled normalized Hadamard:
                // H/√padded is orthonormal; sampling d of `padded` columns
                // needs √(padded/d) on top.
                let scale = (padded as f32).sqrt().recip() * (padded as f32 / d as f32).sqrt();
                Repr::Srht { sign, sel, scale, padded }
            }
        };
        SketchMatrix { n, d, repr }
    }

    pub fn kind(&self) -> SketchKind {
        match self.repr {
            Repr::Dense(_) => SketchKind::Gaussian,
            Repr::Subsample { .. } => SketchKind::Subsample,
            Repr::CountSketch { .. } => SketchKind::CountSketch,
            Repr::Srht { .. } => SketchKind::Srht,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Resident bytes of the realised representation — what a rank pays to
    /// keep this sketch in RAM (Gaussian materialises `n×d` floats; the
    /// structured families are `O(n)` or `O(d)`). Feeds the compressed
    /// data plane's residency accounting ([`crate::data::compress`]).
    pub fn resident_bytes(&self) -> usize {
        match &self.repr {
            Repr::Dense(m) => m.data().len() * 4,
            Repr::Subsample { idx, .. } => idx.len() * 8,
            Repr::CountSketch { bucket, sign } => bucket.len() * 8 + sign.len() * 4,
            Repr::Srht { sign, sel, .. } => sign.len() * 4 + sel.len() * 8,
        }
    }

    /// `A · S` for dense `A (m×n)` → `m×d`.
    pub fn mul_right_dense(&self, a: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.mul_right_dense_into(a, &mut out);
        out
    }

    /// [`Self::mul_right_dense`] into a caller-owned buffer, resized to
    /// `m×d`. The Subsample path touches no allocator at all; Gaussian and
    /// CountSketch write straight into `out` (the parallel GEMM may use
    /// internal thread-local partials); Srht keeps its `padded`-length FWHT
    /// scratch per call. Results are bitwise identical to the allocating
    /// version for every family.
    pub fn mul_right_dense_into(&self, a: &Mat, out: &mut Mat) {
        assert_eq!(a.cols(), self.n, "A cols != sketch n");
        out.resize_to(a.rows(), self.d);
        match &self.repr {
            Repr::Dense(s) => gemm_nn(a, s, out),
            Repr::Subsample { idx, scale } => {
                let scale = *scale;
                for i in 0..a.rows() {
                    let arow = a.row(i);
                    let orow = out.row_mut(i);
                    for (p, &j) in idx.iter().enumerate() {
                        orow[p] = arow[j] * scale;
                    }
                }
            }
            Repr::CountSketch { bucket, sign } => {
                out.data_mut().fill(0.0);
                for i in 0..a.rows() {
                    let arow = a.row(i);
                    let orow = out.row_mut(i);
                    for (j, &v) in arow.iter().enumerate() {
                        orow[bucket[j]] += sign[j] * v;
                    }
                }
            }
            Repr::Srht { sign, sel, scale, padded } => {
                let mut buf = vec![0.0f32; *padded];
                for i in 0..a.rows() {
                    buf.fill(0.0);
                    for (j, &v) in a.row(i).iter().enumerate() {
                        buf[j] = sign[j] * v;
                    }
                    fwht(&mut buf);
                    let orow = out.row_mut(i);
                    for (p, &s) in sel.iter().enumerate() {
                        orow[p] = buf[s] * scale;
                    }
                }
            }
        }
    }

    /// `A · S` for sparse `A (m×n)` → dense `m×d`.
    pub fn mul_right_sparse(&self, a: &Csr) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.mul_right_sparse_into(a, &mut out);
        out
    }

    /// [`Self::mul_right_sparse`] into a caller-owned buffer, resized to
    /// `m×d`. Subsample keeps a `cols`-length position map per call (the
    /// inverse of the column-index list — same trade as
    /// [`Csr::gather_cols_dense`]); the other families write straight into
    /// `out`.
    pub fn mul_right_sparse_into(&self, a: &Csr, out: &mut Mat) {
        assert_eq!(a.cols(), self.n, "A cols != sketch n");
        out.resize_to(a.rows(), self.d);
        match &self.repr {
            Repr::Dense(s) => a.spmm_into(s, out),
            Repr::Subsample { idx, scale } => {
                let scale = *scale;
                let mut pos = vec![usize::MAX; self.n];
                for (p, &j) in idx.iter().enumerate() {
                    pos[j] = p;
                }
                out.data_mut().fill(0.0);
                for i in 0..a.rows() {
                    let orow = out.row_mut(i);
                    for (j, v) in a.row_iter(i) {
                        let p = pos[j];
                        if p != usize::MAX {
                            orow[p] = v * scale;
                        }
                    }
                }
            }
            Repr::CountSketch { bucket, sign } => {
                out.data_mut().fill(0.0);
                for i in 0..a.rows() {
                    let orow = out.row_mut(i);
                    for (j, v) in a.row_iter(i) {
                        orow[bucket[j]] += sign[j] * v;
                    }
                }
            }
            Repr::Srht { sign, sel, scale, .. } => {
                // O(nnz · d): directly H[j, sel[p]] = (-1)^{popcount(j & sel[p])}
                out.data_mut().fill(0.0);
                for i in 0..a.rows() {
                    let orow = out.row_mut(i);
                    for (j, v) in a.row_iter(i) {
                        let sv = sign[j] * v * scale;
                        for (p, &s) in sel.iter().enumerate() {
                            let h = if ((j & s).count_ones() & 1) == 0 { 1.0 } else { -1.0 };
                            orow[p] += sv * h;
                        }
                    }
                }
            }
        }
    }

    /// `A · S` dispatching on the matrix kind.
    pub fn mul_right(&self, a: &crate::linalg::Matrix) -> Mat {
        match a {
            crate::linalg::Matrix::Dense(m) => self.mul_right_dense(m),
            crate::linalg::Matrix::Sparse(m) => self.mul_right_sparse(m),
        }
    }

    /// [`Self::mul_right`] into a caller-owned buffer — the zero-steady-state
    /// entry point of the overlapped pipeline ([`crate::algos::dsanls`]).
    pub fn mul_right_into(&self, a: &crate::linalg::Matrix, out: &mut Mat) {
        match a {
            crate::linalg::Matrix::Dense(m) => self.mul_right_dense_into(m, out),
            crate::linalg::Matrix::Sparse(m) => self.mul_right_sparse_into(m, out),
        }
    }

    /// `Vᵀ_block · S_block` where `v_block` holds rows
    /// `row_offset .. row_offset + v_block.rows()` of the virtual `n×k`
    /// matrix `V` — the per-node summand `B̄_r = (V_{J_r:})ᵀ S_{J_r:}` of
    /// Eq. 11. Result is `k×d`.
    pub fn mul_rows_tn(&self, v_block: &Mat, row_offset: usize) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.mul_rows_tn_into(v_block, row_offset, &mut out);
        out
    }

    /// [`Self::mul_rows_tn`] into a caller-owned buffer, resized to `k×d`.
    /// Subsample / CountSketch / Srht touch no allocator; the Gaussian path
    /// still materialises the `rows×d` sketch row block for the GEMM.
    pub fn mul_rows_tn_into(&self, v_block: &Mat, row_offset: usize, out: &mut Mat) {
        let rows = v_block.rows();
        let k = v_block.cols();
        assert!(row_offset + rows <= self.n, "row block outside sketch");
        out.resize_to(k, self.d);
        out.data_mut().fill(0.0);
        match &self.repr {
            Repr::Dense(s) => {
                let s_block = s.row_block(row_offset..row_offset + rows);
                gemm_tn(v_block, &s_block, out);
            }
            Repr::Subsample { idx, scale } => {
                for (p, &g) in idx.iter().enumerate() {
                    if g >= row_offset && g < row_offset + rows {
                        let vrow = v_block.row(g - row_offset);
                        for l in 0..k {
                            out.set(l, p, vrow[l] * scale);
                        }
                    }
                }
            }
            Repr::CountSketch { bucket, sign } => {
                for j in 0..rows {
                    let g = row_offset + j;
                    let (b, s) = (bucket[g], sign[g]);
                    let vrow = v_block.row(j);
                    for l in 0..k {
                        let cur = out.get(l, b);
                        out.set(l, b, cur + s * vrow[l]);
                    }
                }
            }
            Repr::Srht { sign, sel, scale, .. } => {
                for j in 0..rows {
                    let g = row_offset + j;
                    let sv = sign[g] * scale;
                    let vrow = v_block.row(j);
                    for (p, &s) in sel.iter().enumerate() {
                        let h = if ((g & s).count_ones() & 1) == 0 { sv } else { -sv };
                        for l in 0..k {
                            let cur = out.get(l, p);
                            out.set(l, p, cur + h * vrow[l]);
                        }
                    }
                }
            }
        }
    }

    /// Materialise `S` as a dense `n×d` matrix (tests + the Theorem-3
    /// sketch-inversion attack in [`crate::secure::privacy`]).
    pub fn to_dense(&self) -> Mat {
        match &self.repr {
            Repr::Dense(s) => s.clone(),
            Repr::Subsample { idx, scale } => {
                let mut m = Mat::zeros(self.n, self.d);
                for (p, &i) in idx.iter().enumerate() {
                    m.set(i, p, *scale);
                }
                m
            }
            Repr::CountSketch { bucket, sign } => {
                let mut m = Mat::zeros(self.n, self.d);
                for i in 0..self.n {
                    m.set(i, bucket[i], sign[i]);
                }
                m
            }
            Repr::Srht { sign, sel, scale, .. } => {
                let mut m = Mat::zeros(self.n, self.d);
                for i in 0..self.n {
                    for (p, &s) in sel.iter().enumerate() {
                        let h = if ((i & s).count_ones() & 1) == 0 { 1.0 } else { -1.0 };
                        m.set(i, p, sign[i] * h * scale);
                    }
                }
                m
            }
        }
    }

    /// FLOP estimate for `A·S` with `A: m×n` (`nnz` stored values) — used by
    /// the coordinator's cost model and the complexity tests.
    pub fn apply_cost(&self, m: usize, nnz: usize) -> usize {
        match &self.repr {
            Repr::Dense(_) => m * self.n * self.d,
            Repr::Subsample { .. } => nnz.min(m * self.d) + m * self.d,
            Repr::CountSketch { .. } => nnz,
            Repr::Srht { padded, .. } => m * padded * padded.trailing_zeros() as usize,
        }
    }
}

/// In-place fast Walsh–Hadamard transform (length must be a power of two).
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        for chunk in data.chunks_mut(h * 2) {
            let (a, b) = chunk.split_at_mut(h);
            for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                let (u, v) = (*x, *y);
                *x = u + v;
                *y = u - v;
            }
        }
        h *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Role, StreamRng};

    fn all_kinds() -> [SketchKind; 4] {
        [SketchKind::Gaussian, SketchKind::Subsample, SketchKind::CountSketch, SketchKind::Srht]
    }

    #[test]
    fn deterministic_across_nodes() {
        for kind in all_kinds() {
            let mut r1 = StreamRng::new(99).for_iteration(3, Role::SketchU);
            let mut r2 = StreamRng::new(99).for_iteration(3, Role::SketchU);
            let s1 = SketchMatrix::generate(kind, 32, 8, &mut r1);
            let s2 = SketchMatrix::generate(kind, 32, 8, &mut r2);
            assert_eq!(s1.to_dense().data(), s2.to_dense().data(), "{kind:?}");
        }
    }

    #[test]
    fn mul_right_dense_matches_materialised() {
        let mut rng = Pcg64::new(5, 1);
        let a = Mat::rand_uniform(10, 32, 1.0, &mut rng);
        for kind in all_kinds() {
            let mut r = Pcg64::new(7, 2);
            let s = SketchMatrix::generate(kind, 32, 8, &mut r);
            let got = s.mul_right_dense(&a);
            let expect = a.matmul(&s.to_dense());
            for (x, y) in got.data().iter().zip(expect.data().iter()) {
                assert!((x - y).abs() < 1e-3, "{kind:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn mul_right_sparse_matches_dense_path() {
        let mut rng = Pcg64::new(6, 1);
        let dense = Mat::from_fn(12, 32, |i, j| {
            if (i * 31 + j * 7) % 5 == 0 {
                ((i + j) as f32).sin().abs()
            } else {
                0.0
            }
        });
        let _ = &mut rng;
        let sparse = Csr::from_dense(&dense, 0.0);
        for kind in all_kinds() {
            let mut r = Pcg64::new(8, 3);
            let s = SketchMatrix::generate(kind, 32, 8, &mut r);
            let got = s.mul_right_sparse(&sparse);
            let expect = s.mul_right_dense(&dense);
            for (x, y) in got.data().iter().zip(expect.data().iter()) {
                assert!((x - y).abs() < 1e-3, "{kind:?}");
            }
        }
    }

    #[test]
    fn mul_rows_tn_matches_block_product() {
        // Σ_r (V_{J_r:})ᵀ S_{J_r:} must equal Vᵀ S  (Eq. 11)
        let mut rng = Pcg64::new(9, 1);
        let v = Mat::rand_uniform(32, 5, 1.0, &mut rng);
        for kind in all_kinds() {
            let mut r = Pcg64::new(11, 4);
            let s = SketchMatrix::generate(kind, 32, 8, &mut r);
            let expect = v.transpose().matmul(&s.to_dense());
            // two blocks: rows 0..13 and 13..32
            let b1 = s.mul_rows_tn(&v.row_block(0..13), 0);
            let mut b2 = s.mul_rows_tn(&v.row_block(13..32), 13);
            b2.axpy(1.0, &b1);
            for (x, y) in b2.data().iter().zip(expect.data().iter()) {
                assert!((x - y).abs() < 1e-3, "{kind:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn expectation_identity() {
        // E[S Sᵀ] ≈ I (Assumption 1): average over many draws.
        let n = 16;
        let d = 8;
        for kind in all_kinds() {
            let trials = 600;
            let mut acc = Mat::zeros(n, n);
            for t in 0..trials {
                let mut r = Pcg64::new(1000 + t as u128, kind as u128);
                let s = SketchMatrix::generate(kind, n, d, &mut r).to_dense();
                let sst = s.matmul_nt(&s);
                acc.axpy(1.0 / trials as f32, &sst);
            }
            for i in 0..n {
                for j in 0..n {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    let got = acc.get(i, j);
                    assert!(
                        (got - expect).abs() < 0.25,
                        "{kind:?} E[SSᵀ][{i},{j}] = {got}"
                    );
                }
            }
        }
    }

    #[test]
    fn fwht_orthogonality() {
        // FWHT applied twice = n * identity
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let orig = v.clone();
        fwht(&mut v);
        fwht(&mut v);
        for (a, b) in v.iter().zip(orig.iter()) {
            assert!((a / 8.0 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn into_variants_match_allocating_paths_bitwise() {
        // the overlapped pipeline reuses buffers across iterations, so the
        // _into paths must reproduce the allocating paths bit-for-bit even
        // when `out` starts with stale shape and contents
        let mut rng = Pcg64::new(5, 9);
        let a = Mat::rand_uniform(10, 32, 1.0, &mut rng);
        let sparse = Csr::from_dense(
            &Mat::from_fn(10, 32, |i, j| if (i * 13 + j * 5) % 3 == 0 { a.get(i, j) } else { 0.0 }),
            0.0,
        );
        let v = Mat::rand_uniform(19, 5, 1.0, &mut rng);
        for kind in all_kinds() {
            let mut r = Pcg64::new(21, 6);
            let s = SketchMatrix::generate(kind, 32, 8, &mut r);
            let mut out = Mat::from_vec(1, 3, vec![7.0, 8.0, 9.0]); // stale
            s.mul_right_dense_into(&a, &mut out);
            assert_eq!(out.data(), s.mul_right_dense(&a).data(), "{kind:?} dense");
            s.mul_right_sparse_into(&sparse, &mut out);
            assert_eq!(out.data(), s.mul_right_sparse(&sparse).data(), "{kind:?} sparse");
            s.mul_rows_tn_into(&v, 13, &mut out);
            assert_eq!(out.data(), s.mul_rows_tn(&v, 13).data(), "{kind:?} rows_tn");
        }
    }

    #[test]
    fn subsample_preserves_sparsity_cost() {
        let mut r = Pcg64::new(3, 3);
        let s = SketchMatrix::generate(SketchKind::Subsample, 1000, 10, &mut r);
        // O(m·d) apply cost, far below gaussian's O(m·n·d)
        assert!(s.apply_cost(100, 5000) < 100 * 1000 * 10 / 50);
    }
}
