//! Local-solver backend abstraction: the same proximal-CD update step,
//! served either by the pure-rust solver (any shape) or by the AOT-compiled
//! JAX/Pallas artifact through PJRT (fixed shapes).
//!
//! The hot loop asks the backend to solve
//! `min_{U≥0} ‖A − U·B‖² + μ‖U − Uᵗ‖²` given the sketched operands
//! `A (rows×d)`, `B (k×d)` — the per-node inner step of DSANLS (Alg. 2
//! line 8). The PJRT backend proves the three layers compose: the update
//! executed from rust is numerically the Pallas kernel's output.

use super::{ExecInput, PjrtRuntime};
use crate::error::Result;
use crate::linalg::Mat;
use crate::solvers::{self, Normal};

/// A backend that can perform the proximal-CD factor update in place.
///
/// Not `Send`/`Sync`: the PJRT client wraps thread-local FFI handles, so
/// each simulated node constructs its own backend inside its thread (PJRT
/// compilation is cached per artifact by XLA, so this is cheap after the
/// first node).
pub trait LocalSolver {
    /// Update `u` for `min ‖a − u·b‖² + μ‖u − uᵗ‖²` (one CD sweep).
    fn cd_update(&self, u: &mut Mat, a: &Mat, b: &Mat, mu: f32) -> Result<()>;

    /// Human-readable backend name.
    fn name(&self) -> &'static str;
}

/// Pure-rust backend (shape-generic, the default).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl LocalSolver for NativeBackend {
    fn cd_update(&self, u: &mut Mat, a: &Mat, b: &Mat, mu: f32) -> Result<()> {
        let (gram, cross) = solvers::normal_from(a, b);
        solvers::cd::proximal_cd_update(u, &Normal::new(&gram, &cross), mu);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend: dispatches to the compiled `cd_update` artifact whose
/// shape matches; errors for unsupported shapes (callers fall back to
/// native — see [`HybridBackend`]).
pub struct PjrtBackend {
    runtime: PjrtRuntime,
}

impl PjrtBackend {
    pub fn new(runtime: PjrtRuntime) -> Self {
        PjrtBackend { runtime }
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }

    /// The artifact name for a given shape, per the AOT manifest convention.
    fn artifact_for(&self, rows: usize, k: usize, d: usize) -> Option<String> {
        let name = format!("cd_update_r{rows}_k{k}_d{d}");
        if self.runtime.spec(&name).is_some() {
            Some(name)
        } else {
            None
        }
    }

    /// True iff a compiled artifact exists for this shape.
    pub fn supports(&self, rows: usize, k: usize, d: usize) -> bool {
        self.artifact_for(rows, k, d).is_some()
    }
}

impl LocalSolver for PjrtBackend {
    fn cd_update(&self, u: &mut Mat, a: &Mat, b: &Mat, mu: f32) -> Result<()> {
        let (rows, k) = (u.rows(), u.cols());
        let d = a.cols();
        let Some(name) = self.artifact_for(rows, k, d) else {
            crate::bail!("no compiled artifact for shape r{rows}_k{k}_d{d}");
        };
        let outs = self.runtime.execute(
            &name,
            &[ExecInput::Matrix(a), ExecInput::Matrix(b), ExecInput::Matrix(u), ExecInput::Scalar(mu)],
        )?;
        let out = outs.into_iter().next().ok_or_else(|| crate::err!("empty output"))?;
        if (out.rows(), out.cols()) != (rows, k) {
            crate::bail!("artifact returned {}x{}, expected {rows}x{k}", out.rows(), out.cols());
        }
        *u = out;
        Ok(())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// PJRT where a matching artifact exists, native otherwise.
pub struct HybridBackend {
    pjrt: Option<PjrtBackend>,
    native: NativeBackend,
}

impl HybridBackend {
    /// Try to load the PJRT runtime; degrade to native-only when artifacts
    /// are absent (reported on stderr, not fatal — python is build-time
    /// only, and the offline build always takes this path).
    pub fn auto() -> Self {
        let pjrt = PjrtRuntime::load(&PjrtRuntime::default_dir())
            .map(PjrtBackend::new)
            .map_err(|e| eprintln!("PJRT backend unavailable: {e}"))
            .ok();
        HybridBackend { pjrt, native: NativeBackend }
    }

    pub fn has_pjrt(&self) -> bool {
        self.pjrt.is_some()
    }
}

impl LocalSolver for HybridBackend {
    fn cd_update(&self, u: &mut Mat, a: &Mat, b: &Mat, mu: f32) -> Result<()> {
        if let Some(p) = &self.pjrt {
            if p.supports(u.rows(), u.cols(), a.cols()) {
                return p.cd_update(u, a, b, mu);
            }
        }
        self.native.cd_update(u, a, b, mu)
    }

    fn name(&self) -> &'static str {
        if self.pjrt.is_some() {
            "hybrid(pjrt+native)"
        } else {
            "hybrid(native)"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn native_backend_matches_direct_solver() {
        let mut rng = Pcg64::new(900, 0);
        let a = Mat::rand_uniform(12, 8, 1.0, &mut rng);
        let b = Mat::rand_uniform(4, 8, 1.0, &mut rng);
        let u0 = Mat::rand_uniform(12, 4, 1.0, &mut rng);

        let mut u1 = u0.clone();
        NativeBackend.cd_update(&mut u1, &a, &b, 2.0).unwrap();

        let mut u2 = u0;
        let (gram, cross) = solvers::normal_from(&a, &b);
        solvers::cd::proximal_cd_update(&mut u2, &Normal::new(&gram, &cross), 2.0);

        assert_eq!(u1.data(), u2.data());
    }
}
