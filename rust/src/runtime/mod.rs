//! PJRT runtime: load the AOT-compiled L1/L2 artifacts and execute them
//! from the rust hot path.
//!
//! Build-time python (`make artifacts`) lowers the JAX/Pallas update step to
//! **HLO text** under `artifacts/` plus a `manifest.json` describing each
//! entry point's shapes. With the `pjrt` cargo feature enabled (requires a
//! vendored `xla` crate), this module compiles those artifacts once on a
//! PJRT CPU client and exposes typed `execute` wrappers. The default
//! (offline) build ships a stub whose [`PjrtRuntime::load`] returns an
//! error, so every caller — the CLI `artifacts` command, the
//! [`backend::HybridBackend`], the round-trip tests — degrades cleanly to
//! the native rust solvers.
//!
//! HLO *text* is the interchange format — the image's xla_extension 0.5.1
//! rejects jax≥0.5 serialized protos (64-bit instruction ids); the text
//! parser reassigns ids.
//!
//! The [`backend::LocalSolver`] trait lets the coordinator pick between the
//! shape-generic pure-rust solver and the fixed-shape compiled artifact;
//! integration tests assert the two agree to float tolerance.

pub mod backend;

pub use backend::{HybridBackend, LocalSolver, NativeBackend, PjrtBackend};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::linalg::Mat;
use crate::metrics::JsonValue;

/// One artifact entry from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Named integer attributes (e.g. rows/k/d for the CD update).
    pub dims: HashMap<String, usize>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let json = JsonValue::parse(&text).map_err(|e| crate::err!("manifest parse: {e}"))?;
        let entries_json = json
            .get("entries")
            .and_then(|v| if let JsonValue::Array(a) = v { Some(a) } else { None })
            .ok_or_else(|| crate::err!("manifest missing entries[]"))?;
        let mut entries = Vec::new();
        for e in entries_json {
            let name = e
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| crate::err!("entry missing name"))?
                .to_string();
            let file = e
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| crate::err!("entry missing file"))?
                .to_string();
            let mut dims = HashMap::new();
            if let Some(JsonValue::Object(fields)) = e.get("dims") {
                for (k, v) in fields {
                    if let Some(n) = v.as_f64() {
                        dims.insert(k.clone(), n as usize);
                    }
                }
            }
            entries.push(ArtifactSpec { name, file, dims });
        }
        Ok(Manifest { entries })
    }
}

/// An input to [`PjrtRuntime::execute`].
pub enum ExecInput<'a> {
    Matrix(&'a Mat),
    Scalar(f32),
}

/// Default artifact directory: `$DSANLS_ARTIFACTS` or `./artifacts`.
fn artifact_dir() -> PathBuf {
    std::env::var("DSANLS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

// ---------------------------------------------------------------------------
// Real implementation (requires the vendored `xla` crate)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;

    /// A compiled PJRT runtime holding every artifact executable.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        execs: HashMap<String, xla::PjRtLoadedExecutable>,
        specs: HashMap<String, ArtifactSpec>,
        dir: PathBuf,
    }

    impl std::fmt::Debug for PjrtRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "PjrtRuntime({} artifacts from {:?})", self.execs.len(), self.dir)
        }
    }

    impl PjrtRuntime {
        /// Default artifact directory: `$DSANLS_ARTIFACTS` or `./artifacts`.
        pub fn default_dir() -> PathBuf {
            artifact_dir()
        }

        /// Load and compile every artifact in `dir`.
        pub fn load(dir: &Path) -> Result<PjrtRuntime> {
            let manifest = Manifest::load(dir)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| crate::err!("PJRT cpu client: {e:?}"))?;
            let mut execs = HashMap::new();
            let mut specs = HashMap::new();
            for spec in manifest.entries {
                let path = dir.join(&spec.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| crate::err!("non-utf8 path"))?,
                )
                .map_err(|e| crate::err!("HLO parse {path:?}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| crate::err!("compile {}: {e:?}", spec.name))?;
                execs.insert(spec.name.clone(), exe);
                specs.insert(spec.name.clone(), spec);
            }
            if execs.is_empty() {
                crate::bail!("no artifacts in {dir:?}");
            }
            Ok(PjrtRuntime { client, execs, specs, dir: dir.to_path_buf() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn names(&self) -> Vec<&str> {
            self.specs.keys().map(|s| s.as_str()).collect()
        }

        pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
            self.specs.get(name)
        }

        /// Execute artifact `name` on matrix/scalar inputs; returns the
        /// output matrices (tuple elements, row-major).
        pub fn execute(&self, name: &str, inputs: &[ExecInput<'_>]) -> Result<Vec<Mat>> {
            let exe = self
                .execs
                .get(name)
                .ok_or_else(|| crate::err!("unknown artifact {name}; have {:?}", self.names()))?;
            let mut literals = Vec::with_capacity(inputs.len());
            for inp in inputs {
                literals.push(inp.to_literal()?);
            }
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| crate::err!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| crate::err!("sync {name}: {e:?}"))?;
            // artifacts are lowered with return_tuple=True
            let mut outs = Vec::new();
            let tuple = result.to_tuple().map_err(|e| crate::err!("tuple {name}: {e:?}"))?;
            for lit in tuple {
                let shape = lit.array_shape().map_err(|e| crate::err!("shape: {e:?}"))?;
                let dims = shape.dims();
                let (rows, cols) = match dims.len() {
                    2 => (dims[0] as usize, dims[1] as usize),
                    1 => (1, dims[0] as usize),
                    0 => (1, 1),
                    d => crate::bail!("unsupported output rank {d}"),
                };
                let values = lit.to_vec::<f32>().map_err(|e| crate::err!("to_vec: {e:?}"))?;
                outs.push(Mat::from_vec(rows, cols, values));
            }
            Ok(outs)
        }
    }

    impl ExecInput<'_> {
        pub(super) fn to_literal(&self) -> Result<xla::Literal> {
            match self {
                ExecInput::Matrix(m) => xla::Literal::vec1(m.data())
                    .reshape(&[m.rows() as i64, m.cols() as i64])
                    .map_err(|e| crate::err!("reshape: {e:?}")),
                ExecInput::Scalar(s) => Ok(xla::Literal::from(*s)),
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtRuntime;

// ---------------------------------------------------------------------------
// Offline stub (default build: no `xla` crate in the image)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use super::*;

    /// Stub runtime: keeps the full API surface so callers compile, but
    /// [`PjrtRuntime::load`] always fails and the hybrid backend falls back
    /// to the native rust solvers.
    #[derive(Debug)]
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        /// Default artifact directory: `$DSANLS_ARTIFACTS` or `./artifacts`.
        pub fn default_dir() -> PathBuf {
            artifact_dir()
        }

        /// Always fails in the offline build; enable the `pjrt` feature
        /// (with a vendored `xla` crate) for the real runtime.
        pub fn load(dir: &Path) -> Result<PjrtRuntime> {
            // surface whether artifacts exist so the message is actionable
            let manifest = Manifest::load(dir).map(|m| m.entries.len());
            match manifest {
                Ok(n) => crate::bail!(
                    "built without the `pjrt` feature — {n} artifact(s) present in \
                     {dir:?} but no XLA runtime is compiled in"
                ),
                Err(e) => crate::bail!("built without the `pjrt` feature (and {e})"),
            }
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn spec(&self, _name: &str) -> Option<&ArtifactSpec> {
            None
        }

        /// Unreachable in practice ([`PjrtRuntime::load`] never succeeds).
        pub fn execute(&self, name: &str, _inputs: &[ExecInput<'_>]) -> Result<Vec<Mat>> {
            crate::bail!("pjrt feature disabled; cannot execute {name}")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_impl::PjrtRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    // Full PJRT round-trip tests live in `rust/tests/pjrt_roundtrip.rs`
    // (they need `make artifacts` and the `pjrt` feature). Here: manifest
    // parsing only.

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("dsanls_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries":[{"name":"cd_update","file":"cd.hlo.txt","dims":{"rows":128,"k":16,"d":32}}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].name, "cd_update");
        assert_eq!(m.entries[0].dims["rows"], 128);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_is_error() {
        let dir = std::env::temp_dir().join("dsanls_manifest_none");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("manifest.json")).ok();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_fails_with_actionable_message() {
        let dir = std::env::temp_dir().join("dsanls_stub_load");
        std::fs::create_dir_all(&dir).unwrap();
        let e = PjrtRuntime::load(&dir).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
