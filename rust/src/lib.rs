//! # dsanls — Fast and Secure Distributed Nonnegative Matrix Factorization
//!
//! Reproduction of Qian et al., *"Fast and Secure Distributed Nonnegative
//! Matrix Factorization"*, IEEE TKDE 2020.
//!
//! The crate is the **Layer-3 rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   [`algos::dsanls`] distributed sketched-ANLS algorithm, the
//!   MPI-FAUN-style baselines ([`algos::dist_mu`], [`algos::dist_hals`],
//!   [`algos::dist_anls_bpp`]), and the four secure federated protocols in
//!   [`secure`] (Syn-SD, Syn-SSD, Asyn-SD, Asyn-SSD), all generic over the
//!   pluggable [`transport`] layer — an in-process simulated cluster (the
//!   [`dist`] clock/stall model) or real multi-process TCP workers
//!   (`dsanls launch` / `dsanls worker`). The single front door is the
//!   [`nmf::job::Job`] builder: one composition of algorithm × transport ×
//!   data source, with streaming progress observers. Trained factors get
//!   a production consumer in the [`serve`] subsystem (`dsanls serve` /
//!   `dsanls query`): checkpoint-loaded [`serve::FactorModel`]s answering
//!   batched top-k / reconstruction / fold-in queries over the same wire
//!   framing, hot-swappable to newer checkpoints with zero downtime, and
//!   scaled out behind the [`router`] consistent-hash tier
//!   (`dsanls route`).
//! * **L2 — JAX model** (`python/compile/model.py`) — the sketched update
//!   step as a JAX graph, AOT-lowered to HLO text under `artifacts/`.
//! * **L1 — Pallas kernels** (`python/compile/kernels/`) — proximal
//!   coordinate descent, projected gradient and sketch-apply kernels,
//!   verified against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) so the compiled L1/L2 path can be exercised from the rust
//! hot loop; the pure-rust [`solvers`] are the shape-generic default.
//!
//! Python is **never** on the request path: `make artifacts` runs once at
//! build time, and the `dsanls` binary is self-contained afterwards.

pub mod algos;
pub mod binio;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod error;
pub mod linalg;
pub mod metrics;
pub mod nmf;
pub mod parallel;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod secure;
pub mod serve;
pub mod sketch;
pub mod solvers;
pub mod testkit;
pub mod transport;

/// Crate-wide result alias.
pub type Result<T> = error::Result<T>;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
