//! Iteration-boundary state replication and epoch recovery for elastic
//! runs.
//!
//! The protocol the algorithm runners ([`crate::algos`],
//! [`crate::secure::syn`]) drive:
//!
//! 1. **Boundary commit.** At the start of every iteration `t` each rank
//!    contributes its serialized factor state to an *untimed* all-gather
//!    ([`Elastic::commit`]); every rank then holds the full cluster state
//!    for iteration `t`. Untimed means the replication traffic perturbs
//!    neither the modelled clock nor the byte counters the paper's
//!    communication-volume claims are asserted on.
//! 2. **Fault.** A peer dies mid-iteration; the survivor's next collective
//!    unwinds with a [`PeerLostSignal`] payload, which the runner catches
//!    via [`run_step`] and holds until the iteration boundary.
//! 3. **Recovery.** Survivors call [`Elastic::recover`]: the transport
//!    rebuilds membership ([`Communicator::rebuild`] parks until a
//!    replacement joins), then *all* ranks — survivors and the joiner —
//!    run a two-phase exchange that elects a donor (the lowest rank
//!    holding a commit) and adopts the donor's committed state wholesale.
//!    Everyone, survivor or joiner, restarts from the committed iteration:
//!    a uniform rollback of at most one iteration.
//!
//! Because the per-iteration RNG streams are keyed by iteration number
//! ([`crate::nmf::seed::StreamRng::for_iteration`]), replaying from the
//! committed iteration reproduces the uninterrupted run bit-for-bit in
//! the factors. The virtual clock, statistics and error trace of the
//! replayed stretch do diverge (the fault cost real rounds); the chaos
//! tests therefore assert factor identity, not trace identity.

use crate::error::Result;
use crate::transport::wire::{push_f64_bits, push_u64_bits, take_f64_bits, take_u64_bits};
use crate::transport::{Communicator, PeerLostSignal};

use super::NodeCtx;

/// A recovered position: where to restart the iteration loop.
#[derive(Debug, Clone)]
pub struct Recovery {
    /// Iteration to re-enter the loop at (the committed boundary).
    pub iteration: usize,
    /// The exact-norm pair `(fro_sq_u_side, fro_sq_v_side)` — runner
    /// specific scalars committed alongside the factors (runners that only
    /// need one slot leave the other 0).
    pub fro_sq: (f64, f64),
    /// This rank's serialized factor state at the committed boundary.
    pub state: Vec<f32>,
}

/// Per-rank elastic state: the latest committed boundary plus the epoch
/// counter reported in [`crate::nmf::job::Outcome::epochs`].
#[derive(Debug, Default)]
pub struct Elastic {
    /// Membership epochs this rank has participated in *beyond* the first
    /// (0 for an undisturbed run; callers report `epochs + 1`).
    pub rebuilds: usize,
    /// `(iteration, fro_sq pair, per-rank state blobs in rank order)`.
    committed: Option<(usize, (f64, f64), Vec<Vec<f32>>)>,
}

impl Elastic {
    /// Fresh elastic state with nothing committed yet.
    pub fn new() -> Self {
        Elastic::default()
    }

    /// Replicate the boundary state for iteration `t`: every rank
    /// contributes its own serialized factors, every rank stores the full
    /// set. Runs untimed — replication must not disturb the measured run.
    pub fn commit<C: Communicator>(
        &mut self,
        ctx: &mut NodeCtx<C>,
        t: usize,
        fro_sq: (f64, f64),
        own_state: &[f32],
    ) {
        let parts = ctx.untimed(|ctx| ctx.all_gather(own_state));
        self.committed = Some((t, fro_sq, parts));
    }

    /// Iteration the latest commit belongs to, if any.
    pub fn committed_iteration(&self) -> Option<usize> {
        self.committed.as_ref().map(|(t, _, _)| *t)
    }

    /// Rebuild membership after a peer loss and adopt the donor's
    /// committed state. `joining` is true on a replacement rank that
    /// entered via the epoch-join handshake (its transport is already at
    /// the new epoch, so it skips the rebuild call and brings no commit).
    ///
    /// All ranks of the new membership must call this together.
    pub fn recover<C: Communicator>(
        &mut self,
        ctx: &mut NodeCtx<C>,
        min_ranks: usize,
        joining: bool,
    ) -> Result<Recovery> {
        if !joining {
            ctx.comm_mut().rebuild(min_ranks)?;
        }
        self.rebuilds += 1;

        // phase 1: tiny header gather — who holds a commit, and for which
        // iteration. The donor is the lowest-ranked holder; commits at the
        // same boundary are identical by construction, so any holder works,
        // but electing deterministically keeps the protocol auditable.
        let mut header = Vec::with_capacity(7);
        match &self.committed {
            Some((t, fro, _)) => {
                header.push(1.0f32);
                push_u64_bits(&mut header, *t as u64);
                push_f64_bits(&mut header, fro.0);
                push_f64_bits(&mut header, fro.1);
            }
            None => {
                header.push(0.0f32);
                push_u64_bits(&mut header, 0);
                push_f64_bits(&mut header, 0.0);
                push_f64_bits(&mut header, 0.0);
            }
        }
        let headers = ctx.untimed(|ctx| ctx.all_gather(&header));
        let donor = headers
            .iter()
            .position(|h| h.first().copied() == Some(1.0))
            .ok_or_else(|| {
                crate::err!("no surviving rank holds a committed state to recover from")
            })?;
        let mut pos = 1;
        let iteration = take_u64_bits(&headers[donor], &mut pos)? as usize;
        let fro_sq =
            (take_f64_bits(&headers[donor], &mut pos)?, take_f64_bits(&headers[donor], &mut pos)?);

        // phase 2: the donor ships the full committed blob set; everyone
        // else contributes an empty slice. Also untimed.
        let own_payload = if ctx.rank == donor {
            let (_, _, parts) = self.committed.as_ref().expect("donor holds a commit");
            encode_parts(parts)
        } else {
            Vec::new()
        };
        let shipped = ctx.untimed(|ctx| ctx.all_gather(&own_payload));
        let parts = decode_parts(&shipped[donor])?;
        if parts.len() != ctx.nodes() {
            crate::bail!(
                "recovered commit carries {} rank blobs, cluster has {}",
                parts.len(),
                ctx.nodes()
            );
        }
        let state = parts[ctx.rank].clone();
        // everyone now holds the same commit — including the joiner, which
        // can donate if another rank dies before the next boundary
        self.committed = Some((iteration, fro_sq, parts));
        Ok(Recovery { iteration, fro_sq, state })
    }
}

/// Serialize rank-ordered blobs with length prefixes.
fn encode_parts(parts: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::with_capacity(2 + parts.iter().map(|p| p.len() + 2).sum::<usize>());
    push_u64_bits(&mut out, parts.len() as u64);
    for p in parts {
        push_u64_bits(&mut out, p.len() as u64);
        out.extend_from_slice(p);
    }
    out
}

/// Inverse of [`encode_parts`].
fn decode_parts(payload: &[f32]) -> Result<Vec<Vec<f32>>> {
    let mut pos = 0;
    let n = take_u64_bits(payload, &mut pos)? as usize;
    let mut parts = Vec::with_capacity(n);
    for _ in 0..n {
        let len = take_u64_bits(payload, &mut pos)? as usize;
        if pos + len > payload.len() {
            crate::bail!("payload underrun decoding committed blob ({len} elems at {pos})");
        }
        parts.push(payload[pos..pos + len].to_vec());
        pos += len;
    }
    Ok(parts)
}

/// Run one guarded step of an elastic iteration: a [`PeerLostSignal`]
/// unwinding out of `f` is caught and returned as `Err` so the runner can
/// recover at the boundary; every other panic — including the chaos
/// harness's [`crate::transport::FaultKillSignal`], which must kill the
/// rank for real — resumes unwinding.
pub fn run_step<T>(f: impl FnOnce() -> T) -> std::result::Result<T, PeerLostSignal> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(v) => Ok(v),
        Err(payload) => match payload.downcast::<PeerLostSignal>() {
            Ok(signal) => Err(*signal),
            Err(other) => std::panic::resume_unwind(other),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::CommModel;
    use crate::transport::{FaultPlan, SimCluster, SimComm};

    #[test]
    fn parts_codec_round_trips() {
        let parts = vec![vec![1.0f32, 2.0, 3.0], vec![], vec![4.5f32]];
        let enc = encode_parts(&parts);
        assert_eq!(decode_parts(&enc).unwrap(), parts);
        // truncation is a typed error, not a panic
        assert!(decode_parts(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn run_step_catches_only_peer_loss() {
        let ok: std::result::Result<u32, _> = run_step(|| 7);
        assert_eq!(ok.unwrap(), 7);
        let err = run_step(|| -> u32 {
            std::panic::panic_any(PeerLostSignal {
                peer: Some(2),
                detail: "peer 2 disconnected".into(),
            })
        })
        .unwrap_err();
        assert_eq!(err.peer, Some(2));
        // a plain panic must keep unwinding
        let plain = std::panic::catch_unwind(|| run_step(|| -> u32 { panic!("hard fault") }));
        assert!(plain.is_err());
    }

    #[test]
    fn recover_without_any_commit_is_a_typed_error() {
        // 2 ranks, rank 1 dies before any boundary commit happened
        let cluster = SimCluster::new(2);
        cluster.set_fault_plan(FaultPlan::new().kill(1, 0));
        cluster.set_rejoin_timeout(std::time::Duration::from_secs(10));
        let mut out_err = String::new();
        std::thread::scope(|s| {
            let c0 = cluster.clone();
            let survivor = s.spawn(move || {
                let comm = SimComm::new(0, c0);
                let mut ctx = NodeCtx::new(comm, CommModel::default());
                let mut el = Elastic::new();
                // rank 1 dies on its first fault_check; our first gather
                // unwinds with the typed signal
                let step = run_step(|| {
                    ctx.all_gather(&[0.0f32]);
                });
                assert!(step.is_err(), "peer loss did not surface");
                // no commit was ever made: recovery must fail cleanly once
                // the replacement shows up
                el.recover(&mut ctx, 1, false).unwrap_err().to_string()
            });
            let c1 = cluster.clone();
            s.spawn(move || {
                // rank 1: die immediately, then re-join and run the same
                // (failing) recovery protocol
                let died = std::panic::catch_unwind(|| {
                    let comm = SimComm::new(1, c1.clone());
                    let mut ctx = NodeCtx::new(comm, CommModel::default());
                    ctx.comm_mut().fault_check(0);
                });
                assert!(died.is_err());
                let comm = SimComm::join(&c1, 1).unwrap();
                let mut ctx = NodeCtx::new(comm, CommModel::default());
                let mut el = Elastic::new();
                let err = el.recover(&mut ctx, 1, true).unwrap_err();
                assert!(err.to_string().contains("no surviving rank"), "{err}");
            });
            out_err = survivor.join().unwrap();
        });
        assert!(out_err.contains("no surviving rank holds a committed state"), "{out_err}");
    }

    #[test]
    fn commit_then_recover_adopts_the_donor_state() {
        let cluster = SimCluster::new(3);
        cluster.set_fault_plan(FaultPlan::new().kill(2, 1));
        cluster.set_rejoin_timeout(std::time::Duration::from_secs(10));
        let mut recovered: Vec<Option<Recovery>> = vec![None, None, None];
        std::thread::scope(|s| {
            let mut slots = recovered.iter_mut();
            for rank in 0..3usize {
                let slot = slots.next().unwrap();
                let cl = cluster.clone();
                s.spawn(move || {
                    let run = |joining: bool, cl: &std::sync::Arc<SimCluster>| {
                        let comm = if joining {
                            SimComm::join(cl, rank).unwrap()
                        } else {
                            SimComm::new(rank, cl.clone())
                        };
                        let mut ctx = NodeCtx::new(comm, CommModel::default());
                        let mut el = Elastic::new();
                        if !joining {
                            // boundary 0: everyone commits rank-flavoured state
                            ctx.comm_mut().fault_check(0);
                            el.commit(
                                &mut ctx,
                                0,
                                (10.0, 20.0),
                                &[rank as f32 * 100.0, rank as f32 * 100.0 + 1.0],
                            );
                            // boundary 1: the fault plan kills rank 2 here
                            let step = run_step(|| {
                                ctx.comm_mut().fault_check(1);
                                ctx.all_gather(&[rank as f32]);
                            });
                            assert!(step.is_err(), "rank {rank}: expected peer loss");
                        }
                        el.recover(&mut ctx, 2, joining).unwrap()
                    };
                    let first = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run(false, &cl)
                    }));
                    let rec = match first {
                        Ok(rec) => rec,
                        Err(payload) => {
                            // only the chaos kill may unwind; re-join and
                            // recover as the replacement incarnation
                            if payload.downcast_ref::<crate::transport::FaultKillSignal>().is_none()
                            {
                                std::panic::resume_unwind(payload);
                            }
                            assert_eq!(rank, 2);
                            run(true, &cl)
                        }
                    };
                    *slot = Some(rec);
                });
            }
        });
        for (rank, rec) in recovered.iter().enumerate() {
            let rec = rec.as_ref().expect("rank produced no recovery");
            assert_eq!(rec.iteration, 0, "rank {rank}");
            assert_eq!(rec.fro_sq, (10.0, 20.0), "rank {rank}");
            // the joiner (rank 2) gets the *dead incarnation's* committed
            // state — that is the whole point of replication
            assert_eq!(
                rec.state,
                vec![rank as f32 * 100.0, rank as f32 * 100.0 + 1.0],
                "rank {rank}"
            );
        }
    }
}
