//! Cluster execution layer: the virtual-clock/statistics node context the
//! distributed algorithms run in, generic over the transport backend.
//!
//! The communication substrate itself lives in [`crate::transport`]: a
//! [`Communicator`] trait with an in-process simulated backend
//! ([`crate::transport::SimComm`]) and a real multi-process TCP backend
//! ([`crate::transport::TcpComm`]). This module supplies what the
//! *algorithms* see on top of it:
//!
//! * [`NodeCtx`] — identity, a virtual clock, [`CommStats`] accounting and
//!   the rank-ordered deterministic collectives (`all_reduce_sum`,
//!   `all_gather`). The reduction is the **same code for every backend**,
//!   summing contributions in rank order, so a seeded run produces
//!   bit-identical factors whether the ranks are threads or TCP processes.
//! * [`run_cluster`] — N simulated node threads (the default substrate for
//!   tests and the figure sweeps), with the modelled-clock/stall semantics:
//!   synchronous collectives are barriers, everyone leaves at
//!   `max(clock_r) + t_comm`, and waiting shows up as
//!   [`CommStats::stall_time`] — how the imbalanced-workload experiments
//!   (paper Fig. 7/9) observe stragglers without real sleeps.
//! * [`run_tcp_cluster`] — the same shape over real localhost TCP (one
//!   thread per rank, each with its own [`crate::transport::TcpComm`]);
//!   used by the backend-equivalence tests and benches. Real deployments
//!   use one *process* per rank via `dsanls launch` / `dsanls worker`
//!   ([`crate::coordinator::launch`]).
//!
//! Timing discipline follows the backend ([`Timing`]): the simulated
//! backend charges analytic wire time from [`CommModel`] and measures
//! stalls against the exchanged clock stamps; the TCP backend charges
//! measured wall-clock around each blocking collective.
//!
//! Byte accounting (per node): under the modelled discipline an all-reduce
//! charges the payload once (ring schedule, size independent of `N`) and an
//! all-gather charges `own·(N−1)` sent — this is what makes the baselines'
//! `O(nk)` gather visibly more expensive than DSANLS's `O(kd)` reduce in
//! `tests/paper_claims.rs`. The measured discipline charges the actual
//! full-mesh traffic (`payload·(N−1)`).
//!
//! * **Out-of-band evaluation** — [`NodeCtx::untimed`] suppresses both the
//!   clock and the byte counters, so error traces can gather factors
//!   without perturbing the measured communication volume (DSANLS's
//!   `O(kd)` claim is asserted on these counters).
//!
//! Transport failures are fatal to the *iteration*, not necessarily the
//!   node: a rank that lost a collective peer cannot finish the round, so
//!   the collective wrappers panic — but a peer-loss failure panics with
//!   the typed [`crate::transport::PeerLostSignal`] payload, which the
//!   elastic runners ([`elastic`]) catch at the next iteration boundary to
//!   rebuild membership and resume. Every other failure panics with the
//!   plain message and the cluster driver (thread scope or worker process)
//!   surfaces it.
//!
//! **Control plane**: supervised runs ([`crate::nmf::control`]) add one
//! untimed three-float all-reduce per iteration — the collective stop
//! poll ([`crate::nmf::control::RunControl::poll_sync`]) that lets every
//! rank leave the loop at the same iteration on cancellation, deadline or
//! convergence. Because it runs under [`NodeCtx::untimed`] it disturbs
//! neither the modelled clock nor the byte counters the paper's
//! communication-volume claims are asserted on. A *killed* job
//! additionally interrupts the transport inboxes so blocked reads fail
//! fast instead of waiting out an I/O timeout.

use std::time::{Duration, Instant};

use crate::transport::wire::Precision;
use crate::transport::{
    Communicator, PeerLostSignal, PendingExchange, SimCluster, SimComm, TcpComm, Timing,
};

pub mod elastic;

/// Abort a collective with the failure typed for the elastic runners:
/// peer-loss errors unwind as a [`PeerLostSignal`] payload (recoverable at
/// an iteration boundary), everything else as a plain message panic.
fn collective_panic(rank: usize, op: &str, e: crate::error::Error) -> ! {
    let detail = format!("{op} failed on rank {rank}: {e}");
    if let Some(peer) = e.lost_peer() {
        std::panic::panic_any(PeerLostSignal { peer, detail });
    }
    panic!("{detail}");
}

/// Modelled interconnect: latency (seconds) + bandwidth (bytes/second).
/// Default is a 10 Gbps / 100 µs datacenter link (the paper's cluster is
/// 10 GbE).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// One-way message latency in seconds.
    pub latency: f64,
    /// Link bandwidth in bytes per second.
    pub bandwidth: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel { latency: 100e-6, bandwidth: 1.25e9 }
    }
}

impl CommModel {
    /// Time for one point-to-point message of `bytes`.
    pub fn p2p_time(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Time for a ring all-reduce of a `bytes` payload across `nodes`.
    /// Each node sends ≈2× the payload regardless of `N` (reduce-scatter +
    /// all-gather phases), paying the latency per phase.
    pub fn all_reduce_time(&self, bytes: usize, nodes: usize) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        2.0 * (self.latency + bytes as f64 / self.bandwidth)
    }

    /// Time for an all-gather where this node receives `recv_bytes` in total
    /// from `nodes − 1` peers.
    pub fn all_gather_time(&self, recv_bytes: usize, nodes: usize) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        (nodes - 1) as f64 * self.latency + recv_bytes as f64 / self.bandwidth
    }
}

/// Per-node communication / compute statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    pub bytes_sent: usize,
    pub bytes_received: usize,
    /// Collective / point-to-point operations entered.
    pub messages: usize,
    /// Measured local compute seconds ([`NodeCtx::compute`]).
    pub compute_time: f64,
    /// Wire seconds (modelled or measured, per the backend).
    pub comm_time: f64,
    /// Seconds spent waiting for stragglers at synchronous barriers
    /// (modelled backend only; the measured backend folds waiting into
    /// `comm_time`).
    pub stall_time: f64,
}

// ---------------------------------------------------------------------------
// Node context
// ---------------------------------------------------------------------------

/// Handle each cluster node receives: identity, virtual clock, statistics
/// and the synchronous collectives, over any [`Communicator`] backend.
pub struct NodeCtx<C: Communicator> {
    /// This node's rank in `0..nodes`.
    pub rank: usize,
    nodes: usize,
    model: CommModel,
    timing: Timing,
    clock: f64,
    stats: CommStats,
    suppress: bool,
    comm: C,
}

impl<C: Communicator> NodeCtx<C> {
    /// Wrap a connected communicator with the clock/statistics context.
    pub fn new(comm: C, model: CommModel) -> Self {
        NodeCtx {
            rank: comm.rank(),
            nodes: comm.nodes(),
            timing: comm.timing(),
            model,
            clock: 0.0,
            stats: CommStats::default(),
            suppress: false,
            comm,
        }
    }

    /// Number of nodes in the cluster.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Direct access to the transport (tagged P2P for the asynchronous
    /// protocols; collective users should stay on the wrappers below).
    pub fn comm_mut(&mut self) -> &mut C {
        &mut self.comm
    }

    /// Consume the context, returning the transport and final statistics.
    pub fn into_parts(self) -> (C, CommStats, f64) {
        (self.comm, self.stats, self.clock)
    }

    /// Run `f`, measuring its wall time into the virtual clock and
    /// `compute_time`. Returns `f`'s result.
    pub fn compute<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let tick = Instant::now();
        let out = f();
        let dt = tick.elapsed().as_secs_f64();
        if !self.suppress {
            self.clock += dt;
            self.stats.compute_time += dt;
        }
        out
    }

    /// Advance the virtual clock by `dt` seconds of synthetic compute
    /// (failure/skew injection in tests).
    pub fn advance(&mut self, dt: f64) {
        if !self.suppress {
            self.clock += dt;
            self.stats.compute_time += dt;
        }
    }

    /// Run `f` with the clock and the byte counters frozen — for
    /// out-of-band evaluation that must not disturb the measured run.
    /// Collectives inside still synchronise (all ranks must enter them).
    pub fn untimed<T>(&mut self, f: impl FnOnce(&mut Self) -> T) -> T {
        let was = self.suppress;
        self.suppress = true;
        // restore on unwind too: an elastic runner catches peer-loss panics
        // thrown from inside untimed sections and keeps using this context
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(self)));
        self.suppress = was;
        match out {
            Ok(v) => v,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }

    /// In-place all-reduce: `buf ← Σ_r buf_r`, summed in rank order so the
    /// result is bit-identical on every node, for every thread schedule
    /// and for every backend. All ranks must pass equal-length buffers.
    pub fn all_reduce_sum(&mut self, buf: &mut [f32]) {
        let bytes = std::mem::size_of_val(buf);
        let tick = Instant::now();
        let g = self
            .comm
            .exchange(self.clock, buf)
            .unwrap_or_else(|e| collective_panic(self.rank, "all-reduce", e));
        buf.fill(0.0);
        for slot in &g.parts {
            debug_assert_eq!(slot.len(), buf.len(), "all_reduce_sum length mismatch");
            for (b, v) in buf.iter_mut().zip(slot.iter()) {
                *b += v;
            }
        }
        if !self.suppress {
            self.stats.messages += 1;
            match self.timing {
                Timing::Modelled => {
                    let stall = (g.max_clock - self.clock).max(0.0);
                    let t = self.model.all_reduce_time(bytes, self.nodes);
                    self.stats.stall_time += stall;
                    self.stats.comm_time += t;
                    self.stats.bytes_sent += bytes;
                    self.stats.bytes_received += bytes;
                    self.clock = g.max_clock + t;
                }
                Timing::Measured => {
                    let dt = tick.elapsed().as_secs_f64();
                    let peers = self.nodes.saturating_sub(1);
                    self.stats.comm_time += dt;
                    self.stats.bytes_sent += bytes * peers;
                    self.stats.bytes_received += bytes * peers;
                    self.clock += dt;
                }
            }
        }
    }

    /// All-gather: every rank contributes a slice (lengths may differ);
    /// returns all contributions in rank order.
    pub fn all_gather(&mut self, data: &[f32]) -> Vec<Vec<f32>> {
        let own = std::mem::size_of_val(data);
        let tick = Instant::now();
        let g = self
            .comm
            .exchange(self.clock, data)
            .unwrap_or_else(|e| collective_panic(self.rank, "all-gather", e));
        if !self.suppress {
            let total: usize = g.parts.iter().map(|s| s.len() * 4).sum();
            let recv = total.saturating_sub(own);
            let peers = self.nodes.saturating_sub(1);
            self.stats.messages += peers;
            self.stats.bytes_sent += own * peers;
            self.stats.bytes_received += recv;
            match self.timing {
                Timing::Modelled => {
                    let stall = (g.max_clock - self.clock).max(0.0);
                    let t = self.model.all_gather_time(recv, self.nodes);
                    self.stats.stall_time += stall;
                    self.stats.comm_time += t;
                    self.clock = g.max_clock + t;
                }
                Timing::Measured => {
                    let dt = tick.elapsed().as_secs_f64();
                    self.stats.comm_time += dt;
                    self.clock += dt;
                }
            }
        }
        g.parts
    }

    /// [`NodeCtx::all_reduce_sum`] with the payload quantized to
    /// `precision` on the wire. `Precision::F32` delegates to the exact
    /// path (bit-identical, byte-identical); the 2-byte precisions charge
    /// the quantized byte volume and sum the round-tripped contributions —
    /// still in rank order, so the result stays bit-identical across
    /// backends at every precision.
    pub fn all_reduce_sum_q(&mut self, buf: &mut [f32], precision: Precision) {
        if precision == Precision::F32 {
            return self.all_reduce_sum(buf);
        }
        let pending = self.all_reduce_start(buf, precision);
        self.all_reduce_finish(pending, buf);
    }

    /// Post a non-blocking all-reduce of `buf` (quantized to `precision`
    /// on the wire) and return the in-flight handle. The caller runs
    /// local compute, then calls [`NodeCtx::all_reduce_finish`] — pendings
    /// must finish in start order, all before the next blocking
    /// collective.
    ///
    /// With no compute between start and finish the accounting degenerates
    /// to exactly the blocking [`NodeCtx::all_reduce_sum`] numbers; with
    /// compute in between, wire time that the compute covered is charged
    /// to neither `comm_time` nor `stall_time` — that is the overlap win
    /// the modelled clock measures.
    pub fn all_reduce_start(&mut self, buf: &[f32], precision: Precision) -> PendingReduce {
        let wire_bytes = buf.len() * precision.bytes_per_element();
        let pending = self
            .comm
            .exchange_start_q(self.clock, buf, precision)
            .unwrap_or_else(|e| collective_panic(self.rank, "all-reduce start", e));
        PendingReduce { pending, wire_bytes, start_clock: self.clock, len: buf.len() }
    }

    /// Wait for a posted all-reduce and fold the result into `buf`
    /// (`buf ← Σ_r buf_r`, rank-ordered). `buf` must be the same length
    /// that was posted (its current contents are overwritten).
    pub fn all_reduce_finish(&mut self, pending: PendingReduce, buf: &mut [f32]) {
        let PendingReduce { pending, wire_bytes, start_clock, len } = pending;
        debug_assert_eq!(len, buf.len(), "all_reduce_finish length mismatch");
        let tick = Instant::now(); // Measured: time only the blocked wait
        let g = pending
            .wait()
            .unwrap_or_else(|e| collective_panic(self.rank, "all-reduce", e));
        buf.fill(0.0);
        for slot in &g.parts {
            debug_assert_eq!(slot.len(), buf.len(), "all_reduce_sum length mismatch");
            for (b, v) in buf.iter_mut().zip(slot.iter()) {
                *b += v;
            }
        }
        if !self.suppress {
            self.stats.messages += 1;
            match self.timing {
                Timing::Modelled => {
                    let t = self.model.all_reduce_time(wire_bytes, self.nodes);
                    // the reduction lands once the last contributor posted
                    // and the wire round completed
                    let arrival = g.max_clock.max(start_clock) + t;
                    let wait = (arrival - self.clock).max(0.0);
                    // of the wait, up to t is wire time; the rest is
                    // straggler stall (identical split to the blocking
                    // path when nothing overlapped)
                    let wire = wait.min(t);
                    self.stats.comm_time += wire;
                    self.stats.stall_time += wait - wire;
                    self.stats.bytes_sent += wire_bytes;
                    self.stats.bytes_received += wire_bytes;
                    self.clock = self.clock.max(arrival);
                }
                Timing::Measured => {
                    let dt = tick.elapsed().as_secs_f64();
                    let peers = self.nodes.saturating_sub(1);
                    self.stats.comm_time += dt;
                    self.stats.bytes_sent += wire_bytes * peers;
                    self.stats.bytes_received += wire_bytes * peers;
                    self.clock += dt;
                }
            }
        }
    }

    /// [`NodeCtx::all_gather`] with contributions quantized to `precision`
    /// on the wire (`Precision::F32` is byte- and bit-identical to the
    /// exact path).
    pub fn all_gather_q(&mut self, data: &[f32], precision: Precision) -> Vec<Vec<f32>> {
        if precision == Precision::F32 {
            return self.all_gather(data);
        }
        let pending = self.all_gather_start(data, precision);
        self.all_gather_finish(pending)
    }

    /// Post a non-blocking all-gather (see [`NodeCtx::all_reduce_start`]
    /// for the overlap/ordering contract).
    pub fn all_gather_start(&mut self, data: &[f32], precision: Precision) -> PendingGather {
        let own_wire = data.len() * precision.bytes_per_element();
        let pending = self
            .comm
            .exchange_start_q(self.clock, data, precision)
            .unwrap_or_else(|e| collective_panic(self.rank, "all-gather start", e));
        PendingGather { pending, own_wire, start_clock: self.clock, precision }
    }

    /// Wait for a posted all-gather; returns all contributions in rank
    /// order.
    pub fn all_gather_finish(&mut self, pending: PendingGather) -> Vec<Vec<f32>> {
        let PendingGather { pending, own_wire, start_clock, precision } = pending;
        let tick = Instant::now();
        let g = pending
            .wait()
            .unwrap_or_else(|e| collective_panic(self.rank, "all-gather", e));
        if !self.suppress {
            let elem = precision.bytes_per_element();
            let total: usize = g.parts.iter().map(|s| s.len() * elem).sum();
            let recv = total.saturating_sub(own_wire);
            let peers = self.nodes.saturating_sub(1);
            self.stats.messages += peers;
            self.stats.bytes_sent += own_wire * peers;
            self.stats.bytes_received += recv;
            match self.timing {
                Timing::Modelled => {
                    let t = self.model.all_gather_time(recv, self.nodes);
                    let arrival = g.max_clock.max(start_clock) + t;
                    let wait = (arrival - self.clock).max(0.0);
                    let wire = wait.min(t);
                    self.stats.comm_time += wire;
                    self.stats.stall_time += wait - wire;
                    self.clock = self.clock.max(arrival);
                }
                Timing::Measured => {
                    let dt = tick.elapsed().as_secs_f64();
                    self.stats.comm_time += dt;
                    self.clock += dt;
                }
            }
        }
        g.parts
    }

    /// Current virtual time in seconds.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Snapshot of the accumulated statistics.
    pub fn stats(&self) -> CommStats {
        self.stats
    }
}

/// An in-flight [`NodeCtx::all_reduce_start`]: the sends are posted, the
/// clock/byte accounting is deferred to [`NodeCtx::all_reduce_finish`].
pub struct PendingReduce {
    pending: PendingExchange,
    /// Bytes this rank's contribution occupies on the wire (already
    /// precision-scaled).
    wire_bytes: usize,
    /// Virtual clock when the reduction was posted.
    start_clock: f64,
    len: usize,
}

/// An in-flight [`NodeCtx::all_gather_start`].
pub struct PendingGather {
    pending: PendingExchange,
    own_wire: usize,
    start_clock: f64,
    precision: Precision,
}

// ---------------------------------------------------------------------------
// Cluster drivers
// ---------------------------------------------------------------------------

/// Per-node intra-node parallelism cap: `N` node workers × GEMM threads
/// must not oversubscribe the machine, and — just as important — the cap
/// must be **identical across backends** so the thread-count-sensitive
/// reductions (`gemm_tn` partials) split work the same way and stay
/// bit-identical (§Perf: the nested spawn storm inflated per-node wallclock
/// ~5× on 10-node runs before this cap existed).
pub fn apply_node_thread_policy(nodes: usize) {
    if nodes > 1 {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
        crate::parallel::set_local_threads(Some((cores / nodes).max(1)));
    }
}

/// Run `f` once per node on its own thread over the **simulated** backend
/// and return the outputs in rank order. Panics in any node propagate.
///
/// KEEP IN SYNC: `crate::nmf::job::drive_sim` mirrors this driver's
/// single-rank inline path and per-thread cap policy — the sim/TCP and
/// builder/legacy bit-identity contracts depend on the two staying
/// behaviourally identical (same for [`run_tcp_cluster`] vs `drive_tcp`).
pub fn run_cluster<T, F>(nodes: usize, model: CommModel, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&mut NodeCtx<SimComm>) -> T + Sync,
{
    assert!(nodes > 0, "run_cluster needs at least one node");
    let cluster = SimCluster::new(nodes);
    if nodes == 1 {
        // single node: run inline with full intra-node parallelism
        let mut ctx = NodeCtx::new(SimComm::new(0, cluster), model);
        return vec![f(&mut ctx)];
    }
    let mut out: Vec<Option<T>> = (0..nodes).map(|_| None).collect();
    std::thread::scope(|s| {
        for (rank, slot) in out.iter_mut().enumerate() {
            let comm = SimComm::new(rank, cluster.clone());
            let f = &f;
            s.spawn(move || {
                apply_node_thread_policy(nodes);
                let mut ctx = NodeCtx::new(comm, model);
                *slot = Some(f(&mut ctx));
                crate::parallel::set_local_threads(None);
            });
        }
    });
    out.into_iter().map(|o| o.expect("node produced no output")).collect()
}

/// Run `f` once per rank over the **real TCP** backend (localhost mesh,
/// rendezvous included), one thread per rank inside this process. Same
/// shape as [`run_cluster`], so the backend-equivalence tests can run the
/// identical node closure on both substrates. Multi-*process* deployment
/// goes through `dsanls launch` instead ([`crate::coordinator::launch`]).
pub fn run_tcp_cluster<T, F>(nodes: usize, model: CommModel, f: F) -> crate::error::Result<Vec<T>>
where
    T: Send,
    F: Fn(&mut NodeCtx<TcpComm>) -> T + Sync,
{
    use crate::transport::{Rendezvous, TcpOptions};
    assert!(nodes > 0, "run_tcp_cluster needs at least one node");
    let rdv = Rendezvous::bind(0)?;
    let addr = rdv.addr();
    let mut out: Vec<Option<crate::error::Result<T>>> = (0..nodes).map(|_| None).collect();
    let rdv_result = std::thread::scope(|s| {
        let coord = s.spawn(move || rdv.wait_workers(nodes, Duration::from_secs(30)));
        for (rank, slot) in out.iter_mut().enumerate() {
            let addr = addr.clone();
            let f = &f;
            s.spawn(move || {
                let run = (|| {
                    let comm = TcpComm::connect(&addr, rank, nodes, &TcpOptions::default())?;
                    apply_node_thread_policy(nodes);
                    let mut ctx = NodeCtx::new(comm, model);
                    let value = f(&mut ctx);
                    crate::parallel::set_local_threads(None);
                    Ok(value)
                })();
                *slot = Some(run);
            });
        }
        // hold the coordinator-side connections until every rank finished
        coord.join().expect("rendezvous thread panicked")
    });
    rdv_result?;
    out.into_iter().map(|o| o.expect("rank produced no output")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_is_rank_ordered_and_deterministic() {
        for nodes in [1usize, 2, 3, 7] {
            let results = run_cluster(nodes, CommModel::default(), |ctx| {
                let mut buf = vec![(ctx.rank + 1) as f32; 8];
                ctx.all_reduce_sum(&mut buf);
                buf
            });
            let expect: f32 = (1..=nodes).map(|r| r as f32).sum();
            for r in &results {
                assert!(r.iter().all(|&v| v == expect), "{r:?} != {expect}");
            }
        }
    }

    #[test]
    fn all_gather_rank_order() {
        let results = run_cluster(4, CommModel::default(), |ctx| {
            let mine = vec![ctx.rank as f32; ctx.rank + 1]; // ragged lengths
            ctx.all_gather(&mine)
        });
        for gathered in &results {
            assert_eq!(gathered.len(), 4);
            for (rank, block) in gathered.iter().enumerate() {
                assert_eq!(block.len(), rank + 1);
                assert!(block.iter().all(|&v| v == rank as f32));
            }
        }
    }

    #[test]
    fn repeated_collectives_do_not_cross_rounds() {
        // fast nodes must not leak a round-t payload into round t+1
        let results = run_cluster(3, CommModel::default(), |ctx| {
            let mut sums = Vec::new();
            for round in 0..20 {
                let mut buf = vec![(round * 10 + ctx.rank) as f32];
                ctx.all_reduce_sum(&mut buf);
                sums.push(buf[0]);
            }
            sums
        });
        for r in &results {
            for (round, &s) in r.iter().enumerate() {
                let expect = (0..3).map(|rank| (round * 10 + rank) as f32).sum::<f32>();
                assert_eq!(s, expect, "round {round}");
            }
        }
    }

    #[test]
    fn barrier_clock_and_stall_accounting() {
        let results = run_cluster(3, CommModel { latency: 0.0, bandwidth: f64::INFINITY }, |ctx| {
            if ctx.rank == 0 {
                ctx.advance(2.0); // straggler
            }
            let mut buf = [1.0f32; 4];
            ctx.all_reduce_sum(&mut buf);
            (ctx.clock(), ctx.stats())
        });
        for (rank, (clock, stats)) in results.iter().enumerate() {
            assert!((clock - 2.0).abs() < 1e-9, "rank {rank} clock {clock}");
            if rank == 0 {
                assert_eq!(stats.stall_time, 0.0);
            } else {
                assert!((stats.stall_time - 2.0).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn untimed_freezes_clock_and_bytes() {
        let results = run_cluster(2, CommModel::default(), |ctx| {
            ctx.untimed(|ctx| {
                let mut buf = [1.0f32; 256];
                ctx.all_reduce_sum(&mut buf);
                let _ = ctx.all_gather(&buf);
            });
            (ctx.clock(), ctx.stats())
        });
        for (clock, stats) in &results {
            assert_eq!(*clock, 0.0);
            assert_eq!(stats.bytes_sent, 0);
            assert_eq!(stats.bytes_received, 0);
            assert_eq!(stats.messages, 0);
        }
    }

    #[test]
    fn comm_model_times() {
        let c = CommModel { latency: 1e-3, bandwidth: 1e6 };
        assert!((c.p2p_time(1000) - 2e-3).abs() < 1e-12);
        assert_eq!(c.all_reduce_time(1000, 1), 0.0);
        assert!(c.all_reduce_time(1000, 4) > c.p2p_time(1000));
        let free = CommModel { latency: 0.0, bandwidth: f64::INFINITY };
        assert_eq!(free.all_reduce_time(123456, 8), 0.0);
        assert_eq!(free.all_gather_time(123456, 8), 0.0);
    }

    /// The identical node body over both backends must yield identical
    /// values (the generic function is monomorphised per transport).
    fn collective_mix_node<C: crate::transport::Communicator>(ctx: &mut NodeCtx<C>) -> Vec<f32> {
        let mut buf = vec![(ctx.rank + 1) as f32 * 0.125; 16];
        ctx.all_reduce_sum(&mut buf);
        let gathered = ctx.all_gather(&buf[..4]);
        let mut out = buf;
        for part in gathered {
            out.extend_from_slice(&part);
        }
        out
    }

    #[test]
    fn tcp_cluster_collectives_match_sim() {
        let sim = run_cluster(3, CommModel::default(), |ctx| collective_mix_node(ctx));
        let tcp = run_tcp_cluster(3, CommModel::default(), |ctx| collective_mix_node(ctx))
            .expect("tcp cluster failed");
        assert_eq!(sim, tcp);
    }

    #[test]
    fn overlapped_reduce_matches_blocking_result_and_degenerate_accounting() {
        // same payloads through both paths; with zero compute between
        // start and finish, clock/stall/bytes must match blocking exactly
        let blocking = run_cluster(3, CommModel::default(), |ctx| {
            if ctx.rank == 0 {
                ctx.advance(1.0);
            }
            let mut buf = vec![(ctx.rank + 1) as f32 * 0.25; 32];
            ctx.all_reduce_sum(&mut buf);
            (buf, ctx.clock(), ctx.stats())
        });
        let overlapped = run_cluster(3, CommModel::default(), |ctx| {
            if ctx.rank == 0 {
                ctx.advance(1.0);
            }
            let mut buf = vec![(ctx.rank + 1) as f32 * 0.25; 32];
            let p = ctx.all_reduce_start(&buf, Precision::F32);
            ctx.all_reduce_finish(p, &mut buf);
            (buf, ctx.clock(), ctx.stats())
        });
        for ((b_buf, b_clock, b_stats), (o_buf, o_clock, o_stats)) in
            blocking.iter().zip(overlapped.iter())
        {
            assert_eq!(b_buf, o_buf);
            assert!((b_clock - o_clock).abs() < 1e-12, "{b_clock} vs {o_clock}");
            assert_eq!(b_stats.bytes_sent, o_stats.bytes_sent);
            assert_eq!(b_stats.messages, o_stats.messages);
            assert!((b_stats.comm_time - o_stats.comm_time).abs() < 1e-12);
            assert!((b_stats.stall_time - o_stats.stall_time).abs() < 1e-12);
        }
    }

    #[test]
    fn overlap_hides_wire_time_behind_compute() {
        // wire takes 2·(latency) = 2s for a tiny payload; 5s of compute
        // posted between start and finish must fully hide it
        let model = CommModel { latency: 1.0, bandwidth: f64::INFINITY };
        let results = run_cluster(2, model, |ctx| {
            let mut buf = vec![1.0f32; 4];
            let p = ctx.all_reduce_start(&buf, Precision::F32);
            ctx.advance(5.0); // overlapped local compute
            ctx.all_reduce_finish(p, &mut buf);
            (ctx.clock(), ctx.stats())
        });
        for (clock, stats) in &results {
            // arrival = max_clock(0) + 2 < clock(5): nothing to wait for
            assert!((clock - 5.0).abs() < 1e-9, "clock {clock}");
            assert_eq!(stats.comm_time, 0.0, "wire time should be hidden");
            assert_eq!(stats.stall_time, 0.0);
            // bytes are still charged — overlap hides time, not traffic
            assert_eq!(stats.bytes_sent, 16);
        }
    }

    #[test]
    fn quantized_reduce_halves_bytes_and_stays_deterministic() {
        let exact = run_cluster(3, CommModel::default(), |ctx| {
            let mut buf = vec![0.1f32 + ctx.rank as f32; 64];
            ctx.all_reduce_sum_q(&mut buf, Precision::F32);
            (buf, ctx.stats().bytes_sent)
        });
        let quant = run_cluster(3, CommModel::default(), |ctx| {
            let mut buf = vec![0.1f32 + ctx.rank as f32; 64];
            ctx.all_reduce_sum_q(&mut buf, Precision::Bf16);
            (buf, ctx.stats().bytes_sent)
        });
        // all ranks agree bit-for-bit within each precision
        for r in 1..3 {
            assert_eq!(exact[0].0, exact[r].0);
            assert_eq!(quant[0].0, quant[r].0);
        }
        // bf16 charges exactly half the exact bytes
        assert_eq!(exact[0].1, 64 * 4);
        assert_eq!(quant[0].1, 64 * 2);
        // and the quantized sum is close but not identical
        let rel = (quant[0].0[0] - exact[0].0[0]).abs() / exact[0].0[0].abs();
        assert!(rel < 1.0 / 128.0, "bf16 sum off by {rel}");
        assert_ne!(exact[0].0, quant[0].0);
    }

    #[test]
    fn quantized_gather_accounts_quantized_bytes() {
        let results = run_cluster(2, CommModel::default(), |ctx| {
            let mine = vec![0.5f32 + ctx.rank as f32; 10];
            let parts = ctx.all_gather_q(&mine, Precision::Fp16);
            (parts, ctx.stats())
        });
        for (parts, stats) in &results {
            assert_eq!(parts.len(), 2);
            for (r, p) in parts.iter().enumerate() {
                let expect = Precision::Fp16.round_trip(0.5 + r as f32);
                assert!(p.iter().all(|&v| v.to_bits() == expect.to_bits()));
            }
            assert_eq!(stats.bytes_sent, 10 * 2); // 10 elems × 2 bytes × 1 peer
            assert_eq!(stats.bytes_received, 10 * 2);
        }
    }
}
