//! In-process simulated cluster: N node threads, deterministic collectives,
//! and a modelled network clock.
//!
//! Every distributed algorithm in the crate ([`crate::algos`],
//! [`crate::secure`]) runs on this substrate. Design contract:
//!
//! * **Determinism** — collectives combine contributions in *rank order*,
//!   so a sum is bit-identical regardless of thread scheduling, and
//!   node-count-invariance tests can compare traces across `N`.
//! * **Simulated time** — each node carries a virtual clock: measured local
//!   compute time (via [`NodeCtx::compute`]) plus modelled wire time from
//!   [`CommModel`]. Synchronous collectives are barriers: everyone leaves at
//!   `max(clock_r) + t_comm`, and the wait shows up as
//!   [`CommStats::stall_time`] — that is how the imbalanced-workload
//!   experiments (paper Fig. 7/9) observe stragglers without real sleeps.
//! * **Out-of-band evaluation** — [`NodeCtx::untimed`] suppresses both the
//!   clock and the byte counters, so error traces can gather factors without
//!   perturbing the measured communication volume (DSANLS's `O(kd)` claim is
//!   asserted on these counters).
//!
//! Byte accounting (per node): an all-reduce charges the payload once (ring
//! schedule, size independent of `N`); an all-gather charges `own·(N−1)`
//! sent — this is what makes the baselines' `O(nk)` gather visibly more
//! expensive than DSANLS's `O(kd)` reduce in `tests/paper_claims.rs`.
//!
//! The asynchronous protocols use [`MailboxHub`] (parameter-server mailbox
//! channels) instead of the barrier collectives — no synchronisation, each
//! client advances its private clock.
//!
//! Intra-node data parallelism is capped inside node threads via
//! [`crate::parallel::set_local_threads`] so `N` nodes × GEMM workers never
//! oversubscribe the machine.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Modelled interconnect: latency (seconds) + bandwidth (bytes/second).
/// Default is a 10 Gbps / 100 µs datacenter link (the paper's cluster is
/// 10 GbE).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// One-way message latency in seconds.
    pub latency: f64,
    /// Link bandwidth in bytes per second.
    pub bandwidth: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel { latency: 100e-6, bandwidth: 1.25e9 }
    }
}

impl CommModel {
    /// Time for one point-to-point message of `bytes`.
    pub fn p2p_time(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Time for a ring all-reduce of a `bytes` payload across `nodes`.
    /// Each node sends ≈2× the payload regardless of `N` (reduce-scatter +
    /// all-gather phases), paying the latency per phase.
    pub fn all_reduce_time(&self, bytes: usize, nodes: usize) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        2.0 * (self.latency + bytes as f64 / self.bandwidth)
    }

    /// Time for an all-gather where this node receives `recv_bytes` in total
    /// from `nodes − 1` peers.
    pub fn all_gather_time(&self, recv_bytes: usize, nodes: usize) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        (nodes - 1) as f64 * self.latency + recv_bytes as f64 / self.bandwidth
    }
}

/// Per-node communication / compute statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    pub bytes_sent: usize,
    pub bytes_received: usize,
    /// Collective / point-to-point operations entered.
    pub messages: usize,
    /// Measured local compute seconds ([`NodeCtx::compute`]).
    pub compute_time: f64,
    /// Modelled wire seconds.
    pub comm_time: f64,
    /// Seconds spent waiting for stragglers at synchronous barriers.
    pub stall_time: f64,
}

// ---------------------------------------------------------------------------
// Deterministic rank-ordered exchange (the collective backbone)
// ---------------------------------------------------------------------------

struct ExchangeState {
    deposited: usize,
    collected: usize,
    slots: Vec<Vec<f32>>,
    max_clock: f64,
}

struct Shared {
    n: usize,
    lock: Mutex<ExchangeState>,
    cv: Condvar,
}

impl Shared {
    fn new(n: usize) -> Self {
        Shared {
            n,
            lock: Mutex::new(ExchangeState {
                deposited: 0,
                collected: 0,
                slots: (0..n).map(|_| Vec::new()).collect(),
                max_clock: 0.0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Deposit `payload`, wait for all ranks, return every rank's payload in
    /// rank order plus the maximum clock observed at the barrier.
    ///
    /// Double-phase barrier: a round is *depositing* until all `n` ranks
    /// arrive, then *collecting* until all `n` have read; only then do the
    /// slots reset, so a fast node re-entering for the next collective
    /// blocks instead of clobbering the previous round.
    fn exchange(&self, rank: usize, clock: f64, payload: Vec<f32>) -> (Vec<Vec<f32>>, f64) {
        if self.n == 1 {
            return (vec![payload], clock);
        }
        let mut g = self.lock.lock().unwrap();
        // wait until the depositing phase of a fresh round is open
        while !(g.deposited < self.n && g.collected == 0) {
            g = self.cv.wait(g).unwrap();
        }
        g.slots[rank] = payload;
        g.max_clock = if g.deposited == 0 { clock } else { g.max_clock.max(clock) };
        g.deposited += 1;
        if g.deposited == self.n {
            self.cv.notify_all();
        }
        while g.deposited < self.n {
            g = self.cv.wait(g).unwrap();
        }
        let out: Vec<Vec<f32>> = g.slots.clone();
        let max_clock = g.max_clock;
        g.collected += 1;
        if g.collected == self.n {
            g.deposited = 0;
            g.collected = 0;
            self.cv.notify_all();
        }
        (out, max_clock)
    }
}

// ---------------------------------------------------------------------------
// Node context
// ---------------------------------------------------------------------------

/// Handle each simulated node receives: identity, virtual clock, statistics
/// and the synchronous collectives.
pub struct NodeCtx<'a> {
    /// This node's rank in `0..nodes`.
    pub rank: usize,
    nodes: usize,
    comm: CommModel,
    clock: f64,
    stats: CommStats,
    suppress: bool,
    shared: &'a Shared,
}

impl<'a> NodeCtx<'a> {
    fn new(rank: usize, nodes: usize, comm: CommModel, shared: &'a Shared) -> Self {
        NodeCtx {
            rank,
            nodes,
            comm,
            clock: 0.0,
            stats: CommStats::default(),
            suppress: false,
            shared,
        }
    }

    /// Number of nodes in the cluster.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Run `f`, measuring its wall time into the virtual clock and
    /// `compute_time`. Returns `f`'s result.
    pub fn compute<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let tick = Instant::now();
        let out = f();
        let dt = tick.elapsed().as_secs_f64();
        if !self.suppress {
            self.clock += dt;
            self.stats.compute_time += dt;
        }
        out
    }

    /// Advance the virtual clock by `dt` seconds of synthetic compute
    /// (failure/skew injection in tests).
    pub fn advance(&mut self, dt: f64) {
        if !self.suppress {
            self.clock += dt;
            self.stats.compute_time += dt;
        }
    }

    /// Run `f` with the clock and the byte counters frozen — for
    /// out-of-band evaluation that must not disturb the measured run.
    /// Collectives inside still synchronise (all ranks must enter them).
    pub fn untimed<T>(&mut self, f: impl FnOnce(&mut NodeCtx<'a>) -> T) -> T {
        let was = self.suppress;
        self.suppress = true;
        let out = f(self);
        self.suppress = was;
        out
    }

    /// In-place all-reduce: `buf ← Σ_r buf_r`, summed in rank order so the
    /// result is bit-identical on every node and for every thread schedule.
    /// All ranks must pass equal-length buffers.
    pub fn all_reduce_sum(&mut self, buf: &mut [f32]) {
        let bytes = std::mem::size_of_val(buf);
        let (slots, max_clock) = self.shared.exchange(self.rank, self.clock, buf.to_vec());
        buf.fill(0.0);
        for slot in &slots {
            debug_assert_eq!(slot.len(), buf.len(), "all_reduce_sum length mismatch");
            for (b, v) in buf.iter_mut().zip(slot.iter()) {
                *b += v;
            }
        }
        if !self.suppress {
            let stall = (max_clock - self.clock).max(0.0);
            let t = self.comm.all_reduce_time(bytes, self.nodes);
            self.stats.stall_time += stall;
            self.stats.comm_time += t;
            self.stats.bytes_sent += bytes;
            self.stats.bytes_received += bytes;
            self.stats.messages += 1;
            self.clock = max_clock + t;
        }
    }

    /// All-gather: every rank contributes a slice (lengths may differ);
    /// returns all contributions in rank order.
    pub fn all_gather(&mut self, data: &[f32]) -> Vec<Vec<f32>> {
        let own = std::mem::size_of_val(data);
        let (slots, max_clock) = self.shared.exchange(self.rank, self.clock, data.to_vec());
        if !self.suppress {
            let total: usize = slots.iter().map(|s| s.len() * 4).sum();
            let recv = total.saturating_sub(own);
            let stall = (max_clock - self.clock).max(0.0);
            let t = self.comm.all_gather_time(recv, self.nodes);
            self.stats.stall_time += stall;
            self.stats.comm_time += t;
            self.stats.bytes_sent += own * self.nodes.saturating_sub(1);
            self.stats.bytes_received += recv;
            self.stats.messages += self.nodes.saturating_sub(1);
            self.clock = max_clock + t;
        }
        slots
    }

    /// Current virtual time in seconds.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Snapshot of the accumulated statistics.
    pub fn stats(&self) -> CommStats {
        self.stats
    }
}

/// Run `f` once per node on its own thread and return the outputs in rank
/// order. Panics in any node propagate. Each node thread caps its intra-node
/// data parallelism at `cores / nodes` so the cluster simulation does not
/// oversubscribe the machine (§Perf: the nested spawn storm inflated
/// per-node wallclock ~5× on 10-node runs before this cap existed).
pub fn run_cluster<T, F>(nodes: usize, comm: CommModel, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&mut NodeCtx<'_>) -> T + Sync,
{
    assert!(nodes > 0, "run_cluster needs at least one node");
    let shared = Shared::new(nodes);
    if nodes == 1 {
        // single node: run inline with full intra-node parallelism
        let mut ctx = NodeCtx::new(0, 1, comm, &shared);
        return vec![f(&mut ctx)];
    }
    let mut out: Vec<Option<T>> = (0..nodes).map(|_| None).collect();
    std::thread::scope(|s| {
        for (rank, slot) in out.iter_mut().enumerate() {
            let shared = &shared;
            let f = &f;
            s.spawn(move || {
                let cores =
                    std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
                crate::parallel::set_local_threads(Some((cores / nodes).max(1)));
                let mut ctx = NodeCtx::new(rank, nodes, comm, shared);
                *slot = Some(f(&mut ctx));
                crate::parallel::set_local_threads(None);
            });
        }
    });
    out.into_iter().map(|o| o.expect("node produced no output")).collect()
}

// ---------------------------------------------------------------------------
// Mailboxes (asynchronous parameter-server transport)
// ---------------------------------------------------------------------------

/// Tag marking a client's final message to the server.
pub const TAG_SHUTDOWN: u64 = u64::MAX;

/// One message on the parameter-server channel.
pub struct Packet {
    /// Sender rank (`usize::MAX` for server replies).
    pub from: usize,
    /// Sender's virtual clock when the packet left.
    pub sent_at: f64,
    pub payload: Vec<f32>,
    pub tag: u64,
}

/// Server side of the mailbox transport: a shared inbox plus one reply
/// channel per client.
pub struct MailboxHub {
    /// Messages from all clients, in arrival order.
    pub inbox: mpsc::Receiver<Packet>,
    replies: Vec<mpsc::Sender<Packet>>,
    delivered: AtomicUsize,
}

/// Client side: send to the server, receive that server's replies.
pub struct Mailbox {
    rank: usize,
    to_hub: mpsc::Sender<Packet>,
    from_hub: mpsc::Receiver<Packet>,
}

impl MailboxHub {
    /// Create a hub and one mailbox per client rank.
    pub fn new(nodes: usize) -> (MailboxHub, Vec<Mailbox>) {
        let (to_hub, inbox) = mpsc::channel();
        let mut replies = Vec::with_capacity(nodes);
        let mut clients = Vec::with_capacity(nodes);
        for rank in 0..nodes {
            let (reply_tx, reply_rx) = mpsc::channel();
            replies.push(reply_tx);
            clients.push(Mailbox { rank, to_hub: to_hub.clone(), from_hub: reply_rx });
        }
        (MailboxHub { inbox, replies, delivered: AtomicUsize::new(0) }, clients)
    }

    /// Reply to client `to`. Returns `Err` if the client already hung up.
    pub fn reply(&self, to: usize, p: Packet) -> Result<(), mpsc::SendError<Packet>> {
        self.delivered.fetch_add(1, Ordering::Relaxed);
        self.replies[to].send(p)
    }

    /// Number of replies successfully handed to clients.
    pub fn delivered(&self) -> usize {
        self.delivered.load(Ordering::Relaxed)
    }
}

impl Mailbox {
    /// Send `payload` to the server, stamped with the local virtual clock.
    pub fn send(&self, clock: f64, tag: u64, payload: Vec<f32>) {
        let _ = self.to_hub.send(Packet { from: self.rank, sent_at: clock, payload, tag });
    }

    /// Block until the server replies.
    pub fn recv(&self) -> Result<Packet, mpsc::RecvError> {
        self.from_hub.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_is_rank_ordered_and_deterministic() {
        for nodes in [1usize, 2, 3, 7] {
            let results = run_cluster(nodes, CommModel::default(), |ctx| {
                let mut buf = vec![(ctx.rank + 1) as f32; 8];
                ctx.all_reduce_sum(&mut buf);
                buf
            });
            let expect: f32 = (1..=nodes).map(|r| r as f32).sum();
            for r in &results {
                assert!(r.iter().all(|&v| v == expect), "{r:?} != {expect}");
            }
        }
    }

    #[test]
    fn all_gather_rank_order() {
        let results = run_cluster(4, CommModel::default(), |ctx| {
            let mine = vec![ctx.rank as f32; ctx.rank + 1]; // ragged lengths
            ctx.all_gather(&mine)
        });
        for gathered in &results {
            assert_eq!(gathered.len(), 4);
            for (rank, block) in gathered.iter().enumerate() {
                assert_eq!(block.len(), rank + 1);
                assert!(block.iter().all(|&v| v == rank as f32));
            }
        }
    }

    #[test]
    fn repeated_collectives_do_not_cross_rounds() {
        // fast nodes must not leak a round-t payload into round t+1
        let results = run_cluster(3, CommModel::default(), |ctx| {
            let mut sums = Vec::new();
            for round in 0..20 {
                let mut buf = vec![(round * 10 + ctx.rank) as f32];
                ctx.all_reduce_sum(&mut buf);
                sums.push(buf[0]);
            }
            sums
        });
        for r in &results {
            for (round, &s) in r.iter().enumerate() {
                let expect = (0..3).map(|rank| (round * 10 + rank) as f32).sum::<f32>();
                assert_eq!(s, expect, "round {round}");
            }
        }
    }

    #[test]
    fn barrier_clock_and_stall_accounting() {
        let results = run_cluster(3, CommModel { latency: 0.0, bandwidth: f64::INFINITY }, |ctx| {
            if ctx.rank == 0 {
                ctx.advance(2.0); // straggler
            }
            let mut buf = [1.0f32; 4];
            ctx.all_reduce_sum(&mut buf);
            (ctx.clock(), ctx.stats())
        });
        for (rank, (clock, stats)) in results.iter().enumerate() {
            assert!((clock - 2.0).abs() < 1e-9, "rank {rank} clock {clock}");
            if rank == 0 {
                assert_eq!(stats.stall_time, 0.0);
            } else {
                assert!((stats.stall_time - 2.0).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn untimed_freezes_clock_and_bytes() {
        let results = run_cluster(2, CommModel::default(), |ctx| {
            ctx.untimed(|ctx| {
                let mut buf = [1.0f32; 256];
                ctx.all_reduce_sum(&mut buf);
                let _ = ctx.all_gather(&buf);
            });
            (ctx.clock(), ctx.stats())
        });
        for (clock, stats) in &results {
            assert_eq!(*clock, 0.0);
            assert_eq!(stats.bytes_sent, 0);
            assert_eq!(stats.bytes_received, 0);
            assert_eq!(stats.messages, 0);
        }
    }

    #[test]
    fn comm_model_times() {
        let c = CommModel { latency: 1e-3, bandwidth: 1e6 };
        assert!((c.p2p_time(1000) - 2e-3).abs() < 1e-12);
        assert_eq!(c.all_reduce_time(1000, 1), 0.0);
        assert!(c.all_reduce_time(1000, 4) > c.p2p_time(1000));
        let free = CommModel { latency: 0.0, bandwidth: f64::INFINITY };
        assert_eq!(free.all_reduce_time(123456, 8), 0.0);
        assert_eq!(free.all_gather_time(123456, 8), 0.0);
    }

    #[test]
    fn mailbox_roundtrip() {
        let (hub, clients) = MailboxHub::new(2);
        std::thread::scope(|s| {
            s.spawn(move || {
                let mut live = 2;
                while live > 0 {
                    let p = hub.inbox.recv().unwrap();
                    if p.tag == TAG_SHUTDOWN {
                        live -= 1;
                        continue;
                    }
                    let doubled: Vec<f32> = p.payload.iter().map(|v| v * 2.0).collect();
                    hub.reply(
                        p.from,
                        Packet { from: usize::MAX, sent_at: p.sent_at, payload: doubled, tag: p.tag },
                    )
                    .unwrap();
                }
            });
            for mb in clients {
                s.spawn(move || {
                    mb.send(0.5, 7, vec![1.0, 2.0]);
                    let reply = mb.recv().unwrap();
                    assert_eq!(reply.payload, vec![2.0, 4.0]);
                    assert_eq!(reply.tag, 7);
                    mb.send(1.0, TAG_SHUTDOWN, Vec::new());
                });
            }
        });
    }
}
