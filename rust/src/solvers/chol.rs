//! Small dense SPD solves (Cholesky) used by the exact NNLS/BPP baseline.

use crate::linalg::Mat;

pub use crate::linalg::dot;

/// Cholesky factorisation `G = L·Lᵀ` of an SPD matrix (lower triangular L,
/// row-major). Returns `None` if a pivot is non-positive (G singular /
/// indefinite) — callers fall back to ridge damping.
pub fn cholesky(g: &Mat) -> Option<Mat> {
    let n = g.rows();
    assert_eq!(g.cols(), n);
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = g.get(i, j) as f64;
            for p in 0..j {
                s -= l.get(i, p) as f64 * l.get(j, p) as f64;
            }
            if i == j {
                if s <= 1e-12 {
                    return None;
                }
                l.set(i, i, s.sqrt() as f32);
            } else {
                l.set(i, j, (s / l.get(j, j) as f64) as f32);
            }
        }
    }
    Some(l)
}

/// Solve `G x = b` given the Cholesky factor `L` (forward + backward subst).
pub fn solve_chol(l: &Mat, b: &[f32], x: &mut [f32]) {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(x.len(), n);
    // L y = b
    for i in 0..n {
        let mut s = b[i] as f64;
        for p in 0..i {
            s -= l.get(i, p) as f64 * x[p] as f64;
        }
        x[i] = (s / l.get(i, i) as f64) as f32;
    }
    // Lᵀ x = y
    for i in (0..n).rev() {
        let mut s = x[i] as f64;
        for p in i + 1..n {
            s -= l.get(p, i) as f64 * x[p] as f64;
        }
        x[i] = (s / l.get(i, i) as f64) as f32;
    }
}

/// Solve `G x = b` for SPD `G`, with automatic ridge fallback when the
/// factorisation fails numerically.
pub fn solve_spd(g: &Mat, b: &[f32], x: &mut [f32]) {
    if let Some(l) = cholesky(g) {
        solve_chol(&l, b, x);
        return;
    }
    // ridge: (G + δI) x = b, escalating δ until the factorisation succeeds
    // (rank-deficient grams arise whenever k exceeds the data's true rank)
    let n = g.rows();
    let mut delta = 1e-6f32.max(1e-7 * g.max_abs());
    for _ in 0..40 {
        let mut damped = g.clone();
        for i in 0..n {
            let v = damped.get(i, i) + delta;
            damped.set(i, i, v);
        }
        if let Some(l) = cholesky(&damped) {
            solve_chol(&l, b, x);
            return;
        }
        delta *= 10.0;
    }
    // pathological input (NaN/inf): fall back to zeros
    x.fill(0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed as u128, 0);
        let b = Mat::rand_uniform(n + 3, n, 1.0, &mut rng);
        b.gram() // Bᵀ·B, SPD w.h.p.
    }

    #[test]
    fn cholesky_reconstructs() {
        let g = random_spd(6, 51);
        let l = cholesky(&g).expect("SPD must factor");
        let llt = l.matmul_nt(&l);
        for (a, b) in llt.data().iter().zip(g.data().iter()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        let g = random_spd(5, 53);
        let b = [1.0f32, -2.0, 0.5, 3.0, -1.5];
        let mut x = [0.0f32; 5];
        solve_spd(&g, &b, &mut x);
        // check G x ≈ b
        for i in 0..5 {
            let got: f32 = (0..5).map(|j| g.get(i, j) * x[j]).sum();
            assert!((got - b[i]).abs() < 1e-2, "row {i}: {got} vs {}", b[i]);
        }
    }

    #[test]
    fn singular_falls_back_to_ridge() {
        let g = Mat::zeros(3, 3); // singular
        let b = [1.0f32, 1.0, 1.0];
        let mut x = [0.0f32; 3];
        solve_spd(&g, &b, &mut x); // must not panic
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
