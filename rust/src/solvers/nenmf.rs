//! NeNMF — Nesterov accelerated gradient NLS solver (Guan et al., cited as
//! [17] in the paper's Sec. 2.1.1). An *extension* baseline: exact-ish NLS
//! solves at `O(1/t²)` rate, sitting between one-step PGD and exact BPP in
//! the cost/accuracy space.
//!
//! Per outer call we run `INNER` Nesterov steps on
//! `min_{X≥0} ‖A − X·B‖²` with step `1/L`, `L = λ_max(G)` estimated by a
//! few power iterations on the k×k gram (cheap: k ≪ m).

use super::Normal;
use crate::linalg::Mat;
use crate::parallel;

/// Nesterov inner iterations per outer call.
pub const INNER: usize = 6;

/// Estimate `λ_max(G)` by power iteration (G is k×k SPD).
pub fn lambda_max(g: &Mat) -> f32 {
    let k = g.rows();
    let mut v = vec![1.0f32 / (k as f32).sqrt(); k];
    let mut lam = 0.0f32;
    for _ in 0..12 {
        let mut w = vec![0.0f32; k];
        for (i, wi) in w.iter_mut().enumerate() {
            *wi = crate::linalg::dot(&v, &g.data()[i * k..(i + 1) * k]);
        }
        let norm = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm <= 1e-20 {
            return 0.0;
        }
        lam = norm;
        for (vi, wi) in v.iter_mut().zip(w.iter()) {
            *vi = wi / norm;
        }
    }
    lam
}

/// NeNMF update: several Nesterov-accelerated projected gradient steps,
/// row-parallel, in place.
pub fn nenmf_update(x: &mut Mat, nrm: &Normal<'_>) {
    let k = nrm.k();
    assert_eq!(x.cols(), k);
    assert_eq!(x.rows(), nrm.rows());
    let g = nrm.gram.data();
    let cross = nrm.cross;
    let lam = lambda_max(nrm.gram);
    if lam <= 0.0 {
        return;
    }
    let inv_l = 1.0 / (2.0 * lam); // f = ‖A−XB‖² has ∇-Lipschitz constant 2λ_max
    parallel::par_chunks_mut(x.data_mut(), 128 * k, |chunk_idx, rows_chunk| {
        let i0 = chunk_idx * 128;
        let n_rows = rows_chunk.len() / k;
        let mut y = vec![0.0f32; k];
        let mut x_prev = vec![0.0f32; k];
        let mut grad = vec![0.0f32; k];
        for li in 0..n_rows {
            let i = i0 + li;
            let xrow = &mut rows_chunk[li * k..(li + 1) * k];
            let crow = cross.row(i);
            y.copy_from_slice(xrow);
            x_prev.copy_from_slice(xrow);
            let mut t_prev = 1.0f32;
            for _ in 0..INNER {
                // grad = 2(y·G − c)
                for (j, gj) in grad.iter_mut().enumerate() {
                    *gj = 2.0 * (crate::linalg::dot(&y, &g[j * k..(j + 1) * k]) - crow[j]);
                }
                // x ← max(y − grad/L, 0)
                for j in 0..k {
                    xrow[j] = (y[j] - inv_l * grad[j]).max(0.0);
                }
                // momentum
                let t = 0.5 * (1.0 + (1.0 + 4.0 * t_prev * t_prev).sqrt());
                let beta = (t_prev - 1.0) / t;
                for j in 0..k {
                    y[j] = xrow[j] + beta * (xrow[j] - x_prev[j]);
                }
                x_prev.copy_from_slice(xrow);
                t_prev = t;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::normal_from;
    use crate::solvers::testutil::*;

    #[test]
    fn lambda_max_bounds_spectrum() {
        let mut rng = crate::rng::Pcg64::new(71, 0);
        let b = Mat::rand_uniform(10, 6, 1.0, &mut rng);
        let g = b.gram();
        let lam = lambda_max(&g);
        // λ_max ≤ trace, λ_max ≥ max diagonal entry
        let trace: f32 = (0..6).map(|j| g.get(j, j)).sum();
        let max_diag = (0..6).map(|j| g.get(j, j)).fold(0.0f32, f32::max);
        assert!(lam <= trace * 1.01, "{lam} vs trace {trace}");
        assert!(lam >= max_diag * 0.99, "{lam} vs max diag {max_diag}");
    }

    #[test]
    fn converges_faster_than_single_pgd_step() {
        let (_, b, a) = random_instance(14, 5, 30, 91);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(72, 0);
        let x0 = Mat::rand_uniform(14, 5, 0.5, &mut rng);

        let mut x_ne = x0.clone();
        nenmf_update(&mut x_ne, &nrm);

        let mut x_pgd = x0.clone();
        let eta = crate::solvers::pgd::safe_eta(&gram, 0);
        crate::solvers::pgd::pgd_update(&mut x_pgd, &nrm, eta);

        let r_ne = residual(&x_ne, &b, &a);
        let r_pgd = residual(&x_pgd, &b, &a);
        assert!(r_ne < r_pgd, "NeNMF {r_ne} must beat one PGD step {r_pgd}");
        assert!(x_ne.is_nonnegative());
    }

    #[test]
    fn repeated_updates_reach_exact_solution() {
        let (xstar, b, a) = random_instance(10, 4, 30, 93);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(73, 0);
        let mut x = Mat::rand_uniform(10, 4, 1.0, &mut rng);
        for _ in 0..80 {
            nenmf_update(&mut x, &nrm);
        }
        assert!(x.dist_sq(&xstar) < 1e-4, "dist² = {}", x.dist_sq(&xstar));
    }
}
