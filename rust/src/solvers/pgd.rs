//! One-step projected gradient descent (paper Sec. 3.5.1, Eq. 14).
//!
//! `X ← max{ X − 2η·(X·G − C), 0 }` with `G = B·Bᵀ`, `C = A·Bᵀ`.
//!
//! Exactly **one** step per outer iteration: on the sketched subproblem the
//! gradient is an unbiased estimator of the true subproblem gradient
//! (Eq. 16), so iterating DSANLS with this update is (generalised) SGD on
//! the original NLS problem; the step sizes must satisfy the
//! Robbins–Monro conditions `Ση = ∞, Ση² < ∞` (Theorem 1).

use super::Normal;
use crate::linalg::Mat;
use crate::parallel;

/// One projected-gradient step in place; `eta` is the step size `η_t`.
pub fn pgd_update(x: &mut Mat, nrm: &Normal<'_>, eta: f32) {
    let k = nrm.k();
    assert_eq!(x.cols(), k);
    assert_eq!(x.rows(), nrm.rows());
    assert!(eta > 0.0, "PGD needs a positive step size");
    let g = nrm.gram.data();
    let cross = nrm.cross;
    parallel::par_chunks_mut(x.data_mut(), 128 * k, |chunk_idx, rows_chunk| {
        let i0 = chunk_idx * 128;
        let n_rows = rows_chunk.len() / k;
        let mut scratch = super::RowScratch::new(k);
        let xg = scratch.slice(k);
        for li in 0..n_rows {
            let i = i0 + li;
            let xrow = &mut rows_chunk[li * k..(li + 1) * k];
            let crow = cross.row(i);
            // xg = x_row · G  (G symmetric ⇒ row-major dot per column)
            for (j, out) in xg.iter_mut().enumerate() {
                *out = crate::linalg::dot(xrow, &g[j * k..(j + 1) * k]);
            }
            for j in 0..k {
                xrow[j] = (xrow[j] - 2.0 * eta * (xg[j] - crow[j])).max(0.0);
            }
        }
    });
}

/// Diminishing step-size schedule `η_t = η₀ / (1 + γ·t)` satisfying
/// `Ση_t = ∞`, `Ση_t² < ∞` (with γ>0 it is Θ(1/t)).
#[derive(Debug, Clone, Copy)]
pub struct StepSchedule {
    pub eta0: f32,
    pub gamma: f32,
}

impl StepSchedule {
    pub fn eta(&self, t: usize) -> f32 {
        self.eta0 / (1.0 + self.gamma * t as f32)
    }
}

/// Gram-aware safe step size: `η_t = 0.45/tr(G) · 1/(1+γ·t)`.
///
/// Gradient descent on `‖A − XB‖²` is stable for `η < 1/(2·λ_max(G))`;
/// `tr(G) ≥ λ_max(G)` bounds it without an eigensolve. The raw
/// `η₀/(1+γt)` schedule diverges to NaN whenever the data scale makes
/// `tr(G)` large — the algorithms must call this instead of hard-coding η.
pub fn safe_eta(gram: &Mat, t: usize) -> f32 {
    let trace: f32 = (0..gram.rows()).map(|j| gram.get(j, j)).sum();
    (0.45 / trace.max(1e-12)) / (1.0 + 0.05 * t as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::normal_from;
    use crate::solvers::testutil::*;

    #[test]
    fn gradient_step_matches_formula() {
        let (_, b, a) = random_instance(4, 3, 10, 21);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(5, 5);
        let x0 = Mat::rand_uniform(4, 3, 1.0, &mut rng);
        let mut x = x0.clone();
        let eta = 0.01;
        pgd_update(&mut x, &nrm, eta);
        // reference: max(X − 2η(XG − C), 0) via full matrix ops
        let xg = x0.matmul(&gram);
        for i in 0..4 {
            for j in 0..3 {
                let expect =
                    (x0.get(i, j) - 2.0 * eta * (xg.get(i, j) - cross.get(i, j))).max(0.0);
                assert!((x.get(i, j) - expect).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn repeated_steps_converge_on_consistent_instance() {
        let (xstar, b, a) = random_instance(6, 3, 40, 23);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        // Lipschitz-safe step: η < 1/(2λ_max(G)); bound λ_max by trace.
        let trace: f32 = (0..3).map(|j| gram.get(j, j)).sum();
        let eta = 0.45 / trace;
        let mut rng = crate::rng::Pcg64::new(6, 6);
        let mut x = Mat::rand_uniform(6, 3, 1.0, &mut rng);
        for _ in 0..3000 {
            pgd_update(&mut x, &nrm, eta);
        }
        assert!(x.dist_sq(&xstar) < 1e-4, "dist² = {}", x.dist_sq(&xstar));
    }

    #[test]
    fn schedule_is_diminishing() {
        let s = StepSchedule { eta0: 0.1, gamma: 0.5 };
        assert!(s.eta(0) > s.eta(1));
        assert!(s.eta(10) > s.eta(100));
        assert!(s.eta(1_000_000) < 1e-5);
    }
}
