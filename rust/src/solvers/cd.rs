//! Proximal coordinate descent — the paper's Alg. 3 and DSANLS's default
//! subproblem solver.
//!
//! Solves one pass of
//! `min_{X≥0} ‖A − X·B‖² + μ‖X − Xᵗ‖²`
//! column-by-column (Gauss–Seidel over the k columns, closed form per
//! column, Eq. 19):
//!
//! ```text
//! X_{:j} ← max{ (μ·Xᵗ_{:j} + C_{:j} − Σ_{l≠j} G_{l j} X_{:l}) / (G_{jj} + μ), 0 }
//! ```
//!
//! with `C = A·Bᵀ`, `G = B·Bᵀ`, columns `l < j` already updated and `l > j`
//! still old — exactly the sweep order of Alg. 3. The μ-regulariser keeps
//! the iterate anchored at `Xᵗ` so the solver does **not** converge to the
//! (shifted) optimum of the sketched subproblem; `μ_t → ∞` drives overall
//! convergence (Theorem 1).
//!
//! The problem is row-independent (Eq. 18), so the sweep runs row-wise:
//! each row performs its own k-column Gauss–Seidel pass entirely in
//! registers/L1 — this is also the access pattern of the L1 Pallas kernel
//! (`python/compile/kernels/proximal_cd.py`), which parallelises rows on
//! the grid and runs the same sequential k-loop per row.

use super::Normal;
use crate::linalg::Mat;
use crate::parallel;

/// One proximal-CD pass over all k columns, in place, parallel over rows.
///
/// `mu` is the proximal weight `μ_t` (the paper uses `μ_t = α + β·t`).
/// `mu = 0` degrades to plain HALS.
pub fn proximal_cd_update(x: &mut Mat, nrm: &Normal<'_>, mu: f32) {
    let k = nrm.k();
    assert_eq!(x.cols(), k);
    assert_eq!(x.rows(), nrm.rows());
    assert!(mu >= 0.0, "negative proximal weight");
    let gram = nrm.gram;
    let cross = nrm.cross;
    let g = gram.data();
    parallel::par_chunks_mut(x.data_mut(), 128 * k, |chunk_idx, rows_chunk| {
        let i0 = chunk_idx * 128;
        let n_rows = rows_chunk.len() / k;
        for li in 0..n_rows {
            let i = i0 + li;
            let xrow = &mut rows_chunk[li * k..(li + 1) * k];
            let crow = cross.row(i);
            for j in 0..k {
                // T = μ·x_old_j + c_j − Σ_{l≠j} G_{lj}·x_l   (x_l mixed old/new)
                // §Perf: branch-free — full vectorisable dot, then add the
                // j-term back (2.3 → ~5 GFLOP/s on the sweep microbench).
                let gcol = &g[j * k..(j + 1) * k]; // row j of G == col j (sym)
                let xj = xrow[j];
                let full = crate::linalg::dot(xrow, gcol);
                let t = mu * xj + crow[j] - (full - gcol[j] * xj);
                let denom = gcol[j] + mu;
                xrow[j] = if denom > 0.0 { (t / denom).max(0.0) } else { 0.0 };
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::testutil::*;
    use crate::solvers::normal_from;

    #[test]
    fn single_column_closed_form() {
        // k = 1: one CD pass IS the exact solution of the regularised problem:
        // x = max((μ x⁰ + c) / (g + μ), 0)
        let (_, b, a) = random_instance(5, 1, 9, 3);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(1, 1);
        let x0 = Mat::rand_uniform(5, 1, 1.0, &mut rng);
        let mut x = x0.clone();
        let mu = 0.7;
        proximal_cd_update(&mut x, &nrm, mu);
        for i in 0..5 {
            let expect = ((mu * x0.get(i, 0) + cross.get(i, 0)) / (gram.get(0, 0) + mu)).max(0.0);
            assert!((x.get(i, 0) - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn mu_zero_recovers_exact_on_easy_instance() {
        // With μ=0 and repeated sweeps, CD converges to the exact NLS
        // solution; on a consistent instance (A = X*·B) that is X*.
        let (xstar, b, a) = random_instance(8, 3, 30, 11);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(2, 2);
        let mut x = Mat::rand_uniform(8, 3, 1.0, &mut rng);
        for _ in 0..200 {
            proximal_cd_update(&mut x, &nrm, 0.0);
        }
        assert!(
            x.dist_sq(&xstar) < 1e-5,
            "CD did not reach the generator: dist² = {}",
            x.dist_sq(&xstar)
        );
    }

    #[test]
    fn large_mu_freezes_iterate() {
        // μ → ∞ must pin X at Xᵗ (proximal anchoring).
        let (_, b, a) = random_instance(6, 4, 15, 5);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(3, 3);
        let x0 = Mat::rand_uniform(6, 4, 1.0, &mut rng);
        let mut x = x0.clone();
        proximal_cd_update(&mut x, &nrm, 1e9);
        assert!(x.dist_sq(&x0) < 1e-6, "large μ moved the iterate");
    }

    #[test]
    fn monotone_descent_of_regularised_objective() {
        // One full sweep must not increase ‖A−XB‖² + μ‖X−X⁰‖² (exact
        // coordinate minimisation of a convex function).
        let (_, b, a) = random_instance(10, 5, 25, 17);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(4, 4);
        let x0 = Mat::rand_uniform(10, 5, 1.0, &mut rng);
        let mu = 2.5;
        let obj = |x: &Mat| residual(x, &b, &a) + mu as f64 * x.dist_sq(&x0);
        let mut x = x0.clone();
        let before = obj(&x);
        proximal_cd_update(&mut x, &nrm, mu);
        let after = obj(&x);
        assert!(after <= before + 1e-9, "{before} -> {after}");
    }
}
