//! Multiplicative updates (Lee–Seung), the "MU" baseline of Fig. 2.
//!
//! `X ← X ∘ C ./ (X·G + ε)` with `C = A·Bᵀ ≥ 0`, `G = B·Bᵀ`.
//!
//! Majorisation–minimisation: the objective decreases monotonically when
//! `A ≥ 0` elementwise (true for NMF inputs). MU never leaves the
//! nonnegative orthant and never zeroes an entry exactly (it multiplies),
//! which is why it converges slowly near sparse solutions — visible in the
//! paper's Fig. 2 where MU "converges relatively slowly and usually has a
//! bad convergence result".

use super::Normal;
use crate::linalg::Mat;
use crate::parallel;

/// Damping added to the denominator for numerical safety.
pub const MU_EPS: f32 = 1e-9;

/// One multiplicative update in place.
pub fn mu_update(x: &mut Mat, nrm: &Normal<'_>) {
    let k = nrm.k();
    assert_eq!(x.cols(), k);
    assert_eq!(x.rows(), nrm.rows());
    let g = nrm.gram.data();
    let cross = nrm.cross;
    parallel::par_chunks_mut(x.data_mut(), 128 * k, |chunk_idx, rows_chunk| {
        let i0 = chunk_idx * 128;
        let n_rows = rows_chunk.len() / k;
        let mut scratch = super::RowScratch::new(k);
        let xg = scratch.slice(k);
        for li in 0..n_rows {
            let i = i0 + li;
            let xrow = &mut rows_chunk[li * k..(li + 1) * k];
            let crow = cross.row(i);
            for (j, out) in xg.iter_mut().enumerate() {
                *out = crate::linalg::dot(xrow, &g[j * k..(j + 1) * k]);
            }
            for j in 0..k {
                let num = crow[j].max(0.0); // guard: sketched C may dip <0
                xrow[j] *= num / (xg[j] + MU_EPS);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::normal_from;
    use crate::solvers::testutil::*;

    #[test]
    fn objective_monotone_decrease() {
        let (_, b, a) = random_instance(10, 4, 18, 41);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(10, 10);
        let mut x = Mat::rand_uniform(10, 4, 1.0, &mut rng);
        let mut prev = residual(&x, &b, &a);
        for _ in 0..50 {
            mu_update(&mut x, &nrm);
            let cur = residual(&x, &b, &a);
            assert!(cur <= prev + 1e-6, "MU increased the objective: {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn stays_strictly_nonnegative() {
        let (_, b, a) = random_instance(6, 3, 10, 43);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(11, 11);
        let mut x = Mat::rand_uniform(6, 3, 1.0, &mut rng);
        for _ in 0..20 {
            mu_update(&mut x, &nrm);
            assert!(x.is_nonnegative());
            assert!(!x.has_non_finite());
        }
    }

    #[test]
    fn fixed_point_at_exact_solution() {
        // At X = X* (consistent instance) the update is ≈ identity.
        let (xstar, b, a) = random_instance(5, 3, 20, 47);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut x = xstar.clone();
        mu_update(&mut x, &nrm);
        assert!(x.dist_sq(&xstar) < 1e-6);
    }
}
