//! HALS — hierarchical alternating least squares (exact cyclic coordinate
//! descent), one of the MPI-FAUN baselines (Sec. 2.1.1 / Fig. 2 "HALS").
//!
//! Identical sweep to [`super::cd`] with `μ = 0`: each column update is the
//! exact minimiser of the (unregularised) NLS objective in that coordinate
//! block. On the *unsketched* subproblem this is the classic fast NMF
//! solver; on a sketched subproblem it must NOT be used (it converges to
//! the shifted optimum — the reason the paper adds the proximal term).

use super::{cd, Normal};
use crate::linalg::Mat;

/// One HALS sweep in place.
pub fn hals_update(x: &mut Mat, nrm: &Normal<'_>) {
    cd::proximal_cd_update(x, nrm, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::normal_from;
    use crate::solvers::testutil::*;

    #[test]
    fn hals_is_cd_with_zero_mu() {
        let (_, b, a) = random_instance(7, 3, 12, 31);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(8, 8);
        let x0 = Mat::rand_uniform(7, 3, 1.0, &mut rng);
        let mut x1 = x0.clone();
        let mut x2 = x0;
        hals_update(&mut x1, &nrm);
        cd::proximal_cd_update(&mut x2, &nrm, 0.0);
        assert_eq!(x1.data(), x2.data());
    }

    #[test]
    fn converges_to_exact_solution() {
        let (xstar, b, a) = random_instance(9, 4, 35, 37);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut rng = crate::rng::Pcg64::new(9, 9);
        let mut x = Mat::rand_uniform(9, 4, 1.0, &mut rng);
        for _ in 0..300 {
            hals_update(&mut x, &nrm);
        }
        assert!(x.dist_sq(&xstar) < 1e-5);
    }
}
