//! Exact NNLS via Block Principal Pivoting (Kim & Park 2011) — the
//! "ANLS/BPP" baseline the paper benchmarks against (MPI-FAUN-ABPP).
//!
//! Per row `x` of the factor we solve the strictly convex QP
//! `min_{x≥0} ½·xᵀGx − cᵀx` exactly, by maintaining a partition of the
//! variables into a free set `F` (x_F > 0, y_F = 0) and an active set
//! (x = 0, y ≥ 0), where `y = Gx − c` is the dual. Exchanges follow the
//! full-exchange rule with Murty's single-variable backup to guarantee
//! finite termination.
//!
//! Complexity per row is `O(#pivots · |F|³)` — the reason Fig. 3 shows
//! ANLS/BPP with the **highest** per-iteration cost of all baselines.

use super::Normal;
use crate::linalg::Mat;
use crate::parallel;
use crate::solvers::chol;

/// Solve `min_{x≥0} ‖a − x·B‖²` exactly for every row of `x`, in place.
pub fn nnls_bpp_update(x: &mut Mat, nrm: &Normal<'_>) {
    let k = nrm.k();
    assert_eq!(x.cols(), k);
    assert_eq!(x.rows(), nrm.rows());
    let gram = nrm.gram;
    let cross = nrm.cross;
    parallel::par_chunks_mut(x.data_mut(), 32 * k, |chunk_idx, rows_chunk| {
        let i0 = chunk_idx * 32;
        let n_rows = rows_chunk.len() / k;
        let mut ws = Workspace::new(k);
        for li in 0..n_rows {
            let i = i0 + li;
            let xrow = &mut rows_chunk[li * k..(li + 1) * k];
            nnls_bpp_row(gram, cross.row(i), xrow, &mut ws);
        }
    });
}

/// Reusable per-thread scratch.
struct Workspace {
    free: Vec<bool>,
    y: Vec<f32>,
    sub_c: Vec<f32>,
    sub_x: Vec<f32>,
    idx: Vec<usize>,
}

impl Workspace {
    fn new(k: usize) -> Self {
        Workspace {
            free: vec![false; k],
            y: vec![0.0; k],
            sub_c: vec![0.0; k],
            sub_x: vec![0.0; k],
            idx: Vec::with_capacity(k),
        }
    }
}

/// Exact NNLS for one row: KKT via block principal pivoting.
fn nnls_bpp_row(g: &Mat, c: &[f32], x: &mut [f32], ws: &mut Workspace) {
    let k = c.len();
    const TOL: f32 = 1e-7;

    // start from the all-active partition: x = 0, y = −c
    ws.free.iter_mut().for_each(|f| *f = false);
    x.iter_mut().for_each(|v| *v = 0.0);
    for j in 0..k {
        ws.y[j] = -c[j];
    }

    let mut backup_budget = 3usize; // p in Kim–Park: full exchanges left before backup rule
    let mut lowest_infeasible = usize::MAX;
    let max_pivots = 5 * k + 10;

    for _ in 0..max_pivots {
        // infeasible variables: free with x<0, or active with y<0
        let mut n_bad = 0usize;
        let mut last_bad = usize::MAX;
        for j in 0..k {
            let bad = if ws.free[j] { x[j] < -TOL } else { ws.y[j] < -TOL };
            if bad {
                n_bad += 1;
                last_bad = j;
            }
        }
        if n_bad == 0 {
            // feasible: clip tiny negatives from roundoff
            for v in x.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            return;
        }

        if n_bad < lowest_infeasible {
            lowest_infeasible = n_bad;
            backup_budget = 3;
            // full exchange: flip every infeasible variable
            for j in 0..k {
                let bad = if ws.free[j] { x[j] < -TOL } else { ws.y[j] < -TOL };
                if bad {
                    ws.free[j] = !ws.free[j];
                }
            }
        } else if backup_budget > 0 {
            backup_budget -= 1;
            for j in 0..k {
                let bad = if ws.free[j] { x[j] < -TOL } else { ws.y[j] < -TOL };
                if bad {
                    ws.free[j] = !ws.free[j];
                }
            }
        } else {
            // Murty's backup rule: flip only the largest-index infeasible
            ws.free[last_bad] = !ws.free[last_bad];
        }

        solve_partition(g, c, &ws.free.clone(), x, ws);
    }
    // Fallback (should not happen): project
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Given partition `free`, solve `G_FF x_F = c_F`, set x elsewhere to 0,
/// and recompute the dual `y = Gx − c` on the active set.
fn solve_partition(g: &Mat, c: &[f32], free: &[bool], x: &mut [f32], ws: &mut Workspace) {
    let k = c.len();
    ws.idx.clear();
    for j in 0..k {
        if free[j] {
            ws.idx.push(j);
        }
    }
    let f = ws.idx.len();
    for j in 0..k {
        if !free[j] {
            x[j] = 0.0;
        }
    }
    if f > 0 {
        // gather G_FF and c_F
        let mut sub_g = Mat::zeros(f, f);
        for (a, &ja) in ws.idx.iter().enumerate() {
            for (b, &jb) in ws.idx.iter().enumerate() {
                sub_g.set(a, b, g.get(ja, jb));
            }
            ws.sub_c[a] = c[ja];
        }
        chol::solve_spd(&sub_g, &ws.sub_c[..f], &mut ws.sub_x[..f]);
        for (a, &ja) in ws.idx.iter().enumerate() {
            x[ja] = ws.sub_x[a];
        }
    }
    // dual on active set: y = G x − c
    for j in 0..k {
        if free[j] {
            ws.y[j] = 0.0;
        } else {
            let mut s = -c[j];
            for (a, &ja) in ws.idx.iter().enumerate() {
                let _ = a;
                s += g.get(j, ja) * x[ja];
            }
            ws.y[j] = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::normal_from;
    use crate::solvers::testutil::*;

    #[test]
    fn exact_on_consistent_instance() {
        // A = X*·B with X* ≥ 0 ⇒ the NNLS solution is X* itself.
        let (xstar, b, a) = random_instance(10, 5, 30, 61);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut x = Mat::zeros(10, 5);
        nnls_bpp_update(&mut x, &nrm);
        assert!(x.dist_sq(&xstar) < 1e-4, "dist² = {}", x.dist_sq(&xstar));
    }

    #[test]
    fn kkt_conditions_hold() {
        // On a generic (inconsistent) instance, verify the KKT system:
        // x ≥ 0, y = Gx − c ≥ 0, x∘y = 0.
        let mut rng = crate::rng::Pcg64::new(12, 12);
        let a = Mat::rand_gaussian(8, 25, 1.0, rng.clone());
        let b = Mat::rand_uniform(4, 25, 1.0, &mut rng);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        let mut x = Mat::zeros(8, 4);
        nnls_bpp_update(&mut x, &nrm);
        assert!(x.is_nonnegative());
        for i in 0..8 {
            for j in 0..4 {
                let mut y = -cross.get(i, j);
                for l in 0..4 {
                    y += gram.get(j, l) * x.get(i, l);
                }
                assert!(y > -1e-2, "dual feasibility violated: y[{i},{j}] = {y}");
                let comp = y * x.get(i, j);
                assert!(comp.abs() < 1e-2, "complementarity violated: {comp}");
            }
        }
    }

    #[test]
    fn beats_or_matches_every_other_solver() {
        // BPP is exact: after one update its residual must be ≤ the
        // residual of many HALS sweeps.
        let mut rng = crate::rng::Pcg64::new(13, 13);
        let a = Mat::rand_uniform(12, 40, 1.0, &mut rng);
        let b = Mat::rand_uniform(6, 40, 1.0, &mut rng);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);

        let mut x_bpp = Mat::zeros(12, 6);
        nnls_bpp_update(&mut x_bpp, &nrm);

        let mut x_hals = Mat::rand_uniform(12, 6, 0.5, &mut rng);
        for _ in 0..100 {
            crate::solvers::hals::hals_update(&mut x_hals, &nrm);
        }
        let r_bpp = residual(&x_bpp, &b, &a);
        let r_hals = residual(&x_hals, &b, &a);
        assert!(
            r_bpp <= r_hals + 1e-3 * r_hals.abs().max(1.0),
            "BPP {r_bpp} worse than HALS {r_hals}"
        );
    }
}
