//! Nonnegative least-squares subproblem solvers.
//!
//! Every NMF iteration solves (exactly or approximately) a row-independent
//! NLS problem in **normal-equation form**: given the Gram matrix
//! `G = B·Bᵀ (k×k)` and cross-products `C = A·Bᵀ (rows×k)`, update each row
//! `x` of the factor towards `min_{x≥0} ‖a − x B‖²` — whose gradient is
//! `2(x·G − c)`.
//!
//! Solvers:
//! * [`cd::proximal_cd_update`]   — the paper's Alg. 3 (DSANLS default);
//! * [`pgd::pgd_update`]          — one projected-gradient step (Sec. 3.5.1,
//!   ≡ SGD on the unsketched problem);
//! * [`hals::hals_update`]        — HALS cyclic coordinate descent (exact CD,
//!   baseline, also "MPI-FAUN-HALS");
//! * [`mu::mu_update`]            — Lee–Seung multiplicative updates;
//! * [`bpp::nnls_bpp_update`]     — block principal pivoting, the exact
//!   ANLS/BPP solver ("MPI-FAUN-ABPP").
//!
//! All operate on a `rows×k` factor **in place**, parallelised over rows,
//! and allocate nothing per call beyond what the caller supplies.

pub mod bpp;
pub mod cd;
pub mod chol;
pub mod hals;
pub mod mu;
pub mod nenmf;
pub mod pgd;

use crate::linalg::{gemm_nn, gemm_nt, gemm_tn, Mat, Matrix};

/// Which subproblem solver an algorithm uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    /// Proximal coordinate descent (Alg. 3) — DSANLS default.
    ProximalCd,
    /// One projected gradient step (Sec. 3.5.1).
    Pgd,
    /// HALS exact cyclic CD (baseline).
    Hals,
    /// Multiplicative updates (baseline).
    Mu,
    /// Exact NNLS via block principal pivoting (baseline).
    AnlsBpp,
    /// Nesterov accelerated gradient (NeNMF, extension baseline).
    NeNmf,
}

impl SolverKind {
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::ProximalCd => "rcd",
            SolverKind::Pgd => "pgd",
            SolverKind::Hals => "hals",
            SolverKind::Mu => "mu",
            SolverKind::AnlsBpp => "anls-bpp",
            SolverKind::NeNmf => "nenmf",
        }
    }
}

impl std::str::FromStr for SolverKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "rcd" | "cd" | "proximal-cd" => Ok(SolverKind::ProximalCd),
            "pgd" => Ok(SolverKind::Pgd),
            "hals" => Ok(SolverKind::Hals),
            "mu" => Ok(SolverKind::Mu),
            "bpp" | "anls-bpp" | "abpp" => Ok(SolverKind::AnlsBpp),
            "nenmf" => Ok(SolverKind::NeNmf),
            other => Err(format!("unknown solver: {other}")),
        }
    }
}

/// Normal-equation operands shared by all solvers:
/// `gram = B·Bᵀ` (k×k) and `cross = A·Bᵀ` (rows×k).
pub struct Normal<'a> {
    pub gram: &'a Mat,
    pub cross: &'a Mat,
}

impl<'a> Normal<'a> {
    pub fn new(gram: &'a Mat, cross: &'a Mat) -> Self {
        assert_eq!(gram.rows(), gram.cols(), "gram must be square");
        assert_eq!(gram.rows(), cross.cols(), "gram k != cross k");
        Normal { gram, cross }
    }

    pub fn k(&self) -> usize {
        self.gram.rows()
    }

    pub fn rows(&self) -> usize {
        self.cross.rows()
    }
}

/// Compute `gram = B·Bᵀ` and `cross = A·Bᵀ` from raw operands.
/// `a: rows×d`, `b: k×d` (both in the *sketched* coordinate system).
///
/// Allocates fresh outputs; iteration loops should prefer
/// [`Workspace::normal_from`], which reuses scratch across iterations.
pub fn normal_from(a: &Mat, b: &Mat) -> (Mat, Mat) {
    let gram = b.matmul_nt(b);
    let cross = a.matmul_nt(b);
    (gram, cross)
}

/// Reusable per-iteration scratch for the normal-equation operands.
///
/// Every ANLS-style iteration needs a `k×k` gram and a `rows×k` cross
/// matrix; allocating them fresh each iteration put two heap round-trips
/// (plus page faults on first touch) inside the hot loop. A `Workspace`
/// owns both buffers and regrows them only when shapes change, so
/// steady-state iterations perform **zero** allocations in the
/// GEMM → normal-equation → solver kernel path (asserted single-threaded
/// by `tests/alloc_hotpath.rs`; multithreaded runs add only O(1)
/// pool-dispatch bookkeeping per parallel region). One workspace per
/// node/loop; it is not shareable across threads by design (each
/// simulated node owns its own).
#[derive(Debug)]
pub struct Workspace {
    gram: Mat,
    cross: Mat,
    /// Ping-pong pair for the overlapped pipeline: while one slot's
    /// sketched operand feeds the current normal equations, the next
    /// iteration's operand is prefetched into the other slot.
    pipe: [Mat; 2],
    /// Reduction payload scratch for the overlapped pipeline (the `k×d`
    /// summand posted to the non-blocking all-reduce).
    summand: Mat,
}

impl Default for Workspace {
    fn default() -> Self {
        Self::new()
    }
}

impl Workspace {
    pub fn new() -> Self {
        Workspace {
            gram: Mat::zeros(0, 0),
            cross: Mat::zeros(0, 0),
            pipe: [Mat::zeros(0, 0), Mat::zeros(0, 0)],
            summand: Mat::zeros(0, 0),
        }
    }

    /// Move pipeline buffer `slot` out of the workspace, leaving an empty
    /// matrix behind (`Mat::zeros(0, 0)` holds no heap storage, so the
    /// swap allocates nothing). The dance exists for the borrow checker:
    /// the taken buffer is borrowed immutably as a [`Workspace::normal_from`]
    /// operand while the workspace itself is borrowed mutably. Pair every
    /// take with a [`Workspace::restore_pipe`] so the buffer's capacity
    /// survives into the next iteration.
    pub fn take_pipe(&mut self, slot: usize) -> Mat {
        std::mem::replace(&mut self.pipe[slot], Mat::zeros(0, 0))
    }

    /// Return a buffer taken by [`Workspace::take_pipe`].
    pub fn restore_pipe(&mut self, slot: usize, m: Mat) {
        self.pipe[slot] = m;
    }

    /// Move the reduction-summand scratch out (same discipline as
    /// [`Workspace::take_pipe`]).
    pub fn take_summand(&mut self) -> Mat {
        std::mem::replace(&mut self.summand, Mat::zeros(0, 0))
    }

    /// Return the buffer taken by [`Workspace::take_summand`].
    pub fn restore_summand(&mut self, m: Mat) {
        self.summand = m;
    }

    /// Sketched operands: `gram = B·Bᵀ` (k×k), `cross = A·Bᵀ` (rows×k)
    /// with `a: rows×d`, `b: k×d` — the [`normal_from`] equivalent that
    /// writes into owned scratch.
    pub fn normal_from(&mut self, a: &Mat, b: &Mat) -> Normal<'_> {
        assert_eq!(a.cols(), b.cols(), "sketched operands disagree on d");
        self.gram.resize_to(b.rows(), b.rows());
        gemm_nt(b, b, &mut self.gram);
        self.cross.resize_to(a.rows(), b.rows());
        gemm_nt(a, b, &mut self.cross);
        Normal::new(&self.gram, &self.cross)
    }

    /// Unsketched operands: `gram = FᵀF` (k×k), `cross = M·F` (rows×k)
    /// for the exact subproblem `min_{X≥0} ‖M − X·Fᵀ‖`.
    pub fn normal_unsketched(&mut self, m: &Matrix, fixed: &Mat) -> Normal<'_> {
        let k = fixed.cols();
        self.gram.resize_to(k, k);
        gemm_tn(fixed, fixed, &mut self.gram);
        match m {
            Matrix::Dense(md) => {
                assert_eq!(md.cols(), fixed.rows());
                self.cross.resize_to(md.rows(), k);
                gemm_nn(md, fixed, &mut self.cross);
            }
            Matrix::Sparse(ms) => ms.spmm_into(fixed, &mut self.cross),
        }
        Normal::new(&self.gram, &self.cross)
    }

    /// Buffer identities (gram ptr, cross ptr) — lets tests assert that
    /// steady-state iterations reuse rather than reallocate.
    pub fn scratch_ptrs(&self) -> (usize, usize) {
        (self.gram.data().as_ptr() as usize, self.cross.data().as_ptr() as usize)
    }

    /// Buffer identities of the pipeline scratch (pipe 0, pipe 1,
    /// summand) — the overlapped-iteration analogue of
    /// [`Workspace::scratch_ptrs`]. Only meaningful while the buffers are
    /// resident (not taken).
    pub fn pipeline_ptrs(&self) -> (usize, usize, usize) {
        (
            self.pipe[0].data().as_ptr() as usize,
            self.pipe[1].data().as_ptr() as usize,
            self.summand.data().as_ptr() as usize,
        )
    }
}

/// Per-row-sweep `x·G` scratch of length `k`: stack-backed for every
/// realistic rank (`k ≤ 128`), heap only beyond — keeps the PGD/MU row
/// sweeps allocation-free in steady state. Shared by [`pgd`] and [`mu`].
pub(crate) struct RowScratch {
    stack: [f32; 128],
    heap: Vec<f32>,
}

impl RowScratch {
    pub(crate) fn new(k: usize) -> Self {
        RowScratch {
            stack: [0.0; 128],
            heap: if k > 128 { vec![0.0; k] } else { Vec::new() },
        }
    }

    pub(crate) fn slice(&mut self, k: usize) -> &mut [f32] {
        if k <= 128 {
            &mut self.stack[..k]
        } else {
            &mut self.heap[..k]
        }
    }
}

/// Dispatch an in-place factor update for `min_{X≥0} ‖A − X·B‖²` given the
/// precomputed normal operands. `step` parametrises the solver (η for PGD,
/// μ for proximal CD; ignored by the exact baselines).
pub fn update(kind: SolverKind, x: &mut Mat, nrm: &Normal<'_>, step: f32) {
    match kind {
        SolverKind::ProximalCd => cd::proximal_cd_update(x, nrm, step),
        SolverKind::Pgd => pgd::pgd_update(x, nrm, step),
        SolverKind::Hals => hals::hals_update(x, nrm),
        SolverKind::Mu => mu::mu_update(x, nrm),
        SolverKind::AnlsBpp => bpp::nnls_bpp_update(x, nrm),
        SolverKind::NeNmf => nenmf::nenmf_update(x, nrm),
    }
}

/// Like [`update`], but derives a *stable* step internally: `μ_t` from the
/// schedule for proximal CD, the gram-aware [`pgd::safe_eta`] for PGD.
/// Every iterative algorithm in the crate funnels through this.
pub fn update_auto(
    kind: SolverKind,
    x: &mut Mat,
    nrm: &Normal<'_>,
    mu: &crate::nmf::MuSchedule,
    t: usize,
) {
    let step = match kind {
        SolverKind::ProximalCd => mu.mu(t),
        SolverKind::Pgd => pgd::safe_eta(nrm.gram, t),
        _ => 0.0,
    };
    update(kind, x, nrm, step);
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::rng::Pcg64;

    /// Random well-conditioned NLS instance with a known nonnegative
    /// generator: A = X* · B with X* ≥ 0.
    pub fn random_instance(rows: usize, k: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::new(seed as u128, 0);
        let xstar = Mat::rand_uniform(rows, k, 1.0, &mut rng);
        let b = Mat::rand_uniform(k, d, 1.0, &mut rng);
        let a = xstar.matmul(&b);
        (xstar, b, a)
    }

    /// ‖A − X·B‖²_F
    pub fn residual(x: &Mat, b: &Mat, a: &Mat) -> f64 {
        a.dist_sq(&x.matmul(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::*;

    #[test]
    fn workspace_matches_allocating_normal_from() {
        let mut rng = crate::rng::Pcg64::new(71, 3);
        let a = Mat::rand_uniform(20, 15, 1.0, &mut rng);
        let b = Mat::rand_uniform(4, 15, 1.0, &mut rng);
        let (gram, cross) = normal_from(&a, &b);
        let mut ws = Workspace::new();
        {
            let nrm = ws.normal_from(&a, &b);
            assert_eq!(nrm.gram.data(), gram.data());
            assert_eq!(nrm.cross.data(), cross.data());
        }
        // steady state: same shapes ⇒ same buffers (no reallocation)
        let ptrs = ws.scratch_ptrs();
        for _ in 0..3 {
            let _ = ws.normal_from(&a, &b);
            assert_eq!(ws.scratch_ptrs(), ptrs, "workspace reallocated in steady state");
        }
        // unsketched path agrees with the direct formulas, dense and sparse
        let m_dense = Mat::rand_uniform(12, 9, 1.0, &mut rng);
        let fixed = Mat::rand_uniform(9, 4, 1.0, &mut rng);
        let want_gram = fixed.gram();
        let want_cross = m_dense.matmul(&fixed);
        {
            let nrm = ws.normal_unsketched(&Matrix::Dense(m_dense.clone()), &fixed);
            assert_eq!(nrm.gram.data(), want_gram.data());
            assert_eq!(nrm.cross.data(), want_cross.data());
        }
        let sparse = crate::linalg::Csr::from_dense(&m_dense, 0.5);
        let want_sparse_cross = sparse.spmm(&fixed);
        let nrm = ws.normal_unsketched(&Matrix::Sparse(sparse), &fixed);
        assert_eq!(nrm.cross.data(), want_sparse_cross.data());
    }

    #[test]
    fn all_solvers_decrease_residual() {
        let (_, b, a) = random_instance(12, 4, 20, 42);
        let (gram, cross) = normal_from(&a, &b);
        let nrm = Normal::new(&gram, &cross);
        for kind in [
            SolverKind::ProximalCd,
            SolverKind::Pgd,
            SolverKind::Hals,
            SolverKind::Mu,
            SolverKind::AnlsBpp,
        ] {
            let mut rng = crate::rng::Pcg64::new(7, 7);
            let mut x = Mat::rand_uniform(12, 4, 0.5, &mut rng);
            let before = residual(&x, &b, &a);
            let step = match kind {
                SolverKind::Pgd => 0.02,
                SolverKind::ProximalCd => 1.0,
                _ => 0.0,
            };
            update(kind, &mut x, &nrm, step);
            let after = residual(&x, &b, &a);
            assert!(after < before, "{kind:?}: {before} -> {after}");
            assert!(x.is_nonnegative(), "{kind:?} violated nonnegativity");
        }
    }
}
