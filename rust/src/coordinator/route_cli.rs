//! `dsanls route` — the replicated-serving router CLI.
//!
//! Fronts a set of `dsanls serve` replicas with a consistent-hash
//! router ([`crate::router`]) on one address. Clients keep using plain
//! `dsanls query --addr ROUTER`; replicas come from `--replicas
//! host:port,...` or `--hosts FILE` (one address per line, `#`
//! comments — the same file format `dsanls launch` uses, so a serving
//! fleet can reuse the training address book).

use std::time::Duration;

use crate::error::{Context, Result};
use crate::router::{route, RouteOptions};

fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn parse_num<T: std::str::FromStr>(args: &[String], flag: &str) -> Result<Option<T>> {
    match flag_value(args, flag) {
        None => Ok(None),
        Some(v) => v
            .parse::<T>()
            .map(Some)
            .map_err(|_| crate::err!("{flag} expects a number, got {v:?}")),
    }
}

/// Parse the replica list from `--replicas` (comma-separated) or
/// `--hosts FILE` (one per line, blank lines and `#` comments skipped).
fn parse_replicas(args: &[String]) -> Result<Vec<String>> {
    let replicas: Vec<String> = if let Some(list) = flag_value(args, "--replicas") {
        list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
    } else if let Some(path) = flag_value(args, "--hosts") {
        std::fs::read_to_string(path)
            .with_context(|| format!("reading replica hosts file {path}"))?
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(String::from)
            .collect()
    } else {
        crate::bail!("route needs --replicas host:port,... or --hosts FILE");
    };
    if replicas.is_empty() {
        crate::bail!("route: replica list is empty");
    }
    Ok(replicas)
}

/// Entry point for `dsanls route --replicas host:port,... --bind ADDR`.
pub fn route_main(args: &[String]) -> Result<()> {
    let replicas = parse_replicas(args)?;
    let bind = flag_value(args, "--bind").unwrap_or("127.0.0.1:7979");

    let mut opts = RouteOptions::default();
    if let Some(v) = parse_num::<usize>(args, "--vnodes")? {
        opts.vnodes = v.max(1);
    }
    if let Some(ms) = parse_num::<u64>(args, "--timeout-ms")? {
        opts.io_timeout = Duration::from_millis(ms.max(1));
    }
    if let Some(ms) = parse_num::<u64>(args, "--cooldown-ms")? {
        opts.cooldown = Duration::from_millis(ms);
    }

    let handle = route(bind, &replicas, opts)?;
    // the line the deploy walkthrough (and any operator script) waits for
    println!("routing on {} across {} replicas", handle.addr(), replicas.len());
    // route until killed (SIGINT/SIGTERM); the threads own all the work
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn replica_list_parsing() {
        assert_eq!(
            parse_replicas(&s(&["--replicas", "a:1, b:2,c:3"])).unwrap(),
            vec!["a:1", "b:2", "c:3"]
        );
        assert!(parse_replicas(&s(&["--replicas", " , "])).is_err());
        assert!(parse_replicas(&s(&[])).is_err());
        let path = std::env::temp_dir().join(format!("dsanls_hosts_{}", std::process::id()));
        std::fs::write(&path, "# serving fleet\nhost-a:7878\n\n  host-b:7878\n").unwrap();
        let args = s(&["--hosts", path.to_str().unwrap()]);
        assert_eq!(parse_replicas(&args).unwrap(), vec!["host-a:7878", "host-b:7878"]);
        let _ = std::fs::remove_file(&path);
    }
}
