//! Multi-process deployment: the `dsanls launch` coordinator and the
//! `dsanls worker` rank entry point.
//!
//! `dsanls launch --nodes N [--config cfg.toml] [--key=value ...]` binds a
//! [`Rendezvous`] listener, spawns `N` worker processes of the same binary
//! (`N + 1` for the asynchronous protocols — the extra rank is the
//! parameter server) — or, with `--hosts FILE`, waits for externally
//! started workers on other machines — performs the magic/version/rank
//! handshake, and broadcasts the mesh address book. Each worker builds
//! **only its rank's blocks** of the dataset ([`crate::data::shard`]):
//! shard-local windowed synthesis by default (seed-derived, no data
//! shipping), or pre-sliced block files via `--shards DIR`. The full
//! matrix is never materialised on a worker. Each rank then runs the
//! configured algorithm over [`crate::transport::TcpComm`] and streams its
//! result chunks back over the rendezvous connection. The coordinator
//! assembles them into the same [`Outcome`] the simulated path produces,
//! including per-rank load/residency statistics.
//!
//! Because the collectives reduce in rank order on every backend — and
//! because sharded ranks obtain the **exact** global `‖M‖²` (manifest, or
//! the ordered chain reduction [`crate::data::shard::exact_fro_sq`]) — a
//! seeded `launch` run produces factors **bit-identical** to the
//! in-process simulated run of the same config; `--verify-sim` re-runs
//! the simulator in the coordinator and asserts exactly that.
//!
//! Result chunks ride the same length-prefixed f32 frames as the data
//! plane ([`crate::transport::wire`]): matrices carry `[rows, cols,
//! data…]`, exact `u64`/`f64` statistics are bit-split across f32 lanes,
//! and worker failures arrive as `Error` frames whose text the coordinator
//! surfaces verbatim.

use std::io::Write as _;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::algos::{self, NodeOutput, TracePoint};
use crate::config::{Algorithm as AlgoFamily, ExperimentConfig};
use crate::coordinator::{self, Outcome};
use crate::data::compress;
use crate::data::partition::{uniform_partition, Partition};
use crate::data::shard::{self, LoadSource, LoadStats, NodeData, NodeInput};
use crate::data::Dataset;
use crate::dist::CommStats;
use crate::error::{Context, Result};
use crate::linalg::Mat;
use crate::metrics;
use crate::nmf::control::{
    CheckpointCfg, ControlToken, ElasticCtl, RunControl, StopPolicy, StopReason,
};
use crate::nmf::job::{Algo, Algorithm as _, RankEnv, RankOutput};
use crate::secure::{asyn, syn, SecureAlgo};
use crate::transport::wire::{
    self, decode_text, encode_text, push_f64_bits, push_u64_bits, take_f64_bits, take_u64_bits,
    Frame, FrameKind, Precision,
};
use crate::transport::{Rendezvous, TcpComm, TcpOptions, WorkerConn};

/// Result-chunk codes (frame tag of `FrameKind::Result`).
const RES_U: u64 = 1;
const RES_V: u64 = 2;
const RES_TRACE: u64 = 3;
const RES_STATS: u64 = 4;
const RES_SAMPLES: u64 = 5;
const RES_DONE: u64 = 6;
/// `‖M‖²_F` (f64 bits), shipped by the async server so the coordinator
/// need not regenerate the dataset just to merge traces.
const RES_FRO: u64 = 7;
/// Per-rank data-plane statistics ([`LoadStats`]).
const RES_LOAD: u64 = 8;

// ---------------------------------------------------------------------------
// Payload codecs (matrices, traces, statistics)
// ---------------------------------------------------------------------------

fn mat_payload(m: &Mat) -> Vec<f32> {
    assert!(m.rows() < (1 << 24) && m.cols() < (1 << 24), "dims exceed exact-f32 range");
    let mut p = Vec::with_capacity(2 + m.data().len());
    p.push(m.rows() as f32);
    p.push(m.cols() as f32);
    p.extend_from_slice(m.data());
    p
}

fn mat_from_payload(p: &[f32]) -> Result<Mat> {
    if p.len() < 2 {
        crate::bail!("matrix chunk too short");
    }
    let rows = p[0] as usize;
    let cols = p[1] as usize;
    if p.len() != 2 + rows * cols {
        crate::bail!("matrix chunk: {} values for {rows}x{cols}", p.len() - 2);
    }
    Ok(Mat::from_vec(rows, cols, p[2..].to_vec()))
}

fn trace_payload(trace: &[TracePoint]) -> Vec<f32> {
    let mut p = Vec::with_capacity(trace.len() * 5);
    for t in trace {
        p.push(t.iteration as f32);
        push_f64_bits(&mut p, t.sim_time);
        push_f64_bits(&mut p, t.rel_error);
    }
    p
}

fn trace_from_payload(p: &[f32]) -> Result<Vec<TracePoint>> {
    if p.len() % 5 != 0 {
        crate::bail!("trace chunk length {} not a multiple of 5", p.len());
    }
    let mut out = Vec::with_capacity(p.len() / 5);
    let mut pos = 0;
    while pos < p.len() {
        let iteration = p[pos] as usize;
        pos += 1;
        let sim_time = take_f64_bits(p, &mut pos)?;
        let rel_error = take_f64_bits(p, &mut pos)?;
        out.push(TracePoint { iteration, sim_time, rel_error });
    }
    Ok(out)
}

fn stats_payload(s: &CommStats, final_clock: f64, stop: StopReason, epochs: usize) -> Vec<f32> {
    let mut p = Vec::with_capacity(18);
    push_u64_bits(&mut p, s.bytes_sent as u64);
    push_u64_bits(&mut p, s.bytes_received as u64);
    push_u64_bits(&mut p, s.messages as u64);
    push_f64_bits(&mut p, s.compute_time);
    push_f64_bits(&mut p, s.comm_time);
    push_f64_bits(&mut p, s.stall_time);
    push_f64_bits(&mut p, final_clock);
    push_u64_bits(&mut p, stop.code());
    push_u64_bits(&mut p, epochs as u64);
    p
}

fn stats_from_payload(p: &[f32]) -> Result<(CommStats, f64, StopReason, usize)> {
    let mut pos = 0;
    let stats = CommStats {
        bytes_sent: take_u64_bits(p, &mut pos)? as usize,
        bytes_received: take_u64_bits(p, &mut pos)? as usize,
        messages: take_u64_bits(p, &mut pos)? as usize,
        compute_time: take_f64_bits(p, &mut pos)?,
        comm_time: take_f64_bits(p, &mut pos)?,
        stall_time: take_f64_bits(p, &mut pos)?,
    };
    let final_clock = take_f64_bits(p, &mut pos)?;
    let stop = StopReason::from_code(take_u64_bits(p, &mut pos)?)?;
    let epochs = (take_u64_bits(p, &mut pos)? as usize).max(1);
    Ok((stats, final_clock, stop, epochs))
}

fn samples_payload(samples: &[(f64, f64, usize)]) -> Vec<f32> {
    let mut p = Vec::with_capacity(samples.len() * 6);
    for &(clock, resid, iters) in samples {
        push_f64_bits(&mut p, clock);
        push_f64_bits(&mut p, resid);
        push_u64_bits(&mut p, iters as u64);
    }
    p
}

fn samples_from_payload(p: &[f32]) -> Result<Vec<(f64, f64, usize)>> {
    if p.len() % 6 != 0 {
        crate::bail!("samples chunk length {} not a multiple of 6", p.len());
    }
    let mut out = Vec::with_capacity(p.len() / 6);
    let mut pos = 0;
    while pos < p.len() {
        let clock = take_f64_bits(p, &mut pos)?;
        let resid = take_f64_bits(p, &mut pos)?;
        let iters = take_u64_bits(p, &mut pos)? as usize;
        out.push((clock, resid, iters));
    }
    Ok(out)
}

fn load_payload(l: &LoadStats) -> Vec<f32> {
    let mut p = Vec::with_capacity(14);
    push_u64_bits(&mut p, l.rank as u64);
    push_u64_bits(&mut p, l.block_rows as u64);
    push_u64_bits(&mut p, l.block_cols as u64);
    push_u64_bits(&mut p, l.nnz as u64);
    push_u64_bits(&mut p, l.bytes as u64);
    push_f64_bits(&mut p, l.load_secs);
    push_u64_bits(&mut p, l.source.code());
    p
}

fn load_from_payload(p: &[f32]) -> Result<LoadStats> {
    let mut pos = 0;
    Ok(LoadStats {
        rank: take_u64_bits(p, &mut pos)? as usize,
        block_rows: take_u64_bits(p, &mut pos)? as usize,
        block_cols: take_u64_bits(p, &mut pos)? as usize,
        nnz: take_u64_bits(p, &mut pos)? as usize,
        bytes: take_u64_bits(p, &mut pos)? as usize,
        load_secs: take_f64_bits(p, &mut pos)?,
        source: LoadSource::from_code(take_u64_bits(p, &mut pos)?)?,
    })
}

fn send_chunk(stream: &mut TcpStream, tag: u64, payload: &[f32]) -> Result<()> {
    wire::write_frame_parts(stream, FrameKind::Result, tag, 0.0, payload)
        .context("reporting result to coordinator")
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// How many TCP ranks a config needs: one per node, plus the parameter
/// server for the asynchronous protocols.
pub fn cluster_ranks(cfg: &ExperimentConfig) -> usize {
    Algo::from_config(cfg).cluster_ranks()
}

/// `dsanls worker --rendezvous HOST:PORT --rank R [--bind IP[:PORT]]
/// [--advertise HOST[:PORT]] [--shards DIR] [config args…]` — one rank of
/// a `launch` cluster. Spawned automatically by `launch` on single-host
/// runs; started by the operator (one per host) for multi-host runs, with
/// `--bind` pointing at an interface the peers can reach (see
/// DEPLOYMENT.md). The worker builds **only its rank's blocks** of the
/// dataset — shard-local synthesis by default, block files with
/// `--shards` — never the full matrix.
pub fn worker_main(args: &[String]) -> Result<()> {
    let mut rendezvous = None;
    let mut rank = None;
    let mut shards: Option<PathBuf> = None;
    let mut bind: Option<String> = None;
    let mut advertise: Option<String> = None;
    let mut join = false;
    let mut wctl = WorkerControlArgs::default();
    let mut cfg_args: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--elastic" => {
                wctl.elastic = true;
                i += 1;
            }
            "--join" => {
                join = true;
                i += 1;
            }
            "--rendezvous" => {
                rendezvous = Some(args.get(i + 1).context("--rendezvous needs HOST:PORT")?.clone());
                i += 2;
            }
            "--rank" => {
                let v = args.get(i + 1).context("--rank needs a number")?;
                rank = Some(v.parse::<usize>().map_err(|e| crate::err!("--rank {v}: {e}"))?);
                i += 2;
            }
            "--shards" => {
                shards = Some(PathBuf::from(args.get(i + 1).context("--shards needs a DIR")?));
                i += 2;
            }
            "--bind" => {
                bind = Some(args.get(i + 1).context("--bind needs IP[:PORT]")?.clone());
                i += 2;
            }
            "--advertise" => {
                advertise =
                    Some(args.get(i + 1).context("--advertise needs HOST[:PORT]")?.clone());
                i += 2;
            }
            flag if WorkerControlArgs::takes(flag) => {
                let v = args.get(i + 1).with_context(|| format!("{flag} needs a value"))?;
                wctl.apply(flag, v)?;
                i += 2;
            }
            _ => {
                cfg_args.push(args[i].clone());
                i += 1;
            }
        }
    }
    let addr = rendezvous.context("worker needs --rendezvous HOST:PORT")?;
    let rank = rank.context("worker needs --rank R")?;
    if join && !wctl.elastic {
        crate::bail!("--join re-enters an elastic cluster; it needs --elastic too");
    }
    let cfg = super::parse_cli_config(&cfg_args).map_err(crate::error::Error::msg)?;
    let ranks = cluster_ranks(&cfg);

    let topts = TcpOptions {
        connect_timeout: Duration::from_secs_f64(cfg.net_timeout_s.max(1.0)),
        io_timeout: Some(Duration::from_secs_f64((cfg.net_timeout_s * 4.0).max(1.0))),
        bind,
        advertise,
        elastic: wctl.elastic,
    };
    // a replacement re-enters via the epoch-join handshake (the survivors
    // are parked in the mesh rebuild); a founding worker bootstraps as ever
    let mut comm = if join {
        TcpComm::connect_join(&addr, rank, ranks, &topts, None)
            .with_context(|| format!("replacement rank {rank} re-joining cluster at {addr}"))?
    } else {
        TcpComm::connect(&addr, rank, ranks, &topts)
            .with_context(|| format!("worker rank {rank} joining cluster at {addr}"))?
    };
    let mut report = comm
        .take_rendezvous()
        .context("rendezvous channel already taken")?;

    // run the rank; ship failures back as Error frames before exiting
    match run_rank(&cfg, comm, rank, &mut report, shards.as_deref(), &wctl, join) {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = format!("rank {rank}: {e}");
            let _ = wire::write_frame(
                &mut report,
                &Frame::new(FrameKind::Error, rank as u64, 0.0, encode_text(&msg)),
            );
            Err(crate::error::Error::msg(msg))
        }
    }
}

/// Control-plane flags a worker accepts (forwarded verbatim by `launch`):
/// stop policy, checkpoint/resume, elastic membership, and the
/// fault-injection pair used by the retry tests and operator drills.
#[derive(Debug, Default, Clone)]
struct WorkerControlArgs {
    checkpoint: Option<PathBuf>,
    checkpoint_every: Option<usize>,
    resume: Option<PathBuf>,
    max_seconds: Option<f64>,
    target_error: Option<f64>,
    fault_rank: Option<usize>,
    fault_iteration: Option<usize>,
    /// `--elastic`: keep the mesh listener open, replicate boundary state,
    /// and recover from peer loss by membership rebuild instead of dying.
    elastic: bool,
}

/// Default checkpoint cadence when `--checkpoint` is given without
/// `--checkpoint-every`.
pub const DEFAULT_CHECKPOINT_EVERY: usize = 10;

impl WorkerControlArgs {
    fn takes(flag: &str) -> bool {
        matches!(
            flag,
            "--checkpoint"
                | "--checkpoint-every"
                | "--resume"
                | "--max-seconds"
                | "--target-error"
                | "--fault-rank"
                | "--fault-iteration"
        )
    }

    fn apply(&mut self, flag: &str, v: &str) -> Result<()> {
        let us = |v: &str| v.parse::<usize>().map_err(|e| crate::err!("{flag} {v}: {e}"));
        let fl = |v: &str| v.parse::<f64>().map_err(|e| crate::err!("{flag} {v}: {e}"));
        match flag {
            "--checkpoint" => self.checkpoint = Some(PathBuf::from(v)),
            "--checkpoint-every" => {
                let n = us(v)?;
                if n == 0 {
                    crate::bail!("--checkpoint-every needs a cadence ≥ 1 iteration");
                }
                self.checkpoint_every = Some(n);
            }
            "--resume" => self.resume = Some(PathBuf::from(v)),
            "--max-seconds" => self.max_seconds = Some(fl(v)?),
            "--target-error" => self.target_error = Some(fl(v)?),
            "--fault-rank" => self.fault_rank = Some(us(v)?),
            "--fault-iteration" => self.fault_iteration = Some(us(v)?),
            other => crate::bail!("unknown worker control flag {other}"),
        }
        Ok(())
    }

    /// Resolve into a [`RunControl`] for `rank` running `cfg` over data of
    /// the given global shape. The resume checkpoint is read and validated
    /// here (every worker reads the shared file and slices its blocks),
    /// through the same [`Algo::ckpt_identity`] / `load_resume` path the
    /// in-process job uses.
    fn resolve(
        &self,
        cfg: &ExperimentConfig,
        rank: usize,
        rows: usize,
        cols: usize,
    ) -> Result<RunControl> {
        let mut resume = None;
        if self.checkpoint.is_some() || self.resume.is_some() {
            let (tag, seed, k, iterations, params) = Algo::from_config(cfg).ckpt_identity()?;
            if let Some(p) = &self.checkpoint {
                crate::nmf::control::validate_checkpoint_path(p)?;
            }
            if let Some(path) = &self.resume {
                resume = Some(crate::nmf::control::load_resume(
                    path, tag, seed, k, rows, cols, params, iterations,
                )?);
            }
        }
        let stop = StopPolicy {
            max_seconds: self.max_seconds,
            target_error: self.target_error,
        };
        Ok(RunControl {
            token: ControlToken::new(),
            deadline: RunControl::deadline_from(&stop),
            stop,
            checkpoint: self.checkpoint.as_ref().map(|p| CheckpointCfg {
                every: self.checkpoint_every.unwrap_or(DEFAULT_CHECKPOINT_EVERY).max(1),
                path: p.clone(),
            }),
            resume,
            fault_at: (self.fault_rank == Some(rank))
                .then_some(self.fault_iteration)
                .flatten(),
            // a worker's token is created here and reachable by nothing —
            // with no stop policy the per-iteration poll skips its
            // collective (every rank derives the same answer from the same
            // forwarded flags, so all skip alike)
            cancellable: false,
            // min_ranks 1: over TCP the rebuild waits for a replacement of
            // every dead rank anyway (full-width membership), so the floor
            // only guards the degenerate everyone-else-died case
            elastic: self.elastic.then_some(ElasticCtl { min_ranks: 1 }),
        })
    }
}

/// What a rank holds after the data plane resolved: raw blocks, or the two
/// fixed sketched views of a compressed shard directory.
enum RankBlocks {
    Raw(NodeData),
    Compressed(Box<crate::data::CompressedBlock>),
}

/// Build this rank's blocks — shard files when `--shards` was given
/// (raw or compressed, autodetected from the manifest version),
/// shard-local synthesis otherwise. Never materialises the full matrix.
fn build_node_data(
    cfg: &ExperimentConfig,
    rank: usize,
    shards: Option<&Path>,
) -> Result<(RankBlocks, LoadSource, Option<Partition>)> {
    let algo = Algo::from_config(cfg);
    let (need_rows, need_cols) = algo.block_needs(rank);
    let secure = matches!(cfg.algorithm, AlgoFamily::Secure(_));
    if let Some(dir) = shards {
        if compress::manifest_version(dir)? == compress::COMPRESSED_FORMAT_VERSION {
            if secure {
                crate::bail!(
                    "compressed shard directory {}: the secure protocols need the raw \
                     column partition — re-run `dsanls shard` without --compress",
                    dir.display()
                );
            }
            if cfg.overlap_comm {
                crate::bail!(
                    "network.overlap_comm needs the raw blocks to prefetch against — \
                     compressed shards hold only the fixed sketched views; drop the flag"
                );
            }
            let (block, man) = crate::data::CompressedBlock::load(dir, rank)?;
            validate_manifest(cfg, &man.base)?;
            let cols = man.base.col_partition();
            return Ok((
                RankBlocks::Compressed(Box::new(block)),
                LoadSource::CompressedShard,
                Some(cols),
            ));
        }
        if rank >= cfg.nodes {
            // async parameter server: global metadata only
            let manifest = shard::read_manifest(dir)?;
            validate_manifest(cfg, &manifest)?;
            check_shard_skew(cfg, &manifest, dir, secure)?;
            let data = NodeData::metadata(manifest.rows, manifest.cols, Some(manifest.fro_sq));
            let cols = manifest.col_partition();
            return Ok((RankBlocks::Raw(data), LoadSource::FileShard, Some(cols)));
        }
        let (data, manifest) = NodeData::load(dir, rank, need_rows, need_cols)?;
        validate_manifest(cfg, &manifest)?;
        manifest.require_uniform_for(dir, secure)?;
        check_shard_skew(cfg, &manifest, dir, secure)?;
        let cols = manifest.col_partition();
        return Ok((RankBlocks::Raw(data), LoadSource::FileShard, Some(cols)));
    }

    // shard-local synthesis: every data rank generates its row block (the
    // ordered ‖M‖² chain needs it even when the algorithm won't — it is
    // dropped right after), plus the column block its algorithm iterates
    // on; both blocks come from ONE pass over the generator stream
    let dataset = Dataset::from_name(&cfg.dataset)
        .with_context(|| format!("unknown dataset {}", cfg.dataset))?;
    let (rows, cols) = dataset.scaled_shape(cfg.scale);
    let row_range = (rank < cfg.nodes).then(|| uniform_partition(rows, cfg.nodes).range(rank));
    let col_range = if need_cols {
        Some(if secure {
            coordinator::secure_partition(cfg, cols).range(rank)
        } else {
            uniform_partition(cols, cfg.nodes).range(rank)
        })
    } else {
        None
    };
    let data = NodeData::generate(dataset, cfg.seed, cfg.scale, row_range, col_range);
    Ok((RankBlocks::Raw(data), LoadSource::SynthShard, None))
}

/// A `secure.skew > 0` config promises a skewed column layout, but a
/// shard directory carries its *own* partition (which the run will use);
/// a **uniform** directory would silently ignore the requested skew, so
/// that combination is a typed error pointing at `--balance nnz`.
/// Balanced directories are exactly the skewed-secure deployment path.
fn check_shard_skew(
    cfg: &ExperimentConfig,
    manifest: &shard::ShardManifest,
    dir: &Path,
    secure: bool,
) -> Result<()> {
    if secure && cfg.skew > 0.0 && !manifest.is_balanced() {
        crate::bail!(
            "secure.skew > 0 but shard directory {} is uniform-partitioned (the run uses \
             the directory's partition) — re-shard with `dsanls shard --balance nnz`, or \
             drop --shards for shard-local synthesis",
            dir.display()
        );
    }
    Ok(())
}

/// One tiny barrier every rank always enters, carrying its data-plane
/// mode: ranks that disagree (some started with `--shards`, some without)
/// would otherwise run different startup collectives — the synth-mode
/// ‖M‖² chain would pair with a file-mode rank's first algorithm
/// collective and decode garbage. Disagreement becomes a clear error.
fn check_data_plane_agreement(comm: &mut TcpComm, source: LoadSource) -> Result<()> {
    use crate::transport::Communicator as _;
    let mine = source.code() as f32;
    let g = comm.exchange(0.0, &[mine]).context("data-plane mode handshake")?;
    for (peer, part) in g.parts.iter().enumerate() {
        if part.as_slice() != [mine] {
            let peer_mode = part
                .first()
                .and_then(|&c| LoadSource::from_code(c as u64).ok())
                .map_or("unknown", |s| s.label());
            crate::bail!(
                "data-plane mode mismatch: rank {peer} loads via {peer_mode}, this rank via \
                 {} — start every worker with the same --shards setting",
                source.label()
            );
        }
    }
    Ok(())
}

/// Reject shard directories that do not match the experiment config (a
/// mismatch would otherwise surface as a confusing `--verify-sim` failure
/// or a hung collective).
fn validate_manifest(cfg: &ExperimentConfig, m: &shard::ShardManifest) -> Result<()> {
    if m.nodes != cfg.nodes {
        crate::bail!(
            "shard directory was built for {} nodes, this run uses {} — re-run `dsanls shard`",
            m.nodes,
            cfg.nodes
        );
    }
    if shard::is_file_dataset(&m.dataset) {
        // file-ingested shards (`dsanls shard --input`) are authoritative:
        // there is no generator config to cross-check against
        return Ok(());
    }
    if !m.dataset.eq_ignore_ascii_case(&cfg.dataset) || m.seed != cfg.seed || m.scale != cfg.scale
    {
        crate::bail!(
            "shard directory holds {} (seed {}, scale {}), config wants {} (seed {}, scale {})",
            m.dataset,
            m.seed,
            m.scale,
            cfg.dataset,
            cfg.seed,
            cfg.scale
        );
    }
    Ok(())
}

/// Execute this rank's share of the configured algorithm and stream the
/// results back over the rendezvous connection.
#[allow(clippy::too_many_arguments)]
fn run_rank(
    cfg: &ExperimentConfig,
    mut comm: TcpComm,
    rank: usize,
    report: &mut TcpStream,
    shards: Option<&Path>,
    wctl: &WorkerControlArgs,
    joining: bool,
) -> Result<()> {
    // ---- shard-aware data plane: this rank's blocks, nothing more ----
    let tick = Instant::now();
    let (mut blocks, source, shard_cols) = build_node_data(cfg, rank, shards)?;
    // measure pure build/load time before any collective: the barriers
    // below wait on peers, which would smear every rank's number up to
    // the slowest (EXPERIMENTS.md §sharded-vs-full compares load_secs)
    let load_secs = tick.elapsed().as_secs_f64();
    if let RankBlocks::Compressed(_) = &blocks {
        // the typed surface area matches the Job builder's: the modes that
        // need raw blocks (or a re-servable copy of them) are rejected
        // up front rather than failing mid-collective
        if joining || wctl.elastic {
            crate::bail!(
                "elastic membership is not supported on compressed shards yet — a \
                 joiner would need the dead rank's sketched views re-served; use \
                 `launch --retries` for whole-attempt restarts instead"
            );
        }
        if wctl.checkpoint.is_some() || wctl.resume.is_some() {
            crate::bail!(
                "checkpoint/resume is not supported on compressed input — the \
                 checkpoint fingerprint cannot attest which sketched views produced \
                 the factors; run to completion and save the output instead"
            );
        }
    }
    if joining {
        // the survivors are parked in the mesh-level epoch rebuild, not
        // the startup collectives — a replacement must skip the data-plane
        // barrier and the ‖M‖² chain; the recovery exchange delivers the
        // authoritative Frobenius norm with the adopted state
        if let RankBlocks::Raw(data) = &mut blocks {
            if data.fro_sq.is_none() {
                data.fro_sq = Some(f64::NAN);
            }
        }
    } else {
        // every rank enters this barrier unconditionally, so a --shards
        // mismatch across hosts (raw vs compressed vs synthesis) surfaces
        // as an actionable error here instead of desynchronising the
        // collective stream (file-mode ranks skip the ‖M‖² chain that
        // synth-mode ranks run)
        check_data_plane_agreement(&mut comm, source)?;
        if let RankBlocks::Raw(data) = &mut blocks {
            if data.fro_sq.is_none() {
                // synth mode: resolve the exact global ‖M‖² with the ordered
                // chain (bit-identical to the full-matrix value)
                let fro = shard::exact_fro_sq(&mut comm, cfg.nodes, data.m_rows.as_ref())
                    .with_context(|| format!("rank {rank} resolving global ‖M‖²"))?;
                data.fro_sq = Some(fro);
            }
        }
    }
    let (need_rows, _) = Algo::from_config(cfg).block_needs(rank);
    let (load, rows, cols) = match &mut blocks {
        RankBlocks::Raw(data) => {
            if !need_rows {
                data.drop_rows(); // the chain was its only consumer
            }
            (data.load_stats(rank, load_secs, source), data.rows, data.cols)
        }
        RankBlocks::Compressed(cb) => (
            LoadStats {
                rank,
                block_rows: cb.row_range.len(),
                block_cols: cb.col_range.len(),
                // the views are dense: every held value is explicit
                nnz: cb.u_view().data().len() + cb.v_view().data().len(),
                bytes: cb.resident_bytes(),
                load_secs,
                source,
            },
            cb.rows,
            cb.cols,
        ),
    };

    // resolve the control plane now that the global shape is known (the
    // resume checkpoint validates against it); every worker derives the
    // identical stop policy from the identical forwarded flags, which is
    // what keeps the per-iteration collective stop poll agreed
    let ctl = wctl.resolve(cfg, rank, rows, cols)?;

    // mirror the simulated cluster's per-node thread cap so the
    // thread-count-sensitive reductions split identically (bit-identity)
    crate::dist::apply_node_thread_policy(cfg.nodes);

    // catch panics from the algorithm layer (collective failures panic) so
    // they reach the coordinator as Error frames, not silent worker deaths
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_rank_inner(cfg, comm, rank, &blocks, &load, report, &ctl, shard_cols, joining)
    }));
    crate::parallel::set_local_threads(None);
    match outcome {
        Ok(res) => res,
        Err(panic) => {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .or_else(|| {
                    // an unrecovered (or non-elastic) peer loss carries a
                    // typed payload — surface its detail, not "panicked"
                    panic
                        .downcast_ref::<crate::transport::PeerLostSignal>()
                        .map(|s| s.detail.clone())
                })
                .unwrap_or_else(|| "worker panicked".into());
            Err(crate::error::Error::msg(msg))
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_rank_inner(
    cfg: &ExperimentConfig,
    comm: TcpComm,
    rank: usize,
    blocks: &RankBlocks,
    load: &LoadStats,
    report: &mut TcpStream,
    ctl: &RunControl,
    shard_cols: Option<Partition>,
    joining: bool,
) -> Result<()> {
    send_chunk(report, RES_LOAD, &load_payload(load))?;
    // one generic node runner covers every algorithm family — the worker
    // only matches on the *output* kind to pick its wire encoding
    let algo = Algo::from_config(cfg);
    let input = match blocks {
        RankBlocks::Raw(data) => NodeInput::Shard(data),
        RankBlocks::Compressed(cb) => NodeInput::Compressed(cb.as_ref()),
    };
    // shard directories carry their column partition (possibly
    // nnz-balanced); otherwise derive it from the config
    let cols = shard_cols.unwrap_or_else(|| coordinator::secure_partition(cfg, input.dims().1));
    let env = RankEnv {
        rank,
        input,
        cols: &cols,
        observer: None,
        audit: None,
        ctl,
        joining,
    };
    match algo.run_rank(comm, env)? {
        RankOutput::Node(out) => send_node_output(report, &out),
        RankOutput::Syn(out) => {
            send_chunk(report, RES_U, &mat_payload(&out.u_local))?;
            send_chunk(report, RES_V, &mat_payload(&out.v_block))?;
            send_chunk(report, RES_TRACE, &trace_payload(&out.trace))?;
            send_chunk(
                report,
                RES_STATS,
                &stats_payload(&out.stats, out.final_clock, out.stop, out.epochs),
            )?;
            send_chunk(report, RES_DONE, &[])
        }
        RankOutput::AsynServer { u, fro_sq } => {
            send_chunk(report, RES_U, &mat_payload(&u))?;
            let mut fro = Vec::with_capacity(2);
            push_f64_bits(&mut fro, fro_sq);
            send_chunk(report, RES_FRO, &fro)?;
            send_chunk(report, RES_DONE, &[])
        }
        RankOutput::AsynClient(out) => {
            send_chunk(report, RES_V, &mat_payload(&out.v_block))?;
            send_chunk(report, RES_SAMPLES, &samples_payload(&out.samples))?;
            send_chunk(
                report,
                RES_STATS,
                &stats_payload(&out.stats, out.final_clock, out.stop, 1),
            )?;
            send_chunk(report, RES_DONE, &[])
        }
    }
}

fn send_node_output(stream: &mut TcpStream, out: &NodeOutput) -> Result<()> {
    send_chunk(stream, RES_U, &mat_payload(&out.u_block))?;
    send_chunk(stream, RES_V, &mat_payload(&out.v_block))?;
    send_chunk(stream, RES_TRACE, &trace_payload(&out.trace))?;
    send_chunk(
        stream,
        RES_STATS,
        &stats_payload(&out.stats, out.final_clock, out.stop, out.epochs),
    )?;
    send_chunk(stream, RES_DONE, &[])
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

struct WorkerResult {
    u: Option<Mat>,
    v: Option<Mat>,
    trace: Vec<TracePoint>,
    stats: CommStats,
    final_clock: f64,
    samples: Vec<(f64, f64, usize)>,
    fro_sq: Option<f64>,
    load: Option<LoadStats>,
    stop: StopReason,
    epochs: usize,
}

impl Default for WorkerResult {
    fn default() -> Self {
        WorkerResult {
            u: None,
            v: None,
            trace: Vec::new(),
            stats: CommStats::default(),
            final_clock: 0.0,
            samples: Vec::new(),
            fro_sq: None,
            load: None,
            stop: StopReason::Completed,
            epochs: 1,
        }
    }
}

fn read_worker_result(stream: &mut TcpStream, rank: usize) -> Result<WorkerResult> {
    let mut res = WorkerResult::default();
    loop {
        let f = wire::read_frame(stream)
            .with_context(|| format!("reading results from worker rank {rank}"))?;
        match f.kind {
            FrameKind::Result => match f.tag {
                RES_U => res.u = Some(mat_from_payload(&f.payload)?),
                RES_V => res.v = Some(mat_from_payload(&f.payload)?),
                RES_TRACE => res.trace = trace_from_payload(&f.payload)?,
                RES_STATS => {
                    let (stats, clock, stop, epochs) = stats_from_payload(&f.payload)?;
                    res.stats = stats;
                    res.final_clock = clock;
                    res.stop = stop;
                    res.epochs = epochs;
                }
                RES_SAMPLES => res.samples = samples_from_payload(&f.payload)?,
                RES_FRO => {
                    let mut pos = 0;
                    res.fro_sq = Some(take_f64_bits(&f.payload, &mut pos)?);
                }
                RES_LOAD => res.load = Some(load_from_payload(&f.payload)?),
                RES_DONE => return Ok(res),
                other => crate::bail!("unknown result chunk {other} from rank {rank}"),
            },
            FrameKind::Error => crate::bail!("worker failed: {}", decode_text(&f.payload)),
            other => crate::bail!("unexpected {other:?} frame from worker rank {rank}"),
        }
    }
}

/// Options controlling a `launch` run (parsed from the CLI).
pub struct LaunchOptions {
    /// The resolved experiment configuration.
    pub cfg: ExperimentConfig,
    /// Rendezvous port (0 = ephemeral).
    pub port: u16,
    /// Rendezvous bind host (default `127.0.0.1`; use a reachable
    /// interface or `0.0.0.0` for multi-host runs).
    pub bind_host: String,
    /// Re-run the simulated backend in-process and assert the factors are
    /// bit-identical (deterministic algorithms only).
    pub verify_sim: bool,
    /// Expected worker hosts (one per rank, from `--hosts FILE`). When
    /// set, `launch` does not spawn local workers — it waits for the
    /// operator-started ones and prints the command each host should run.
    pub hosts: Option<Vec<String>>,
    /// Shard directory forwarded to the workers (`--shards DIR`).
    pub shards: Option<String>,
    /// Checkpoint file forwarded to the workers (`--checkpoint PATH`) —
    /// also the file rank-failure retries resume from.
    pub checkpoint: Option<PathBuf>,
    /// Resume file forwarded to the workers on the first attempt
    /// (`--resume PATH`).
    pub resume: Option<PathBuf>,
    /// Rank-failure retry budget (`--retries N`, default 0): on a worker
    /// failure the whole cluster restarts from the latest checkpoint.
    pub retries: usize,
    /// Job-level wall-clock budget (`--max-seconds S`). Anchored once at
    /// launch start and forwarded to each attempt's workers as the
    /// *remaining* budget, so retries cannot multiply it.
    pub max_seconds: Option<f64>,
    /// Fault injection forwarded to the workers on the FIRST attempt only
    /// (`--fault-rank R --fault-iteration T` — tests and operator drills).
    pub fault: Option<(usize, usize)>,
    /// Elastic membership (`--elastic`): a dead worker does not restart
    /// the cluster — the survivors quiesce at the iteration boundary, the
    /// coordinator respawns the rank as `worker --join`, and the epoch
    /// handshake folds it back in. Orthogonal to `--retries`, which
    /// restarts the whole attempt.
    pub elastic: bool,
    /// Replacement-spawn budget for one elastic attempt (`--max-joins N`,
    /// default 3). Distinct from the retry budget: joins never restart
    /// survivors, so a joined run reports `retries: 0`.
    pub max_joins: usize,
    /// Arguments forwarded verbatim to the workers (config file + overrides).
    pub forward: Vec<String>,
}

/// Parse `launch` CLI arguments.
pub fn parse_launch_args(args: &[String]) -> Result<LaunchOptions> {
    let mut nodes_override = None;
    let mut port = 0u16;
    let mut bind_host = "127.0.0.1".to_string();
    let mut verify_sim = false;
    let mut hosts: Option<Vec<String>> = None;
    let mut shards: Option<String> = None;
    let mut checkpoint: Option<PathBuf> = None;
    let mut checkpoint_every: Option<String> = None;
    let mut resume: Option<PathBuf> = None;
    let mut retries = 0usize;
    let mut max_seconds: Option<f64> = None;
    let mut fault_rank: Option<usize> = None;
    let mut fault_iteration: Option<usize> = None;
    let mut elastic = false;
    let mut max_joins: Option<usize> = None;
    let mut overlap = false;
    let mut wire_precision: Option<Precision> = None;
    let mut stop_forward: Vec<String> = Vec::new();
    let mut forward: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--max-seconds" => {
                let v = args.get(i + 1).context("--max-seconds needs a value")?;
                max_seconds =
                    Some(v.parse::<f64>().map_err(|e| crate::err!("--max-seconds {v}: {e}"))?);
                i += 2;
            }
            "--target-error" => {
                let v = args.get(i + 1).context("--target-error needs a value")?;
                v.parse::<f64>().map_err(|e| crate::err!("--target-error {v}: {e}"))?;
                stop_forward.push("--target-error".into());
                stop_forward.push(v.clone());
                i += 2;
            }
            "--checkpoint" => {
                checkpoint = Some(PathBuf::from(
                    args.get(i + 1).context("--checkpoint needs a PATH")?,
                ));
                i += 2;
            }
            "--checkpoint-every" => {
                let v = args.get(i + 1).context("--checkpoint-every needs a number")?;
                let n =
                    v.parse::<usize>().map_err(|e| crate::err!("--checkpoint-every {v}: {e}"))?;
                if n == 0 {
                    crate::bail!("--checkpoint-every needs a cadence ≥ 1 iteration");
                }
                checkpoint_every = Some(v.clone());
                i += 2;
            }
            "--resume" => {
                resume = Some(PathBuf::from(args.get(i + 1).context("--resume needs a PATH")?));
                i += 2;
            }
            "--retries" => {
                let v = args.get(i + 1).context("--retries needs a number")?;
                retries = v.parse::<usize>().map_err(|e| crate::err!("--retries {v}: {e}"))?;
                i += 2;
            }
            "--fault-rank" => {
                let v = args.get(i + 1).context("--fault-rank needs a rank")?;
                fault_rank =
                    Some(v.parse::<usize>().map_err(|e| crate::err!("--fault-rank {v}: {e}"))?);
                i += 2;
            }
            "--fault-iteration" => {
                let v = args.get(i + 1).context("--fault-iteration needs a number")?;
                fault_iteration = Some(
                    v.parse::<usize>().map_err(|e| crate::err!("--fault-iteration {v}: {e}"))?,
                );
                i += 2;
            }
            "--nodes" => {
                let v = args.get(i + 1).context("--nodes needs a number")?;
                nodes_override =
                    Some(v.parse::<usize>().map_err(|e| crate::err!("--nodes {v}: {e}"))?);
                i += 2;
            }
            "--port" => {
                let v = args.get(i + 1).context("--port needs a number")?;
                port = v.parse::<u16>().map_err(|e| crate::err!("--port {v}: {e}"))?;
                i += 2;
            }
            "--bind" => {
                bind_host = args.get(i + 1).context("--bind needs a HOST")?.clone();
                i += 2;
            }
            "--hosts" => {
                let path = args.get(i + 1).context("--hosts needs a FILE")?;
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading hosts file {path}"))?;
                let list: Vec<String> = text
                    .lines()
                    .map(str::trim)
                    .filter(|l| !l.is_empty() && !l.starts_with('#'))
                    .map(str::to_string)
                    .collect();
                if list.is_empty() {
                    crate::bail!("hosts file {path} lists no hosts");
                }
                hosts = Some(list);
                i += 2;
            }
            "--shards" => {
                shards = Some(args.get(i + 1).context("--shards needs a DIR")?.clone());
                i += 2;
            }
            "--verify-sim" => {
                verify_sim = true;
                i += 1;
            }
            "--elastic" => {
                elastic = true;
                i += 1;
            }
            "--max-joins" => {
                let v = args.get(i + 1).context("--max-joins needs a number")?;
                max_joins =
                    Some(v.parse::<usize>().map_err(|e| crate::err!("--max-joins {v}: {e}"))?);
                i += 2;
            }
            "--overlap" => {
                overlap = true;
                i += 1;
            }
            "--wire-precision" => {
                let v = args.get(i + 1).context("--wire-precision needs f32|fp16|bf16")?;
                wire_precision = Some(v.parse()?);
                i += 2;
            }
            "--config" => {
                forward.push(args[i].clone());
                forward.push(args.get(i + 1).context("--config needs a path")?.clone());
                i += 2;
            }
            _ => {
                forward.push(args[i].clone());
                i += 1;
            }
        }
    }
    // `forward` holds pure config args at this point; parse, then append
    // the worker-only flags so spawned/printed worker commands carry them
    let mut cfg = super::parse_cli_config(&forward).map_err(crate::error::Error::msg)?;
    if let Some(n) = nodes_override {
        cfg.nodes = n;
        forward.push(format!("--experiment.nodes={n}"));
    }
    if overlap {
        cfg.overlap_comm = true;
        forward.push("--network.overlap=true".into());
    }
    if let Some(p) = wire_precision {
        cfg.wire_precision = p;
        forward.push(format!("--network.precision={p}"));
    }
    if let Some(dir) = &shards {
        forward.push("--shards".into());
        forward.push(dir.clone());
    }
    forward.extend(stop_forward);
    if let Some(p) = &checkpoint {
        forward.push("--checkpoint".into());
        forward.push(p.display().to_string());
    }
    if let Some(v) = &checkpoint_every {
        if checkpoint.is_none() {
            crate::bail!("--checkpoint-every needs --checkpoint PATH");
        }
        forward.push("--checkpoint-every".into());
        forward.push(v.clone());
    }
    if elastic {
        // workers inherit the elastic control plane through the same
        // forwarded-flag path as the stop policy, so every rank (and any
        // later replacement) derives the identical RunControl
        forward.push("--elastic".into());
    }
    let fault = match (fault_rank, fault_iteration) {
        (Some(r), Some(t)) => Some((r, t)),
        (None, None) => None,
        _ => crate::bail!("--fault-rank and --fault-iteration must be given together"),
    };
    if cfg.nodes == 0 {
        crate::bail!("launch needs at least one node");
    }
    if retries > 0 && hosts.is_some() {
        crate::bail!(
            "--retries needs locally spawned workers; with --hosts the operator restarts \
             them (use --resume with the checkpoint file instead)"
        );
    }
    if max_joins.is_some() && !elastic {
        crate::bail!("--max-joins is the elastic replacement budget; it needs --elastic");
    }
    if elastic {
        if hosts.is_some() {
            crate::bail!(
                "--elastic respawns replacements locally; with --hosts the operator \
                 starts them (`dsanls worker --join --rank R …` on the failed host)"
            );
        }
        if matches!(cfg.algorithm, AlgoFamily::Secure(SecureAlgo::AsynSd | SecureAlgo::AsynSsdV))
        {
            crate::bail!(
                "--elastic covers the synchronous meshes; the asynchronous server \
                 already tolerates client churn without it"
            );
        }
        if cfg.overlap_comm {
            crate::bail!(
                "--elastic cannot roll back an in-flight overlapped exchange — drop \
                 --overlap (or network.overlap) to run elastic"
            );
        }
    }
    if let Some(h) = &hosts {
        let expect = cluster_ranks(&cfg);
        if h.len() != expect {
            crate::bail!(
                "hosts file lists {} hosts but this run needs {expect} ranks \
                 (one per node{})",
                h.len(),
                if expect > cfg.nodes { " plus the parameter server" } else { "" }
            );
        }
    }
    Ok(LaunchOptions {
        cfg,
        port,
        bind_host,
        verify_sim,
        hosts,
        shards,
        checkpoint,
        resume,
        retries,
        max_seconds,
        fault,
        elastic,
        max_joins: max_joins.unwrap_or(3),
        forward,
    })
}


/// `dsanls launch` — spawn (or, with `--hosts`, wait for) the worker
/// processes, run the experiment over real TCP, assemble and report the
/// outcome. With `--retries N`, a worker failure restarts the whole
/// cluster from the latest `--checkpoint` file (a dead rank collapses the
/// synchronous mesh, so the clean recovery unit is the attempt): bounded
/// attempts, surfaced in [`Outcome::retries`].
pub fn launch_main(args: &[String]) -> Result<()> {
    let opts = parse_launch_args(args)?;
    let cfg = &opts.cfg;

    // the workers take their column partition from the shard manifest, so
    // --verify-sim must hand the SAME partition to the simulated re-run
    let mut shard_cols: Option<Partition> = None;
    let mut compressed_dir: Option<PathBuf> = None;
    if let Some(dir) = &opts.shards {
        let dir = Path::new(dir);
        // fail fast on a mismatched shard set, before anything connects
        if compress::manifest_version(dir)? == compress::COMPRESSED_FORMAT_VERSION {
            let man = compress::read_compressed_manifest(dir)?;
            validate_manifest(cfg, &man.base)?;
            if matches!(cfg.algorithm, AlgoFamily::Secure(_)) {
                crate::bail!(
                    "compressed shards are supported by DSANLS and the MPI-FAUN \
                     baselines only — re-run `dsanls shard` without --compress for \
                     the secure protocols"
                );
            }
            if opts.elastic {
                crate::bail!(
                    "--elastic is not supported on compressed shards yet — a joiner \
                     would need the dead rank's sketched views re-served; use \
                     --retries for whole-attempt restarts instead"
                );
            }
            if opts.checkpoint.is_some() || opts.resume.is_some() {
                crate::bail!(
                    "--checkpoint/--resume are not supported on compressed input — \
                     the checkpoint fingerprint cannot attest which sketched views \
                     produced the factors"
                );
            }
            if cfg.overlap_comm {
                crate::bail!(
                    "network.overlap_comm needs the raw blocks to prefetch against — \
                     drop the flag to run on compressed shards"
                );
            }
            shard_cols = Some(man.base.col_partition());
            compressed_dir = Some(dir.to_path_buf());
        } else {
            let manifest = shard::read_manifest(dir)?;
            validate_manifest(cfg, &manifest)?;
            if opts.verify_sim && shard::is_file_dataset(&manifest.dataset) {
                crate::bail!(
                    "--verify-sim needs a generator-backed dataset; {} shards came from an \
                     external file the simulator cannot regenerate",
                    manifest.dataset
                );
            }
            shard_cols = Some(manifest.col_partition());
        }
    }

    // one rendezvous listener for every attempt: re-binding a pinned
    // --port between retries can hit TIME_WAIT (EADDRINUSE) and burn the
    // retry budget on bind failures instead of resuming
    let rdv = Rendezvous::bind_on(&opts.bind_host, opts.port)?;
    // the wall-clock budget is a property of the JOB: anchor it once, so
    // retried attempts receive only the remaining budget
    let started = Instant::now();
    let mut attempt = 0usize;
    let mut outcome = loop {
        match launch_attempt(&opts, &rdv, attempt, started) {
            Ok(out) => break out,
            Err(e) if attempt < opts.retries => {
                attempt += 1;
                let from = match resume_path_for(&opts, attempt) {
                    Some(p) => format!("checkpoint {}", p.display()),
                    None => "scratch (no checkpoint yet)".into(),
                };
                eprintln!(
                    "worker failure: {e}\nretrying (attempt {attempt}/{}) from {from}",
                    opts.retries
                );
            }
            Err(e) => return Err(e),
        }
    };
    outcome.retries = attempt;

    for l in &outcome.loads {
        println!(
            "rank {}: {} rows × {} cols resident ({} values, {:.1} MiB) loaded in {:.3}s [{}]",
            l.rank,
            l.block_rows,
            l.block_cols,
            l.nnz,
            l.bytes as f64 / (1024.0 * 1024.0),
            l.load_secs,
            l.source.label()
        );
    }
    println!(
        "final rel-error {:.4}  sec/iter {:.5}  stop: {}  retries: {}  epochs: {}  {}",
        outcome.final_error(),
        outcome.sec_per_iter,
        outcome.stop_reason.label(),
        outcome.retries,
        outcome.epochs,
        metrics::stats_summary(&outcome.stats)
    );
    let path = std::path::Path::new(&cfg.output_dir).join(format!("{}-tcp.csv", cfg.name));
    if let Err(e) = metrics::write_series_csv(&path, &[outcome.series()]) {
        eprintln!("write {path:?}: {e}");
    } else {
        println!("trace written to {path:?}");
    }

    if opts.verify_sim {
        if outcome.stop_reason != StopReason::Completed {
            println!(
                "verify-sim: skipped (run stopped early: {})",
                outcome.stop_reason.label()
            );
        } else {
            verify_against_sim(cfg, &outcome, shard_cols, compressed_dir.as_deref())?;
        }
    }
    Ok(())
}

/// The file the given attempt resumes from: the checkpoint once it
/// exists (later attempts), else the operator's `--resume`, else nothing.
fn resume_path_for(opts: &LaunchOptions, attempt: usize) -> Option<PathBuf> {
    if attempt > 0 {
        if let Some(p) = &opts.checkpoint {
            if p.exists() {
                return Some(p.clone());
            }
        }
    }
    opts.resume.clone()
}

/// One launch attempt on the shared rendezvous listener: spawn (or wait
/// for) workers, collect and assemble. Fault-injection flags are
/// forwarded on the first attempt only — the injected death must not
/// recur on the retry — and `--max-seconds` forwards the budget
/// *remaining* since `started`, not the full amount again.
fn launch_attempt(
    opts: &LaunchOptions,
    rdv: &Rendezvous,
    attempt: usize,
    started: Instant,
) -> Result<Outcome> {
    let cfg = &opts.cfg;
    let ranks = cluster_ranks(cfg);
    let mut forward = opts.forward.clone();
    if let Some(p) = resume_path_for(opts, attempt) {
        forward.push("--resume".into());
        forward.push(p.display().to_string());
    }
    if let Some(budget) = opts.max_seconds {
        let remaining = (budget - started.elapsed().as_secs_f64()).max(0.0);
        forward.push("--max-seconds".into());
        forward.push(format!("{remaining}"));
    }
    // replacements spawned mid-attempt must NOT inherit the injected fault
    // (the drill would kill every incarnation of the rank in turn) —
    // snapshot the forward list before the fault flags go on
    let join_forward = forward.clone();
    if attempt == 0 {
        if let Some((r, t)) = opts.fault {
            forward.push("--fault-rank".into());
            forward.push(r.to_string());
            forward.push("--fault-iteration".into());
            forward.push(t.to_string());
        }
    }

    println!(
        "launching {} over TCP: {} worker process(es){} on {}",
        cfg.algorithm.name(),
        cfg.nodes,
        if ranks > cfg.nodes { " + 1 parameter server" } else { "" },
        rdv.addr()
    );

    let mut children: Vec<Child> = Vec::with_capacity(ranks);
    if let Some(hosts) = &opts.hosts {
        // multi-host: the operator starts one worker per host; print the
        // exact command each host should run (see DEPLOYMENT.md). A
        // wildcard-bound rendezvous is not dialable, so print a
        // placeholder the operator must substitute with a reachable IP.
        let dial = if opts.bind_host == "0.0.0.0" || opts.bind_host == "::" {
            format!("<COORDINATOR_HOST>:{}", rdv.port())
        } else {
            rdv.addr()
        };
        println!("waiting for {ranks} externally started worker(s):");
        let fwd: String = forward.iter().map(|a| shell_quote(a)).collect::<Vec<_>>().join(" ");
        for (rank, host) in hosts.iter().enumerate() {
            println!(
                "  host {host}: dsanls worker --rendezvous {dial} --rank {rank} --bind {host} {fwd}"
            );
        }
    } else {
        let exe = std::env::current_exe().context("locating own binary")?;
        for rank in 0..ranks {
            let mut cmd = Command::new(&exe);
            cmd.arg("worker")
                .arg("--rendezvous")
                .arg(rdv.addr())
                .arg("--rank")
                .arg(rank.to_string())
                .args(&forward)
                .stdin(Stdio::null());
            let child = cmd
                .spawn()
                .with_context(|| format!("spawning worker rank {rank}"))?;
            children.push(child);
        }
    }

    let run = if opts.elastic && opts.hosts.is_none() {
        launch_collect_elastic(cfg, rdv, ranks, opts, &join_forward, &mut children)
    } else {
        launch_collect(cfg, rdv, ranks)
    };
    // reap the children regardless of how collection went
    let collected_ok = run.is_ok();
    let mut worker_failure = None;
    for (rank, mut child) in children.into_iter().enumerate() {
        if collected_ok {
            let status = child.wait().context("waiting for worker")?;
            if !status.success() && worker_failure.is_none() {
                worker_failure = Some(format!("worker rank {rank} exited with {status}"));
            }
        } else {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
    let outcome = run?;
    if let Some(fail) = worker_failure {
        crate::bail!("{fail}");
    }
    Ok(outcome)
}

/// Minimal POSIX-shell quoting for the printed copy-pasteable worker
/// commands (plain tokens pass through; anything else is single-quoted).
fn shell_quote(s: &str) -> String {
    let plain = !s.is_empty()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || "-_=./:,@+".contains(c));
    if plain {
        s.to_string()
    } else {
        format!("'{}'", s.replace('\'', "'\\''"))
    }
}

/// Accept the workers, gather their results, and assemble the outcome.
fn launch_collect(cfg: &ExperimentConfig, rdv: &Rendezvous, ranks: usize) -> Result<Outcome> {
    let timeout = Duration::from_secs_f64((cfg.net_timeout_s * 4.0).max(5.0));
    let mut conns = rdv.wait_workers(ranks, timeout)?;
    let mut results: Vec<WorkerResult> = Vec::with_capacity(ranks);
    for conn in conns.iter_mut() {
        results.push(read_worker_result(&mut conn.stream, conn.rank)?);
    }
    assemble_outcome(cfg, results)
}

/// Stream one worker's result chunks on a dedicated thread, delivering the
/// outcome (or the channel failure) through `tx`. Elastic collection needs
/// this concurrency: while survivors are still streaming, the coordinator
/// must simultaneously serve the re-join rendezvous and respawn children —
/// a sequential `read_worker_result` loop would deadlock the epoch.
fn spawn_result_reader(
    mut conn: WorkerConn,
    tx: std::sync::mpsc::Sender<(usize, Result<WorkerResult>)>,
) {
    let rank = conn.rank;
    let _ = std::thread::Builder::new()
        .name(format!("dsanls-collect-r{rank}"))
        .spawn(move || {
            let res = read_worker_result(&mut conn.stream, rank);
            let _ = tx.send((rank, res));
        });
}

/// Elastic collection: results stream concurrently (one reader thread per
/// rendezvous connection) while the coordinator admits re-joining
/// replacements on the shared listener and respawns a `worker --join
/// --rank R` child for each dead one, up to `opts.max_joins` per attempt.
/// A rank's death therefore never restarts the survivors — the attempt
/// fails only when the join budget is exhausted (or the replacement also
/// cannot finish), which is what the `--retries` path then picks up.
///
/// `join_forward` is the forwarded argument list WITHOUT the
/// fault-injection flags: an injected drill must kill only the first
/// incarnation of the rank, never its replacement.
fn launch_collect_elastic(
    cfg: &ExperimentConfig,
    rdv: &Rendezvous,
    ranks: usize,
    opts: &LaunchOptions,
    join_forward: &[String],
    children: &mut [Child],
) -> Result<Outcome> {
    use std::sync::mpsc;
    let timeout = Duration::from_secs_f64((cfg.net_timeout_s * 4.0).max(5.0));
    let conns = rdv.wait_workers(ranks, timeout)?;
    // the coordinator keeps the live address book: accept_join patches the
    // dead rank's slot with the replacement's fresh mesh address and ships
    // the updated roster back in the join handshake
    let mut book: Vec<String> = conns.iter().map(|c| c.mesh_addr.clone()).collect();

    let (tx, rx) = mpsc::channel::<(usize, Result<WorkerResult>)>();
    for conn in conns {
        spawn_result_reader(conn, tx.clone());
    }

    let exe = std::env::current_exe().context("locating own binary")?;
    let mut results: Vec<Option<WorkerResult>> = (0..ranks).map(|_| None).collect();
    // a dead worker's result channel fails mid-stream; the error is held
    // per rank and only surfaces if no replacement delivers in its place
    let mut chan_err: Vec<Option<crate::error::Error>> = (0..ranks).map(|_| None).collect();
    let mut reaped = vec![false; children.len()];
    let mut joins_left = opts.max_joins;
    loop {
        while let Ok((rank, res)) = rx.try_recv() {
            match res {
                Ok(r) => {
                    results[rank] = Some(r);
                    chan_err[rank] = None;
                }
                Err(e) => chan_err[rank] = Some(e),
            }
        }
        if results.iter().all(|r| r.is_some()) {
            break;
        }
        if reaped.iter().all(|&r| r) {
            // every child has exited (all cleanly — a failed exit either
            // respawned below or bailed): missing results are stragglers
            // still buffered on their sockets, or coordinator-side read
            // failures that nothing can repair any more
            match rx.recv_timeout(timeout) {
                Ok((rank, Ok(r))) => results[rank] = Some(r),
                Ok((_, Err(e))) => return Err(e),
                Err(_) => {
                    let e = chan_err.iter_mut().find_map(Option::take).unwrap_or_else(|| {
                        crate::err!("workers exited before delivering all results")
                    });
                    return Err(e);
                }
            }
            continue;
        }
        // serve the re-join rendezvous: a replacement dials in with a Join
        // hello, gets the patched roster, and streams its results over
        // this new connection (the dead original's channel is abandoned)
        if let Some(conn) = rdv.accept_join(&mut book, Duration::from_millis(20))? {
            spawn_result_reader(conn, tx.clone());
        }
        // reap dead children and respawn replacements within the budget
        for rank in 0..children.len() {
            if reaped[rank] || results[rank].is_some() {
                continue;
            }
            let Some(status) = children[rank]
                .try_wait()
                .with_context(|| format!("polling worker rank {rank}"))?
            else {
                continue;
            };
            reaped[rank] = true;
            if status.success() {
                continue; // clean exit — its result chunks are in flight
            }
            if joins_left == 0 {
                let why = chan_err[rank]
                    .take()
                    .map_or(String::new(), |e| format!(": {e}"));
                crate::bail!(
                    "worker rank {rank} died ({status}) with the join budget exhausted \
                     (--max-joins {}){why}",
                    opts.max_joins
                );
            }
            joins_left -= 1;
            eprintln!(
                "worker rank {rank} died ({status}); spawning replacement \
                 ({joins_left} join(s) left in the budget)"
            );
            let mut cmd = Command::new(&exe);
            cmd.arg("worker")
                .arg("--join")
                .arg("--rendezvous")
                .arg(rdv.addr())
                .arg("--rank")
                .arg(rank.to_string())
                .args(join_forward)
                .stdin(Stdio::null());
            children[rank] = cmd
                .spawn()
                .with_context(|| format!("spawning replacement for rank {rank}"))?;
            reaped[rank] = false;
        }
    }
    let results: Vec<WorkerResult> = results.into_iter().flatten().collect();
    assemble_outcome(cfg, results)
}

fn assemble_outcome(cfg: &ExperimentConfig, mut results: Vec<WorkerResult>) -> Result<Outcome> {
    let label = format!("{}/tcp", cfg.algorithm.name());
    let loads: Vec<LoadStats> = results.iter().filter_map(|r| r.load).collect();
    let stop_reason =
        results.iter().map(|r| r.stop).fold(StopReason::Completed, StopReason::merge);
    // survivors and the joiner agree on the rebuild count; the max guards
    // against a rank whose stats predate the last epoch
    let epochs = results.iter().map(|r| r.epochs).max().unwrap_or(1).max(1);
    match cfg.algorithm {
        AlgoFamily::Dsanls | AlgoFamily::Baseline(_) => {
            let mut outputs = Vec::with_capacity(results.len());
            for (rank, r) in results.into_iter().enumerate() {
                outputs.push(NodeOutput {
                    u_block: r.u.with_context(|| format!("rank {rank} sent no U block"))?,
                    v_block: r.v.with_context(|| format!("rank {rank} sent no V block"))?,
                    trace: r.trace,
                    stats: r.stats,
                    final_clock: r.final_clock,
                    stop: r.stop,
                    epochs: r.epochs,
                });
            }
            let span = algos::trace_span(&outputs[0].trace, cfg.iterations);
            let run = algos::reduce_outputs(outputs, cfg.rank, span);
            Ok(Outcome {
                label,
                trace: run.trace,
                stats: run.stats,
                sec_per_iter: run.sec_per_iter,
                u: run.u,
                v: run.v,
                loads,
                stop_reason,
                retries: 0,
                epochs,
            })
        }
        AlgoFamily::Secure(SecureAlgo::SynSd
        | SecureAlgo::SynSsdU
        | SecureAlgo::SynSsdV
        | SecureAlgo::SynSsdUv) => {
            let mut outputs = Vec::with_capacity(results.len());
            for (rank, r) in results.into_iter().enumerate() {
                outputs.push(syn::SynNodeOutput {
                    u_local: r.u.with_context(|| format!("rank {rank} sent no U"))?,
                    v_block: r.v.with_context(|| format!("rank {rank} sent no V block"))?,
                    trace: r.trace,
                    stats: r.stats,
                    final_clock: r.final_clock,
                    stop: r.stop,
                    epochs: r.epochs,
                });
            }
            let span = algos::trace_span(&outputs[0].trace, cfg.t1 * cfg.t2);
            let run = syn::assemble_syn(outputs, cfg.rank, span);
            Ok(Outcome {
                label,
                trace: run.trace,
                stats: run.stats,
                sec_per_iter: run.sec_per_iter,
                u: run.u,
                v: run.v,
                loads,
                stop_reason,
                retries: 0,
                epochs,
            })
        }
        AlgoFamily::Secure(SecureAlgo::AsynSd | SecureAlgo::AsynSsdV) => {
            let server = results
                .pop()
                .context("async run returned no server result")?;
            let server_u = server.u.context("server sent no U")?;
            let m_fro_sq = server.fro_sq.context("server sent no ‖M‖² chunk")?;
            let mut outs = Vec::with_capacity(results.len());
            for (rank, r) in results.into_iter().enumerate() {
                outs.push(asyn::AsynClientOutput {
                    v_block: r.v.with_context(|| format!("client {rank} sent no V block"))?,
                    samples: r.samples,
                    stats: r.stats,
                    final_clock: r.final_clock,
                    stop: r.stop,
                });
            }
            let run =
                asyn::assemble_asyn(server_u, outs, &coordinator::asyn_options(cfg), m_fro_sq);
            Ok(Outcome {
                label,
                trace: run.trace,
                stats: run.stats,
                sec_per_iter: run.sec_per_iter,
                u: run.u,
                v: run.v,
                loads,
                stop_reason,
                retries: 0,
                epochs,
            })
        }
    }
}

/// Re-run the configured experiment on the simulated backend and compare
/// factors bit-for-bit (deterministic algorithms only). `shard_cols` is
/// the column partition a `--shards` run actually used (from the
/// manifest — possibly nnz-balanced): the simulated re-run must use the
/// identical partition or the comparison would spuriously diverge.
fn verify_against_sim(
    cfg: &ExperimentConfig,
    tcp: &Outcome,
    shard_cols: Option<Partition>,
    compressed: Option<&Path>,
) -> Result<()> {
    if matches!(cfg.algorithm, AlgoFamily::Secure(SecureAlgo::AsynSd | SecureAlgo::AsynSsdV)) {
        println!("verify-sim: skipped (asynchronous protocols are order-dependent by design)");
        return Ok(());
    }
    print!("verify-sim: running simulated backend… ");
    std::io::stdout().flush().ok();
    let sim = {
        use crate::nmf::job::{DataSource, Job};
        if let Some(dir) = compressed {
            // the simulated re-run reads the SAME sketched views, so
            // bit-identity across backends holds on the compressed plane too
            let cols = compress::read_compressed_manifest(dir)?.base.cols;
            Job::builder()
                .from_config(cfg, cols)
                .data(DataSource::Compressed(dir.to_path_buf()))
                .run()
                .unwrap_or_else(|e| panic!("verify-sim run failed: {e}"))
        } else {
            let m = coordinator::load_dataset(cfg);
            let mut b = Job::builder()
                .from_config(cfg, m.cols())
                .data(DataSource::Full(&m));
            if let (Some(p), AlgoFamily::Secure(_)) = (&shard_cols, &cfg.algorithm) {
                b = b.secure_partition(p.clone());
            }
            b.run()
                .unwrap_or_else(|e| panic!("verify-sim run failed: {e}"))
        }
    };
    let identical = sim.u.data() == tcp.u.data() && sim.v.data() == tcp.v.data();
    println!("factors bit-identical to simulated backend: {identical}");
    if !identical {
        crate::bail!(
            "TCP factors diverge from simulator (sim err {:.6}, tcp err {:.6})",
            sim.final_error(),
            tcp.final_error()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_codecs_roundtrip() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32 * 0.5);
        let back = mat_from_payload(&mat_payload(&m)).unwrap();
        assert_eq!(back.data(), m.data());
        assert_eq!((back.rows(), back.cols()), (3, 4));
        assert!(mat_from_payload(&[3.0, 4.0, 1.0]).is_err(), "short matrix must error");

        let trace = vec![
            TracePoint { iteration: 0, sim_time: 0.0, rel_error: 1.0 },
            TracePoint { iteration: 7, sim_time: 1.0 / 3.0, rel_error: 0.123456789 },
        ];
        let back = trace_from_payload(&trace_payload(&trace)).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].iteration, 7);
        assert_eq!(back[1].sim_time, 1.0 / 3.0);
        assert_eq!(back[1].rel_error, 0.123456789);

        let stats = CommStats {
            bytes_sent: usize::MAX / 2,
            bytes_received: 12345,
            messages: 999,
            compute_time: 1.5,
            comm_time: 2.5e-7,
            stall_time: 0.0,
        };
        let (bs, clock, stop, epochs) =
            stats_from_payload(&stats_payload(&stats, 42.042, StopReason::TargetReached, 3))
                .unwrap();
        assert_eq!(bs, stats);
        assert_eq!(clock, 42.042);
        assert_eq!(stop, StopReason::TargetReached);
        assert_eq!(epochs, 3);
        // a zero on the wire clamps to the founding epoch
        let (_, _, _, epochs) =
            stats_from_payload(&stats_payload(&stats, 0.0, StopReason::Completed, 0)).unwrap();
        assert_eq!(epochs, 1);

        let samples = vec![(0.5, 123.456, 10usize), (1.5, 0.001, 20)];
        let back = samples_from_payload(&samples_payload(&samples)).unwrap();
        assert_eq!(back, samples);
    }

    #[test]
    fn launch_args_parse() {
        let args: Vec<String> = ["--nodes", "4", "--verify-sim", "--experiment.rank=3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = parse_launch_args(&args).unwrap();
        assert_eq!(o.cfg.nodes, 4);
        assert!(o.verify_sim);
        assert_eq!(o.cfg.rank, 3);
        assert!(o.forward.iter().any(|a| a == "--experiment.nodes=4"));
        assert!(!o.forward.iter().any(|a| a == "--verify-sim"));
        assert_eq!(o.retries, 0);
        assert!(o.checkpoint.is_none() && o.resume.is_none() && o.fault.is_none());
        assert!(!o.elastic, "elastic is opt-in");
        assert_eq!(o.max_joins, 3, "default replacement budget");
    }

    #[test]
    fn launch_elastic_flags_parse_and_validate() {
        let args: Vec<String> = ["--nodes", "2", "--elastic", "--max-joins", "5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = parse_launch_args(&args).unwrap();
        assert!(o.elastic);
        assert_eq!(o.max_joins, 5);
        // the elastic control plane forwards to every worker (and thus to
        // any later replacement) as a plain worker flag
        assert!(o.forward.iter().any(|a| a == "--elastic"));
        assert!(!o.forward.iter().any(|a| a == "--max-joins"));

        // the budget flag alone is a user error
        let args: Vec<String> = ["--max-joins", "2"].iter().map(|s| s.to_string()).collect();
        let err = parse_launch_args(&args).unwrap_err();
        assert!(err.to_string().contains("--elastic"), "{err}");

        // elastic × overlapped exchanges cannot be rolled back at a boundary
        let args: Vec<String> =
            ["--nodes", "2", "--elastic", "--overlap"].iter().map(|s| s.to_string()).collect();
        let err = parse_launch_args(&args).unwrap_err();
        assert!(err.to_string().contains("overlap"), "{err}");

        // elastic × async: the server already tolerates churn
        let args: Vec<String> =
            ["--nodes", "2", "--elastic", "--experiment.algorithm=asyn-sd"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = parse_launch_args(&args).unwrap_err();
        assert!(err.to_string().contains("asynchronous"), "{err}");
    }

    #[test]
    fn launch_overlap_and_precision_flags_parse_and_forward() {
        let args: Vec<String> = ["--nodes", "2", "--overlap", "--wire-precision", "bf16"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = parse_launch_args(&args).unwrap();
        assert!(o.cfg.overlap_comm);
        assert_eq!(o.cfg.wire_precision, Precision::Bf16);
        // the sugar flags forward as config overrides so workers inherit them
        assert!(o.forward.iter().any(|a| a == "--network.overlap=true"));
        assert!(o.forward.iter().any(|a| a == "--network.precision=bf16"));
        assert!(!o.forward.iter().any(|a| a == "--overlap" || a == "--wire-precision"));

        let args: Vec<String> =
            ["--wire-precision", "int8"].iter().map(|s| s.to_string()).collect();
        assert!(parse_launch_args(&args).is_err());
    }

    #[test]
    fn launch_control_args_parse_and_forward() {
        let args: Vec<String> = [
            "--nodes",
            "2",
            "--retries",
            "3",
            "--checkpoint",
            "/tmp/run.ckpt",
            "--checkpoint-every",
            "5",
            "--max-seconds",
            "12.5",
            "--target-error",
            "0.08",
            "--fault-rank",
            "1",
            "--fault-iteration",
            "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let o = parse_launch_args(&args).unwrap();
        assert_eq!(o.retries, 3);
        assert_eq!(o.checkpoint.as_deref(), Some(Path::new("/tmp/run.ckpt")));
        assert_eq!(o.fault, Some((1, 4)));
        assert_eq!(o.max_seconds, Some(12.5));
        // convergence + checkpoint flags forward to the workers…
        for flag in ["--target-error", "--checkpoint", "--checkpoint-every"] {
            assert!(o.forward.iter().any(|a| a == flag), "{flag} must forward");
        }
        // …but resume, fault injection and the (remaining) wall-clock
        // budget are per-attempt decisions appended in launch_attempt
        assert!(!o
            .forward
            .iter()
            .any(|a| a == "--resume" || a == "--fault-rank" || a == "--max-seconds"));

        // fault flags must come as a pair
        let args: Vec<String> =
            ["--fault-rank", "1"].iter().map(|s| s.to_string()).collect();
        assert!(parse_launch_args(&args).is_err());
        // --checkpoint-every without --checkpoint is a user error
        let args: Vec<String> =
            ["--checkpoint-every", "5"].iter().map(|s| s.to_string()).collect();
        assert!(parse_launch_args(&args).is_err());
    }

    #[test]
    fn worker_control_args_resolve() {
        let mut w = WorkerControlArgs::default();
        w.apply("--max-seconds", "30").unwrap();
        w.apply("--target-error", "0.1").unwrap();
        w.apply("--checkpoint", "/tmp/x.ckpt").unwrap();
        w.apply("--fault-rank", "1").unwrap();
        w.apply("--fault-iteration", "7").unwrap();
        let cfg = ExperimentConfig::default();
        let ctl = w.resolve(&cfg, 1, 100, 80).unwrap();
        assert_eq!(ctl.stop.max_seconds, Some(30.0));
        assert_eq!(ctl.stop.target_error, Some(0.1));
        assert_eq!(ctl.fault_at, Some(7), "fault fires on the matching rank");
        assert_eq!(
            ctl.checkpoint.as_ref().unwrap().every,
            DEFAULT_CHECKPOINT_EVERY,
            "cadence defaults when only --checkpoint is given"
        );
        let ctl = w.resolve(&cfg, 0, 100, 80).unwrap();
        assert_eq!(ctl.fault_at, None, "other ranks must not fault");
        assert_eq!(ctl.elastic, None, "elastic is opt-in");
        let mut we = WorkerControlArgs::default();
        we.elastic = true;
        let ctl = we.resolve(&cfg, 0, 100, 80).unwrap();
        assert_eq!(ctl.elastic, Some(ElasticCtl { min_ranks: 1 }));

        // secure + checkpoint is rejected with a typed error
        let mut cfg = ExperimentConfig::default();
        cfg.apply("experiment.algorithm", "syn-sd").unwrap();
        let err = w.resolve(&cfg, 0, 100, 80).unwrap_err();
        assert!(err.to_string().contains("secure"), "{err}");
    }
}
