//! Multi-process deployment: the `dsanls launch` coordinator and the
//! `dsanls worker` rank entry point.
//!
//! `dsanls launch --nodes N [--config cfg.toml] [--key=value ...]` binds a
//! [`Rendezvous`] listener, spawns `N` worker processes of the same binary
//! (`N + 1` for the asynchronous protocols — the extra rank is the
//! parameter server), performs the magic/version/rank handshake, and
//! broadcasts the mesh roster. Each worker regenerates the dataset from
//! the shared config (datasets are seed-derived, so no data shipping is
//! needed), runs its rank of the configured algorithm over
//! [`crate::transport::TcpComm`], and streams its result chunks back over
//! the rendezvous connection. The coordinator assembles them into the same
//! [`Outcome`] the simulated path produces.
//!
//! Because the collectives reduce in rank order on every backend, a seeded
//! `launch` run produces factors **bit-identical** to the in-process
//! simulated run of the same config — `--verify-sim` re-runs the simulator
//! in the coordinator and asserts exactly that.
//!
//! Result chunks ride the same length-prefixed f32 frames as the data
//! plane ([`crate::transport::wire`]): matrices carry `[rows, cols,
//! data…]`, exact `u64`/`f64` statistics are bit-split across f32 lanes,
//! and worker failures arrive as `Error` frames whose text the coordinator
//! surfaces verbatim.

use std::io::Write as _;
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use crate::algos::{self, NodeOutput, TracePoint};
use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::{self, Outcome};
use crate::dist::{CommStats, NodeCtx};
use crate::error::{Context, Result};
use crate::linalg::Mat;
use crate::metrics;
use crate::nmf::init_factors;
use crate::rng::Role;
use crate::secure::{asyn, syn, SecureAlgo};
use crate::transport::wire::{
    self, decode_text, encode_text, push_f64_bits, push_u64_bits, take_f64_bits, take_u64_bits,
    Frame, FrameKind,
};
use crate::transport::{Rendezvous, TcpComm, TcpOptions};

/// Result-chunk codes (frame tag of `FrameKind::Result`).
const RES_U: u64 = 1;
const RES_V: u64 = 2;
const RES_TRACE: u64 = 3;
const RES_STATS: u64 = 4;
const RES_SAMPLES: u64 = 5;
const RES_DONE: u64 = 6;
/// `‖M‖²_F` (f64 bits), shipped by the async server so the coordinator
/// need not regenerate the dataset just to merge traces.
const RES_FRO: u64 = 7;

// ---------------------------------------------------------------------------
// Payload codecs (matrices, traces, statistics)
// ---------------------------------------------------------------------------

fn mat_payload(m: &Mat) -> Vec<f32> {
    assert!(m.rows() < (1 << 24) && m.cols() < (1 << 24), "dims exceed exact-f32 range");
    let mut p = Vec::with_capacity(2 + m.data().len());
    p.push(m.rows() as f32);
    p.push(m.cols() as f32);
    p.extend_from_slice(m.data());
    p
}

fn mat_from_payload(p: &[f32]) -> Result<Mat> {
    if p.len() < 2 {
        crate::bail!("matrix chunk too short");
    }
    let rows = p[0] as usize;
    let cols = p[1] as usize;
    if p.len() != 2 + rows * cols {
        crate::bail!("matrix chunk: {} values for {rows}x{cols}", p.len() - 2);
    }
    Ok(Mat::from_vec(rows, cols, p[2..].to_vec()))
}

fn trace_payload(trace: &[TracePoint]) -> Vec<f32> {
    let mut p = Vec::with_capacity(trace.len() * 5);
    for t in trace {
        p.push(t.iteration as f32);
        push_f64_bits(&mut p, t.sim_time);
        push_f64_bits(&mut p, t.rel_error);
    }
    p
}

fn trace_from_payload(p: &[f32]) -> Result<Vec<TracePoint>> {
    if p.len() % 5 != 0 {
        crate::bail!("trace chunk length {} not a multiple of 5", p.len());
    }
    let mut out = Vec::with_capacity(p.len() / 5);
    let mut pos = 0;
    while pos < p.len() {
        let iteration = p[pos] as usize;
        pos += 1;
        let sim_time = take_f64_bits(p, &mut pos)?;
        let rel_error = take_f64_bits(p, &mut pos)?;
        out.push(TracePoint { iteration, sim_time, rel_error });
    }
    Ok(out)
}

fn stats_payload(s: &CommStats, final_clock: f64) -> Vec<f32> {
    let mut p = Vec::with_capacity(14);
    push_u64_bits(&mut p, s.bytes_sent as u64);
    push_u64_bits(&mut p, s.bytes_received as u64);
    push_u64_bits(&mut p, s.messages as u64);
    push_f64_bits(&mut p, s.compute_time);
    push_f64_bits(&mut p, s.comm_time);
    push_f64_bits(&mut p, s.stall_time);
    push_f64_bits(&mut p, final_clock);
    p
}

fn stats_from_payload(p: &[f32]) -> Result<(CommStats, f64)> {
    let mut pos = 0;
    let stats = CommStats {
        bytes_sent: take_u64_bits(p, &mut pos)? as usize,
        bytes_received: take_u64_bits(p, &mut pos)? as usize,
        messages: take_u64_bits(p, &mut pos)? as usize,
        compute_time: take_f64_bits(p, &mut pos)?,
        comm_time: take_f64_bits(p, &mut pos)?,
        stall_time: take_f64_bits(p, &mut pos)?,
    };
    let final_clock = take_f64_bits(p, &mut pos)?;
    Ok((stats, final_clock))
}

fn samples_payload(samples: &[(f64, f64, usize)]) -> Vec<f32> {
    let mut p = Vec::with_capacity(samples.len() * 6);
    for &(clock, resid, iters) in samples {
        push_f64_bits(&mut p, clock);
        push_f64_bits(&mut p, resid);
        push_u64_bits(&mut p, iters as u64);
    }
    p
}

fn samples_from_payload(p: &[f32]) -> Result<Vec<(f64, f64, usize)>> {
    if p.len() % 6 != 0 {
        crate::bail!("samples chunk length {} not a multiple of 6", p.len());
    }
    let mut out = Vec::with_capacity(p.len() / 6);
    let mut pos = 0;
    while pos < p.len() {
        let clock = take_f64_bits(p, &mut pos)?;
        let resid = take_f64_bits(p, &mut pos)?;
        let iters = take_u64_bits(p, &mut pos)? as usize;
        out.push((clock, resid, iters));
    }
    Ok(out)
}

fn send_chunk(stream: &mut TcpStream, tag: u64, payload: &[f32]) -> Result<()> {
    wire::write_frame_parts(stream, FrameKind::Result, tag, 0.0, payload)
        .context("reporting result to coordinator")
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// How many TCP ranks a config needs: one per node, plus the parameter
/// server for the asynchronous protocols.
pub fn cluster_ranks(cfg: &ExperimentConfig) -> usize {
    match cfg.algorithm {
        Algorithm::Secure(SecureAlgo::AsynSd | SecureAlgo::AsynSsdV) => cfg.nodes + 1,
        _ => cfg.nodes,
    }
}

/// `dsanls worker --rendezvous HOST:PORT --rank R [config args…]` — one
/// rank of a `launch` cluster, normally spawned by the coordinator.
/// Deployment is **single-host** today: the rendezvous and mesh listeners
/// bind `127.0.0.1` and the roster carries ports only, so workers must
/// run on the coordinator's machine (multi-host needs a host-carrying
/// roster — see ROADMAP).
pub fn worker_main(args: &[String]) -> Result<()> {
    let mut rendezvous = None;
    let mut rank = None;
    let mut cfg_args: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--rendezvous" => {
                rendezvous = Some(args.get(i + 1).context("--rendezvous needs HOST:PORT")?.clone());
                i += 2;
            }
            "--rank" => {
                let v = args.get(i + 1).context("--rank needs a number")?;
                rank = Some(v.parse::<usize>().map_err(|e| crate::err!("--rank {v}: {e}"))?);
                i += 2;
            }
            _ => {
                cfg_args.push(args[i].clone());
                i += 1;
            }
        }
    }
    let addr = rendezvous.context("worker needs --rendezvous HOST:PORT")?;
    let rank = rank.context("worker needs --rank R")?;
    let cfg = super::parse_cli_config(&cfg_args).map_err(crate::error::Error::msg)?;
    let ranks = cluster_ranks(&cfg);

    let topts = TcpOptions {
        connect_timeout: Duration::from_secs_f64(cfg.net_timeout_s.max(1.0)),
        io_timeout: Some(Duration::from_secs_f64((cfg.net_timeout_s * 4.0).max(1.0))),
    };
    let mut comm = TcpComm::connect(&addr, rank, ranks, &topts)
        .with_context(|| format!("worker rank {rank} joining cluster at {addr}"))?;
    let mut report = comm
        .take_rendezvous()
        .context("rendezvous channel already taken")?;

    // run the rank; ship failures back as Error frames before exiting
    match run_rank(&cfg, comm, rank, &mut report) {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = format!("rank {rank}: {e}");
            let _ = wire::write_frame(
                &mut report,
                &Frame::new(FrameKind::Error, rank as u64, 0.0, encode_text(&msg)),
            );
            Err(crate::error::Error::msg(msg))
        }
    }
}

/// Execute this rank's share of the configured algorithm and stream the
/// results back over the rendezvous connection.
fn run_rank(
    cfg: &ExperimentConfig,
    comm: TcpComm,
    rank: usize,
    report: &mut TcpStream,
) -> Result<()> {
    let m = coordinator::load_dataset(cfg);
    // mirror the simulated cluster's per-node thread cap so the
    // thread-count-sensitive reductions split identically (bit-identity)
    crate::dist::apply_node_thread_policy(cfg.nodes);

    // catch panics from the algorithm layer (collective failures panic) so
    // they reach the coordinator as Error frames, not silent worker deaths
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_rank_inner(cfg, comm, rank, &m, report)
    }));
    crate::parallel::set_local_threads(None);
    match outcome {
        Ok(res) => res,
        Err(panic) => {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "worker panicked".into());
            Err(crate::error::Error::msg(msg))
        }
    }
}

fn run_rank_inner(
    cfg: &ExperimentConfig,
    comm: TcpComm,
    rank: usize,
    m: &crate::linalg::Matrix,
    report: &mut TcpStream,
) -> Result<()> {
    match cfg.algorithm {
        Algorithm::Dsanls => {
            let opts = coordinator::dsanls_options(cfg);
            let mut ctx = NodeCtx::new(comm, cfg.comm);
            let out = algos::dsanls::dsanls_node(&mut ctx, m, &opts);
            send_node_output(report, &out)
        }
        Algorithm::Baseline(solver) => {
            let opts = coordinator::dist_anls_options(cfg, solver);
            let mut ctx = NodeCtx::new(comm, cfg.comm);
            let out = algos::dist_anls::dist_anls_node(&mut ctx, m, &opts);
            send_node_output(report, &out)
        }
        Algorithm::Secure(algo @ (SecureAlgo::SynSd
        | SecureAlgo::SynSsdU
        | SecureAlgo::SynSsdV
        | SecureAlgo::SynSsdUv)) => {
            let cols = coordinator::secure_partition(cfg, m.cols());
            let opts = coordinator::syn_options(cfg);
            let mut ctx = NodeCtx::new(comm, cfg.comm);
            let out = syn::syn_node(&mut ctx, m, &cols, &opts, algo, None);
            send_chunk(report, RES_U, &mat_payload(&out.u_local))?;
            send_chunk(report, RES_V, &mat_payload(&out.v_block))?;
            send_chunk(report, RES_TRACE, &trace_payload(&out.trace))?;
            send_chunk(report, RES_STATS, &stats_payload(&out.stats, out.final_clock))?;
            send_chunk(report, RES_DONE, &[])
        }
        Algorithm::Secure(variant @ (SecureAlgo::AsynSd | SecureAlgo::AsynSsdV)) => {
            let cols = coordinator::secure_partition(cfg, m.cols());
            let opts = coordinator::asyn_options(cfg);
            let stream_rng = crate::rng::StreamRng::new(opts.seed);
            let (u_init, v_full) = {
                let mut rng = stream_rng.for_iteration(0, Role::Init);
                init_factors(m, opts.rank, &mut rng)
            };
            if rank == asyn::server_rank(cfg.nodes) {
                let fro_sq = m.fro_sq();
                let u = asyn::server_loop(comm, &opts, u_init);
                send_chunk(report, RES_U, &mat_payload(&u))?;
                let mut fro = Vec::with_capacity(2);
                push_f64_bits(&mut fro, fro_sq);
                send_chunk(report, RES_FRO, &fro)?;
                send_chunk(report, RES_DONE, &[])
            } else {
                let v0 = v_full.row_block(cols.range(rank));
                let out =
                    asyn::client_loop(comm, rank, m, &cols, &opts, variant, u_init, v0, None);
                send_chunk(report, RES_V, &mat_payload(&out.v_block))?;
                send_chunk(report, RES_SAMPLES, &samples_payload(&out.samples))?;
                send_chunk(report, RES_STATS, &stats_payload(&out.stats, out.final_clock))?;
                send_chunk(report, RES_DONE, &[])
            }
        }
    }
}

fn send_node_output(stream: &mut TcpStream, out: &NodeOutput) -> Result<()> {
    send_chunk(stream, RES_U, &mat_payload(&out.u_block))?;
    send_chunk(stream, RES_V, &mat_payload(&out.v_block))?;
    send_chunk(stream, RES_TRACE, &trace_payload(&out.trace))?;
    send_chunk(stream, RES_STATS, &stats_payload(&out.stats, out.final_clock))?;
    send_chunk(stream, RES_DONE, &[])
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

#[derive(Default)]
struct WorkerResult {
    u: Option<Mat>,
    v: Option<Mat>,
    trace: Vec<TracePoint>,
    stats: CommStats,
    final_clock: f64,
    samples: Vec<(f64, f64, usize)>,
    fro_sq: Option<f64>,
}

fn read_worker_result(stream: &mut TcpStream, rank: usize) -> Result<WorkerResult> {
    let mut res = WorkerResult::default();
    loop {
        let f = wire::read_frame(stream)
            .with_context(|| format!("reading results from worker rank {rank}"))?;
        match f.kind {
            FrameKind::Result => match f.tag {
                RES_U => res.u = Some(mat_from_payload(&f.payload)?),
                RES_V => res.v = Some(mat_from_payload(&f.payload)?),
                RES_TRACE => res.trace = trace_from_payload(&f.payload)?,
                RES_STATS => {
                    let (stats, clock) = stats_from_payload(&f.payload)?;
                    res.stats = stats;
                    res.final_clock = clock;
                }
                RES_SAMPLES => res.samples = samples_from_payload(&f.payload)?,
                RES_FRO => {
                    let mut pos = 0;
                    res.fro_sq = Some(take_f64_bits(&f.payload, &mut pos)?);
                }
                RES_DONE => return Ok(res),
                other => crate::bail!("unknown result chunk {other} from rank {rank}"),
            },
            FrameKind::Error => crate::bail!("worker failed: {}", decode_text(&f.payload)),
            other => crate::bail!("unexpected {other:?} frame from worker rank {rank}"),
        }
    }
}

/// Options controlling a `launch` run (parsed from the CLI).
pub struct LaunchOptions {
    pub cfg: ExperimentConfig,
    /// Rendezvous port (0 = ephemeral).
    pub port: u16,
    /// Re-run the simulated backend in-process and assert the factors are
    /// bit-identical (deterministic algorithms only).
    pub verify_sim: bool,
    /// Arguments forwarded verbatim to the workers (config file + overrides).
    pub forward: Vec<String>,
}

/// Parse `launch` CLI arguments.
pub fn parse_launch_args(args: &[String]) -> Result<LaunchOptions> {
    let mut nodes_override = None;
    let mut port = 0u16;
    let mut verify_sim = false;
    let mut forward: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--nodes" => {
                let v = args.get(i + 1).context("--nodes needs a number")?;
                nodes_override =
                    Some(v.parse::<usize>().map_err(|e| crate::err!("--nodes {v}: {e}"))?);
                i += 2;
            }
            "--port" => {
                let v = args.get(i + 1).context("--port needs a number")?;
                port = v.parse::<u16>().map_err(|e| crate::err!("--port {v}: {e}"))?;
                i += 2;
            }
            "--verify-sim" => {
                verify_sim = true;
                i += 1;
            }
            "--config" => {
                forward.push(args[i].clone());
                forward.push(args.get(i + 1).context("--config needs a path")?.clone());
                i += 2;
            }
            _ => {
                forward.push(args[i].clone());
                i += 1;
            }
        }
    }
    let mut cfg = super::parse_cli_config(&forward).map_err(crate::error::Error::msg)?;
    if let Some(n) = nodes_override {
        cfg.nodes = n;
        forward.push(format!("--experiment.nodes={n}"));
    }
    if cfg.nodes == 0 {
        crate::bail!("launch needs at least one node");
    }
    Ok(LaunchOptions { cfg, port, verify_sim, forward })
}

/// `dsanls launch` — spawn the worker processes, run the experiment over
/// real TCP, assemble and report the outcome.
pub fn launch_main(args: &[String]) -> Result<()> {
    let opts = parse_launch_args(args)?;
    let cfg = &opts.cfg;
    let ranks = cluster_ranks(cfg);

    let rdv = Rendezvous::bind(opts.port)?;
    println!(
        "launching {} over TCP: {} worker process(es){} on {}",
        cfg.algorithm.name(),
        cfg.nodes,
        if ranks > cfg.nodes { " + 1 parameter server" } else { "" },
        rdv.addr()
    );

    let exe = std::env::current_exe().context("locating own binary")?;
    let mut children: Vec<Child> = Vec::with_capacity(ranks);
    for rank in 0..ranks {
        let mut cmd = Command::new(&exe);
        cmd.arg("worker")
            .arg("--rendezvous")
            .arg(rdv.addr())
            .arg("--rank")
            .arg(rank.to_string())
            .args(&opts.forward)
            .stdin(Stdio::null());
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning worker rank {rank}"))?;
        children.push(child);
    }

    let run = launch_collect(cfg, &rdv, ranks);
    // reap the children regardless of how collection went
    let collected_ok = run.is_ok();
    let mut worker_failure = None;
    for (rank, mut child) in children.into_iter().enumerate() {
        if collected_ok {
            let status = child.wait().context("waiting for worker")?;
            if !status.success() && worker_failure.is_none() {
                worker_failure = Some(format!("worker rank {rank} exited with {status}"));
            }
        } else {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
    let outcome = run?;
    if let Some(fail) = worker_failure {
        crate::bail!("{fail}");
    }

    println!(
        "final rel-error {:.4}  sec/iter {:.5}  {}",
        outcome.final_error(),
        outcome.sec_per_iter,
        metrics::stats_summary(&outcome.stats)
    );
    let path = std::path::Path::new(&cfg.output_dir).join(format!("{}-tcp.csv", cfg.name));
    if let Err(e) = metrics::write_series_csv(&path, &[outcome.series()]) {
        eprintln!("write {path:?}: {e}");
    } else {
        println!("trace written to {path:?}");
    }

    if opts.verify_sim {
        verify_against_sim(cfg, &outcome)?;
    }
    Ok(())
}

/// Accept the workers, gather their results, and assemble the outcome.
fn launch_collect(cfg: &ExperimentConfig, rdv: &Rendezvous, ranks: usize) -> Result<Outcome> {
    let timeout = Duration::from_secs_f64((cfg.net_timeout_s * 4.0).max(5.0));
    let mut conns = rdv.wait_workers(ranks, timeout)?;
    let mut results: Vec<WorkerResult> = Vec::with_capacity(ranks);
    for conn in conns.iter_mut() {
        results.push(read_worker_result(&mut conn.stream, conn.rank)?);
    }
    assemble_outcome(cfg, results)
}

fn assemble_outcome(cfg: &ExperimentConfig, mut results: Vec<WorkerResult>) -> Result<Outcome> {
    let label = format!("{}/tcp", cfg.algorithm.name());
    match cfg.algorithm {
        Algorithm::Dsanls | Algorithm::Baseline(_) => {
            let mut outputs = Vec::with_capacity(results.len());
            for (rank, r) in results.into_iter().enumerate() {
                outputs.push(NodeOutput {
                    u_block: r.u.with_context(|| format!("rank {rank} sent no U block"))?,
                    v_block: r.v.with_context(|| format!("rank {rank} sent no V block"))?,
                    trace: r.trace,
                    stats: r.stats,
                    final_clock: r.final_clock,
                });
            }
            let run = algos::reduce_outputs(outputs, cfg.rank, cfg.iterations);
            Ok(Outcome {
                label,
                trace: run.trace,
                stats: run.stats,
                sec_per_iter: run.sec_per_iter,
                u: run.u,
                v: run.v,
            })
        }
        Algorithm::Secure(SecureAlgo::SynSd
        | SecureAlgo::SynSsdU
        | SecureAlgo::SynSsdV
        | SecureAlgo::SynSsdUv) => {
            let mut outputs = Vec::with_capacity(results.len());
            for (rank, r) in results.into_iter().enumerate() {
                outputs.push(syn::SynNodeOutput {
                    u_local: r.u.with_context(|| format!("rank {rank} sent no U"))?,
                    v_block: r.v.with_context(|| format!("rank {rank} sent no V block"))?,
                    trace: r.trace,
                    stats: r.stats,
                    final_clock: r.final_clock,
                });
            }
            let run = syn::assemble_syn(outputs, cfg.rank, cfg.t1 * cfg.t2);
            Ok(Outcome {
                label,
                trace: run.trace,
                stats: run.stats,
                sec_per_iter: run.sec_per_iter,
                u: run.u,
                v: run.v,
            })
        }
        Algorithm::Secure(SecureAlgo::AsynSd | SecureAlgo::AsynSsdV) => {
            let server = results
                .pop()
                .context("async run returned no server result")?;
            let server_u = server.u.context("server sent no U")?;
            let m_fro_sq = server.fro_sq.context("server sent no ‖M‖² chunk")?;
            let mut outs = Vec::with_capacity(results.len());
            for (rank, r) in results.into_iter().enumerate() {
                outs.push(asyn::AsynClientOutput {
                    v_block: r.v.with_context(|| format!("client {rank} sent no V block"))?,
                    samples: r.samples,
                    stats: r.stats,
                    final_clock: r.final_clock,
                });
            }
            let run =
                asyn::assemble_asyn(server_u, outs, &coordinator::asyn_options(cfg), m_fro_sq);
            Ok(Outcome {
                label,
                trace: run.trace,
                stats: run.stats,
                sec_per_iter: run.sec_per_iter,
                u: run.u,
                v: run.v,
            })
        }
    }
}

/// Re-run the configured experiment on the simulated backend and compare
/// factors bit-for-bit (deterministic algorithms only).
fn verify_against_sim(cfg: &ExperimentConfig, tcp: &Outcome) -> Result<()> {
    if matches!(cfg.algorithm, Algorithm::Secure(SecureAlgo::AsynSd | SecureAlgo::AsynSsdV)) {
        println!("verify-sim: skipped (asynchronous protocols are order-dependent by design)");
        return Ok(());
    }
    print!("verify-sim: running simulated backend… ");
    std::io::stdout().flush().ok();
    let m = coordinator::load_dataset(cfg);
    let sim = coordinator::run_on(cfg, &m);
    let identical = sim.u.data() == tcp.u.data() && sim.v.data() == tcp.v.data();
    println!("factors bit-identical to simulated backend: {identical}");
    if !identical {
        crate::bail!(
            "TCP factors diverge from simulator (sim err {:.6}, tcp err {:.6})",
            sim.final_error(),
            tcp.final_error()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_codecs_roundtrip() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32 * 0.5);
        let back = mat_from_payload(&mat_payload(&m)).unwrap();
        assert_eq!(back.data(), m.data());
        assert_eq!((back.rows(), back.cols()), (3, 4));
        assert!(mat_from_payload(&[3.0, 4.0, 1.0]).is_err(), "short matrix must error");

        let trace = vec![
            TracePoint { iteration: 0, sim_time: 0.0, rel_error: 1.0 },
            TracePoint { iteration: 7, sim_time: 1.0 / 3.0, rel_error: 0.123456789 },
        ];
        let back = trace_from_payload(&trace_payload(&trace)).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].iteration, 7);
        assert_eq!(back[1].sim_time, 1.0 / 3.0);
        assert_eq!(back[1].rel_error, 0.123456789);

        let stats = CommStats {
            bytes_sent: usize::MAX / 2,
            bytes_received: 12345,
            messages: 999,
            compute_time: 1.5,
            comm_time: 2.5e-7,
            stall_time: 0.0,
        };
        let (bs, clock) = stats_from_payload(&stats_payload(&stats, 42.042)).unwrap();
        assert_eq!(bs, stats);
        assert_eq!(clock, 42.042);

        let samples = vec![(0.5, 123.456, 10usize), (1.5, 0.001, 20)];
        let back = samples_from_payload(&samples_payload(&samples)).unwrap();
        assert_eq!(back, samples);
    }

    #[test]
    fn launch_args_parse() {
        let args: Vec<String> = ["--nodes", "4", "--verify-sim", "--experiment.rank=3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = parse_launch_args(&args).unwrap();
        assert_eq!(o.cfg.nodes, 4);
        assert!(o.verify_sim);
        assert_eq!(o.cfg.rank, 3);
        assert!(o.forward.iter().any(|a| a == "--experiment.nodes=4"));
        assert!(!o.forward.iter().any(|a| a == "--verify-sim"));
    }
}
