//! `dsanls serve` / `dsanls query` — the serving-plane CLI surface.
//!
//! `serve` loads a [`FactorModel`] from a training checkpoint and fronts
//! it with the [`crate::serve::server`] batcher on a TCP address; `query`
//! is the matching smoke-test client (top-k, reconstruction, user and
//! item fold-in, stats, and `--reload` hot-swap against a running
//! server). With `--watch-checkpoint` the serve loop polls the checkpoint
//! file and hot-swaps each rewrite into the live server — checkpoints are
//! written atomically (tmp + rename), so a poll never observes a torn
//! file. DEPLOYMENT.md walks through the pair end-to-end and
//! `scripts/deploy_localhost.sh` executes the walkthrough in CI.

use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::serve::{serve, CheckpointSource, FactorModel, ServeClient, ServeOptions};
use crate::solvers::SolverKind;

fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn parse_num<T: std::str::FromStr>(args: &[String], flag: &str) -> Result<Option<T>> {
    match flag_value(args, flag) {
        None => Ok(None),
        Some(v) => v
            .parse::<T>()
            .map(Some)
            .map_err(|_| crate::err!("{flag} expects a number, got {v:?}")),
    }
}

/// Entry point for `dsanls serve --checkpoint FILE --bind ADDR [...]`.
pub fn serve_main(args: &[String]) -> Result<()> {
    let ckpt = PathBuf::from(
        flag_value(args, "--checkpoint")
            .ok_or_else(|| crate::err!("serve needs --checkpoint FILE (a training checkpoint)"))?,
    );
    let bind = flag_value(args, "--bind").unwrap_or("127.0.0.1:7878");

    let mut opts = ServeOptions::default();
    if let Some(n) = parse_num::<usize>(args, "--batch-max")? {
        opts.batch_max = n.max(1);
    }
    if let Some(us) = parse_num::<u64>(args, "--batch-wait-us")? {
        opts.batch_wait_us = us;
    }
    if let Some(n) = parse_num::<usize>(args, "--cache")? {
        opts.cache_cap = n;
    }
    if let Some(n) = parse_num::<usize>(args, "--sweeps")? {
        opts.sweeps = n.max(1);
    }
    if let Some(t) = parse_num::<usize>(args, "--threads")? {
        opts.threads = Some(t.max(1));
    }
    if let Some(s) = flag_value(args, "--solver") {
        opts.solver = s.parse::<SolverKind>().map_err(crate::error::Error::msg)?;
    }
    let expect_algo = flag_value(args, "--expect-algo").map(String::from);
    let expect_params = parse_num::<u64>(args, "--expect-params")?;
    // remember where the model came from so OP_RELOAD (and the watcher
    // below) can re-read it with the same identity gate
    opts.source = Some(CheckpointSource {
        path: ckpt.clone(),
        expect_algo: expect_algo.clone(),
        expect_params,
    });

    let model = FactorModel::load(&ckpt)?;
    model.check_identity(expect_algo.as_deref(), expect_params)?;
    println!(
        "loaded {} checkpoint {} (iteration {}): {} users × {} items, k={}",
        model.meta().algo,
        ckpt.display(),
        model.iteration(),
        model.users(),
        model.items(),
        model.k()
    );

    let handle = serve(bind, model, opts)?;
    // the line the deploy walkthrough (and any operator script) waits for
    println!("serving on {}", handle.addr());

    if has_flag(args, "--watch-checkpoint") {
        let interval = parse_num::<u64>(args, "--watch-interval-ms")?.unwrap_or(500).max(1);
        let mut stamp = file_stamp(&ckpt);
        loop {
            std::thread::sleep(std::time::Duration::from_millis(interval));
            let now = file_stamp(&ckpt);
            if now == stamp {
                continue;
            }
            stamp = now;
            // checkpoints land by atomic rename, so a changed stamp means a
            // complete new file — never a half-written one
            match handle.reload() {
                Ok((gen, it)) => {
                    println!("swapped to generation {gen} (checkpoint iteration {it})")
                }
                // a bad rewrite (wrong algo, truncated copy) keeps the old
                // generation serving; the operator sees why on stderr
                Err(e) => eprintln!("checkpoint watch: reload failed, still serving: {e}"),
            }
        }
    }
    // serve until killed (SIGINT/SIGTERM); the threads own all the work
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Cheap change detector for `--watch-checkpoint`: (mtime, len) of the
/// checkpoint file, `None` while it is missing (mid-rename or deleted).
fn file_stamp(path: &Path) -> Option<(std::time::SystemTime, u64)> {
    std::fs::metadata(path).ok().and_then(|m| m.modified().ok().map(|t| (t, m.len())))
}

fn parse_users(args: &[String]) -> Result<Vec<u64>> {
    let list = flag_value(args, "--users")
        .ok_or_else(|| {
            crate::err!(
                "query needs --users ID[,ID...] (or --fold-in / --fold-in-item / --stats / --reload)"
            )
        })?;
    list.split(',')
        .map(|s| {
            s.trim()
                .parse::<u64>()
                .map_err(|_| crate::err!("--users expects comma-separated ids, got {s:?}"))
        })
        .collect()
}

fn parse_fold_row(spec: &str, flag: &str, id_name: &str) -> Result<Vec<(u64, f32)>> {
    spec.split(',')
        .map(|pair| {
            let (id, val) = pair.split_once(':').ok_or_else(|| {
                crate::err!(
                    "{flag} expects {}:RATING pairs, got {pair:?}",
                    id_name.to_uppercase()
                )
            })?;
            let id = id
                .trim()
                .parse::<u64>()
                .map_err(|_| crate::err!("bad fold-in {id_name} id {id:?}"))?;
            let val = val
                .trim()
                .parse::<f32>()
                .map_err(|_| crate::err!("bad fold-in rating {val:?}"))?;
            Ok((id, val))
        })
        .collect()
}

fn fmt_top(row: &[(u64, f32)]) -> String {
    row.iter().map(|&(i, s)| format!("{i}:{s:.4}")).collect::<Vec<_>>().join(" ")
}

/// Entry point for `dsanls query --addr HOST:PORT <mode flags>`.
pub fn query_main(args: &[String]) -> Result<()> {
    let addr = flag_value(args, "--addr").unwrap_or("127.0.0.1:7878");
    let mut client = ServeClient::connect(addr)?;

    if has_flag(args, "--stats") {
        println!("{}", client.stats()?);
        return Ok(());
    }

    if has_flag(args, "--reload") {
        let (gen, it) = client.reload()?;
        println!("reloaded: generation {gen} (checkpoint iteration {it})");
        return Ok(());
    }

    if let Some(spec) = flag_value(args, "--fold-in") {
        let row = parse_fold_row(spec, "--fold-in", "item")?;
        let n = parse_num::<usize>(args, "--top-k")?.unwrap_or(0);
        let (w, top) = client.fold_in(&row, n)?;
        println!(
            "fold-in w: {}",
            w.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(" ")
        );
        if !top.is_empty() {
            println!("fold-in top: {}", fmt_top(&top));
        }
        return Ok(());
    }

    if let Some(spec) = flag_value(args, "--fold-in-item") {
        let col = parse_fold_row(spec, "--fold-in-item", "user")?;
        let n = parse_num::<usize>(args, "--top-k")?.unwrap_or(0);
        let (h, top) = client.fold_in_item(&col, n)?;
        println!(
            "fold-in-item h: {}",
            h.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(" ")
        );
        if !top.is_empty() {
            println!("fold-in-item top users: {}", fmt_top(&top));
        }
        return Ok(());
    }

    let users = parse_users(args)?;
    if has_flag(args, "--reconstruct") {
        let scores = client.reconstruct(&users)?;
        for (r, &id) in users.iter().enumerate() {
            let row = scores.row(r);
            // argmax: the id a --top-k query of the same user must lead with
            let (argmax, max) = row
                .iter()
                .enumerate()
                .fold((0usize, f32::NEG_INFINITY), |best, (i, &v)| {
                    if v > best.1 {
                        (i, v)
                    } else {
                        best
                    }
                });
            let mean = row.iter().sum::<f32>() / row.len().max(1) as f32;
            println!(
                "user {id}: cols={} argmax={argmax} max={max:.4} mean={mean:.4}",
                row.len()
            );
        }
        return Ok(());
    }

    let n = parse_num::<usize>(args, "--top-k")?.unwrap_or(10);
    let rows = client.top_k(&users, n)?;
    for (row, &id) in rows.iter().zip(&users) {
        println!("user {id}: {}", fmt_top(row));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn arg_parsers() {
        let args = s(&["--users", "1, 2,3"]);
        assert_eq!(parse_users(&args).unwrap(), vec![1, 2, 3]);
        assert!(parse_users(&s(&["--users", "1,x"])).is_err());
        assert_eq!(
            parse_fold_row("3:1.5, 7:2", "--fold-in", "item").unwrap(),
            vec![(3, 1.5), (7, 2.0)]
        );
        assert!(parse_fold_row("3=1.5", "--fold-in", "item").is_err());
        let err = parse_fold_row("3=1.5", "--fold-in-item", "user").unwrap_err().to_string();
        assert!(err.contains("--fold-in-item expects USER:RATING"), "{err}");
        assert_eq!(parse_num::<usize>(&s(&["--top-k", "5"]), "--top-k").unwrap(), Some(5));
        assert!(parse_num::<usize>(&s(&["--top-k", "five"]), "--top-k").is_err());
    }
}
