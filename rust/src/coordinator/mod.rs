//! Experiment coordinator: config → dataset → cluster → algorithm → report.
//!
//! This is the launcher layer the CLI (`rust/src/main.rs`), the benches
//! (`benches/*.rs`) and the examples build on. One entry point,
//! [`run_experiment`], covers every algorithm in the paper on the
//! simulated transport; [`launch`] runs the same experiments over real
//! TCP worker processes (`dsanls launch` / `dsanls worker`), and
//! [`shard_cli`] pre-slices datasets into on-disk shard directories
//! (`dsanls shard`) for multi-host deployments. After training,
//! [`serve_cli`] puts the checkpointed factors behind a TCP inference
//! server (`dsanls serve` / `dsanls query` — see [`crate::serve`]), and
//! [`route_cli`] fronts several such replicas with a consistent-hash
//! router (`dsanls route` — see [`crate::router`]).
//!
//! ## Launch lifecycle (multi-process path)
//!
//! 1. **shard (optional, offline)** — `dsanls shard` materialises the
//!    dataset once, writes per-rank block files + a manifest carrying the
//!    exact global `‖M‖²` ([`crate::data::shard`]); the operator copies
//!    each rank its blocks.
//! 2. **bind** — `dsanls launch` binds the rendezvous listener
//!    ([`crate::transport::Rendezvous`]) and either spawns local workers
//!    or (with `--hosts`) waits for externally started ones.
//! 3. **bootstrap** — each worker handshakes (magic/version/rank), sends
//!    its advertised mesh address, receives the address book, and forms
//!    the full TCP peer mesh ([`crate::transport::tcp`]).
//! 4. **load** — each worker builds its rank-local [`crate::data::NodeData`]
//!    (shard files, or windowed shard-local synthesis) — the full matrix
//!    is never materialised on a worker — and, when no manifest supplied
//!    it, resolves the exact global norm with the ordered chain reduction.
//! 5. **run** — the rank executes its algorithm over
//!    [`crate::transport::TcpComm`]; rank-ordered reductions keep factors
//!    bit-identical to the in-process simulator.
//! 6. **collect** — result chunks stream back over the rendezvous
//!    connections; the coordinator assembles the same [`Outcome`] the
//!    simulated path produces (now including per-rank [`LoadStats`]),
//!    and `--verify-sim` asserts factor bit-identity.

#![warn(missing_docs)]

pub mod launch;
pub mod route_cli;
pub mod serve_cli;
pub mod shard_cli;

use std::path::Path;

use crate::config::ExperimentConfig;
use crate::data::partition::{imbalanced_partition, uniform_partition, Partition};
use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::nmf::job::{DataSource, Job};

/// The uniform outcome of any experiment run (defined in
/// [`crate::nmf::job`]; re-exported here for the launcher layer).
pub use crate::nmf::job::Outcome;

/// Config→options mapping (defined in [`crate::nmf::job`]; re-exported for
/// the launcher layer and the benches).
pub use crate::nmf::job::{asyn_options, dist_anls_options, dsanls_options, syn_options};

/// Generate the dataset named in the config (scaled).
pub fn load_dataset(cfg: &ExperimentConfig) -> Matrix {
    Dataset::from_name(&cfg.dataset)
        .unwrap_or_else(|| panic!("unknown dataset {}", cfg.dataset))
        .generate_scaled(cfg.seed, cfg.scale)
}

/// Column partition for the secure protocols (uniform or skewed).
pub fn secure_partition(cfg: &ExperimentConfig, cols: usize) -> Partition {
    if cfg.skew > 0.0 {
        imbalanced_partition(cols, cfg.nodes, cfg.skew)
    } else {
        uniform_partition(cols, cfg.nodes)
    }
}

/// Parse `--config FILE` plus `--section.key=value` overrides (shared by
/// the `run`/`compare`/`secure` subcommands, the workers and `launch`).
pub fn parse_cli_config(args: &[String]) -> Result<ExperimentConfig, String> {
    let mut cfg = ExperimentConfig::default();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if a == "--config" {
            let path = args.get(i + 1).ok_or("--config needs a path")?;
            cfg = ExperimentConfig::from_file(Path::new(path))?;
            i += 2;
        } else if let Some(rest) = a.strip_prefix("--") {
            let (key, value) =
                rest.split_once('=').ok_or(format!("expected --key=value: {a}"))?;
            cfg.apply(key, value)?;
            i += 1;
        } else {
            return Err(format!("unexpected argument: {a}"));
        }
    }
    Ok(cfg)
}

/// Run the experiment described by `cfg` on matrix `m` (pass the
/// pre-generated matrix so sweeps reuse it). One builder invocation covers
/// every algorithm family — adding a method means a new
/// [`crate::nmf::job::Algo`] variant, not a new dispatch arm here.
pub fn run_on(cfg: &ExperimentConfig, m: &Matrix) -> Outcome {
    Job::builder()
        .from_config(cfg, m.cols())
        .data(DataSource::Full(m))
        .run()
        .unwrap_or_else(|e| panic!("experiment {} failed: {e}", cfg.name))
}

/// Convenience: load the dataset and run.
pub fn run_experiment(cfg: &ExperimentConfig) -> Outcome {
    let m = load_dataset(cfg);
    run_on(cfg, &m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(algorithm: &str) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.apply("experiment.algorithm", algorithm).unwrap();
        cfg.apply("experiment.dataset", "face").unwrap();
        cfg.apply("experiment.scale", "0.05").unwrap();
        cfg.apply("experiment.nodes", "2").unwrap();
        cfg.apply("experiment.rank", "4").unwrap();
        cfg.apply("experiment.iterations", "10").unwrap();
        cfg.apply("experiment.eval_every", "0").unwrap();
        cfg.t1 = 4;
        cfg.t2 = 2;
        cfg.rounds = 4;
        cfg.local_iters = 2;
        cfg
    }

    #[test]
    fn dispatches_every_algorithm() {
        for algo in ["dsanls", "hals", "mu", "syn-sd", "syn-ssd-uv", "asyn-sd", "asyn-ssd-v"] {
            let cfg = tiny_cfg(algo);
            let out = run_experiment(&cfg);
            assert!(!out.trace.is_empty(), "{algo}: empty trace");
            assert!(out.final_error().is_finite(), "{algo}: bad error");
            assert!(out.u.is_nonnegative(), "{algo}: negative factor");
        }
    }

    #[test]
    fn traced_error_matches_factors_for_sync() {
        // for the deterministic sync algorithms, the traced final error must
        // equal the error recomputed from the returned factors
        let cfg = tiny_cfg("dsanls");
        let m = load_dataset(&cfg);
        let out = run_on(&cfg, &m);
        let recomputed = out.check_error(&m);
        assert!(
            (out.final_error() - recomputed).abs() < 1e-4,
            "traced {} vs recomputed {}",
            out.final_error(),
            recomputed
        );
    }

    #[test]
    fn skewed_partition_used_when_configured() {
        let mut cfg = tiny_cfg("syn-sd");
        cfg.skew = 0.5;
        cfg.nodes = 4; // skew only shows with >2 nodes (node 0 takes 50 %)
        let m = load_dataset(&cfg);
        let p = secure_partition(&cfg, m.cols());
        assert!(p.len(0) > p.len(1) * 2, "{} vs {}", p.len(0), p.len(1));
    }
}
